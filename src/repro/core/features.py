"""Sampling-based feature extractor (paper §5, Algorithms 1 and 2).

Neighborhood features — `n-propagation sampling` (Alg. 1), batched:
for each anchor vertex v, gather its ≤n-hop neighborhood from the padded
adjacency (fixed fan-out ⇒ static shapes), rank by exact distance to x_v,
and draw one positive from the top-k_pos and one negative from the next
k_neg ("hard negatives"). Emitted as id-triples (v, v+, v−); the loss
quantizes them with the *current* differentiable quantizer so gradients
reach rotation + codebooks through all three legs.

Routing features (Alg. 2), batched: run real beam searches with the current
quantizer's ADC distances (`beam_search_trace` records the ranked global
candidate set b_i at every hop — exactly Definition 6), then label each b_i
with the candidate that is truly closest to the query in the ORIGINAL space.
The paper's text says "learn how to select the correct next-hop"; labeling
with the quantizer's own (possibly wrong) choice would make the loss
degenerate, so the supervision is the exact-distance argmin over b_i
(offline we have the full vectors — this is training-time only).

Sampling under churn (codebook refresh, DESIGN.md §12): both samplers take
an optional ``tombstones`` uint32 bitset (the streaming index's deleted-id
words, TRACED — flipping bits between generations never recompiles, and
output shapes depend only on the batch sizes). Dead vertices never appear
in any emitted feature: triplet candidates and traced routing beams mask
them to the sentinel, a dead anchor invalidates its triplet, and the
routing label is the exact-distance argmin over the LIVE candidates only.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.graphs.adjacency import Graph
from repro.search import beam
from repro.search.beam import _bit_get


def _dead_fn(tombstones: Optional[jax.Array], n: int):
    """ids → bool "tombstoned" mask (False everywhere when no bitset).
    Out-of-range ids (the sentinel n, -1 padding) are never "dead" — they
    are already invalid and masked by the samplers' own sentinel logic."""
    if tombstones is None:
        return lambda ids: jnp.zeros(jnp.shape(ids), bool)
    ts = jnp.asarray(tombstones, jnp.uint32)

    def dead(ids):
        ok = (ids >= 0) & (ids < n)
        return _bit_get(ts, jnp.where(ok, ids, 0)).astype(bool) & ok

    return dead


class TripletBatch(NamedTuple):
    v: jax.Array       # (B,) anchor ids
    vpos: jax.Array    # (B,) positive ids
    vneg: jax.Array    # (B,) negative ids
    valid: jax.Array   # (B,) bool — neighborhood was large enough


class RoutingBatch(NamedTuple):
    q: jax.Array        # (B, D) query vectors
    cand: jax.Array     # (B, h) ranked candidate ids (sentinel-padded)
    label: jax.Array    # (B,) index of the true best candidate within cand
    valid: jax.Array    # (B,) bool — hop happened and ≥2 candidates


# --------------------------------------------------------------------------
# Alg. 1 — n-propagation sampling
# --------------------------------------------------------------------------

def _gather_hops(neighbors: jax.Array, v: jax.Array, n_hops: int) -> jax.Array:
    """(≤ R + R²+ ...,) candidate ids for one vertex (duplicates included)."""
    n = neighbors.shape[0]
    cand = [neighbors[v]]
    frontier = neighbors[v]
    for _ in range(n_hops - 1):
        nxt = neighbors[jnp.where(frontier < n, frontier, 0)].reshape(-1)
        nxt = jnp.where(frontier.repeat(neighbors.shape[1]) < n, nxt, n)
        cand.append(nxt)
        frontier = nxt
    return jnp.concatenate(cand)


def sample_triplets(key: jax.Array, graph: Graph, x: jax.Array,
                    anchors: jax.Array, *, n_hops: int = 2, k_pos: int = 10,
                    k_neg: int = 30,
                    tombstones: Optional[jax.Array] = None) -> TripletBatch:
    """Batched Alg. 1. anchors: (B,) vertex ids.

    ``tombstones`` (optional uint32 bitset over [0, n)): dead vertices are
    masked out of every neighborhood BEFORE ranking — they can never be
    drawn as positives or negatives — and a dead anchor yields
    ``valid=False`` (callers should sample anchors from the live set; this
    is the backstop)."""
    n = graph.n
    xp = jnp.concatenate([x, jnp.zeros((1, x.shape[1]), x.dtype)])
    dead = _dead_fn(tombstones, n)

    def one(key, v):
        cand = _gather_hops(graph.neighbors, v, n_hops)          # (C,)
        cand = jnp.where(cand == v, n, cand)
        cand = jnp.where(dead(cand), n, cand)
        # dedup: keep first occurrence (sort by id, mask repeats)
        order = jnp.argsort(cand)
        sc = cand[order]
        dup = jnp.concatenate([jnp.array([False]), sc[1:] == sc[:-1]])
        cand = jnp.where(dup, n, sc)
        d = jnp.sum((xp[cand] - xp[v]) ** 2, axis=-1)
        d = jnp.where(cand == n, jnp.inf, d)
        rank = jnp.argsort(d)
        ranked = cand[rank]                                      # distinct ids
        n_valid = jnp.sum(d < jnp.inf)
        kp, kn = jax.random.split(key)
        pos_hi = jnp.minimum(k_pos, n_valid)
        pos_idx = jax.random.randint(kp, (), 0, jnp.maximum(pos_hi, 1))
        neg_lo = pos_hi
        neg_hi = jnp.minimum(k_pos + k_neg, n_valid)
        neg_idx = neg_lo + jax.random.randint(
            kn, (), 0, jnp.maximum(neg_hi - neg_lo, 1))
        valid = (n_valid >= 2) & (neg_hi > neg_lo) & ~dead(v)
        return ranked[pos_idx], ranked[jnp.minimum(neg_idx, ranked.shape[0] - 1)], valid

    keys = jax.random.split(key, anchors.shape[0])
    vpos, vneg, valid = jax.vmap(one)(keys, anchors)
    return TripletBatch(v=anchors, vpos=vpos, vneg=vneg, valid=valid)


# --------------------------------------------------------------------------
# Alg. 2 — routing features sampling
# --------------------------------------------------------------------------

def sample_routing(graph: Graph, x: jax.Array, queries: jax.Array,
                   codes: jax.Array, lut_fn, *, h: int = 16,
                   trace_len: int = 48, max_steps: int = 128,
                   tombstones: Optional[jax.Array] = None,
                   entry: Optional[jax.Array] = None) -> RoutingBatch:
    """Batched Alg. 2 with exact-distance next-hop labels.

    codes: (N, M) CURRENT compact codes of the base vectors (quantizer-
    dependent — re-extract when the quantizer moves, paper Fig. 2 loop).

    ``tombstones`` makes the routing walks churn-aware: the beam itself
    routes around dead vertices (never THROUGH them), and because mid-walk
    traced beams may still hold a dead entry at its large-finite rescue
    distance (or an unfilled beam's +inf dead slots), every traced
    candidate is re-scrubbed here — no dead id survives into ``cand``, so
    the exact-distance label is always a live vertex. ``entry`` overrides
    the medoid start (e.g. the streaming engine's re-anchored live entry).
    """
    n = graph.n
    codes_p = jnp.concatenate([codes, jnp.zeros((1, codes.shape[1]), codes.dtype)])
    dist_fn = beam.make_adc_dist_fn(codes_p)
    luts = lut_fn(queries)
    tr = beam.beam_search_trace(graph.neighbors,
                                graph.medoid if entry is None else entry,
                                luts, dist_fn, h=h, max_steps=max_steps,
                                trace_len=trace_len, tombstones=tombstones)
    nq = queries.shape[0]
    xp = jnp.concatenate([x, jnp.zeros((1, x.shape[1]), x.dtype)])

    cand = tr.beam_ids.reshape(nq * trace_len, h)                 # (B, h)
    cand = jnp.where(_dead_fn(tombstones, n)(cand), n, cand)
    hop_valid = tr.hop_valid.reshape(nq * trace_len)
    qrep = jnp.repeat(queries, trace_len, axis=0)                 # (B, D)

    cv = xp[jnp.where(cand == n, 0, cand)]                        # (B, h, D)
    dexact = jnp.sum((cv - qrep[:, None, :]) ** 2, axis=-1)
    dexact = jnp.where(cand == n, jnp.inf, dexact)
    label = jnp.argmin(dexact, axis=1)
    n_cand = jnp.sum(cand != n, axis=1)
    valid = hop_valid & (n_cand >= 2)
    return RoutingBatch(q=qrep, cand=cand, label=label, valid=valid)


def subsample_routing(key: jax.Array, batch: RoutingBatch, size: int) -> RoutingBatch:
    """Uniformly pick `size` (preferring valid) examples from a RoutingBatch."""
    b = batch.valid.shape[0]
    # order: valid examples first (stable), then sample a prefix window
    pri = jnp.argsort(~batch.valid)        # valid (False<True on ~) first
    nvalid = jnp.sum(batch.valid)
    idx = jax.random.randint(key, (size,), 0, jnp.maximum(nvalid, 1))
    take = pri[idx]
    return RoutingBatch(q=batch.q[take], cand=batch.cand[take],
                        label=batch.label[take],
                        valid=batch.valid[take] & (nvalid > 0))
