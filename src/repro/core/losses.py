"""Feature-aware losses + multi-feature joint loss (paper §6, Eq. 8–11).

All three legs of a triplet and all candidates of a routing example pass
through the differentiable quantizer (Gumbel straight-through), so the
gradient reaches the rotation generator θ and the codebooks.

Joint loss: the paper's Eq. 11 has a "learnable coefficient α". A naively
learned multiplicative α on a non-negative loss collapses to 0; we use the
principled homoscedastic-uncertainty weighting (Kendall et al., CVPR'18):
``L = L_routing + exp(−s)·L_neighborhood + s`` with s = params.log_alpha —
the stationary point sets exp(−s) = 1/L_neighborhood, i.e. α self-tunes to
the scale of the neighborhood term. A fixed α is available via config.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import quantizer as Q
from repro.core.features import RoutingBatch, TripletBatch


class LossReport(NamedTuple):
    total: jax.Array
    routing: jax.Array
    neighborhood: jax.Array
    alpha: jax.Array


def neighborhood_loss(cfg: Q.RPQConfig, params: Q.RPQParams, x: jax.Array,
                      batch: TripletBatch, key: jax.Array,
                      margin: float = 1.0,
                      anchor_quantized: bool = True) -> jax.Array:
    """Eq. 8: max(0, σ + δ(x'_v, x'_{v+}) − δ(x'_v, x'_{v−})) ."""
    ka, kp, kn = jax.random.split(key, 3)
    xa = x[batch.v]
    xq_p = Q.quantize_st(cfg, params, x[batch.vpos], kp)
    xq_n = Q.quantize_st(cfg, params, x[batch.vneg], kn)
    if anchor_quantized:
        xq_a = Q.quantize_st(cfg, params, xa, ka)
    else:  # asymmetric variant: anchor stays full-precision (rotated)
        r = Q.rotation_matrix(cfg, params)
        xq_a = xa @ r.T
    dp = jnp.sum((xq_a - xq_p) ** 2, axis=-1)
    dn = jnp.sum((xq_a - xq_n) ** 2, axis=-1)
    # scale-free margin: normalize by the batch's positive-distance scale so
    # σ means "fractions of a typical neighbor distance", not raw units
    scale = jax.lax.stop_gradient(jnp.mean(dp) + 1e-9)
    per = jnp.maximum(0.0, margin + (dp - dn) / scale)
    w = batch.valid.astype(jnp.float32)
    return jnp.sum(per * w) / jnp.maximum(jnp.sum(w), 1.0)


def routing_loss(cfg: Q.RPQConfig, params: Q.RPQParams, x: jax.Array,
                 batch: RoutingBatch, key: jax.Array) -> jax.Array:
    """Eq. 9–10 (sign-fixed): −log softmax_{c ∈ b_i}(−δ(x'_c, x_q)/τ)[v*]."""
    n = x.shape[0]
    xp = jnp.concatenate([x, jnp.zeros((1, x.shape[1]), x.dtype)])
    b, h = batch.cand.shape
    cv = xp[jnp.where(batch.cand == n, 0, batch.cand)]     # (B, h, D)
    xq = Q.quantize_st(cfg, params, cv.reshape(b * h, -1), key).reshape(b, h, -1)
    r = Q.rotation_matrix(cfg, params)
    qrot = batch.q @ r.T                                   # ADC: query exact
    d = jnp.sum((xq - qrot[:, None, :]) ** 2, axis=-1)     # (B, h)
    # per-example scale (stop-grad) keeps the listwise softmax in a sane
    # entropy regime for any data magnitude (cf. quantizer._temp_scale)
    dmin = jnp.min(jnp.where(batch.cand == n, jnp.inf, d), axis=1, keepdims=True)
    spread = jnp.mean(jnp.where(batch.cand == n, 0.0, d - dmin), axis=1,
                      keepdims=True) + 1e-9
    scale = jax.lax.stop_gradient(spread)
    logits = jnp.where(batch.cand == n, -jnp.inf, -d / (scale * cfg.routing_tau))
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, batch.label[:, None], axis=1)[:, 0]
    w = batch.valid.astype(jnp.float32)
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)


def joint_loss(cfg: Q.RPQConfig, params: Q.RPQParams, x: jax.Array,
               trip: TripletBatch, route: RoutingBatch, key: jax.Array,
               *, margin: float = 1.0, fixed_alpha: Optional[float] = None
               ) -> tuple[jax.Array, LossReport]:
    """Eq. 11: L = L_routing + α·L_neighborhood (α learned, see module doc)."""
    kt, kr = jax.random.split(key)
    ln = neighborhood_loss(cfg, params, x, trip, kt, margin=margin)
    lr = routing_loss(cfg, params, x, route, kr)
    if fixed_alpha is not None:
        alpha = jnp.asarray(fixed_alpha, jnp.float32)
        total = lr + alpha * ln
    else:
        s = params.log_alpha
        alpha = jnp.exp(-s)
        total = lr + alpha * ln + s
    return total, LossReport(total=total, routing=lr, neighborhood=ln,
                             alpha=alpha)
