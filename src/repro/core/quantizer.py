"""Differentiable quantizer (paper §4): rotation + Gumbel-Softmax PQ.

State is a plain pytree (:class:`RPQParams`) so the trainer, checkpointing
and sharding layers treat it like any other model.

Conventions
-----------
* All quantization happens in the *rotated* space. Squared Euclidean distance
  is rotation-invariant (R orthonormal), so ADC distances computed there equal
  distances in the original space; queries are rotated once at LUT-build time.
* ``soft_assign`` implements Eq. 6 with the sign fixed (see DESIGN.md):
  ``p(c_k | x_j) = softmax_k(-||x_j - c_k||^2 / T)``.
* ``gumbel_codes`` implements Eq. 7; with ``straight_through=True`` the
  forward value is the exact one-hot argmax (so the decode path equals true
  PQ decode) while the gradient flows through the soft sample.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import rotation as rot
from repro.kernels import ops as kops


class RPQParams(NamedTuple):
    theta: jax.Array      # (D*(D-1)/2,) skew-symmetric generator (upper tri)
    codebooks: jax.Array  # (M, K, D/M) codewords per subspace
    log_alpha: jax.Array  # () learnable loss-mixing coefficient (paper Eq. 11)


class RPQConfig(NamedTuple):
    dim: int
    m: int = 8            # number of subspaces
    k: int = 256          # codewords per subspace (byte codes)
    assign_temp: float = 1.0   # T in softmax(-d/T) (Eq. 6)
    gumbel_tau: float = 1.0    # Gumbel-Softmax temperature (Eq. 7)
    routing_tau: float = 1.0   # τ in the routing loss (Eq. 9)
    adaptive_temp: bool = True  # normalize d by its batch scale before the
                                # softmax so T is data-scale free (without
                                # this, squared distances of O(100) saturate
                                # the softmax and gradients vanish)
    straight_through: bool = True
    learn_rotation: bool = True

    @property
    def dsub(self) -> int:
        return self.dim // self.m


def init_params(cfg: RPQConfig, codebooks: jax.Array) -> RPQParams:
    """Start from R=I and externally-supplied codebooks (k-means init)."""
    assert codebooks.shape == (cfg.m, cfg.k, cfg.dsub), codebooks.shape
    return RPQParams(
        theta=rot.init_rotation_params(cfg.dim),
        codebooks=jnp.asarray(codebooks, jnp.float32),
        log_alpha=jnp.zeros((), jnp.float32),
    )


# --------------------------------------------------------------------------
# Forward paths
# --------------------------------------------------------------------------

def rotation_matrix(cfg: RPQConfig, params: RPQParams) -> jax.Array:
    if not cfg.learn_rotation:
        return jnp.eye(cfg.dim, dtype=jnp.float32)
    return rot.rotation_from_params(params.theta, cfg.dim)


def rotate_split(cfg: RPQConfig, params: RPQParams, x: jax.Array) -> jax.Array:
    """(N, D) → (N, M, dsub) rotated sub-vectors."""
    r = rotation_matrix(cfg, params)
    return rot.split_subvectors(rot.rotate(x, r), cfg.m)


def subspace_distances(cfg: RPQConfig, params: RPQParams, x: jax.Array,
                       *, backend: str = "auto") -> jax.Array:
    """(N, D) → (N, M, K) table of ||rot(x)_j − c_k^j||² (the hot loop)."""
    xs = rotate_split(cfg, params, x)
    return kops.pq_pairwise(xs, params.codebooks, backend=backend)


def _temp_scale(cfg: RPQConfig, d: jax.Array) -> jax.Array:
    """Data-scale normalizer for the assignment softmax.

    Uses the batch-mean *nearest* distance (stop-gradient) so the closest
    codeword sits at d̃ ≈ 1 regardless of the dataset's magnitude.
    """
    if not cfg.adaptive_temp:
        return jnp.asarray(1.0, d.dtype)
    return jax.lax.stop_gradient(jnp.mean(jnp.min(d, axis=-1)) + 1e-12)


def soft_assign(cfg: RPQConfig, params: RPQParams, x: jax.Array) -> jax.Array:
    """Eq. 6 (sign-fixed): codeword assignment probabilities (N, M, K)."""
    d = subspace_distances(cfg, params, x)
    return jax.nn.softmax(-d / (_temp_scale(cfg, d) * cfg.assign_temp), axis=-1)


def gumbel_codes(cfg: RPQConfig, params: RPQParams, x: jax.Array,
                 key: jax.Array) -> jax.Array:
    """Eq. 7: approximate compact code as a (N, M, K) relaxed one-hot.

    softmax((log p + gumbel_noise) / tau); straight-through optionally
    snaps the forward value to the exact one-hot.
    """
    d = subspace_distances(cfg, params, x)
    logp = jax.nn.log_softmax(-d / (_temp_scale(cfg, d) * cfg.assign_temp),
                              axis=-1)
    g = jax.random.gumbel(key, logp.shape, logp.dtype)
    y = jax.nn.softmax((logp + g) / cfg.gumbel_tau, axis=-1)
    if cfg.straight_through:
        hard = jax.nn.one_hot(jnp.argmax(y, axis=-1), cfg.k, dtype=y.dtype)
        y = hard + (y - jax.lax.stop_gradient(y))
    return y


def decode_soft(cfg: RPQConfig, params: RPQParams, probs: jax.Array) -> jax.Array:
    """(N, M, K) assignment (soft or one-hot) → (N, D) quantized vectors
    in the ROTATED space (probs ⊗ codebooks, merged)."""
    sub = jnp.einsum("nmk,mkd->nmd", probs, params.codebooks)
    return rot.merge_subvectors(sub)


def quantize_st(cfg: RPQConfig, params: RPQParams, x: jax.Array,
                key: jax.Array) -> jax.Array:
    """x → x' : end-to-end differentiable quantized vectors (rotated space)."""
    return decode_soft(cfg, params, gumbel_codes(cfg, params, x, key))


# --------------------------------------------------------------------------
# Inference paths (hard codes, LUTs) — what the serving engine uses
# --------------------------------------------------------------------------

def encode(cfg: RPQConfig, params: RPQParams, x: jax.Array,
           *, backend: str = "auto") -> jax.Array:
    """(N, D) → (N, M) hard compact codes (uint8 if K ≤ 256)."""
    d = subspace_distances(cfg, params, x, backend=backend)
    codes = jnp.argmin(d, axis=-1)
    return codes.astype(jnp.uint8 if cfg.k <= 256 else jnp.int32)


def decode(cfg: RPQConfig, params: RPQParams, codes: jax.Array) -> jax.Array:
    """(N, M) codes → (N, D) quantized vectors in the rotated space."""
    sub = jnp.take_along_axis(
        params.codebooks[None], codes[:, :, None, None].astype(jnp.int32), axis=2
    )[:, :, 0, :]
    return rot.merge_subvectors(sub)


def build_lut(cfg: RPQConfig, params: RPQParams, queries: jax.Array) -> jax.Array:
    """(Q, D) queries → (Q, M, K) ADC lookup tables (rotated space)."""
    qs = rotate_split(cfg, params, jnp.atleast_2d(queries))
    return kops.pq_pairwise(qs, params.codebooks, backend="ref")


def adc_distances(cfg: RPQConfig, params: RPQParams, codes: jax.Array,
                  queries: jax.Array, *, backend: str = "auto") -> jax.Array:
    """(Q, D) × (N, M) → (Q, N) ADC distance estimates."""
    luts = build_lut(cfg, params, queries)
    return kops.adc_scan_batch(codes, luts, backend=backend)


def reconstruction_mse(cfg: RPQConfig, params: RPQParams, x: jax.Array) -> jax.Array:
    """Mean ||rot(x) − decode(encode(x))||²; the classic PQ distortion."""
    codes = encode(cfg, params, x)
    xq = decode(cfg, params, codes)
    r = rotation_matrix(cfg, params)
    return jnp.mean(jnp.sum((rot.rotate(x, r) - xq) ** 2, axis=-1))
