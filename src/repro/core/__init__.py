"""The paper's primary contribution: end-to-end routing-guided learned PQ.

rotation.py   adaptive vector decomposition (skew-symmetric expm rotation)
quantizer.py  differentiable quantizer (soft assign + Gumbel-ST, Eq. 6-7)
features.py   n-propagation + routing-feature sampling (Alg. 1-2, Def. 4-6)
losses.py     neighborhood/routing/joint losses (Eq. 8-11)
trainer.py    multi-feature joint training (Adam + one-cycle, Fig. 2)
rpq.py        one-call API: train_rpq(...)
"""
from repro.core.quantizer import RPQConfig, RPQParams  # noqa: F401
from repro.core.rpq import RPQ, train_rpq  # noqa: F401
from repro.core.trainer import TrainConfig, fit, init_rpq, to_model  # noqa: F401
