"""Multi-feature joint training module (paper §6 + Fig. 2 pipeline).

The training loop alternates:
  (1) feature extraction with the CURRENT quantizer — triplets are cheap and
      re-sampled every step; routing features require fresh compact codes +
      beam searches, so they are re-extracted every `refresh_every` steps
      (the pipeline loop in the paper's Fig. 2);
  (2) jitted joint-loss Adam steps (one-cycle LR, lr=1e-3 — paper §6).

Distribution: `data_parallel=True` wraps the step in shard_map over the
`data` axis — triplet/routing examples are sharded, gradients all-reduced
(optionally int8-compressed, dist/compression.py). The quantizer itself is
tiny (≤ a few MB) and stays replicated, exactly like the serving layout.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import PartitionSpec as P

from repro._compat import shard_map
from repro.common import adam, one_cycle, clip_by_global_norm
from repro.core import features as F
from repro.core import losses as L
from repro.core import quantizer as Q
from repro.dist import compression as comp
from repro.dist import sharding as shd
from repro.graphs.adjacency import Graph
from repro.pq import base as pqbase
from repro.pq.pq import train_pq


@dataclasses.dataclass
class TrainConfig:
    steps: int = 1000
    lr: float = 1e-3                # paper §6
    triplet_batch: int = 512
    routing_batch: int = 512
    routing_pool_queries: int = 256  # queries per routing-feature refresh
    refresh_every: int = 100
    beam_h: int = 16                # h candidates per decision (Def. 6)
    n_hops: int = 2                 # Alg. 1 propagation depth
    k_pos: int = 10
    k_neg: int = 30
    margin: float = 1.0
    fixed_alpha: Optional[float] = None
    grad_clip: float = 1.0
    use_routing: bool = True        # ablations: RPQ w/ N only
    use_neighborhood: bool = True   # ablations: RPQ w/ R only
    log_every: int = 50
    # distribution (dist/sharding + optional dist/compression):
    data_parallel: bool = False     # shard_map the step over the data axis
    compress_grads: bool = False    # int8 + error feedback before all-reduce


@dataclasses.dataclass
class TrainState:
    params: Q.RPQParams
    opt_state: object
    step: int
    history: list


def init_rpq(key: jax.Array, cfg: Q.RPQConfig, x: jax.Array,
             kmeans_iters: int = 15) -> Q.RPQParams:
    """K-means-initialized RPQ (R = I start ⇒ classic PQ as the origin)."""
    model = train_pq(key, x, cfg.m, cfg.k, iters=kmeans_iters)
    return Q.init_params(cfg, model.codebooks)


def _make_loss_fn(cfg: Q.RPQConfig, tcfg: TrainConfig):
    def loss_fn(params, x, trip, route, key):
        kt, kr = jax.random.split(key)
        zero = jnp.zeros((), jnp.float32)
        ln = (L.neighborhood_loss(cfg, params, x, trip, kt, margin=tcfg.margin)
              if tcfg.use_neighborhood else zero)
        lr_ = (L.routing_loss(cfg, params, x, route, kr)
               if tcfg.use_routing else zero)
        if tcfg.fixed_alpha is not None or not (tcfg.use_routing and tcfg.use_neighborhood):
            alpha = jnp.asarray(
                1.0 if tcfg.fixed_alpha is None else tcfg.fixed_alpha, jnp.float32)
            total = lr_ + alpha * ln
        else:
            s = params.log_alpha
            alpha = jnp.exp(-s)
            total = lr_ + alpha * ln + s
        return total, L.LossReport(total, lr_, ln, alpha)

    return loss_fn


def make_train_step(cfg: Q.RPQConfig, tcfg: TrainConfig, optimizer):
    """Returns the jitted (params, opt_state, x, trip, route, key) step."""
    loss_fn = _make_loss_fn(cfg, tcfg)

    @jax.jit
    def step(params, opt_state, x, trip, route, key):
        (_, report), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, x, trip, route, key)
        if not cfg.learn_rotation:
            grads = grads._replace(theta=jnp.zeros_like(grads.theta))
        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, report, gnorm

    return step


def _dp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names) \
        or tuple(mesh.axis_names)


def default_dp_mesh():
    """1-D data mesh over every local device (the serving row layout's
    training twin); built inline so pure-library users never touch launch/."""
    return jax.make_mesh((len(jax.devices()),), ("data",))


def init_dp_comp_state(params, n_dp: int):
    """Per-device error-feedback residuals: leading (n_dp,) axis, sharded
    over the data axis by the dp step (each replica keeps its OWN residual —
    error feedback is local by construction)."""
    return jax.tree.map(
        lambda p: jnp.zeros((n_dp,) + jnp.shape(p), jnp.float32), params)


def make_dp_train_step(cfg: Q.RPQConfig, tcfg: TrainConfig, optimizer, mesh,
                       compress: bool = False):
    """Data-parallel step (the docstring's `data_parallel=True` path).

    shard_map over the data axes: triplet/routing batches are row-sharded,
    the base set x and the (tiny) quantizer params stay replicated, local
    gradients are optionally int8-compressed with error feedback
    (dist/compression) and then mean-all-reduced — after which the update
    is replica-identical, exactly like the serving layout.

    Signature: (params, opt_state, comp_state, x, trip, route, key) →
    (params, opt_state, comp_state, report, gnorm). ``comp_state`` is the
    (n_dp, ...) error-feedback pytree from :func:`init_dp_comp_state`
    (pass ``{}`` when ``compress=False``).
    """
    loss_fn = _make_loss_fn(cfg, tcfg)
    dp = _dp_axes(mesh)

    def local_step(params, opt_state, comp_state, x, trip, route, key):
        # decorrelate per-shard Gumbel noise; one global key per step
        key = jax.random.fold_in(key, shd.flat_shard_index(mesh, dp))
        (_, report), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, x, trip, route, key)
        if not cfg.learn_rotation:
            grads = grads._replace(theta=jnp.zeros_like(grads.theta))
        if compress:
            local_state = jax.tree.map(lambda e: e[0], comp_state)
            (q, s), local_state = comp.compress_tree(grads, local_state)
            grads = comp.decompress_tree((q, s))   # ≙ wire format int8+scale
            comp_state = jax.tree.map(lambda e: e[None], local_state)
        grads = jax.lax.pmean(grads, dp)
        report = jax.tree.map(lambda v: jax.lax.pmean(v, dp), report)
        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, comp_state, report, gnorm

    pb = P(dp)
    step = shard_map(local_step, mesh=mesh,
                     in_specs=(P(), P(), pb, P(), pb, pb, P()),
                     out_specs=(P(), P(), pb, P(), P()))
    return jax.jit(step)


def fit(key: jax.Array, cfg: Q.RPQConfig, tcfg: TrainConfig, x: jax.Array,
        graph: Graph, *, params: Optional[Q.RPQParams] = None,
        checkpoint_cb: Optional[Callable] = None,
        start_step: int = 0, opt_state=None, mesh=None,
        tombstones=None, verbose: bool = True) -> TrainState:
    """End-to-end RPQ training (paper Fig. 2). Returns the final TrainState.

    checkpoint_cb(step, params, opt_state) — wired to dist/checkpoint.py by
    launch/train.py; pure library users can ignore it. With
    ``tcfg.data_parallel`` the jitted step runs under shard_map on ``mesh``
    (default: every local device) — see :func:`make_dp_train_step`.

    ``tombstones`` (optional uint32 deleted-id bitset words over [0, n),
    the streaming index's Tombstones layout) makes the whole feature loop
    churn-aware — this is the codebook-refresh path (DESIGN.md §12):
    triplet anchors and routing queries are drawn from LIVE vertices only,
    and the bitset threads into both samplers so no dead id reaches any
    loss term. Warm-start via ``params=`` to refine the serving quantizer
    instead of training from the k-means origin.
    """
    n = x.shape[0]
    key, kinit = jax.random.split(key)
    live_ids, ts_dev = None, None
    if tombstones is not None:
        words = np.asarray(tombstones, np.uint32)
        ids = np.arange(n, dtype=np.int64)
        dead = ((words[ids >> 5] >> (ids & 31).astype(np.uint32)) & 1
                ).astype(bool)
        live_np = np.flatnonzero(~dead)
        if live_np.size == 0:
            raise ValueError("fit: every vertex is tombstoned — nothing "
                             "live to sample features from")
        live_ids = jnp.asarray(live_np, jnp.int32)
        ts_dev = jnp.asarray(words)
    if params is None:
        params = init_rpq(kinit, cfg, x)
    optimizer = adam(one_cycle(tcfg.lr, tcfg.steps))
    if opt_state is None:
        opt_state = optimizer.init(params)
    comp_state = {}
    n_dp = 1
    if tcfg.data_parallel:
        mesh = mesh if mesh is not None else default_dp_mesh()
        for a in _dp_axes(mesh):
            n_dp *= mesh.shape[a]
        if tcfg.triplet_batch % n_dp or tcfg.routing_batch % n_dp:
            raise ValueError(
                f"data_parallel: triplet_batch={tcfg.triplet_batch} and "
                f"routing_batch={tcfg.routing_batch} must divide the "
                f"{n_dp}-way data axis")
        step_fn = make_dp_train_step(cfg, tcfg, optimizer, mesh,
                                     compress=tcfg.compress_grads)
        if tcfg.compress_grads:
            comp_state = init_dp_comp_state(params, n_dp)
    else:
        step_fn = make_train_step(cfg, tcfg, optimizer)

    routing_pool: Optional[F.RoutingBatch] = None
    history = []
    t0 = time.time()
    for step in range(start_step, tcfg.steps):
        # fold_in (not sequential splits): a resumed run re-derives the SAME
        # per-step keys as the uninterrupted run (fault-tolerance semantics)
        k1, k2, k3, k4, k5 = jax.random.split(
            jax.random.fold_in(key, step), 5)
        # ---- feature extraction (paper Fig. 2 outer loop) ----
        if tcfg.use_routing and (routing_pool is None
                                 or step % tcfg.refresh_every == 0):
            model = to_model(cfg, params)
            codes = pqbase.encode(model, x)
            if live_ids is None:
                qidx = jax.random.choice(k1, n, (tcfg.routing_pool_queries,),
                                         replace=False)
            else:  # churn-aware: query AT live vertices only
                qidx = live_ids[jax.random.choice(
                    k1, live_ids.shape[0], (tcfg.routing_pool_queries,),
                    replace=live_ids.shape[0] < tcfg.routing_pool_queries)]
            routing_pool = F.sample_routing(
                graph, x, x[qidx], codes,
                lut_fn=lambda q: pqbase.build_lut(model, q), h=tcfg.beam_h,
                tombstones=ts_dev)
        if live_ids is None:
            anchors = jax.random.randint(k2, (tcfg.triplet_batch,), 0, n)
        else:
            anchors = live_ids[jax.random.randint(
                k2, (tcfg.triplet_batch,), 0, live_ids.shape[0])]
        trip = F.sample_triplets(k3, graph, x, anchors, n_hops=tcfg.n_hops,
                                 k_pos=tcfg.k_pos, k_neg=tcfg.k_neg,
                                 tombstones=ts_dev)
        if tcfg.use_routing:
            route = F.subsample_routing(k4, routing_pool, tcfg.routing_batch)
        else:  # placeholder batch (masked out by use_routing=False);
            #    one row PER REPLICA so it shards under data_parallel
            route = F.RoutingBatch(
                q=jnp.zeros((n_dp, x.shape[1]), jnp.float32),
                cand=jnp.zeros((n_dp, tcfg.beam_h), jnp.int32),
                label=jnp.zeros((n_dp,), jnp.int32),
                valid=jnp.zeros((n_dp,), bool))
        # ---- jitted joint step ----
        if tcfg.data_parallel:
            params, opt_state, comp_state, report, gnorm = step_fn(
                params, opt_state, comp_state, x, trip, route, k5)
        else:
            params, opt_state, report, gnorm = step_fn(
                params, opt_state, x, trip, route, k5)
        if step % tcfg.log_every == 0:
            rec = {k: float(v) for k, v in report._asdict().items()}
            rec.update(step=step, gnorm=float(gnorm), wall=time.time() - t0)
            history.append(rec)
            if verbose:
                print(f"[rpq] step {step:5d} total {rec['total']:.4f} "
                      f"routing {rec['routing']:.4f} "
                      f"nbr {rec['neighborhood']:.4f} α {rec['alpha']:.3f}")
        if checkpoint_cb is not None:
            checkpoint_cb(step, params, opt_state)
    return TrainState(params=params, opt_state=opt_state, step=tcfg.steps,
                      history=history)


def to_model(cfg: Q.RPQConfig, params: Q.RPQParams) -> pqbase.QuantizerModel:
    """Export the learned quantizer for the serving engines."""
    r = Q.rotation_matrix(cfg, params)
    return pqbase.QuantizerModel(r=r, codebooks=params.codebooks)
