"""Adaptive vector decomposition (paper §4, step 1).

A learnable skew-symmetric matrix ``A`` parameterizes a square orthonormal
rotation ``R = expm(A)`` (orthogonality: expm(A)^T = expm(A^T) = expm(-A) =
expm(A)^{-1}).  Rotating ``x → R x`` before the vertical split turns PQ's
fixed chunking into a *learned* decomposition: back-prop through expm adjusts
which (linear combinations of) dimensions land in each sub-vector, balancing
informativeness across subspaces (the paper's Figure 4 case study).

We parameterize by the strictly-upper-triangular entries of ``A`` so the
skew-symmetry constraint can never be violated by an optimizer step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.linalg import expm


def init_rotation_params(dim: int, *, scale: float = 0.0,
                         key: jax.Array | None = None) -> jax.Array:
    """Strictly-upper-triangular parameters of the skew-symmetric generator.

    scale=0 initializes R = I (PQ-compatible start, recommended: training
    begins from the classic vertical split and departs only as the losses
    demand).
    """
    n = dim * (dim - 1) // 2
    if scale == 0.0 or key is None:
        return jnp.zeros((n,), jnp.float32)
    return scale * jax.random.normal(key, (n,), jnp.float32)


def skew_from_params(theta: jax.Array, dim: int) -> jax.Array:
    """Reconstruct the (dim, dim) skew-symmetric A from its upper triangle."""
    iu = jnp.triu_indices(dim, k=1)
    a = jnp.zeros((dim, dim), theta.dtype).at[iu].set(theta)
    return a - a.T


def rotation_from_params(theta: jax.Array, dim: int) -> jax.Array:
    """R = expm(A(theta)); differentiable, exactly orthonormal (up to fp)."""
    return expm(skew_from_params(theta, dim))


def rotate(x: jax.Array, r: jax.Array) -> jax.Array:
    """Apply the rotation: x (.., D) → x @ R^T  (i.e. R x for row vectors)."""
    return x @ r.T


def split_subvectors(x: jax.Array, m: int) -> jax.Array:
    """(..., D) → (..., M, D/M) vertical split of the (rotated) vector."""
    *lead, d = x.shape
    assert d % m == 0, f"D={d} not divisible by M={m}"
    return x.reshape(*lead, m, d // m)


def merge_subvectors(x: jax.Array) -> jax.Array:
    """(..., M, D/M) → (..., D)."""
    *lead, m, dsub = x.shape
    return x.reshape(*lead, m * dsub)
