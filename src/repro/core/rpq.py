"""Top-level RPQ API — one import for the whole paper pipeline.

    from repro.core.rpq import train_rpq
    rpq = train_rpq(key, x, graph)          # paper Fig. 2, end to end
    model = rpq.model                       # serving-side QuantizerModel
    codes = pq.encode(model, x)
    engine = InMemoryEngine(graph, codes, lut_fn=lambda q: pq.build_lut(model, q))
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax

from repro.core import quantizer as Q
from repro.core import trainer as T
from repro.graphs.adjacency import Graph
from repro.pq import base as pqbase


@dataclasses.dataclass
class RPQ:
    cfg: Q.RPQConfig
    params: Q.RPQParams
    history: list

    @property
    def model(self) -> pqbase.QuantizerModel:
        return T.to_model(self.cfg, self.params)

    def encode(self, x):
        return pqbase.encode(self.model, x)

    def lut_fn(self):
        model = self.model
        return lambda q: pqbase.build_lut(model, q)


def train_rpq(key: jax.Array, x: jax.Array, graph: Graph, *,
              m: int = 8, k: int = 256,
              cfg: Optional[Q.RPQConfig] = None,
              tcfg: Optional[T.TrainConfig] = None,
              verbose: bool = True) -> RPQ:
    if cfg is None:
        cfg = Q.RPQConfig(dim=x.shape[1], m=m, k=k)
    if tcfg is None:
        tcfg = T.TrainConfig()
    state = T.fit(key, cfg, tcfg, x, graph, verbose=verbose)
    return RPQ(cfg=cfg, params=state.params, history=state.history)
