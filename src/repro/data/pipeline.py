"""Sharding-aware, resumable input pipeline.

The training drivers checkpoint `iterator.state()` next to params, so a
restarted job resumes mid-epoch WITHOUT replaying or skipping batches
(bit-identical batch sequence — tested in tests/test_pipeline.py):

* determinism: batch t is a pure function of (seed, t) — permutations are
  derived per-epoch via fold_in, never from mutable RNG state;
* elasticity: `shard(host_id, n_hosts)` slices every batch by host, and
  because batches are (seed, t)-pure the SAME global batch sequence is
  reproduced under a different host count after resume;
* infinite stream over a finite array with per-epoch reshuffling (the
  paper's trainer samples anchors/queries — this pipeline feeds it ids).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class IndexStream:
    """Deterministic infinite stream of index batches over [0, n)."""
    n: int
    batch: int
    seed: int = 0
    step: int = 0              # resumable cursor
    host_id: int = 0
    n_hosts: int = 1

    @property
    def batches_per_epoch(self) -> int:
        return max(self.n // self.batch, 1)

    def _epoch_perm(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, epoch]))
        return rng.permutation(self.n)

    def peek(self, step: Optional[int] = None) -> np.ndarray:
        """Global batch at `step` (pure; does not advance the cursor)."""
        t = self.step if step is None else step
        epoch, within = divmod(t, self.batches_per_epoch)
        perm = self._epoch_perm(epoch)
        lo = within * self.batch
        return perm[lo: lo + self.batch]

    def shard(self, ids: np.ndarray) -> np.ndarray:
        """This host's slice of a global batch (contiguous block split)."""
        per = len(ids) // self.n_hosts
        return ids[self.host_id * per: (self.host_id + 1) * per]

    def __next__(self) -> np.ndarray:
        out = self.shard(self.peek())
        self.step += 1
        return out

    def __iter__(self):
        return self

    # ---- checkpoint integration ------------------------------------
    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed, "n": self.n,
                "batch": self.batch}

    @classmethod
    def from_state(cls, state: dict, *, host_id: int = 0, n_hosts: int = 1
                   ) -> "IndexStream":
        return cls(n=state["n"], batch=state["batch"], seed=state["seed"],
                   step=state["step"], host_id=host_id, n_hosts=n_hosts)
