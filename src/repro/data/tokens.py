"""Synthetic LM token streams (for the arch-zoo smoke/e2e paths).

Zipfian unigram draw with a deterministic per-document seed — enough
structure that a reduced LM's loss visibly falls below the uniform-entropy
ceiling within a few hundred steps, with zero external data."""

from __future__ import annotations

import numpy as np


def zipf_tokens(seed: int, batch: int, seq_len: int, vocab: int,
                alpha: float = 1.1) -> np.ndarray:
    """(batch, seq_len) int32 tokens, Zipf(alpha) over [0, vocab)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = ranks ** (-alpha)
    probs /= probs.sum()
    return rng.choice(vocab, size=(batch, seq_len), p=probs).astype(np.int32)


def lm_batch(seed: int, batch: int, seq_len: int, vocab: int):
    """(tokens, labels) = next-token pairs from one Zipf draw."""
    toks = zipf_tokens(seed, batch, seq_len + 1, vocab)
    return toks[:, :-1].copy(), toks[:, 1:].copy()
