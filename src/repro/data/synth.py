"""Synthetic vector datasets emulating the paper's benchmarks (§8.1 Table 3).

This container has no network access, so SIFT/GIST/Deep/BigANN/UKBench are
emulated with matched dimensionality and the structural properties that
matter for quantizers:

* cluster structure (Gaussian mixture — controls LID: more clusters &
  higher noise ⇒ higher local intrinsic dimensionality),
* anisotropy / correlated dimensions (a random orthonormal basis times a
  decaying spectrum — this is what OPQ/RPQ's rotation exploits; SIFT's
  gradient histograms and GIST's Gabor energies are strongly correlated).

`load_dataset` also accepts real `.fvecs` / `.npy` files when present, so
the same benchmarks run unchanged on the true datasets outside the sandbox.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Optional

import numpy as np
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    dim: int
    n_base: int
    n_query: int
    n_clusters: int
    noise: float          # within-cluster std (vs unit centers)
    spectrum_decay: float  # eigenvalue ratio last/first (1.0 = isotropic)
    seed: int = 0


# paper Table 3 stand-ins (dims faithful; sizes scaled to the sandbox)
SPECS = {
    "sift": DatasetSpec("sift", 128, 100_000, 1_000, 200, 0.35, 0.10),
    "gist": DatasetSpec("gist", 960, 20_000, 200, 100, 0.30, 0.02),
    "deep": DatasetSpec("deep", 96, 100_000, 1_000, 200, 0.35, 0.20),
    "bigann": DatasetSpec("bigann", 128, 100_000, 1_000, 200, 0.35, 0.10),
    "ukbench": DatasetSpec("ukbench", 128, 50_000, 200, 500, 0.25, 0.15),
    # small variants for tests / quick examples
    "sift-small": DatasetSpec("sift-small", 64, 10_000, 200, 64, 0.35, 0.15),
    "unit-test": DatasetSpec("unit-test", 32, 2_000, 100, 20, 0.35, 0.25),
}


@dataclasses.dataclass
class Dataset:
    name: str
    base: jnp.ndarray    # (N, D) f32
    queries: jnp.ndarray  # (Q, D) f32
    train: jnp.ndarray   # (T, D) f32 — quantizer training subset

    @property
    def dim(self) -> int:
        return self.base.shape[1]


def synth(spec: DatasetSpec, *, scale: Optional[float] = None) -> Dataset:
    """Generate a clustered anisotropic dataset (+ held-out queries)."""
    rng = np.random.default_rng(spec.seed)
    n, d = spec.n_base, spec.dim
    if scale:
        n = max(int(n * scale), 1000)
    centers = rng.normal(size=(spec.n_clusters, d)).astype(np.float32)
    # anisotropic basis: random rotation × decaying spectrum
    q_basis, _ = np.linalg.qr(rng.normal(size=(d, d)))
    eigs = np.geomspace(1.0, spec.spectrum_decay, d)
    basis = (q_basis * eigs[None, :]).astype(np.float32)

    def draw(count: int, seed: int) -> np.ndarray:
        r = np.random.default_rng(seed)
        asg = r.integers(0, spec.n_clusters, count)
        pts = centers[asg] + spec.noise * r.normal(size=(count, d)).astype(np.float32)
        return (pts @ basis).astype(np.float32)

    base = draw(n, spec.seed + 1)
    queries = draw(spec.n_query, spec.seed + 2)
    # paper: train on a 500K subset of the base — we use 50% (≤ 500k)
    t = min(n // 2, 500_000)
    train = base[rng.permutation(n)[:t]].copy()
    return Dataset(spec.name, jnp.asarray(base), jnp.asarray(queries),
                   jnp.asarray(train))


def _read_fvecs(path: str, max_rows: Optional[int] = None) -> np.ndarray:
    raw = np.fromfile(path, dtype=np.int32)
    d = raw[0]
    raw = raw.reshape(-1, d + 1)[:, 1:]
    if max_rows:
        raw = raw[:max_rows]
    return raw.view(np.float32).copy()


def load_dataset(name: str, *, data_dir: str = "data", scale: Optional[float] = None
                 ) -> Dataset:
    """Real files if present (``<data_dir>/<name>_base.fvecs|.npy``), else synth."""
    base_f = os.path.join(data_dir, f"{name}_base")
    query_f = os.path.join(data_dir, f"{name}_query")
    if os.path.exists(base_f + ".npy"):
        base = np.load(base_f + ".npy").astype(np.float32)
        queries = np.load(query_f + ".npy").astype(np.float32)
    elif os.path.exists(base_f + ".fvecs"):
        base = _read_fvecs(base_f + ".fvecs")
        queries = _read_fvecs(query_f + ".fvecs")
    else:
        if name not in SPECS:
            raise KeyError(f"unknown dataset {name!r}; options: {sorted(SPECS)}")
        return synth(SPECS[name], scale=scale)
    rng = np.random.default_rng(0)
    t = min(len(base) // 2, 500_000)
    train = base[rng.permutation(len(base))[:t]].copy()
    return Dataset(name, jnp.asarray(base), jnp.asarray(queries),
                   jnp.asarray(train))
