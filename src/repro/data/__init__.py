"""Datasets: synthetic stand-ins for the paper's benchmarks + pipeline."""
from repro.data.synth import Dataset, DatasetSpec, SPECS, load_dataset, synth  # noqa: F401
from repro.data.pipeline import IndexStream  # noqa: F401
from repro.data.tokens import lm_batch, zipf_tokens  # noqa: F401
