"""Public, jitted entry points for the PQ kernels with backend dispatch.

Call these from library code. On TPU they run the compiled Pallas kernels;
on CPU (this container) they run the pure-jnp oracle, which XLA fuses well
— the Pallas path is still exercised on CPU via interpret mode in the tests
and can be forced with ``backend="interpret"``.

Backends:

* ``"auto"``      — Pallas compiled on TPU, jnp oracle elsewhere (default).
* ``"pallas"``    — force the Pallas path; interpret mode is then decided
                    by :func:`default_interpret` (compiled only on TPU), so
                    forcing pallas on CPU runs the interpreter, not a crash.
* ``"interpret"`` — force the Pallas path in interpreter mode (tests).
* ``"ref"``       — force the pure-jnp oracle from :mod:`repro.kernels.ref`.

Dtype boundary: callers hand in codes in whatever integer dtype they store
(uint8 for K ≤ 256 indices, uint8 packed bytes for the fs4 layout, int32
ids) and THIS module casts once to the canonical kernel dtypes — int32
plain codes/ids, uint8 packed codes, f32 LUTs. Kernel modules and oracles
assume the canonical dtypes; no per-call casting in callers.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

# Submodules are imported EAGERLY (not inside the dispatch functions):
# kernels/__init__ re-exports same-named functions (adc_scan_fs, hop_adc,
# hop_gather), and a lazy first import of the submodule would setattr the
# MODULE over the package-level function binding, breaking the API
# mid-session. Importing them all here, before __init__ binds the
# functions, keeps the package attributes deterministic.
from repro.kernels import adc_scan as _adc
from repro.kernels import adc_scan_fs as _adcfs
from repro.kernels import hop_adc as _hop
from repro.kernels import hop_gather as _hopg
from repro.kernels import pq_pairwise as _pqp
from repro.kernels import ref as _ref

Backend = Literal["auto", "pallas", "interpret", "ref"]


# --------------------------------------------------------------------------
# Row-padding helpers — the ONE home for the sentinel/divisibility padding
# idiom (search/engine.py, graphs/vamana.py, repro/index/* all pad this way).
# --------------------------------------------------------------------------

def pad_sentinel_row(x: jax.Array) -> jax.Array:
    """(N, ...) → (N+1, ...): append one all-zero row at index N.

    Row N is the sentinel every padded adjacency points at (graphs/
    adjacency.py), so code/vector tables gathered by beam ids must carry a
    readable — never trusted — row there. Callers mask sentinel slots by id,
    not by the row's contents.
    """
    return jnp.concatenate(
        [x, jnp.zeros((1,) + x.shape[1:], x.dtype)], axis=0)


def pad_rows_to_multiple(x: jax.Array, mult: int) -> jax.Array:
    """(N, ...) → (N', ...) with N' the next multiple of ``mult`` (zero-row
    padded) — shard-divisibility padding for row-sharded device_puts."""
    pad = (-x.shape[0]) % mult
    if pad == 0:
        return x
    return jnp.concatenate(
        [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)


def _codes_i32(codes) -> jax.Array:
    """Canonicalize plain (unpacked) codes / id arrays: any int → int32."""
    return jnp.asarray(codes).astype(jnp.int32)


def _codes_u8(packed) -> jax.Array:
    """Canonicalize fs4 packed code bytes: any int → uint8."""
    return jnp.asarray(packed).astype(jnp.uint8)


def _dequant(acc, scale, bias, m: int) -> jax.Array:
    """Per-query affine undo for fs4 int32 accumulators: (Q, X) int32 +
    (Q,) scale/bias → (Q, X) f32. The SAME eager op sequence as the tail of
    the fs oracles, so pallas and ref paths agree bitwise (an in-kernel
    dequant could be FMA-fused under jit and drift an ulp)."""
    return (jnp.asarray(scale, jnp.float32)[:, None] * acc.astype(jnp.float32)
            + m * jnp.asarray(bias, jnp.float32)[:, None])


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    """The ONE backend-autodetect switch for Pallas interpret mode.

    Compiled Mosaic kernels exist only on TPU; everywhere else (CPU CI,
    laptops) the Pallas interpreter is the correct default. Kernel modules
    resolve ``interpret=None`` through this helper instead of hardcoding
    ``interpret=True`` (which would silently interpret on real TPUs too —
    the bug this replaces; see DESIGN.md §3).
    """
    return not _on_tpu()


def _resolve(backend: Backend) -> str:
    if backend == "auto":
        return "pallas" if _on_tpu() else "ref"
    return backend


def _interpret_flag(mode: str) -> bool:
    """interpret= for a resolved pallas/interpret mode."""
    return True if mode == "interpret" else default_interpret()


def adc_scan(codes, lut, *, backend: Backend = "auto", block_n: int = 1024):
    """One-query ADC scan: (N, M) codes × (M, K) LUT → (N,) f32."""
    mode = _resolve(backend)
    codes = _codes_i32(codes)
    if mode == "ref":
        return _ref.adc_scan_ref(codes, lut)
    return _adc.adc_scan(codes, lut, block_n=block_n,
                         interpret=_interpret_flag(mode))


def adc_scan_batch(codes, luts, *, backend: Backend = "auto",
                   block_n: int = 256, block_q: int = 128):
    """Batched ADC scan: (N, M) codes × (Q, M, K) LUTs → (Q, N) f32."""
    mode = _resolve(backend)
    codes = _codes_i32(codes)
    if mode == "ref":
        return _ref.adc_scan_batch_ref(codes, luts)
    return _adc.adc_scan_batch(codes, luts, block_n=block_n, block_q=block_q,
                               interpret=_interpret_flag(mode))


def adc_scan_fs(packed, luts_u8, scale, bias, *, backend: Backend = "auto",
                block_n: int = 512, block_q: int = 64):
    """Batched FAST-SCAN ADC: (N, ceil(M/2)) 4-bit-packed codes ×
    (Q, M, 16) uint8 LUTs + per-query (Q,) (scale, bias) → (Q, N) f32.

    The fs4 serving layout (DESIGN.md §8): half the code bytes, a quarter
    of the LUT bytes, exact int32 accumulation, one dequant per output.
    Pack codes with ``repro.pq.pack.pack_codes`` and quantize LUTs with
    ``repro.pq.pack.quantize_luts``.
    """
    mode = _resolve(backend)
    packed = _codes_u8(packed)
    luts_u8 = _codes_u8(luts_u8)
    if mode == "ref":
        return _ref.adc_scan_fs_ref(packed, luts_u8, scale, bias)
    acc = _adcfs.adc_scan_fs(packed, luts_u8, block_n=block_n,
                             block_q=block_q,
                             interpret=_interpret_flag(mode))
    return _dequant(acc, scale, bias, luts_u8.shape[1])


def hop_gather(codes, luts, *, backend: Backend = "auto", block_q: int = 8):
    """Per-hop beam ADC on PRE-GATHERED codes: (Q, R, M) × (Q, M, K) →
    (Q, R) f32. Prefer :func:`hop_adc` where the ids are still at hand —
    it fuses the gather too."""
    mode = _resolve(backend)
    codes = _codes_i32(codes)
    if mode == "ref":
        return _ref.hop_gather_ref(codes, luts)
    return _hopg.hop_gather(codes, luts, block_q=block_q,
                            interpret=_interpret_flag(mode))


def hop_adc(codes, ids, luts, *, backend: Backend = "auto",
            block_q: int | None = None, m_prefix: int = 0):
    """FUSED per-hop beam ADC: (N, M) codes, (Q, R′) ids, (Q, M, K) LUTs →
    (Q, R′) f32 — gathers the R′ neighbor code rows AND reduces them against
    each query's LUT in one kernel (no (Q, R′, M) HBM round-trip). R′ is the
    beam's frontier width — the graph degree R classically, E·R under
    multi-expansion (beam_search(expand=E), DESIGN.md §9); ``block_q=None``
    lets the kernel pick its query tile from R′. All ids must be valid rows
    in [0, N).

    ``0 < m_prefix < M`` reduces only the FIRST m_prefix subspaces — the
    partial-LUT lower bound of hop pruning (DESIGN.md §11; every LUT entry
    is a squared subdistance ≥ 0, so the prefix sum bounds the full sum
    from below). The Pallas path keeps the resident codes block full-width
    and statically shortens the reduce unroll; the oracle slices."""
    mode = _resolve(backend)
    codes = _codes_i32(codes)
    ids = _codes_i32(ids)
    mp = m_prefix if 0 < m_prefix < codes.shape[1] else 0
    if mode == "ref":
        if mp:
            return _ref.hop_adc_ref(codes[:, :mp], ids, luts[:, :mp])
        return _ref.hop_adc_ref(codes, ids, luts)
    return _hop.hop_adc(codes, ids, luts, block_q=block_q,
                        interpret=_interpret_flag(mode), m_prefix=mp)


def hop_adc_fs(packed, ids, luts_u8, scale, bias, *,
               backend: Backend = "auto", block_q: int | None = None,
               m_prefix: int = 0):
    """FUSED per-hop FAST-SCAN ADC: (N, ceil(M/2)) packed codes, (Q, R′)
    ids, (Q, M, 16) uint8 LUTs + (Q,) (scale, bias) → (Q, R′) f32 — the
    packed twin of :func:`hop_adc` (same gather fusion, half the resident
    code bytes, quarter LUT bytes, int32 accumulation, same frontier-width
    auto-tuning at ``block_q=None``).

    ``m_prefix`` as in :func:`hop_adc`; the dequant then uses
    ``m_prefix · bias`` (bias ≥ 0 — quantize_luts anchors it at the LUT
    minimum), so the partial score lower-bounds the full one in the
    quantized metric too. Odd m_prefix is exact on the oracle as well: the
    paired-LUT table zero-pads the dangling high nibble."""
    mode = _resolve(backend)
    packed = _codes_u8(packed)
    ids = _codes_i32(ids)
    luts_u8 = _codes_u8(luts_u8)
    m = luts_u8.shape[1]
    mp = m_prefix if 0 < m_prefix < m else 0
    if mode == "ref":
        if mp:
            return _ref.hop_adc_fs_ref(packed[:, :(mp + 1) // 2], ids,
                                       luts_u8[:, :mp], scale, bias)
        return _ref.hop_adc_fs_ref(packed, ids, luts_u8, scale, bias)
    acc = _hop.hop_adc_fs(packed, ids, luts_u8, m=m, block_q=block_q,
                          interpret=_interpret_flag(mode), m_prefix=mp)
    return _dequant(acc, scale, bias, mp or m)


def pq_pairwise(x, codebook, *, backend: Backend = "auto", block_n: int = 512):
    """Sub-vector/codeword distance table: (N,M,dsub) × (M,K,dsub) → (N,M,K)."""
    mode = _resolve(backend)
    if mode == "ref":
        return _ref.pq_pairwise_ref(x, codebook)
    return _pqp.pq_pairwise(x, codebook, block_n=block_n,
                            interpret=_interpret_flag(mode))


def kmeans_assign(x, centroids, *, backend: Backend = "auto"):
    """Nearest centroid: (N, D) × (K, D) → (assign (N,) i32, sqdist (N,) f32)."""
    d = pq_pairwise(x[:, None, :], centroids[None, :, :], backend=backend)[:, 0, :]
    idx = jnp.argmin(d, axis=1).astype(jnp.int32)
    best = jnp.take_along_axis(d, idx[:, None], axis=1)[:, 0]
    return idx, best
