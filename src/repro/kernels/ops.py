"""Public, jitted entry points for the PQ kernels with backend dispatch.

Call these from library code. On TPU they run the compiled Pallas kernels;
on CPU (this container) they run the pure-jnp oracle, which XLA fuses well
— the Pallas path is still exercised on CPU via interpret mode in the tests
and can be forced with ``backend="interpret"``.

Backends:

* ``"auto"``      — Pallas compiled on TPU, jnp oracle elsewhere (default).
* ``"pallas"``    — force the Pallas path; interpret mode is then decided
                    by :func:`default_interpret` (compiled only on TPU), so
                    forcing pallas on CPU runs the interpreter, not a crash.
* ``"interpret"`` — force the Pallas path in interpreter mode (tests).
* ``"ref"``       — force the pure-jnp oracle from :mod:`repro.kernels.ref`.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels import adc_scan as _adc
from repro.kernels import pq_pairwise as _pqp
from repro.kernels import ref as _ref

Backend = Literal["auto", "pallas", "interpret", "ref"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    """The ONE backend-autodetect switch for Pallas interpret mode.

    Compiled Mosaic kernels exist only on TPU; everywhere else (CPU CI,
    laptops) the Pallas interpreter is the correct default. Kernel modules
    resolve ``interpret=None`` through this helper instead of hardcoding
    ``interpret=True`` (which would silently interpret on real TPUs too —
    the bug this replaces; see DESIGN.md §3).
    """
    return not _on_tpu()


def _resolve(backend: Backend) -> str:
    if backend == "auto":
        return "pallas" if _on_tpu() else "ref"
    return backend


def _interpret_flag(mode: str) -> bool:
    """interpret= for a resolved pallas/interpret mode."""
    return True if mode == "interpret" else default_interpret()


def adc_scan(codes, lut, *, backend: Backend = "auto", block_n: int = 1024):
    """One-query ADC scan: (N, M) codes × (M, K) LUT → (N,) f32."""
    mode = _resolve(backend)
    if mode == "ref":
        return _ref.adc_scan_ref(codes, lut)
    return _adc.adc_scan(codes, lut, block_n=block_n,
                         interpret=_interpret_flag(mode))


def adc_scan_batch(codes, luts, *, backend: Backend = "auto",
                   block_n: int = 256, block_q: int = 128):
    """Batched ADC scan: (N, M) codes × (Q, M, K) LUTs → (Q, N) f32."""
    mode = _resolve(backend)
    if mode == "ref":
        return _ref.adc_scan_batch_ref(codes, luts)
    return _adc.adc_scan_batch(codes, luts, block_n=block_n, block_q=block_q,
                               interpret=_interpret_flag(mode))


def hop_gather(codes, luts, *, backend: Backend = "auto", block_q: int = 8):
    """Per-hop beam ADC on PRE-GATHERED codes: (Q, R, M) × (Q, M, K) →
    (Q, R) f32. Prefer :func:`hop_adc` where the ids are still at hand —
    it fuses the gather too."""
    mode = _resolve(backend)
    if mode == "ref":
        return _ref.hop_gather_ref(codes, luts)
    from repro.kernels import hop_gather as _hg
    return _hg.hop_gather(codes, luts, block_q=block_q,
                          interpret=_interpret_flag(mode))


def hop_adc(codes, ids, luts, *, backend: Backend = "auto",
            block_q: int = 8):
    """FUSED per-hop beam ADC: (N, M) codes, (Q, R) ids, (Q, M, K) LUTs →
    (Q, R) f32 — gathers the R neighbor code rows AND reduces them against
    each query's LUT in one kernel (no (Q, R, M) HBM round-trip). All ids
    must be valid rows in [0, N)."""
    mode = _resolve(backend)
    if mode == "ref":
        return _ref.hop_adc_ref(codes, ids, luts)
    from repro.kernels import hop_adc as _ha
    return _ha.hop_adc(codes, ids, luts, block_q=block_q,
                       interpret=_interpret_flag(mode))


def pq_pairwise(x, codebook, *, backend: Backend = "auto", block_n: int = 512):
    """Sub-vector/codeword distance table: (N,M,dsub) × (M,K,dsub) → (N,M,K)."""
    mode = _resolve(backend)
    if mode == "ref":
        return _ref.pq_pairwise_ref(x, codebook)
    return _pqp.pq_pairwise(x, codebook, block_n=block_n,
                            interpret=_interpret_flag(mode))


def kmeans_assign(x, centroids, *, backend: Backend = "auto"):
    """Nearest centroid: (N, D) × (K, D) → (assign (N,) i32, sqdist (N,) f32)."""
    d = pq_pairwise(x[:, None, :], centroids[None, :, :], backend=backend)[:, 0, :]
    idx = jnp.argmin(d, axis=1).astype(jnp.int32)
    best = jnp.take_along_axis(d, idx[:, None], axis=1)[:, 0]
    return idx, best
