"""Public, jitted entry points for the PQ kernels with backend dispatch.

Call these from library code. On TPU they run the Pallas kernels; on CPU
(this container) they run the pure-jnp oracle, which XLA fuses well — the
Pallas path is still exercised on CPU via interpret=True in the tests and
can be forced with use_pallas="interpret".
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels import adc_scan as _adc
from repro.kernels import pq_pairwise as _pqp
from repro.kernels import ref as _ref

Backend = Literal["auto", "pallas", "interpret", "ref"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(backend: Backend) -> str:
    if backend == "auto":
        return "pallas" if _on_tpu() else "ref"
    return backend


def adc_scan(codes, lut, *, backend: Backend = "auto", block_n: int = 1024):
    """One-query ADC scan: (N, M) codes × (M, K) LUT → (N,) f32."""
    mode = _resolve(backend)
    if mode == "ref":
        return _ref.adc_scan_ref(codes, lut)
    return _adc.adc_scan(codes, lut, block_n=block_n,
                         interpret=(mode == "interpret"))


def adc_scan_batch(codes, luts, *, backend: Backend = "auto",
                   block_n: int = 256, block_q: int = 128):
    """Batched ADC scan: (N, M) codes × (Q, M, K) LUTs → (Q, N) f32."""
    mode = _resolve(backend)
    if mode == "ref":
        return _ref.adc_scan_batch_ref(codes, luts)
    return _adc.adc_scan_batch(codes, luts, block_n=block_n, block_q=block_q,
                               interpret=(mode == "interpret"))


def hop_gather(codes, luts, *, backend: Backend = "auto", block_q: int = 8):
    """Per-hop beam ADC: (Q, R, M) codes × (Q, M, K) LUTs → (Q, R) f32."""
    mode = _resolve(backend)
    if mode == "ref":
        return _ref.hop_gather_ref(codes, luts)
    from repro.kernels import hop_gather as _hg
    return _hg.hop_gather(codes, luts, block_q=block_q,
                          interpret=(mode == "interpret"))


def pq_pairwise(x, codebook, *, backend: Backend = "auto", block_n: int = 512):
    """Sub-vector/codeword distance table: (N,M,dsub) × (M,K,dsub) → (N,M,K)."""
    mode = _resolve(backend)
    if mode == "ref":
        return _ref.pq_pairwise_ref(x, codebook)
    return _pqp.pq_pairwise(x, codebook, block_n=block_n,
                            interpret=(mode == "interpret"))


def kmeans_assign(x, centroids, *, backend: Backend = "auto"):
    """Nearest centroid: (N, D) × (K, D) → (assign (N,) i32, sqdist (N,) f32)."""
    d = pq_pairwise(x[:, None, :], centroids[None, :, :], backend=backend)[:, 0, :]
    idx = jnp.argmin(d, axis=1).astype(jnp.int32)
    best = jnp.take_along_axis(d, idx[:, None], axis=1)[:, 0]
    return idx, best
