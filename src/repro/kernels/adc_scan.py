"""Pallas TPU kernels for the ADC (asymmetric distance computation) scan.

This is the serving hot loop of PQ-integrated graph ANNS: given the compact
codes of N database vectors and a query's LUT of per-subspace distances,
estimate N squared distances.

TPU adaptation (see DESIGN.md §3)
---------------------------------
The CPU/GPU idiom is a per-lane byte-shuffle gather (AVX `pshufb` over 16-entry
LUTs, or warp gathers). The TPU has no shuffle/gather unit in the hot path, so
we re-derive the scan around the MXU/VPU:

* `adc_scan_kernel` (one query): codes tile (bn, M) lives in VMEM; the LUT
  (M, K) f32 is ≤ 64 KiB and is broadcast to every grid step. For each
  subspace j (static unroll, M ≤ 64) build the comparison mask
  `codes[:, j:j+1] == iota(K)` and reduce `mask * lut[j]` over K — a pure VPU
  (8,128)-lane operation; K = 256 is two lane groups.

* `adc_scan_batch_kernel` (Q queries): the real TPU insight — batching
  queries turns the LUT gather into a GEMM on the MXU. The one-hot expansion
  of a codes tile, onehot(codes) ∈ {0,1}^(bn × M·K), is query-independent, so
  `dists = onehot(codes) @ luts.reshape(Q, M·K).T` scores a (bn, Q) tile with
  one (bn, MK) × (MK, bq) matmul: arithmetic intensity ~bq× higher than the
  scalar scan. bn=256, bq=128, M·K=4096 keeps the one-hot tile (bn × MK bf16 =
  2 MiB) comfortably in VMEM.

Both kernels are validated against kernels/ref.py in interpret mode (CPU) by
tests/test_kernels.py; ops.py picks pallas-on-TPU / jnp-on-CPU automatically.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# --------------------------------------------------------------------------
# Single-query scan (VPU formulation)
# --------------------------------------------------------------------------

def _adc_scan_kernel(codes_ref, lut_ref, out_ref, *, m: int, k: int):
    codes = codes_ref[...]                        # (bn, M) int32
    bn = codes.shape[0]
    acc = jnp.zeros((bn,), jnp.float32)
    iota = jax.lax.broadcasted_iota(jnp.int32, (bn, k), 1)
    for j in range(m):                            # static unroll, M small
        mask = (codes[:, j:j + 1] == iota)        # (bn, K) bool
        row = lut_ref[j, :].astype(jnp.float32)   # (K,)
        acc = acc + jnp.sum(jnp.where(mask, row[None, :], 0.0), axis=1)
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def adc_scan(codes: jax.Array, lut: jax.Array, *, block_n: int = 1024,
             interpret: bool | None = None) -> jax.Array:
    """(N, M) int codes × (M, K) LUT → (N,) f32 distances. Pallas path.

    ``interpret=None`` autodetects via kernels.ops.default_interpret
    (compiled Mosaic on TPU, interpreter elsewhere).
    """
    if interpret is None:
        from repro.kernels.ops import default_interpret
        interpret = default_interpret()
    n, m = codes.shape
    _, k = lut.shape
    n_pad = (-n) % block_n
    codes_i = codes.astype(jnp.int32)
    if n_pad:
        codes_i = jnp.pad(codes_i, ((0, n_pad), (0, 0)))
    grid = (codes_i.shape[0] // block_n,)
    out = pl.pallas_call(
        functools.partial(_adc_scan_kernel, m=m, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, m), lambda i: (i, 0)),
            pl.BlockSpec((m, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((codes_i.shape[0],), jnp.float32),
        interpret=interpret,
    )(codes_i, lut)
    return out[:n]


# --------------------------------------------------------------------------
# Batched-query scan (MXU one-hot GEMM formulation)
# --------------------------------------------------------------------------

def _adc_scan_batch_kernel(codes_ref, luts_ref, out_ref, *, m: int, k: int):
    codes = codes_ref[...]                          # (bn, M) int32
    bn = codes.shape[0]
    # one-hot (bn, M*K) built with a single iota compare; bf16 feeds the MXU.
    iota = jax.lax.broadcasted_iota(jnp.int32, (bn, m, k), 2)
    onehot = (codes[:, :, None] == iota).astype(jnp.bfloat16).reshape(bn, m * k)
    luts = luts_ref[...]                            # (bq, M*K) f32
    # (bn, MK) @ (MK, bq) -> (bn, bq) on the MXU, fp32 accumulation.
    acc = jax.lax.dot_general(
        onehot, luts.astype(jnp.bfloat16).T,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    out_ref[...] = acc.T                            # (bq, bn)


@functools.partial(jax.jit, static_argnames=("block_n", "block_q", "interpret"))
def adc_scan_batch(codes: jax.Array, luts: jax.Array, *, block_n: int = 256,
                   block_q: int = 128,
                   interpret: bool | None = None) -> jax.Array:
    """(N, M) codes × (Q, M, K) LUTs → (Q, N) f32 distances. Pallas path.

    ``interpret=None`` autodetects via kernels.ops.default_interpret.
    """
    if interpret is None:
        from repro.kernels.ops import default_interpret
        interpret = default_interpret()
    n, m = codes.shape
    q, _, k = luts.shape
    n_pad = (-n) % block_n
    q_pad = (-q) % block_q
    codes_i = codes.astype(jnp.int32)
    luts_f = luts.reshape(q, m * k)
    if n_pad:
        codes_i = jnp.pad(codes_i, ((0, n_pad), (0, 0)))
    if q_pad:
        luts_f = jnp.pad(luts_f, ((0, q_pad), (0, 0)))
    np_, qp_ = codes_i.shape[0], luts_f.shape[0]
    grid = (qp_ // block_q, np_ // block_n)
    out = pl.pallas_call(
        functools.partial(_adc_scan_batch_kernel, m=m, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, m), lambda iq, jn: (jn, 0)),
            pl.BlockSpec((block_q, m * k), lambda iq, jn: (iq, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, block_n), lambda iq, jn: (iq, jn)),
        out_shape=jax.ShapeDtypeStruct((qp_, np_), jnp.float32),
        interpret=interpret,
    )(codes_i, luts_f)
    return out[:q, :n]
