"""Pallas TPU kernel: fast-scan bulk ADC over 4-bit packed codes.

The f32 scan (adc_scan.py) moves 1 byte/code and 4 bytes/LUT-entry through
VMEM; this kernel is the fast-scan layout (DESIGN.md §8): K=16 sub-codebooks
pack two 4-bit codes per byte — HALF the code bytes per distance — and the
LUT rides in as uint8 with a per-query (scale, bias) affine — a QUARTER of
the LUT bytes. The tile budget that the layout buys:

* codes tile (bn, ceil(M/2)) uint8: bn=512, M=16 → 4 KiB (vs 8 KiB u8,
  32 KiB of the old int32 staging);
* LUT tile (bq, M·16) uint8: bq=64, M=16 → 16 KiB (vs 64 KiB f32 — and vs
  1 MiB f32 at K=256 for the same M·K=4096 table width).

Compute: the packed bytes are nibble-unpacked IN REGISTER (two VPU shifts),
one-hot expanded, and hit the MXU as a (bn, M·16) × (M·16, bq) GEMM — the
same batching insight as adc_scan_batch, but the contraction is 16× narrower
so the one-hot tile is 16× smaller too. Both operands are exact small
integers in bf16 (one-hot ∈ {0,1}, LUT ≤ 255 < 2⁸ — bf16 holds integers up
to 256 exactly) and the f32 accumulator is exact below 2²⁴, so the int32
accumulators this kernel emits are BIT-EXACT with the oracle
``ref.adc_scan_fs_ref``. The kernel stays pure-integer on purpose: the
affine dequant (`scale·acc + M·bias`) lives in ``ops.adc_scan_fs`` so the
float op sequence is identical on every backend (an in-kernel dequant could
be FMA-fused by XLA and drift an ulp from the eager oracle).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _adc_scan_fs_kernel(codes_ref, luts_ref, out_ref, *, m: int, mb: int):
    p = codes_ref[...].astype(jnp.int32)            # (bn, Mb) packed bytes
    bn = p.shape[0]
    # nibble unpack in-register: byte b → sub-codes (2b, 2b+1)
    nib = jnp.stack([p & 0xF, (p >> 4) & 0xF], axis=-1)
    codes = nib.reshape(bn, 2 * mb)[:, :m]          # (bn, M)
    # one-hot over K=16; bf16 feeds the MXU and is exact for 0/1
    iota = jax.lax.broadcasted_iota(jnp.int32, (bn, m, 16), 2)
    onehot = (codes[:, :, None] == iota).astype(jnp.bfloat16).reshape(bn, m * 16)
    luts = luts_ref[...].astype(jnp.bfloat16)       # (bq, M*16) from uint8
    # (bn, M16) @ (M16, bq) → exact integer counts in f32 (≤ M·255 < 2²⁴)
    acc = jax.lax.dot_general(
        onehot, luts.T,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    out_ref[...] = acc.T.astype(jnp.int32)          # (bq, bn)


@functools.partial(jax.jit, static_argnames=("block_n", "block_q", "interpret"))
def adc_scan_fs(packed: jax.Array, luts_u8: jax.Array, *, block_n: int = 512,
                block_q: int = 64, interpret: bool | None = None) -> jax.Array:
    """(N, ceil(M/2)) packed codes × (Q, M, 16) u8 LUTs → (Q, N) int32
    accumulators (``sum_j lut[q, j, code_j]``, exact).

    Callers go through :func:`repro.kernels.ops.adc_scan_fs`, which casts
    the packed codes to uint8 once at the dispatch boundary and applies the
    per-query dequantization affine. ``interpret=None`` autodetects via
    kernels.ops.default_interpret.
    """
    if interpret is None:
        from repro.kernels.ops import default_interpret
        interpret = default_interpret()
    n, mb = packed.shape
    q, m, k = luts_u8.shape
    assert k == 16, f"fast-scan LUTs are (Q, M, 16); got K={k}"
    n_pad = (-n) % block_n
    q_pad = (-q) % block_q
    luts_flat = luts_u8.reshape(q, m * 16)
    if n_pad:
        packed = jnp.pad(packed, ((0, n_pad), (0, 0)))
    if q_pad:
        luts_flat = jnp.pad(luts_flat, ((0, q_pad), (0, 0)))
    np_, qp_ = packed.shape[0], luts_flat.shape[0]
    grid = (qp_ // block_q, np_ // block_n)
    out = pl.pallas_call(
        functools.partial(_adc_scan_fs_kernel, m=m, mb=mb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, mb), lambda iq, jn: (jn, 0)),
            pl.BlockSpec((block_q, m * 16), lambda iq, jn: (iq, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, block_n), lambda iq, jn: (iq, jn)),
        out_shape=jax.ShapeDtypeStruct((qp_, np_), jnp.int32),
        interpret=interpret,
    )(packed, luts_flat)
    return out[:q, :n]
