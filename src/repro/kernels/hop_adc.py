"""Fused Pallas TPU kernel: gather + ADC reduce for one beam-search hop.

The per-hop hot loop of graph-routed serving does two things per query:
gather the compact code rows of its R′ candidate neighbors, then reduce each
row against the query's LUT. As two XLA ops that round-trips a (Q, R′, M)
gathered-codes array through HBM between the gather and the reduce
(`hop_gather.py` only covers the reduce half). This kernel fuses both: the
ids never leave SMEM, the gathered rows never leave VMEM.

R′ is the FRONTIER width: the adjacency degree R classically, E·R under
multi-expansion beam search (``search/beam.py`` with ``expand=E``,
DESIGN.md §9). The kernel is width-agnostic; two knobs keep the wide rows
efficient:

* the per-row scalar gather loop is UNROLLED ×8 — each ``fori_loop`` trip
  issues 8 independent row copies (SMEM id read + VMEM dynamic slice), so
  the copies pipeline instead of serializing one loop trip per row (the
  trip count at R′=256 drops 256 → 32);
* ``block_q`` auto-tunes to the width (``_auto_block_q``): the query tile
  shrinks 8 → 4 → 2 as R′ grows 64 → 128 → 256 so the LUT tile + out tile
  + gather scratch VMEM working set stays roughly constant.

Layout (DESIGN.md §6, §9):

* ``ids`` (Q, R′) int32 ride in as a scalar-prefetch argument — they live in
  SMEM, where scalars are readable before/without a VMEM DMA, and drive the
  row gather directly (the embedding-lookup idiom of
  ``PrefetchScalarGridSpec``).
* ``codes`` (N, M) int32 are block-resident in VMEM across all grid steps
  (index_map pins block (0, 0)). N here is a SHARD's rows, not the corpus:
  at 1M rows / 512 devices ≈ 2k rows × M=16 × 4 B ≈ 128 KiB — small next
  to the LUT tile.
* ``luts`` (bq, M, K) f32 tile per grid step; per query the reduce is the
  same K-lane iota-compare as adc_scan's VPU formulation (M static unroll).
* grid = (Q / bq,); per-(query, neighbor) row gathers are dynamic slices
  into the resident codes block, staged through an (R′, M) VMEM scratch.

VMEM @ bq=8, R′=64, M=16, K=256: LUT tile 8·16·256·4 = 128 KiB + codes +
scratch ≪ 16 MB; @ bq=2, R′=256 the LUT tile is 32 KiB and the scratch
16 KiB (budget table in DESIGN.md §9). Validated against ``ref.hop_adc_ref``
in interpret mode by tests/test_kernels.py; ``ops.hop_adc`` dispatches
Pallas-on-TPU / jnp-ref elsewhere.

``hop_adc_fs`` below is the FAST-SCAN twin (DESIGN.md §8): the resident
codes block holds 4-bit-packed bytes (half the bytes), the LUT tile is
uint8 with a per-query affine (1/256th of the tile above — K drops to 16
AND the entries to 1 byte), nibbles unpack in-register, and accumulation is
exact int32; the dequant lives in ``ops.hop_adc_fs``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Rows gathered per fori_loop trip — 8 independent dynamic slices per trip
# pipeline where a 1-row loop serialized (the ids wrapper pads R′ up to a
# multiple of this; pad rows gather row 0 and are sliced off the output).
GATHER_UNROLL = 8


def _auto_block_q(r: int) -> int:
    """Default query tile for a frontier of width ``r``: 8 at R′ ≤ 64,
    4 at 128, 2 at 256+ — keeps the LUT tile + out tile + gather scratch
    working set roughly constant as multi-expansion widens the hop
    (DESIGN.md §9 VMEM budget)."""
    return max(1, 512 // max(r, 64))


def _pad_ids_rows(ids_i: jax.Array) -> jax.Array:
    """Pad the frontier axis to a GATHER_UNROLL multiple (pad lanes gather
    row 0 — cheap, discarded by the caller's output slice)."""
    r_pad = (-ids_i.shape[1]) % GATHER_UNROLL
    if r_pad:
        ids_i = jnp.pad(ids_i, ((0, 0), (0, r_pad)))
    return ids_i


def _gather_rows(ids_ref, codes_ref, gathered, q_abs, rp: int):
    """Copy the rp neighbor code rows of query ``q_abs`` into scratch,
    GATHER_UNROLL independent row copies per loop trip."""
    def g_body(gi, _):
        base = gi * GATHER_UNROLL
        for j in range(GATHER_UNROLL):     # static unroll
            row = ids_ref[q_abs, base + j]
            gathered[pl.ds(base + j, 1), :] = codes_ref[pl.ds(row, 1), :]
        return _

    jax.lax.fori_loop(0, rp // GATHER_UNROLL, g_body, 0)


def _hop_adc_kernel(ids_ref, codes_ref, luts_ref, out_ref, gathered,
                    *, m: int, m_eff: int, k: int, rp: int, block_q: int):
    """One grid step: block_q queries × R′ fused gather-reduce. ``m_eff ≤ m``
    statically shortens the reduce unroll — the partial-LUT lower-bound pass
    of hop pruning (DESIGN.md §11); the resident codes block stays full-width
    (no HBM reslice per call), only the loop trip count shrinks."""
    q0 = pl.program_id(0) * block_q

    def q_body(qi, _):
        # 1. gather this query's R′ neighbor code rows into VMEM scratch;
        #    the row index comes straight from SMEM (no VMEM round-trip).
        _gather_rows(ids_ref, codes_ref, gathered, q0 + qi, rp)
        rows = gathered[...]                               # (R′, M) int32
        lut = luts_ref[pl.ds(qi, 1)][0]                    # (M, K) f32
        # 2. LUT reduce: K-lane iota compare per subspace (VPU formulation)
        iota = jax.lax.broadcasted_iota(jnp.int32, (rp, k), 1)
        acc = jnp.zeros((rp,), jnp.float32)
        for j in range(m_eff):                             # M static unroll
            mask = rows[:, j:j + 1] == iota                # (R′, K)
            acc = acc + jnp.sum(
                jnp.where(mask, lut[j, :][None, :], 0.0), axis=1)
        out_ref[pl.ds(qi, 1), :] = acc[None]
        return _

    jax.lax.fori_loop(0, block_q, q_body, 0)


@functools.partial(jax.jit,
                   static_argnames=("block_q", "interpret", "m_prefix"))
def hop_adc(codes: jax.Array, ids: jax.Array, luts: jax.Array, *,
            block_q: int | None = None,
            interpret: bool | None = None,
            m_prefix: int = 0) -> jax.Array:
    """Fused per-hop ADC: (N, M) codes, (Q, R′) ids, (Q, M, K) LUTs → (Q, R′).

    ``out[q, i] = sum_j luts[q, j, codes[ids[q, i], j]]`` — the distance of
    query q to its i-th candidate neighbor. All ids must be valid rows in
    ``[0, N)`` (the beam passes masked-to-0 ids for dead lanes and infs the
    distances afterwards). Codes/ids arrive int32, LUTs f32 — the ONE cast
    from caller dtypes (uint8 codes etc.) lives in kernels.ops, the
    dispatch boundary. ``block_q=None`` auto-tunes the query tile to the
    frontier width (``_auto_block_q``); ``interpret=None`` autodetects:
    compiled Pallas on TPU, interpreter elsewhere
    (kernels.ops.default_interpret). ``0 < m_prefix < M`` reduces only the
    first m_prefix subspaces — the hop-pruning lower bound (the grid, specs
    and resident codes are unchanged; only the reduce unroll shortens).
    """
    if interpret is None:
        from repro.kernels.ops import default_interpret
        interpret = default_interpret()
    q, r = ids.shape
    n, m = codes.shape
    _, _, k = luts.shape
    if block_q is None:
        block_q = _auto_block_q(r)
    q_pad = (-q) % block_q
    ids_i = _pad_ids_rows(ids.astype(jnp.int32))
    rp = ids_i.shape[1]
    luts_f = luts.astype(jnp.float32)
    if q_pad:  # padded queries gather row 0 — cheap, discarded below
        ids_i = jnp.pad(ids_i, ((0, q_pad), (0, 0)))
        luts_f = jnp.pad(luts_f, ((0, q_pad), (0, 0), (0, 0)))
    qp = ids_i.shape[0]
    m_eff = m_prefix if 0 < m_prefix < m else m
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(qp // block_q,),
        in_specs=[
            pl.BlockSpec((n, m), lambda i, ids: (0, 0)),        # resident
            pl.BlockSpec((block_q, m, k), lambda i, ids: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, rp), lambda i, ids: (i, 0)),
        scratch_shapes=[pltpu.VMEM((rp, m), jnp.int32)],
    )
    out = pl.pallas_call(
        functools.partial(_hop_adc_kernel, m=m, m_eff=m_eff, k=k, rp=rp,
                          block_q=block_q),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((qp, rp), jnp.float32),
        interpret=interpret,
    )(ids_i, codes.astype(jnp.int32), luts_f)
    return out[:q, :r]


# --------------------------------------------------------------------------
# Fast-scan variant: 4-bit packed codes + uint8 LUTs (DESIGN.md §8)
# --------------------------------------------------------------------------

def _hop_adc_fs_kernel(ids_ref, codes_ref, luts_ref, out_ref, gathered,
                       *, m: int, m_eff: int, mb: int, rp: int, block_q: int):
    """Packed twin of ``_hop_adc_kernel``: the resident codes block and the
    gather scratch hold PACKED bytes (half the VMEM), the LUT tile is uint8
    (a quarter), nibbles unpack in-register, and the reduce accumulates
    int32 — dequantization happens once in the wrapper. ``m_eff ≤ m``
    statically shortens the reduce unroll (hop-pruning lower bound)."""
    q0 = pl.program_id(0) * block_q

    def q_body(qi, _):
        _gather_rows(ids_ref, codes_ref, gathered, q0 + qi, rp)
        p = gathered[...].astype(jnp.int32)                # (R′, Mb) packed
        nib = jnp.stack([p & 0xF, (p >> 4) & 0xF], axis=-1)
        rows = nib.reshape(rp, 2 * mb)[:, :m]              # (R′, M)
        lut = luts_ref[pl.ds(qi, 1)][0].astype(jnp.int32)  # (M, 16)
        iota = jax.lax.broadcasted_iota(jnp.int32, (rp, 16), 1)
        acc = jnp.zeros((rp,), jnp.int32)
        for j in range(m_eff):                             # M static unroll
            mask = rows[:, j:j + 1] == iota                # (R′, 16)
            acc = acc + jnp.sum(jnp.where(mask, lut[j, :][None, :], 0),
                                axis=1)
        out_ref[pl.ds(qi, 1), :] = acc[None]
        return _

    jax.lax.fori_loop(0, block_q, q_body, 0)


@functools.partial(jax.jit, static_argnames=("m", "block_q", "interpret",
                                             "m_prefix"))
def hop_adc_fs(packed: jax.Array, ids: jax.Array, luts_u8: jax.Array, *,
               m: int, block_q: int | None = None,
               interpret: bool | None = None,
               m_prefix: int = 0) -> jax.Array:
    """Fused per-hop fast-scan ADC: (N, ceil(M/2)) packed codes, (Q, R′)
    ids, (Q, M, 16) u8 LUTs → (Q, R′) int32 exact accumulators.

    Pure-integer on purpose — the per-query dequant affine is applied by
    ``ops.hop_adc_fs`` so the float op sequence matches the oracle
    ``ref.hop_adc_fs_ref`` exactly on every backend. Canonical dtypes
    (uint8 packed, int32 ids) are enforced by kernels.ops. ``block_q=None``
    auto-tunes the query tile to the frontier width. ``0 < m_prefix < m``
    accumulates only the first m_prefix subspaces (hop-pruning lower
    bound); the caller's dequant must then use ``m_prefix · bias``.
    """
    if interpret is None:
        from repro.kernels.ops import default_interpret
        interpret = default_interpret()
    q, r = ids.shape
    n, mb = packed.shape
    if block_q is None:
        block_q = _auto_block_q(r)
    q_pad = (-q) % block_q
    ids_i = _pad_ids_rows(ids.astype(jnp.int32))
    rp = ids_i.shape[1]
    luts_q = luts_u8
    if q_pad:  # padded queries gather row 0 — cheap, discarded below
        ids_i = jnp.pad(ids_i, ((0, q_pad), (0, 0)))
        luts_q = jnp.pad(luts_q, ((0, q_pad), (0, 0), (0, 0)))
    qp = ids_i.shape[0]
    m_eff = m_prefix if 0 < m_prefix < m else m
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(qp // block_q,),
        in_specs=[
            pl.BlockSpec((n, mb), lambda i, ids: (0, 0)),       # resident
            pl.BlockSpec((block_q, m, 16), lambda i, ids: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, rp), lambda i, ids: (i, 0)),
        scratch_shapes=[pltpu.VMEM((rp, mb), jnp.uint8)],
    )
    out = pl.pallas_call(
        functools.partial(_hop_adc_fs_kernel, m=m, m_eff=m_eff, mb=mb, rp=rp,
                          block_q=block_q),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((qp, rp), jnp.int32),
        interpret=interpret,
    )(ids_i, packed, luts_q)
    return out[:q, :r]
