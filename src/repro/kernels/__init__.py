"""Pallas TPU kernels for the paper's compute hot spots (PQ scan & training).

Layout per kernel: <name>.py holds the pl.pallas_call + BlockSpec tiling,
ops.py the jitted public wrapper with backend dispatch, ref.py the pure-jnp
oracle used for validation and as the CPU fallback.
"""
from repro.kernels.ops import adc_scan, adc_scan_batch, pq_pairwise, kmeans_assign  # noqa: F401
