"""Pallas TPU kernels for the paper's compute hot spots (PQ scan & training).

Layout per kernel: <name>.py holds the pl.pallas_call + BlockSpec tiling,
ops.py the jitted public wrapper with backend dispatch, ref.py the pure-jnp
oracle used for validation and as the CPU fallback.
"""
from repro.kernels.ops import (adc_scan, adc_scan_batch, adc_scan_fs,  # noqa: F401
                               hop_adc, hop_adc_fs, hop_gather,
                               kmeans_assign, pq_pairwise)
