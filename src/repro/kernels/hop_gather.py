"""Pallas TPU kernel: per-hop ADC lookup for a BATCH of beam searches.

The beam search's inner op: at each hop, every query gathers its R
neighbors' codes and sums LUT entries — shapes (Q, R, M) codes × (Q, M, K)
LUTs → (Q, R). R is tiny (≤64), so unlike adc_scan this is lane-bound, not
MXU-bound; the kernel keeps each query's LUT resident in VMEM and does the
K-lane iota-compare per subspace (same trick as adc_scan, batched over Q).

grid = (Q / bq,); per step: codes tile (bq, R, M) + LUT tile (bq, M, K).
VMEM @ bq=8, R=64, M=16, K=256: 8·16·256·4 = 128 KiB LUTs + codes ≪ 1 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hop_gather_kernel(codes_ref, luts_ref, out_ref, *, m: int, k: int):
    codes = codes_ref[...]                           # (bq, R, M) int32
    luts = luts_ref[...]                             # (bq, M, K) f32
    bq, r, _ = codes.shape
    acc = jnp.zeros((bq, r), jnp.float32)
    iota = jax.lax.broadcasted_iota(jnp.int32, (bq, r, k), 2)
    for j in range(m):                               # M static unroll
        mask = codes[:, :, j:j + 1] == iota          # (bq, R, K)
        row = luts[:, j, :]                          # (bq, K)
        acc = acc + jnp.sum(
            jnp.where(mask, row[:, None, :], 0.0), axis=2)
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def hop_gather(codes: jax.Array, luts: jax.Array, *, block_q: int = 8,
               interpret: bool | None = None) -> jax.Array:
    """(Q, R, M) int codes × (Q, M, K) LUTs → (Q, R) f32 distances.

    ``interpret=None`` autodetects via kernels.ops.default_interpret.
    """
    if interpret is None:
        from repro.kernels.ops import default_interpret
        interpret = default_interpret()
    q, r, m = codes.shape
    _, _, k = luts.shape
    q_pad = (-q) % block_q
    codes_i = codes.astype(jnp.int32)
    luts_f = luts.astype(jnp.float32)
    if q_pad:
        codes_i = jnp.pad(codes_i, ((0, q_pad), (0, 0), (0, 0)))
        luts_f = jnp.pad(luts_f, ((0, q_pad), (0, 0), (0, 0)))
    grid = (codes_i.shape[0] // block_q,)
    out = pl.pallas_call(
        functools.partial(_hop_gather_kernel, m=m, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, r, m), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_q, m, k), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((codes_i.shape[0], r), jnp.float32),
        interpret=interpret,
    )(codes_i, luts_f)
    return out[:q]
