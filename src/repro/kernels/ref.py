"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics contract: each kernel in adc_scan.py / pq_pairwise.py
must match its oracle here (tests/test_kernels.py sweeps shapes & dtypes and
asserts allclose). They are also the CPU fallback used by ops.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def adc_scan_ref(codes: jax.Array, lut: jax.Array) -> jax.Array:
    """Asymmetric-distance scan for ONE query.

    Args:
      codes: (N, M) integer compact codes, values in [0, K).
      lut:   (M, K) float LUT; lut[j, k] = ||q_j - c_k^j||^2.

    Returns:
      (N,) float32 estimated squared distances: sum_j lut[j, codes[:, j]].
    """
    n, m = codes.shape
    # take_along_axis over the K axis, one gather per subspace.
    gathered = jnp.take_along_axis(
        lut[None, :, :], codes[:, :, None].astype(jnp.int32), axis=2
    )  # (N, M, 1)
    return jnp.sum(gathered[..., 0].astype(jnp.float32), axis=1)


def adc_scan_batch_ref(codes: jax.Array, luts: jax.Array) -> jax.Array:
    """Batched-query ADC scan.

    Args:
      codes: (N, M) integer compact codes.
      luts:  (Q, M, K) float LUTs, one per query.

    Returns:
      (Q, N) float32 estimated squared distances.
    """
    q, m, k = luts.shape
    gathered = luts[:, jnp.arange(m)[None, :], codes.astype(jnp.int32)]  # (Q, N, M)
    return jnp.sum(gathered.astype(jnp.float32), axis=-1)


def hop_gather_ref(codes: jax.Array, luts: jax.Array) -> jax.Array:
    """Per-hop beam ADC: (Q, R, M) codes × (Q, M, K) LUTs → (Q, R) f32."""
    q, r, m = codes.shape
    gathered = jnp.take_along_axis(
        luts[:, None, :, :],                          # (Q, 1, M, K)
        codes[:, :, :, None].astype(jnp.int32), axis=3)[..., 0]  # (Q, R, M)
    return jnp.sum(gathered.astype(jnp.float32), axis=-1)


def hop_adc_ref(codes: jax.Array, ids: jax.Array, luts: jax.Array
                ) -> jax.Array:
    """Fused per-hop ADC (gather + LUT reduce) — oracle for hop_adc.py.

    Width-agnostic in R′: the semantics contract covers the classic R ≤ 64
    hop and the multi-expansion frontier R′ = E·R up to 256+ alike
    (DESIGN.md §9) — one gather + reduce, whatever the row count.

    Args:
      codes: (N, M) integer compact codes of the (local) corpus.
      ids:   (Q, R′) int32 candidate rows per query, all in [0, N).
      luts:  (Q, M, K) float LUTs, one per query.

    Returns:
      (Q, R′) float32: out[q, i] = sum_j luts[q, j, codes[ids[q, i], j]].
    """
    return hop_gather_ref(codes[ids.astype(jnp.int32)], luts)


# --------------------------------------------------------------------------
# Fast-scan (fs4) oracles: two 4-bit codes per byte, uint8 LUTs, exact int32
# accumulation, one affine dequant per output (DESIGN.md §8).
# --------------------------------------------------------------------------

def _pair_lut(luts_u8: jax.Array) -> jax.Array:
    """(..., M, 16) u8 LUT → (..., ceil(M/2), 256) int32 PAIRED table.

    ``pair[..., b, byte] = lut[..., 2b, byte & 15] + lut[..., 2b+1, byte >> 4]``
    so ONE gather with the raw packed byte scores TWO sub-codes — the
    fast-scan idiom that halves gather traffic (nibble convention =
    :mod:`repro.pq.pack`, re-derived here so the kernels package keeps
    zero intra-repo imports). Odd M pads a zero row. Integer sums are
    associative, so this is exactly the per-nibble sum.
    """
    m = luts_u8.shape[-2]
    li = luts_u8.astype(jnp.int32)
    if m % 2:
        li = jnp.pad(li, [(0, 0)] * (li.ndim - 2) + [(0, 1), (0, 0)])
    byte = jnp.arange(256)
    return li[..., 0::2, byte & 0xF] + li[..., 1::2, byte >> 4]


def adc_scan_fs_ref(packed: jax.Array, luts_u8: jax.Array, scale: jax.Array,
                    bias: jax.Array) -> jax.Array:
    """Batched fast-scan ADC — oracle for kernels/adc_scan_fs.py.

    Args:
      packed:  (N, ceil(M/2)) uint8 packed codes (pq.pack convention).
      luts_u8: (Q, M, 16) uint8 quantized LUTs.
      scale:   (Q,) float32 per-query dequant step.
      bias:    (Q,) float32 per-query dequant offset.

    Returns:
      (Q, N) float32: ``scale[q] * sum_j luts_u8[q, j, code_j] + M * bias[q]``
      with the inner sum in exact int32.
    """
    q, m, _ = luts_u8.shape
    pair = _pair_lut(luts_u8)                              # (Q, Mb, 256)
    mb = pair.shape[1]
    qi = jnp.arange(q)[:, None, None]
    bi = jnp.arange(mb)[None, None, :]
    vals = pair[qi, bi, packed.astype(jnp.int32)[None]]    # (Q, N, Mb)
    acc = jnp.sum(vals, axis=-1)                           # (Q, N) int32
    return (jnp.asarray(scale, jnp.float32)[:, None] * acc.astype(jnp.float32)
            + m * jnp.asarray(bias, jnp.float32)[:, None])


def hop_adc_fs_ref(packed: jax.Array, ids: jax.Array, luts_u8: jax.Array,
                   scale: jax.Array, bias: jax.Array) -> jax.Array:
    """Fused per-hop fast-scan ADC — oracle for hop_adc.py's packed variant
    (width-agnostic in R′, like :func:`hop_adc_ref`).

    Args:
      packed:  (N, ceil(M/2)) uint8 packed codes of the (local) corpus.
      ids:     (Q, R′) int32 candidate rows per query, all in [0, N).
      luts_u8: (Q, M, 16) uint8 quantized LUTs.
      scale/bias: (Q,) float32 per-query dequant affine.

    Returns:
      (Q, R′) float32 dequantized distances (exact int32 accumulation).
    """
    q, m, _ = luts_u8.shape
    pair = _pair_lut(luts_u8)                              # (Q, Mb, 256)
    mb = pair.shape[1]
    rows = packed.astype(jnp.int32)[ids.astype(jnp.int32)]  # (Q, R, Mb)
    qi = jnp.arange(q)[:, None, None]
    bi = jnp.arange(mb)[None, None, :]
    acc = jnp.sum(pair[qi, bi, rows], axis=-1)             # (Q, R) int32
    return (jnp.asarray(scale, jnp.float32)[:, None] * acc.astype(jnp.float32)
            + m * jnp.asarray(bias, jnp.float32)[:, None])


def pq_pairwise_ref(x: jax.Array, codebook: jax.Array) -> jax.Array:
    """Per-subspace squared distances between sub-vectors and codewords.

    Args:
      x:        (N, M, dsub) sub-vectors.
      codebook: (M, K, dsub) codewords.

    Returns:
      (N, M, K) float32 squared distances ||x[n,j] - codebook[j,k]||^2.
    """
    x = x.astype(jnp.float32)
    c = codebook.astype(jnp.float32)
    x2 = jnp.sum(x * x, axis=-1)[:, :, None]           # (N, M, 1)
    c2 = jnp.sum(c * c, axis=-1)[None, :, :]           # (1, M, K)
    xc = jnp.einsum("nmd,mkd->nmk", x, c)              # (N, M, K)
    return x2 - 2.0 * xc + c2


def kmeans_assign_ref(x: jax.Array, centroids: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Nearest-centroid assignment (flat, single space).

    Args:
      x:         (N, D)
      centroids: (K, D)

    Returns:
      (assign (N,) int32, sqdist (N,) float32)
    """
    d = pq_pairwise_ref(x[:, None, :], centroids[None, :, :])[:, 0, :]  # (N, K)
    idx = jnp.argmin(d, axis=1).astype(jnp.int32)
    return idx, jnp.take_along_axis(d, idx[:, None], axis=1)[:, 0]
