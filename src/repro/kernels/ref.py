"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics contract: each kernel in adc_scan.py / pq_pairwise.py
must match its oracle here (tests/test_kernels.py sweeps shapes & dtypes and
asserts allclose). They are also the CPU fallback used by ops.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def adc_scan_ref(codes: jax.Array, lut: jax.Array) -> jax.Array:
    """Asymmetric-distance scan for ONE query.

    Args:
      codes: (N, M) integer compact codes, values in [0, K).
      lut:   (M, K) float LUT; lut[j, k] = ||q_j - c_k^j||^2.

    Returns:
      (N,) float32 estimated squared distances: sum_j lut[j, codes[:, j]].
    """
    n, m = codes.shape
    # take_along_axis over the K axis, one gather per subspace.
    gathered = jnp.take_along_axis(
        lut[None, :, :], codes[:, :, None].astype(jnp.int32), axis=2
    )  # (N, M, 1)
    return jnp.sum(gathered[..., 0].astype(jnp.float32), axis=1)


def adc_scan_batch_ref(codes: jax.Array, luts: jax.Array) -> jax.Array:
    """Batched-query ADC scan.

    Args:
      codes: (N, M) integer compact codes.
      luts:  (Q, M, K) float LUTs, one per query.

    Returns:
      (Q, N) float32 estimated squared distances.
    """
    q, m, k = luts.shape
    gathered = luts[:, jnp.arange(m)[None, :], codes.astype(jnp.int32)]  # (Q, N, M)
    return jnp.sum(gathered.astype(jnp.float32), axis=-1)


def hop_gather_ref(codes: jax.Array, luts: jax.Array) -> jax.Array:
    """Per-hop beam ADC: (Q, R, M) codes × (Q, M, K) LUTs → (Q, R) f32."""
    q, r, m = codes.shape
    gathered = jnp.take_along_axis(
        luts[:, None, :, :],                          # (Q, 1, M, K)
        codes[:, :, :, None].astype(jnp.int32), axis=3)[..., 0]  # (Q, R, M)
    return jnp.sum(gathered.astype(jnp.float32), axis=-1)


def hop_adc_ref(codes: jax.Array, ids: jax.Array, luts: jax.Array
                ) -> jax.Array:
    """Fused per-hop ADC (gather + LUT reduce) — oracle for hop_adc.py.

    Args:
      codes: (N, M) integer compact codes of the (local) corpus.
      ids:   (Q, R) int32 candidate rows per query, all in [0, N).
      luts:  (Q, M, K) float LUTs, one per query.

    Returns:
      (Q, R) float32: out[q, i] = sum_j luts[q, j, codes[ids[q, i], j]].
    """
    return hop_gather_ref(codes[ids.astype(jnp.int32)], luts)


def pq_pairwise_ref(x: jax.Array, codebook: jax.Array) -> jax.Array:
    """Per-subspace squared distances between sub-vectors and codewords.

    Args:
      x:        (N, M, dsub) sub-vectors.
      codebook: (M, K, dsub) codewords.

    Returns:
      (N, M, K) float32 squared distances ||x[n,j] - codebook[j,k]||^2.
    """
    x = x.astype(jnp.float32)
    c = codebook.astype(jnp.float32)
    x2 = jnp.sum(x * x, axis=-1)[:, :, None]           # (N, M, 1)
    c2 = jnp.sum(c * c, axis=-1)[None, :, :]           # (1, M, K)
    xc = jnp.einsum("nmd,mkd->nmk", x, c)              # (N, M, K)
    return x2 - 2.0 * xc + c2


def kmeans_assign_ref(x: jax.Array, centroids: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Nearest-centroid assignment (flat, single space).

    Args:
      x:         (N, D)
      centroids: (K, D)

    Returns:
      (assign (N,) int32, sqdist (N,) float32)
    """
    d = pq_pairwise_ref(x[:, None, :], centroids[None, :, :])[:, 0, :]  # (N, K)
    idx = jnp.argmin(d, axis=1).astype(jnp.int32)
    return idx, jnp.take_along_axis(d, idx[:, None], axis=1)[:, 0]
