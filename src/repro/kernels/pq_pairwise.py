"""Pallas TPU kernel: per-subspace pairwise squared distances (N, M, K).

This is the training / k-means hot loop of RPQ: both the Lloyd assignment
step and the differentiable soft-assignment (Eq. 6 of the paper) need the
full table of ||x[n,j] - c[j,k]||^2 for every sub-vector and codeword.

TPU formulation: the cross term is a per-subspace (bn, dsub) × (dsub, K)
matmul on the MXU; the norms are rank-1 VPU broadcasts. Grid is
(N / bn, M) so each grid step holds one subspace's codebook (K × dsub ≤
256×128×4B = 128 KiB) and a (bn, dsub) slab of sub-vectors in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pq_pairwise_kernel(x_ref, cb_ref, out_ref):
    x = x_ref[...][:, 0, :].astype(jnp.float32)      # (bn, dsub)
    c = cb_ref[...][0].astype(jnp.float32)           # (K, dsub)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)      # (bn, 1)
    c2 = jnp.sum(c * c, axis=-1)[None, :]            # (1, K)
    xc = jax.lax.dot_general(                        # (bn, K) on the MXU
        x, c.T, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    out_ref[...] = (x2 - 2.0 * xc + c2)[:, None, :]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def pq_pairwise(x: jax.Array, codebook: jax.Array, *, block_n: int = 512,
                interpret: bool | None = None) -> jax.Array:
    """(N, M, dsub) × (M, K, dsub) → (N, M, K) f32 squared distances.

    ``interpret=None`` autodetects via kernels.ops.default_interpret.
    """
    if interpret is None:
        from repro.kernels.ops import default_interpret
        interpret = default_interpret()
    n, m, dsub = x.shape
    _, k, _ = codebook.shape
    n_pad = (-n) % block_n
    xp = jnp.pad(x, ((0, n_pad), (0, 0), (0, 0))) if n_pad else x
    grid = (xp.shape[0] // block_n, m)
    out = pl.pallas_call(
        _pq_pairwise_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, 1, dsub), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, k, dsub), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, 1, k), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], m, k), jnp.float32),
        interpret=interpret,
    )(xp, codebook)
    return out[:n]
