"""Functional optimizers + LR schedules (optax-free, shardable pytrees).

Design notes
------------
* An :class:`Optimizer` is a pair of pure functions ``init`` / ``update``.
  State is a plain pytree, so under ``jax.jit`` it inherits the params'
  sharding (FSDP shards optimizer slots for free).
* ``slot_dtype`` lets large models (llama3-405b on a 256-chip pod) keep the
  Adam moments in bf16 — the difference between fitting in 16 GB HBM/chip or
  not (see EXPERIMENTS.md §Perf).
* ``one_cycle`` is the schedule prescribed by the RPQ paper (§6: Adam,
  lr=1e-3, one-cycle, decay rate 0.2).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.common.treeutil import global_norm

Schedule = Callable[[jax.Array], jax.Array]  # step -> lr multiplier/value


# --------------------------------------------------------------------------
# Schedules
# --------------------------------------------------------------------------

def constant_schedule(lr: float) -> Schedule:
    def sched(step):
        return jnp.asarray(lr, jnp.float32)
    return sched


def cosine_schedule(lr: float, total_steps: int, final_frac: float = 0.0) -> Schedule:
    def sched(step):
        t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1 - final_frac) * cos)
    return sched


def warmup_cosine(lr: float, total_steps: int, warmup_steps: int,
                  final_frac: float = 0.0) -> Schedule:
    def sched(step):
        warm = lr * step / max(warmup_steps, 1)
        t = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)
    return sched


def one_cycle(lr: float, total_steps: int, pct_start: float = 0.3,
              div_factor: float = 25.0, final_div_factor: float = 1e4) -> Schedule:
    """One-cycle LR: linear ramp to `lr`, cosine anneal to lr/final_div_factor.

    Matches the paper's training recipe (§6). `div_factor` sets the starting
    lr = lr / div_factor.
    """
    up_steps = max(int(total_steps * pct_start), 1)
    down_steps = max(total_steps - up_steps, 1)
    lo0 = lr / div_factor
    lo1 = lr / final_div_factor

    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        up = lo0 + (lr - lo0) * jnp.clip(step / up_steps, 0.0, 1.0)
        t = jnp.clip((step - up_steps) / down_steps, 0.0, 1.0)
        down = lo1 + (lr - lo1) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < up_steps, up, down)
    return sched


# --------------------------------------------------------------------------
# Optimizers
# --------------------------------------------------------------------------

class OptState(NamedTuple):
    step: jax.Array
    inner: Any  # optimizer-specific slots (pytree)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], OptState]
    update: Callable[[Any, OptState, Any], tuple[Any, OptState]]
    # update(grads, state, params) -> (new_params, new_state)


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


def sgd(schedule: Schedule, momentum: float = 0.0, nesterov: bool = False,
        weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        if momentum:
            m = jax.tree.map(lambda p: jnp.zeros_like(p), params)
        else:
            m = None
        return OptState(jnp.zeros((), jnp.int32), m)

    def update(grads, state: OptState, params):
        lr = schedule(state.step)
        if weight_decay:
            grads = jax.tree.map(lambda g, p: g + weight_decay * p.astype(g.dtype),
                                 grads, params)
        if momentum:
            m = jax.tree.map(lambda mm, g: momentum * mm + g, state.inner, grads)
            eff = jax.tree.map(lambda mm, g: g + momentum * mm, m, grads) if nesterov else m
        else:
            m, eff = None, grads
        new_params = jax.tree.map(
            lambda p, g: (p - lr * g.astype(jnp.float32)).astype(p.dtype), params, eff)
        return new_params, OptState(state.step + 1, m)

    return Optimizer(init, update)


def adam(schedule: Schedule, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0, slot_dtype: Optional[jnp.dtype] = None,
         chunk_bytes: int = 1 << 62) -> Optimizer:
    """AdamW. `slot_dtype=jnp.bfloat16` halves optimizer memory (405B option).

    The update math always runs in fp32; only the *stored* moments are cast.
    Leaves larger than `chunk_bytes` update under a lax.scan over their
    leading axis. Disabled by default: measured WORSE on the 405B step (scan
    outputs cannot alias their inputs → extra full-size buffers; the fused
    elementwise chain needs no chunking — EXPERIMENTS.md §Perf iter 7).
    """

    def _slot(p):
        dt = slot_dtype or (p.dtype if jnp.issubdtype(p.dtype, jnp.floating) else jnp.float32)
        return jnp.zeros(p.shape, dt)

    def init(params):
        m = jax.tree.map(_slot, params)
        v = jax.tree.map(_slot, params)
        return OptState(jnp.zeros((), jnp.int32), (m, v))

    def update(grads, state: OptState, params):
        m0, v0 = state.inner
        step = state.step + 1
        lr = schedule(state.step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd_math(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
            mhat = m32 / bc1
            vhat = v32 / bc2
            delta = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                delta = delta + weight_decay * p.astype(jnp.float32)
            newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return newp, m32.astype(m.dtype), v32.astype(v.dtype)

        def upd(p, g, m, v):
            big = (p.ndim >= 2 and p.shape[0] > 1
                   and p.size * 4 > chunk_bytes)
            if not big:
                return upd_math(p, g, m, v)
            def body(_, slices):
                return None, upd_math(*slices)
            _, (newp, nm, nv) = jax.lax.scan(body, None, (p, g, m, v))
            return newp, nm, nv

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(m0)
        flat_v = treedef.flatten_up_to(v0)
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_params = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_params, OptState(step, (new_m, new_v))

    return Optimizer(init, update)
