"""Small pytree utilities used across the framework."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_size(tree) -> int:
    """Total number of elements across all leaves."""
    return sum(x.size for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    """Total bytes across all leaves (per logical array, unsharded)."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_zeros_like(tree, dtype=None):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree)


def tree_cast(tree, dtype):
    """Cast every inexact leaf to `dtype`; leave integer leaves alone."""
    def cast(x):
        if jnp.issubdtype(x.dtype, jnp.inexact):
            return x.astype(dtype)
        return x
    return jax.tree.map(cast, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_scale(tree, s):
    return jax.tree.map(lambda x: x * s, tree)


def global_norm(tree) -> jax.Array:
    """L2 norm over all leaves (computed in fp32 for stability)."""
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))
