"""Shared substrate: optimizers, schedules, RNG and pytree helpers.

Nothing in here depends on the rest of the package; everything else depends
on this. No optax/flax in the environment — the optimizer stack is our own
(and is what the 405B FSDP path shards, so owning it is a feature: we control
the dtype/sharding of every slot).
"""

from repro.common.optim import (  # noqa: F401
    adam,
    sgd,
    OptState,
    Optimizer,
    one_cycle,
    constant_schedule,
    cosine_schedule,
    warmup_cosine,
    clip_by_global_norm,
)
from repro.common.treeutil import (  # noqa: F401
    tree_size,
    tree_bytes,
    tree_zeros_like,
    tree_cast,
    tree_add,
    tree_scale,
    global_norm,
)
