"""Dry-run cell builders: (arch × input-shape × mesh) → lowerable closure.

Each cell bundles a jittable step function, ShapeDtypeStruct inputs (the
`input_specs()` of the brief — weak-type-correct, shardable, zero
allocation), and in/out shardings from dist/sharding.py. launch/dryrun.py
lowers+compiles every cell and captures memory/cost/collective numbers.

Uneven-dimension note: mesh sharding requires divisible dims, so edge lists
/ candidate pools are padded to multiples of 512 with mask inputs (the real
data pipeline does the same padding), and LM vocabs use cfg.vocab_padded.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common import adam, constant_schedule, sgd
from repro.configs import get_arch
from repro.dist import sharding as shd
from repro.launch.mesh import data_axes


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _pad_to(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    fn: Callable
    args: tuple
    in_shardings: tuple
    out_shardings: Any
    donate: tuple = ()
    meta: dict = dataclasses.field(default_factory=dict)

    def lower(self, mesh):
        with mesh:
            jitted = jax.jit(self.fn, in_shardings=self.in_shardings,
                             out_shardings=self.out_shardings,
                             donate_argnums=self.donate)
            return jitted.lower(*self.args)


# ==========================================================================
# LM family
# ==========================================================================

def _lm_cell(arch_id: str, shape, mesh) -> Cell:
    from repro.models import transformer as tf

    spec = get_arch(arch_id)
    cfg = spec.make_config()
    dims = shape.dims
    dp = data_axes(mesh)
    n_dp = _n_dp(mesh)
    # pin activation batch-sharding; fit microbatch count to the mesh
    # (per-microbatch batch must divide the dp axes)
    if shape.kind == "train":
        mb = cfg.microbatches
        while mb > 1 and dims["global_batch"] % (mb * n_dp):
            mb //= 2
        cfg = dataclasses.replace(cfg, microbatches=max(mb, 1),
                                  act_batch_axes=tuple(dp))
    else:
        cfg = dataclasses.replace(cfg, act_batch_axes=tuple(dp)
                                  if dims["global_batch"] % n_dp == 0 else None)
    bspec = shd.named(mesh, shd.lm_batch_spec(mesh))

    params_shape = jax.eval_shape(lambda: tf.init_lm(jax.random.PRNGKey(0), cfg))
    meta = {"params": int(cfg.param_count),
            "active_params": int(cfg.active_param_count)}

    if shape.kind == "train":
        pspecs = shd.tree_pspecs(params_shape, shd.lm_param_rule(mesh))
        fns = tf.make_train_step(cfg, param_pspecs=pspecs)
        opt_shape = jax.eval_shape(fns.opt_init, params_shape)
        p_sh, o_sh = shd.lm_shardings(mesh, cfg, params_shape, opt_shape)
        b, s = dims["global_batch"], dims["seq_len"]
        toks = _sds((b, s), jnp.int32)
        fn = fns.train_step
        return Cell(arch_id, shape.name, fn,
                    (params_shape, opt_shape, toks, toks),
                    (p_sh, o_sh, bspec, bspec),
                    (p_sh, o_sh, shd.named(mesh, P())),
                    donate=(0, 1),
                    meta={**meta, "tokens": b * s, "mode": "train"})

    p_sh, _ = shd.lm_shardings(mesh, cfg, params_shape,
                               jax.eval_shape(lambda p: p, params_shape))

    if shape.kind == "prefill":
        b, s = dims["global_batch"], dims["seq_len"]
        toks = _sds((b, s), jnp.int32)
        cache_spec = shd.named(mesh, shd.lm_cache_spec(mesh, b, s))
        fn = lambda params, tokens: tf.prefill(cfg, params, tokens, max_len=s)
        out_sh = (shd.named(mesh, P(dp, "model")),
                  tf.KVCache(k=cache_spec, v=cache_spec,
                             length=shd.named(mesh, P())))
        return Cell(arch_id, shape.name, fn, (params_shape, toks),
                    (p_sh, bspec), out_sh,
                    meta={**meta, "tokens": b * s, "mode": "prefill"})

    # decode (decode_32k, long_500k): one token against a seq_len KV cache
    b, s = dims["global_batch"], dims["seq_len"]
    cache_p = shd.lm_cache_spec(mesh, b, s)
    cache_spec = shd.named(mesh, cache_p)
    cache_shape = tf.KVCache(
        k=_sds((cfg.n_layers, b, s, cfg.n_kv_heads, cfg.d_head), cfg.dtype),
        v=_sds((cfg.n_layers, b, s, cfg.n_kv_heads, cfg.d_head), cfg.dtype),
        length=_sds((), jnp.int32))
    cache_sh = tf.KVCache(k=cache_spec, v=cache_spec,
                          length=shd.named(mesh, P()))
    # pin decode attention's softmax to the cache's sequence sharding
    # (single axis "model" for batched decode; all axes for long_500k)
    cfg = dataclasses.replace(cfg, act_seq_axis=cache_p[2])
    tok_spec = shd.named(mesh, P(dp) if b % _n_dp(mesh) == 0 else P())
    toks = _sds((b,), jnp.int32)
    fn = lambda params, cache, tokens: tf.decode_step(cfg, params, cache, tokens)
    logit_sh = shd.named(mesh,
                         P(dp, "model") if b % _n_dp(mesh) == 0 else P(None, "model"))
    return Cell(arch_id, shape.name, fn, (params_shape, cache_shape, toks),
                (p_sh, cache_sh, tok_spec), (logit_sh, cache_sh),
                donate=(1,),
                meta={**meta, "tokens": b, "kv_len": s, "mode": "decode"})


def _n_dp(mesh) -> int:
    n = 1
    for a in data_axes(mesh):
        n *= mesh.shape[a]
    return n


# ==========================================================================
# GNN family (gat-cora): 4 shapes with different graph regimes
# ==========================================================================

def _gnn_cell(arch_id: str, shape, mesh) -> Cell:
    from repro.models import gnn

    spec = get_arch(arch_id)
    dims = shape.dims
    dp = data_axes(mesh)
    n_dev = _n_dp(mesh) * mesh.shape["model"]
    edge_spec = shd.named(mesh, shd.gnn_edge_spec(mesh))
    rep = shd.named(mesh, P())
    optimizer = adam(constant_schedule(5e-3))

    if shape.name in ("full_graph_sm", "ogb_products"):
        n, e = dims["n_nodes"], dims["n_edges"]
        d_feat = dims.get("d_feat", 1433)
        cfg = dataclasses.replace(spec.make_config(), d_in=d_feat,
                                  n_classes=47 if shape.name == "ogb_products" else 7)
        e_pad = _pad_to(e, n_dev)
        params_shape = jax.eval_shape(lambda: gnn.init_gat(jax.random.PRNGKey(0), cfg))
        opt_shape = jax.eval_shape(optimizer.init, params_shape)

        def fn(params, opt_state, x, src, dst, emask, labels, lmask):
            loss, g = jax.value_and_grad(
                lambda p: gnn.node_loss(cfg, p, x, src, dst, labels, lmask,
                                        edge_mask=emask))(params)
            params, opt_state = optimizer.update(g, opt_state, params)
            return params, opt_state, loss

        args = (params_shape, opt_shape, _sds((n, d_feat), jnp.float32),
                _sds((e_pad,), jnp.int32), _sds((e_pad,), jnp.int32),
                _sds((e_pad,), jnp.bool_), _sds((n,), jnp.int32),
                _sds((n,), jnp.bool_))
        p_sh = shd.tree_shardings(mesh, params_shape, lambda p, l: P())
        o_sh = shd.tree_shardings(mesh, opt_shape, lambda p, l: P())
        return Cell(arch_id, shape.name, fn, args,
                    (p_sh, o_sh, rep, edge_spec, edge_spec, edge_spec, rep, rep),
                    (p_sh, o_sh, rep), donate=(0, 1),
                    meta={"mode": "train", "edges": e, "nodes": n})

    if shape.name == "minibatch_lg":
        # Reddit-scale fanout-sampled block (d_feat=602, fanout 15×10)
        cfg = dataclasses.replace(spec.make_config(), d_in=602, n_classes=41)
        b = dims["batch_nodes"]
        f1, f2 = dims["fanout"]
        n_slots = _pad_to(b * (1 + f1 + f1 * f2) * 2, n_dev)
        e_slots = _pad_to(b * f1 + b * f1 * f2, n_dev)
        params_shape = jax.eval_shape(lambda: gnn.init_gat(jax.random.PRNGKey(0), cfg))
        opt_shape = jax.eval_shape(optimizer.init, params_shape)

        def fn(params, opt_state, feats, src, dst, emask, seed_local, labels):
            def loss_fn(p):
                h = gnn.forward(cfg, p, feats, src, dst, edge_mask=emask)
                sel = h[seed_local]
                logp = jax.nn.log_softmax(sel.astype(jnp.float32), -1)
                return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], 1))
            loss, g = jax.value_and_grad(loss_fn)(params)
            params, opt_state = optimizer.update(g, opt_state, params)
            return params, opt_state, loss

        args = (params_shape, opt_shape, _sds((n_slots, 602), jnp.float32),
                _sds((e_slots,), jnp.int32), _sds((e_slots,), jnp.int32),
                _sds((e_slots,), jnp.bool_), _sds((b,), jnp.int32),
                _sds((b,), jnp.int32))
        p_sh = shd.tree_shardings(mesh, params_shape, lambda p, l: P())
        o_sh = shd.tree_shardings(mesh, opt_shape, lambda p, l: P())
        return Cell(arch_id, shape.name, fn, args,
                    (p_sh, o_sh, rep, edge_spec, edge_spec, edge_spec, rep, rep),
                    (p_sh, o_sh, rep), donate=(0, 1),
                    meta={"mode": "train", "edges": e_slots, "nodes": n_slots})

    # molecule: batched small graphs, graph-level prediction
    cfg = dataclasses.replace(spec.make_config(), d_in=64, n_classes=2)
    b, n_per, e_per = dims["batch"], dims["n_nodes"], dims["n_edges"]
    n = _pad_to(b * n_per, n_dev)
    e = _pad_to(b * e_per, n_dev)
    params_shape = jax.eval_shape(lambda: gnn.init_gat(jax.random.PRNGKey(0), cfg))
    opt_shape = jax.eval_shape(optimizer.init, params_shape)

    def fn(params, opt_state, x, src, dst, emask, graph_id, y):
        loss, g = jax.value_and_grad(
            lambda p: gnn.graph_pool_loss(cfg, p, x, src, dst, graph_id, b, y,
                                          edge_mask=emask))(params)
        params, opt_state = optimizer.update(g, opt_state, params)
        return params, opt_state, loss

    args = (params_shape, opt_shape, _sds((n, 64), jnp.float32),
            _sds((e,), jnp.int32), _sds((e,), jnp.int32), _sds((e,), jnp.bool_),
            _sds((n,), jnp.int32), _sds((b,), jnp.int32))
    p_sh = shd.tree_shardings(mesh, params_shape, lambda p, l: P())
    o_sh = shd.tree_shardings(mesh, opt_shape, lambda p, l: P())
    return Cell(arch_id, shape.name, fn, args,
                (p_sh, o_sh, rep, edge_spec, edge_spec, edge_spec, rep, rep),
                (p_sh, o_sh, rep), donate=(0, 1),
                meta={"mode": "train", "edges": e, "nodes": n})


# ==========================================================================
# Recsys family
# ==========================================================================

def _recsys_batch_specs(arch_id: str, cfg, batch: int):
    if arch_id == "dlrm-mlperf":
        return {"dense": _sds((batch, cfg.n_dense), jnp.float32),
                "sparse": _sds((batch, cfg.n_sparse), jnp.int32),
                "label": _sds((batch,), jnp.float32)}
    if arch_id == "deepfm":
        return {"sparse": _sds((batch, cfg.n_fields), jnp.int32),
                "label": _sds((batch,), jnp.float32)}
    if arch_id == "din":
        return {"hist": _sds((batch, cfg.seq_len), jnp.int32),
                "hist_mask": _sds((batch, cfg.seq_len), jnp.bool_),
                "target": _sds((batch,), jnp.int32),
                "label": _sds((batch,), jnp.float32)}
    # bert4rec: MLM batch (20 masked positions of 200)
    return {"items": _sds((batch, cfg.seq_len), jnp.int32),
            "pad_mask": _sds((batch, cfg.seq_len), jnp.bool_),
            "mlm_positions": _sds((batch, 20), jnp.int32),
            "mlm_labels": _sds((batch, 20), jnp.int32)}


def _recsys_forward(arch_id: str, cfg):
    from repro.models import recsys as rs

    if arch_id == "dlrm-mlperf":
        return lambda p, b: rs.dlrm_forward(cfg, p, b["dense"], b["sparse"])
    if arch_id == "deepfm":
        return lambda p, b: rs.deepfm_forward(cfg, p, b["sparse"])
    if arch_id == "din":
        return lambda p, b: rs.din_forward(cfg, p, b["hist"], b["hist_mask"],
                                           b["target"])
    return None  # bert4rec handled via MLM loss


def _recsys_loss(arch_id: str, cfg, mesh=None):
    from repro.models import recsys as rs

    if arch_id == "bert4rec":
        lspec = P(data_axes(mesh), None, "model") if mesh is not None else None
        return lambda p, b: rs.bert4rec_mlm_loss(
            cfg, p, b["items"], b["pad_mask"], b["mlm_positions"],
            b["mlm_labels"], logit_pspec=lspec)
    fwd = _recsys_forward(arch_id, cfg)
    return lambda p, b: rs.bce_loss(fwd(p, b), b["label"])


def _is_table(path: str) -> bool:
    return "table" in path or "item_emb" in path


def _recsys_cell(arch_id: str, shape, mesh) -> Cell:
    from repro.models import recsys as rs

    spec = get_arch(arch_id)
    cfg = spec.make_config()
    dims = shape.dims
    dp = data_axes(mesh)
    table_axes = "all" if arch_id == "dlrm-mlperf" else "model"
    init_fn = {"dlrm-mlperf": rs.init_dlrm, "deepfm": rs.init_deepfm,
               "din": rs.init_din, "bert4rec": rs.init_bert4rec}[arch_id]
    params_shape = jax.eval_shape(
        lambda: init_fn(jax.random.PRNGKey(0), cfg))
    bsp = lambda leaf: shd.named(mesh, P(dp, *([None] * (leaf.ndim - 1))))

    if shape.kind == "train":
        batch = dims["batch"]
        loss_fn = _recsys_loss(arch_id, cfg, mesh)
        optimizer = adam(constant_schedule(1e-3))

        def fn(params, opt_state, batch_in):
            loss, g = jax.value_and_grad(lambda p: loss_fn(p, batch_in))(params)
            params, opt_state = optimizer.update(g, opt_state, params)
            return params, opt_state, loss

        opt_shape = jax.eval_shape(optimizer.init, params_shape)
        p_sh, o_sh = shd.recsys_shardings(mesh, params_shape, opt_shape,
                                          table_axes=table_axes)
        batch_specs = _recsys_batch_specs(arch_id, cfg, batch)
        b_sh = {k: bsp(v) for k, v in batch_specs.items()}
        return Cell(arch_id, shape.name, fn,
                    (params_shape, opt_shape, batch_specs),
                    (p_sh, o_sh, b_sh),
                    (p_sh, o_sh, shd.named(mesh, P())), donate=(0, 1),
                    meta={"mode": "train", "batch": batch})

    if shape.kind == "serve":
        batch = dims["batch"]
        p_sh, _ = shd.recsys_shardings(mesh, params_shape, params_shape,
                                       table_axes=table_axes)
        if arch_id == "bert4rec":
            def fn(params, b):
                h = rs.bert4rec_encode(cfg, params, b["items"], b["pad_mask"])
                return (h[:, -1] @ params["item_emb"].T).astype(jnp.float32)
            batch_specs = {k: v for k, v in
                           _recsys_batch_specs(arch_id, cfg, batch).items()
                           if k in ("items", "pad_mask")}
        else:
            fwd = _recsys_forward(arch_id, cfg)
            fn = lambda params, b: fwd(params, b)
            batch_specs = {k: v for k, v in
                           _recsys_batch_specs(arch_id, cfg, batch).items()
                           if k != "label"}
        b_sh = {k: bsp(v) for k, v in batch_specs.items()}
        return Cell(arch_id, shape.name, fn, (params_shape, batch_specs),
                    (p_sh, b_sh), None,
                    meta={"mode": "serve", "batch": batch})

    # retrieval_cand: 1 query × 1M candidates (exact-dot baseline path)
    n_cand = _pad_to(dims["n_candidates"],
                     _n_dp(mesh) * mesh.shape["model"])
    d_emb = {"dlrm-mlperf": 128, "deepfm": 10, "din": 18,
             "bert4rec": 64}[arch_id]

    def fn(cand_emb, query):
        return rs.score_candidates_exact(query, cand_emb, k=100)

    cand = _sds((n_cand, d_emb), jnp.float32)
    q = _sds((d_emb,), jnp.float32)
    cand_sh = shd.named(mesh, shd.rpq_rows_spec(mesh))
    return Cell(arch_id, shape.name, fn, (cand, q),
                (cand_sh, shd.named(mesh, P())), None,
                meta={"mode": "retrieval", "n_candidates": n_cand,
                      "d_emb": d_emb})


# ==========================================================================
# RPQ (the paper's system)
# ==========================================================================

def _rpq_cell(arch_id: str, shape, mesh) -> Cell:
    from repro.core import quantizer as Q

    spec = get_arch(arch_id)
    acfg = spec.make_config()
    qcfg = acfg.quant
    dp = data_axes(mesh)
    dims = shape.dims
    n_dev = _n_dp(mesh) * mesh.shape["model"]

    params_shape = jax.eval_shape(
        lambda: Q.init_params(qcfg, jnp.zeros((qcfg.m, qcfg.k, qcfg.dsub))))
    p_sh = shd.rpq_param_spec(mesh, params_shape)
    rep = shd.named(mesh, P())

    if shape.name == "quant_train":
        b, rb, h = dims["batch"], dims["routing_batch"], dims["h"]
        optimizer = adam(constant_schedule(1e-3))
        opt_shape = jax.eval_shape(optimizer.init, params_shape)
        o_sh = shd.tree_shardings(mesh, opt_shape, lambda p, l: P())

        def fn(params, opt_state, trip_x, route_q, route_cand, route_label, key):
            def loss_fn(p):
                kt, kr = jax.random.split(key)
                xa = Q.quantize_st(qcfg, p, trip_x[:, 0], kt)
                xp = Q.quantize_st(qcfg, p, trip_x[:, 1], kt)
                xn = Q.quantize_st(qcfg, p, trip_x[:, 2], kt)
                dpd = jnp.sum((xa - xp) ** 2, -1)
                dnd = jnp.sum((xa - xn) ** 2, -1)
                scale = jax.lax.stop_gradient(jnp.mean(dpd) + 1e-9)
                ln = jnp.mean(jnp.maximum(0.0, 1.0 + (dpd - dnd) / scale))
                bq, hh, d = route_cand.shape
                xq = Q.quantize_st(qcfg, p, route_cand.reshape(bq * hh, d),
                                   kr).reshape(bq, hh, d)
                r = Q.rotation_matrix(qcfg, p)
                qrot = route_q @ r.T
                dd = jnp.sum((xq - qrot[:, None, :]) ** 2, -1)
                logits = -dd / (jax.lax.stop_gradient(jnp.std(dd) + 1e-9))
                logp = jax.nn.log_softmax(logits, -1)
                lr_ = -jnp.mean(jnp.take_along_axis(
                    logp, route_label[:, None], 1))
                s = p.log_alpha
                return lr_ + jnp.exp(-s) * ln + s
            loss, g = jax.value_and_grad(loss_fn)(params)
            params, opt_state = optimizer.update(g, opt_state, params)
            return params, opt_state, loss

        args = (params_shape, opt_shape,
                _sds((b, 3, qcfg.dim), jnp.float32),
                _sds((rb, qcfg.dim), jnp.float32),
                _sds((rb, h, qcfg.dim), jnp.float32),
                _sds((rb,), jnp.int32),
                jax.eval_shape(lambda: jax.random.PRNGKey(0)))
        bspec = lambda nd: shd.named(mesh, P(dp, *([None] * (nd - 1))))
        return Cell(arch_id, shape.name, fn, args,
                    (p_sh, o_sh, bspec(3), bspec(2), bspec(3), bspec(1), rep),
                    (p_sh, o_sh, rep), donate=(0, 1),
                    meta={"mode": "train", "batch": b})

    if shape.name == "encode_bulk":
        n = _pad_to(dims["batch"], n_dev)
        fn = lambda params, x: Q.encode(qcfg, params, x, backend="ref")
        rows = shd.named(mesh, shd.rpq_rows_spec(mesh))
        return Cell(arch_id, shape.name, fn,
                    (params_shape, _sds((n, qcfg.dim), jnp.float32)),
                    (p_sh, rows), rows, meta={"mode": "serve", "n": n})

    # The scatter-gather bodies live in search/engine.py — the SAME
    # implementation ShardedEngine serves with; these cells only prove it
    # lowers/compiles on the production meshes.
    from repro.search import engine as se

    all_axes = shd.row_axes(mesh)

    if shape.name == "adc_bulk":
        # scatter-gather ADC: each shard scans its code rows and returns a
        # LOCAL top-k; the merge concatenates per-shard candidates and
        # re-top-ks — O(shards·k) instead of gathering the (Q, N) distance
        # matrix (GSPMD's sharded top_k gathered it: 8.2 GB/dev → MBs).
        n = _pad_to(dims["n_codes"], n_dev)
        qb = dims["query_batch"]
        kk = 10

        def fn(codes, luts):
            gids, dists = se.sharded_adc_scan(mesh, all_axes, codes, luts,
                                              k=kk)
            return se.merge_shard_topk(gids, dists, kk)

        rows = shd.named(mesh, shd.rpq_rows_spec(mesh))
        return Cell(arch_id, shape.name, fn,
                    (_sds((n, qcfg.m), jnp.uint8),
                     _sds((qb, qcfg.m, qcfg.k), jnp.float32)),
                    (rows, shd.named(mesh, P())), None,
                    meta={"mode": "retrieval", "n_codes": n, "queries": qb})

    if shape.name in ("sharded_graph", "sharded_graph_fs4",
                      "sharded_graph_wide"):
        # graph-ROUTED scatter-gather: every shard beam-searches its OWN
        # Vamana subgraph inside shard_map (O(hops·R) distance work per
        # query per shard instead of the adc_bulk scan's O(N/S)); the merge
        # is the same O(shards·k) shortlist gather. Compiles the SAME
        # sharded_graph_topk that ShardedGraphEngine serves with. The fs4
        # variant feeds the fast-scan layout (DESIGN.md §8): 4-bit packed
        # codes at ceil(M/2) bytes/row + a pq.pack.QuantizedLUT pytree.
        # The _wide variant proves the frontier-batched beam (DESIGN.md §9):
        # expand=4 over an R=64 subgraph, so every round feeds one
        # E·R = 256-wide fused hop-ADC call.
        from repro.pq.pack import QuantizedLUT, packed_width

        n = _pad_to(dims["n_base"], n_dev)
        qb, kk, hh, rr = (dims["query_batch"], dims["k"], dims["h"],
                          dims["r"])
        ee = dims.get("expand", 1)
        n_local = n // n_dev
        fs4 = shape.name.endswith("_fs4")

        def fn(neighbors, medoids, codes, luts):
            gids, dists, hops, ndist, rounds, _trunc = se.sharded_graph_topk(
                mesh, all_axes, neighbors, medoids, codes, luts, k=kk,
                h=hh, max_steps=4 * hh, expand=ee)
            ids, ds = se.merge_shard_topk(gids, dists, kk)
            return ids, ds, hops, ndist, rounds

        rep = shd.named(mesh, P())
        if fs4:
            m_codes = packed_width(qcfg.m)
            luts_spec = QuantizedLUT(
                lut=_sds((qb, qcfg.m, 16), jnp.uint8),
                scale=_sds((qb,), jnp.float32),
                bias=_sds((qb,), jnp.float32))
            luts_sh = QuantizedLUT(lut=rep, scale=rep, bias=rep)
        else:
            m_codes = qcfg.m
            luts_spec = _sds((qb, qcfg.m, qcfg.k), jnp.float32)
            luts_sh = rep
        rows3 = shd.named(mesh, shd.rpq_shard_stack_spec(mesh))
        shards1 = shd.named(mesh, shd.rpq_shard_stack_spec(mesh, 1))
        return Cell(arch_id, shape.name, fn,
                    (_sds((n_dev, n_local, rr), jnp.int32),
                     _sds((n_dev,), jnp.int32),
                     _sds((n_dev, n_local, m_codes), jnp.uint8),
                     luts_spec),
                    (rows3, shards1, rows3, luts_sh), None,
                    meta={"mode": "serve", "n_base": n, "queries": qb,
                          "beam_h": hh, "graph_r": rr, "expand": ee,
                          "layout": "fs4" if fs4 else "u8"})

    # serve_1m: scatter-gather ADC + LOCAL exact rerank per shard, then a
    # global top-k merge (DiskANN-style shortlist, faiss-style distribution)
    n = _pad_to(dims["n_base"], n_dev)
    qb = dims["query_batch"]
    kk = dims["k"]

    def fn(codes, vectors, luts, queries):
        gids, dists = se.sharded_adc_serve(mesh, all_axes, codes, vectors,
                                           luts, queries, k=kk,
                                           shortlist=4 * kk)
        return se.merge_shard_topk(gids, dists, kk)

    rows = shd.named(mesh, shd.rpq_rows_spec(mesh))
    return Cell(arch_id, shape.name, fn,
                (_sds((n, qcfg.m), jnp.uint8),
                 _sds((n, qcfg.dim), jnp.float32),
                 _sds((qb, qcfg.m, qcfg.k), jnp.float32),
                 _sds((qb, qcfg.dim), jnp.float32)),
                (rows, rows, shd.named(mesh, P()), shd.named(mesh, P())),
                None,
                meta={"mode": "serve", "n_base": n, "queries": qb})


# ==========================================================================
# dispatcher
# ==========================================================================

def build_cell(arch_id: str, shape_name: str, mesh) -> Cell:
    spec = get_arch(arch_id)
    shape = next(s for s in spec.shapes if s.name == shape_name)
    if spec.family == "lm":
        return _lm_cell(arch_id, shape, mesh)
    if spec.family == "gnn":
        return _gnn_cell(arch_id, shape, mesh)
    if spec.family == "recsys":
        return _recsys_cell(arch_id, shape, mesh)
    if spec.family == "rpq":
        return _rpq_cell(arch_id, shape, mesh)
    raise KeyError(spec.family)


def all_cells() -> list[tuple[str, str]]:
    from repro.configs import list_archs
    out = []
    for a in list_archs():
        for s in get_arch(a).shapes:
            out.append((a, s.name))
    return out
