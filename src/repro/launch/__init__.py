"""Launchers: mesh builders, multi-pod dryrun, train/serve drivers."""
