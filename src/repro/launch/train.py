"""Fault-tolerant end-to-end RPQ training driver (the paper's pipeline).

    PYTHONPATH=src python -m repro.launch.train \
        --dataset sift-small --steps 400 --ckpt-dir runs/rpq \
        --checkpoint-every 50 [--fail-at-step 120] [--resume]

Builds (or loads) the dataset + Vamana PG, then runs the multi-feature
joint training with atomic checkpointing; on restart (--resume or the
supervise() wrapper after an injected failure) it continues from the
latest checkpoint — the restart is bit-identical (tests/test_dist.py).
"""

from __future__ import annotations

import argparse
import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import RPQConfig, TrainConfig
from repro.core import trainer as T
from repro.data import load_dataset
from repro.dist import checkpoint as ckpt
from repro.dist.fault import FailureInjector, supervise
from repro.graphs import build_vamana
from repro.pq import base as pqbase
from repro.search.engine import HybridEngine
from repro.search.metrics import recall_at_k
from repro.graphs.knn import knn_ids


def build_or_load_graph(key, x, cache_path: str, r: int, l: int):
    if cache_path and os.path.exists(cache_path):
        z = np.load(cache_path)
        from repro.graphs.adjacency import Graph
        return Graph(neighbors=jnp.asarray(z["neighbors"]),
                     medoid=jnp.asarray(z["medoid"]))
    g = build_vamana(key, x, r=r, l=l)
    if cache_path:
        os.makedirs(os.path.dirname(cache_path) or ".", exist_ok=True)
        np.savez(cache_path, neighbors=np.asarray(g.neighbors),
                 medoid=np.asarray(g.medoid))
    return g


def run(args) -> dict:
    key = jax.random.PRNGKey(args.seed)
    ds = load_dataset(args.dataset, scale=args.scale)
    x = ds.train
    kg, kt = jax.random.split(key)
    graph = build_or_load_graph(
        kg, x, os.path.join(args.ckpt_dir, "graph.npz"), args.graph_r,
        args.graph_l)

    cfg = RPQConfig(dim=x.shape[1], m=args.m, k=args.k)
    tcfg = TrainConfig(steps=args.steps, refresh_every=args.refresh_every,
                       triplet_batch=args.batch, routing_batch=args.batch,
                       routing_pool_queries=args.routing_queries,
                       log_every=args.log_every)

    params = None
    opt_state = None
    start_step = 0
    if args.resume or ckpt.latest_step(args.ckpt_dir) is not None:
        step = ckpt.latest_step(args.ckpt_dir)
        if step is not None:
            params_t = T.init_rpq(jax.random.PRNGKey(0), cfg, x[:512],
                                  kmeans_iters=1)  # template only
            from repro.common import adam, one_cycle
            opt_t = adam(one_cycle(tcfg.lr, tcfg.steps)).init(params_t)
            state = ckpt.restore(args.ckpt_dir, step,
                                 like={"params": params_t, "opt": opt_t})
            params, opt_state, start_step = (state["params"], state["opt"],
                                             state["step"] + 1)
            print(f"[train] resumed from step {state['step']}")

    injector = FailureInjector(fail_at_step=args.fail_at_step)
    args.fail_at_step = None  # one-shot: a restarted (replaced) node must
    #                           not re-crash at the same step

    def checkpoint_cb(step, p, o):
        injector.maybe_fail(step)
        if step % args.checkpoint_every == 0 and step > 0:
            ckpt.save(args.ckpt_dir, step, keep=args.keep, params=p, opt=o,
                      extra={"dataset": args.dataset, "m": args.m, "k": args.k})

    state = T.fit(kt, cfg, tcfg, x, graph, params=params,
                  opt_state=opt_state, start_step=start_step,
                  checkpoint_cb=checkpoint_cb, verbose=not args.quiet)
    ckpt.save(args.ckpt_dir, tcfg.steps, keep=args.keep, params=state.params,
              opt=state.opt_state,
              extra={"final": True, "dataset": args.dataset, "m": args.m,
                     "k": args.k})

    # final evaluation: hybrid (DiskANN) serving on the base set
    model = T.to_model(cfg, state.params)
    codes = pqbase.encode(model, ds.base)
    engine = HybridEngine(graph if ds.base.shape[0] == x.shape[0] else
                          build_or_load_graph(kg, ds.base,
                                              os.path.join(args.ckpt_dir, "graph_base.npz"),
                                              args.graph_r, args.graph_l),
                          codes, lambda q: pqbase.build_lut(model, q),
                          vectors=ds.base)
    gt, _ = knn_ids(ds.base, ds.queries, 10)
    res = engine.search(ds.queries, k=10, h=args.beam)
    rec = recall_at_k(res.ids, gt, 10)
    print(f"[train] final recall@10={rec:.4f} mean hops={float(res.hops.mean()):.1f}")
    return {"recall": rec, "history": state.history}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="sift-small")
    ap.add_argument("--scale", type=float, default=None)
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--m", type=int, default=8)
    ap.add_argument("--k", type=int, default=64)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--routing-queries", type=int, default=64)
    ap.add_argument("--refresh-every", type=int, default=100)
    ap.add_argument("--graph-r", type=int, default=24)
    ap.add_argument("--graph-l", type=int, default=48)
    ap.add_argument("--beam", type=int, default=48)
    ap.add_argument("--ckpt-dir", default="runs/rpq")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--keep", type=int, default=3)
    ap.add_argument("--log-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at-step", type=int, default=None,
                    help="inject a crash at this step (fault-tolerance demo)")
    ap.add_argument("--max-restarts", type=int, default=3)
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()

    def attempt():
        return run(args)

    result, restarts = supervise(
        attempt, max_restarts=args.max_restarts,
        on_restart=lambda n, e: print(f"[supervise] restart {n} after: {e}"))
    if restarts:
        print(f"[supervise] completed after {restarts} restart(s)")
    return result


if __name__ == "__main__":
    main()
