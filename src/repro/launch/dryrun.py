import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: lower + compile EVERY (arch × input-shape) cell on the
16×16 single-pod mesh and the 2×16×16 multi-pod mesh, and capture

  * compiled.memory_analysis()  — per-device bytes (proves it fits),
  * compiled.cost_analysis()    — per-device FLOPs/bytes for §Roofline,
  * collective bytes parsed from the partitioned HLO (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute),

into a JSON report consumed by benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                    # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b
  PYTHONPATH=src python -m repro.launch.dryrun --arch rpq --shape serve_1m \
      --multi-pod-only --out reports/dryrun.json
"""

import argparse
import json
import re
import time
import traceback


_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*=\s*"
    r"((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\]))", re.I)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in partitioned HLO.

    Shapes in the partitioned module are PER-DEVICE shard shapes, so the
    sum is per-device collective traffic (matches the roofline convention
    collective_bytes / (chips × link_bw) when multiplied back by chips —
    we report per-device directly).
    """
    out: dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        kind = m.group(1).lower()
        total = 0
        for sm in _SHAPE_RE.finditer(m.group(2)):
            dt, dims = sm.group(1), sm.group(2)
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0) + total
        out["count_" + kind] = out.get("count_" + kind, 0) + 1
    out["total"] = sum(v for k, v in out.items()
                       if not k.startswith("count_"))
    return out


def run_cell(arch: str, shape: str, mesh, mesh_name: str) -> dict:
    from repro.launch.cells import build_cell

    rec = {"arch": arch, "shape": shape, "mesh": mesh_name}
    t0 = time.time()
    try:
        cell = build_cell(arch, shape, mesh)
        lowered = cell.lower(mesh)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):  # jax<=0.4.x: one dict per comp
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
        rec.update(
            ok=True,
            compile_s=round(time.time() - t0, 2),
            meta=cell.meta,
            memory=dict(
                argument_bytes=int(mem.argument_size_in_bytes),
                output_bytes=int(mem.output_size_in_bytes),
                temp_bytes=int(mem.temp_size_in_bytes),
                alias_bytes=int(mem.alias_size_in_bytes),
                code_bytes=int(mem.generated_code_size_in_bytes),
            ),
            cost=dict(
                flops=float(cost.get("flops", 0.0)),
                bytes_accessed=float(cost.get("bytes accessed", 0.0)),
            ),
            collectives=collective_bytes(hlo),
        )
    except Exception as e:  # a failing cell is a bug — record it loudly
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:],
                   compile_s=round(time.time() - t0, 2))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--out", default="reports/dryrun.json")
    ap.add_argument("--append", action="store_true")
    args = ap.parse_args()

    from repro.launch.cells import all_cells
    from repro.launch.mesh import make_production_mesh

    cells = all_cells()
    if args.arch:
        cells = [c for c in cells if c[0] == args.arch]
    if args.shape:
        cells = [c for c in cells if c[1] == args.shape]

    meshes = []
    if not args.multi_pod_only:
        meshes.append(("1pod_16x16", make_production_mesh(multi_pod=False)))
    if not args.single_pod_only:
        meshes.append(("2pod_2x16x16", make_production_mesh(multi_pod=True)))

    results = []
    if args.append and os.path.exists(args.out):
        results = json.load(open(args.out))
        done = {(r["arch"], r["shape"], r["mesh"]) for r in results if r["ok"]}
    else:
        done = set()

    for mesh_name, mesh in meshes:
        for arch, shape in cells:
            if (arch, shape, mesh_name) in done:
                continue
            rec = run_cell(arch, shape, mesh, mesh_name)
            status = "OK " if rec["ok"] else "FAIL"
            mem_gb = (rec.get("memory", {}).get("argument_bytes", 0)
                      + rec.get("memory", {}).get("temp_bytes", 0)) / 1e9
            print(f"[{status}] {mesh_name:13s} {arch:22s} {shape:14s} "
                  f"compile={rec['compile_s']:7.2f}s perdev={mem_gb:7.2f}GB "
                  f"{'' if rec['ok'] else rec.get('error', '')[:120]}",
                  flush=True)
            results = [r for r in results
                       if not (r["arch"] == arch and r["shape"] == shape
                               and r["mesh"] == mesh_name)]
            results.append(rec)
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)

    n_ok = sum(r["ok"] for r in results)
    print(f"\n{n_ok}/{len(results)} cells compiled; report → {args.out}")
    return 0 if n_ok == len(results) else 1


if __name__ == "__main__":
    raise SystemExit(main())
