"""ANN serving driver: load a trained RPQ checkpoint and serve queries.

    PYTHONPATH=src python -m repro.launch.serve --ckpt-dir runs/rpq \
        --dataset sift-small \
        [--scenario hybrid|memory|sharded|sharded-graph|streaming|disk] \
        [--codes u8|fs4] [--h 32] [--entries 8] [--prune-eps 0.1] \
        [--cache-mb 4] [--io-threads 4] [--port-stdin]

``--entries S`` / ``--prune-eps ε`` switch on adaptive routing (DESIGN.md
§11) in every scenario: S > 1 seeds each beam from the PQ-hash coarse
index instead of the single medoid, ε > 0 gates each hop's full ADC pass
behind a partial-LUT estimate. Both default OFF (S=1, ε=0 — bit-identical
to the classic beam). The graph-free ``sharded`` scan has no beam and
ignores them.

``--codes fs4`` serves the fast-scan layout (DESIGN.md §8) — 4-bit packed
codes + quantized uint8 LUTs — through ANY scenario; it needs a quantizer
trained with K ≤ 16 sub-codewords (e.g. ``train.py --m 16 --k 16`` for the
same bytes/vector as M=8, K=256).

Loads the latest checkpoint written by launch/train.py, rebuilds the
serving engine (codes are re-encoded from the checkpointed quantizer —
deterministic), and either runs a one-shot evaluation batch or reads
newline-delimited query vectors from stdin (toy request loop; a real
deployment fronts this with an RPC layer).

Scenarios (search/engine.py, DESIGN.md §5–§6):

* ``memory``        — codes + PG in RAM, single device, ADC-only routing.
* ``hybrid``        — DiskANN-style: ADC routing + exact rerank from "SSD"
                      vectors (default).
* ``sharded``       — graph-free scatter-gather SCAN through ShardedEngine:
                      codes + vectors row-sharded over the local devices per
                      dist/sharding.rpq_rows_spec, per-shard exhaustive scan
                      + local rerank, dist.fault.partial_merge gather — the
                      serve_1m dry-run cell's pattern running for real.
* ``sharded-graph`` — graph-ROUTED scatter-gather through
                      ShardedGraphEngine: one independent Vamana subgraph
                      per device shard (graphs/partition.py, cached next to
                      the checkpoint), the beam search itself runs inside
                      shard_map with local exact rerank — the sharded_graph
                      dry-run cell's pattern running for real.
* ``streaming``     — live serving under CHURN through
                      repro.index.StreamingEngine (DESIGN.md §10): the
                      dataset's tail is held out as an insert stream, then
                      ``--churn-rounds`` rounds of interleaved insert /
                      delete / query batches run against the mutable index
                      (recall scored against the LIVE corpus each round),
                      followed by a consolidation that folds the delta into
                      the next base generation, snapshots it atomically
                      next to the checkpoint, and re-evaluates.
                      ``--refresh-every N`` additionally RETRAINS the
                      quantizer on the live graph every N rounds and at
                      the final consolidation (DESIGN.md §12): each new
                      generation re-encodes against the refreshed
                      codebooks and its snapshot carries them, so a
                      restart restores self-contained.
* ``disk``          — ALL-IN-STORAGE serving (DESIGN.md §14,
                      repro/storage/): the Vamana adjacency + packed codes
                      are written to a per-vertex record segment file and
                      served by DiskEngine — every beam round fetches its
                      candidate records from disk through an async reader
                      with double-buffered frontier prefetch; DRAM holds
                      only the LUTs, the entry points, and an LRU
                      hot-vertex cache (``--cache-mb``). ``--chaos
                      slow_read=5`` models device latency on the real read
                      path; ``--chaos io=0.05`` injects transient read
                      faults (retried); ``--chaos corrupt_record`` flips a
                      record byte silently.
"""

from __future__ import annotations

import argparse
import os
import sys
import time
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import RPQConfig
from repro.core.quantizer import RPQParams
from repro.core.trainer import to_model
from repro.data import load_dataset
from repro.dist import checkpoint as ckpt
from repro.dist.fault import ChaosPlan, InjectedFailure
from repro.dist.retry import RetryPolicy
from repro.graphs.knn import knn_ids
from repro.graphs.partition import PartitionedGraph, build_partitioned_vamana
from repro.launch.train import build_or_load_graph
from repro.pq import base as pqbase
from repro.pq import pack
from repro.search.degrade import DegradationPolicy
from repro.search.engine import (HybridEngine, InMemoryEngine, ShardedEngine,
                                 ShardedGraphEngine)
from repro.search.metrics import live_ground_truth, measure_qps, recall_at_k


def build_or_load_partitioned_graph(key, x, cache_path: str, n_shards: int,
                                    r: int, l: int) -> PartitionedGraph:
    """Per-shard Vamana subgraphs, cached next to the checkpoint (the
    partition depends on the shard count, so the cache is keyed by it)."""
    if cache_path and os.path.exists(cache_path):
        z = np.load(cache_path)
        if int(z["n_shards"]) == n_shards:
            return PartitionedGraph(neighbors=jnp.asarray(z["neighbors"]),
                                    medoids=jnp.asarray(z["medoids"]),
                                    n=int(z["n"]))
    pg = build_partitioned_vamana(key, x, n_shards, r=r, l=l)
    if cache_path:
        os.makedirs(os.path.dirname(cache_path) or ".", exist_ok=True)
        np.savez(cache_path, neighbors=np.asarray(pg.neighbors),
                 medoids=np.asarray(pg.medoids), n=pg.n, n_shards=n_shards)
    return pg


def calibrate_max_rounds(engine, queries, deadline_s: float, **kw) -> int:
    """Turn a wall-clock deadline into a per-call round budget: run one
    warmup batch (absorbs compile), time a steady-state batch, divide the
    observed per-round latency into the deadline (DESIGN.md §13). The
    budget is a TRACED argument downstream, so re-calibrating under drift
    never recompiles."""
    res = engine.search(queries, **kw)
    jax.block_until_ready(res.dists)
    t0 = time.perf_counter()
    res = engine.search(queries, **kw)
    jax.block_until_ready(res.dists)
    elapsed = time.perf_counter() - t0
    rounds = 1.0
    if res.rounds is not None:
        rounds = max(float(np.asarray(res.rounds).max()), 1.0)
    per_round = elapsed / rounds
    return max(1, int(deadline_s / per_round))


def run_streaming(args, model, ds, plan: Optional[ChaosPlan] = None) -> None:
    """The churn loop: hold out the dataset tail as an insert stream, then
    interleave insert / delete / query batches through a StreamingEngine
    and consolidate at the end (DESIGN.md §10)."""
    from repro.index import BaseSegment, StreamingEngine
    from repro.index.segment import encode_codes

    n = int(ds.base.shape[0])
    n0 = n - int(n * args.churn)
    base_x = np.asarray(ds.base[:n0])
    stream = np.asarray(ds.base[n0:])
    graph = build_or_load_graph(jax.random.PRNGKey(0), base_x,
                                f"{args.ckpt_dir}/graph_stream{n0}.npz",
                                args.graph_r, args.graph_l)
    seg = BaseSegment(graph=graph,
                      codes=jnp.asarray(encode_codes(model, base_x,
                                                     args.codes)),
                      vectors=jnp.asarray(base_x), layout=args.codes)
    cap = max(len(stream), 1)
    engine = StreamingEngine(seg, model, delta_capacity=cap)
    print(f"[serve] streaming: base {n0} rows (gen 0), insert stream "
          f"{len(stream)}, delta capacity {cap}, layout {args.codes}")

    rng = np.random.default_rng(0)
    # gid → vector row for live-corpus ground truth: written at insert time
    # (consolidation renumbers gids, so a static base+stream concat would
    # go stale after the first mid-stream generation bump)
    all_x = np.zeros((n0 + cap, base_x.shape[1]), np.float32)
    all_x[:n0] = base_x
    live = np.zeros(n0 + cap, bool)
    live[:n0] = True

    policy = DegradationPolicy()
    budget = {"max_rounds": None}

    def evaluate(tag: str) -> None:
        if args.deadline_ms and budget["max_rounds"] is None:
            budget["max_rounds"] = calibrate_max_rounds(
                engine, ds.queries, args.deadline_ms / 1e3, k=args.k,
                h=args.h)
            print(f"[serve] deadline {args.deadline_ms}ms → "
                  f"max_rounds={budget['max_rounds']}")
        skw = policy.apply(engine, args.degrade_level, h=args.h,
                           expand=args.expand, entries=args.entries,
                           prune_eps=args.prune_eps,
                           max_rounds=budget["max_rounds"])
        gt_g = live_ground_truth(all_x, np.flatnonzero(live), ds.queries,
                                 args.k)
        qps, res = measure_qps(
            lambda q: engine.search(q, k=args.k, **skw), ds.queries)
        trunc = (f" truncated={float(np.asarray(res.truncated).mean()):.2f}"
                 if res.truncated is not None else "")
        print(f"[serve] streaming/{tag}: recall@{args.k}="
              f"{recall_at_k(res.ids, gt_g, args.k):.4f} qps={qps:.1f} "
              f"live={engine.n_live} gen={engine.generation} "
              f"resident={engine.memory_bytes()/1e6:.1f}MB{trunc}")

    snap_dir = f"{args.ckpt_dir}/streaming_index"

    def consolidate_now(refresh, chaos=None) -> dict:
        nonlocal live, all_x
        stats = engine.consolidate(ckpt_dir=snap_dir, keep=3,
                                   refresh=refresh, chaos=chaos)
        # consolidation renumbers: translate the live-corpus bookkeeping
        old_live = np.flatnonzero(live)
        live = np.zeros(stats["n"] + cap, bool)
        live[stats["old2new"][old_live]] = True
        all_x = np.concatenate([
            np.asarray(engine.base.vectors),
            np.zeros((cap, base_x.shape[1]), np.float32)])
        extra = ""
        if stats["refreshed"]:
            rep = stats["refresh"]
            extra = (f", codebooks refreshed (live distortion "
                     f"{rep['distortion_before']:.3f} → "
                     f"{rep['distortion_after']:.3f})")
        print(f"[serve] consolidated → generation {stats['generation']}: "
              f"{stats['n']} rows ({stats['dropped']} dropped, "
              f"{stats['folded']} folded in){extra}, snapshot at "
              f"{snap_dir}")
        return stats

    rounds = max(args.churn_rounds, 1)
    per = -(-max(len(stream), 1) // rounds)
    for i in range(rounds):
        batch = stream[i * per:(i + 1) * per]
        if len(batch):
            gids = engine.insert(batch)
            all_x[gids] = batch
            live[gids] = True
        base_rows = engine.base.n
        live_base = np.flatnonzero(live[:base_rows])
        dead = rng.choice(live_base, min(len(batch), len(live_base)),
                          replace=False)
        engine.delete(dead)
        live[dead] = False
        evaluate(f"round{i}")
        # mid-stream refreshed consolidations close the learning loop
        # (DESIGN.md §12) while the stream keeps flowing; the final
        # consolidation below covers the tail
        if (args.refresh_every and (i + 1) % args.refresh_every == 0
                and i + 1 < rounds):
            consolidate_now(refresh=True)
            evaluate(f"refreshed{i}")
    if plan is not None and plan.crash_phase is not None:
        # chaos drill (DESIGN.md §13): crash mid-consolidation, then prove
        # a restart lands on an intact generation — with the newest
        # snapshot corrupted on top when the plan says so. The drill must
        # demonstrate FALLBACK, not data loss: establish a durable intact
        # generation first (two when corruption will also eat the newest
        # one — a pre_snapshot crash writes nothing, so the corruptor
        # would otherwise hit the only snapshot on disk).
        consolidate_now(refresh=False)
        if plan.corrupt_latest_snapshot:
            consolidate_now(refresh=False)
        try:
            consolidate_now(refresh=bool(args.refresh_every),
                            chaos=plan.consolidate_hook())
        except InjectedFailure as e:
            print(f"[serve] chaos: injected crash during consolidation "
                  f"({e}); restarting from {snap_dir}")
        if plan.corrupt_latest_snapshot:
            from repro.dist.fault import corrupt_snapshot
            step = corrupt_snapshot(snap_dir, seed=plan.seed)
            print(f"[serve] chaos: corrupted snapshot generation {step}")
        engine = StreamingEngine.restore(
            snap_dir, delta_capacity=cap, retry=RetryPolicy(),
            on_fallback=lambda g, e: print(
                f"[serve] chaos: generation {g} failed verification "
                f"({type(e).__name__}) — falling back"))
        live = np.zeros(engine.base.n + cap, bool)
        live[:engine.base.n] = True
        all_x = np.concatenate([np.asarray(engine.base.vectors),
                                np.zeros((cap, base_x.shape[1]),
                                         np.float32)])
        print(f"[serve] chaos: restored generation {engine.generation} "
              f"({engine.n_live} live rows)")
        evaluate("restored")
        return
    consolidate_now(refresh=bool(args.refresh_every))
    evaluate("consolidated")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--dataset", default="sift-small")
    ap.add_argument("--scenario",
                    choices=("hybrid", "memory", "sharded", "sharded-graph",
                             "streaming", "disk"),
                    default="hybrid")
    ap.add_argument("--codes", choices=("u8", "fs4"), default="u8",
                    help="serving layout: u8 = 1 byte/sub-code + f32 LUTs; "
                    "fs4 = fast-scan 4-bit packed codes + quantized uint8 "
                    "LUTs (requires a checkpoint trained with K <= 16)")
    ap.add_argument("--h", type=int, default=32)
    ap.add_argument("--expand", type=int, default=1,
                    help="frontier batch size E (DESIGN.md §9): nodes "
                    "expanded per beam round — each round scores one "
                    "E*R-wide fused hop-ADC call instead of E narrow ones "
                    "(the sharded scenario has no beam and ignores it)")
    ap.add_argument("--entries", type=int, default=1,
                    help="adaptive routing (DESIGN.md §11): seed each beam "
                    "with S entry points from the PQ-hash coarse index "
                    "instead of the single medoid; 1 = classic routing "
                    "(bit-identical). The sharded-graph scenario seeds "
                    "per shard inside shard_map")
    ap.add_argument("--prune-eps", type=float, default=0.0,
                    help="adaptive routing (DESIGN.md §11): probabilistic "
                    "hop pruning margin ε — each hop first scores the "
                    "frontier on a prefix of the subspaces and full-scores "
                    "only lanes whose extrapolated estimate beats the beam "
                    "threshold by ε; 0 = off (bit-identical)")
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--graph-r", type=int, default=24)
    ap.add_argument("--graph-l", type=int, default=48)
    ap.add_argument("--churn", type=float, default=0.1,
                    help="streaming scenario: fraction of the dataset held "
                    "out as the insert stream (an equal count of base rows "
                    "is deleted over the churn rounds)")
    ap.add_argument("--churn-rounds", type=int, default=4,
                    help="streaming scenario: interleaved insert/delete/"
                    "query rounds before consolidation")
    ap.add_argument("--refresh-every", type=int, default=0,
                    help="streaming scenario: run a codebook-REFRESHED "
                    "consolidation every N churn rounds (DESIGN.md §12) — "
                    "the quantizer retrains on the live graph and the new "
                    "generation re-encodes against it; the final "
                    "consolidation refreshes too. 0 = codebooks stay "
                    "frozen across generations (the pre-refresh behavior)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-batch serving deadline (DESIGN.md §13): a "
                    "warmup batch calibrates the per-round latency and the "
                    "beam gets the max_rounds budget that fits — capped "
                    "queries return best-so-far with truncated flags set. "
                    "0 = no deadline. For sharded-graph it also sets the "
                    "straggler deadline of the quorum merge")
    ap.add_argument("--degrade-level", type=int, default=0,
                    help="degradation ladder rung (DESIGN.md §13, "
                    "search/degrade.py): 0 = full config, each level sheds "
                    "the next recall-for-compute knob (L1 expand, L2 "
                    "entries, L3 aggressive prune, L4 rerank, L5 delta "
                    "scan)")
    ap.add_argument("--chaos", default="",
                    help="fault-injection plan (DESIGN.md §13), e.g. "
                    "'dead=1,straggler=2,straggler_ms=50,io=0.05,corrupt,"
                    "crash=consolidate,seed=7': kill shards, slow shards, "
                    "inject transient I/O faults, corrupt the newest "
                    "snapshot, crash mid-consolidation — serving must "
                    "degrade, never throw")
    ap.add_argument("--cache-mb", type=float, default=4.0,
                    help="disk scenario: DRAM budget for the hot-vertex "
                    "cache (LRU over per-vertex records, BFS-seeded from "
                    "the medoid)")
    ap.add_argument("--io-threads", type=int, default=4,
                    help="disk scenario: reader thread-pool width — a "
                    "round's record batch is split across this many "
                    "concurrent pread workers")
    ap.add_argument("--port-stdin", action="store_true",
                    help="read whitespace-separated query vectors on stdin")
    args = ap.parse_args()

    plan = ChaosPlan.parse(args.chaos) if args.chaos else None
    retry = None
    if plan is not None and plan.io_fault_p > 0:
        # every checkpoint read in this process now fails transiently with
        # probability io_fault_p — and retries through the backoff policy
        ckpt.set_io_fault_hook(plan.io_fault())
        retry = RetryPolicy()
        print(f"[serve] chaos: transient I/O fault p={plan.io_fault_p} "
              f"injected on checkpoint reads (retry up to "
              f"{retry.max_attempts} attempts)")

    state = ckpt.restore(args.ckpt_dir, retry=retry)
    extra = state.get("extra") or {}
    ds = load_dataset(extra.get("dataset", args.dataset))
    m, k = extra.get("m", 8), extra.get("k", 64)
    cfg = RPQConfig(dim=ds.dim, m=m, k=k)
    flat = state["params"]
    params = RPQParams(theta=jnp.asarray(flat["theta"]),
                       codebooks=jnp.asarray(flat["codebooks"]),
                       log_alpha=jnp.asarray(flat["log_alpha"]))
    model = to_model(cfg, params)
    print(f"[serve] restored step {state['step']} quantizer "
          f"(M={m}, K={k}) from {args.ckpt_dir}")

    if args.codes == "fs4" and k > 16:
        raise SystemExit(
            f"--codes fs4 needs 4-bit sub-codes (K <= 16); this "
            f"checkpoint was trained with K={k}. Re-train with --k 16 "
            f"(double M to keep the byte budget).")
    if args.scenario == "streaming":  # live mutable index under churn
        if args.port_stdin:
            raise SystemExit(
                "--port-stdin is not available with --scenario streaming: "
                "the scenario runs a fixed churn loop, not a query port")
        run_streaming(args, model, ds, plan)
        return

    codes = pqbase.encode(model, ds.base)
    if args.codes == "fs4":
        # fast-scan layout (DESIGN.md §8): nibble-packed codes + uint8 LUTs.
        # Every scenario below accepts it — the engines dispatch on the
        # QuantizedLUT type that build_lut(quantize=True) returns.
        codes = pack.pack_codes(codes)
        lut_fn = lambda q: pqbase.build_lut(model, q, quantize=True)
        print(f"[serve] fast-scan fs4 layout: {codes.shape[1]} packed "
              f"bytes/vector, uint8 LUTs")
    else:
        lut_fn = lambda q: pqbase.build_lut(model, q)
    if args.scenario == "sharded":  # graph-free scatter-gather scan
        engine = ShardedEngine(codes, lut_fn, vectors=ds.base)
        print(f"[serve] sharded over {engine.n_shards} device shard(s)")
    elif args.scenario == "sharded-graph":  # graph-routed scatter-gather
        n_shards = len(jax.devices())
        pg = build_or_load_partitioned_graph(
            jax.random.PRNGKey(0), ds.base,
            f"{args.ckpt_dir}/graph_part{n_shards}.npz", n_shards,
            args.graph_r, args.graph_l)
        engine = ShardedGraphEngine(pg, codes, lut_fn, vectors=ds.base)
        print(f"[serve] graph-routed over {engine.n_shards} device "
              f"shard(s), {pg.n_local} rows/shard, R={pg.degree}")
    elif args.scenario == "disk":  # all-in-storage tier (DESIGN.md §14)
        from repro.index.segment import BaseSegment
        from repro.storage import DiskEngine, write_segment
        from repro.storage import format as segfmt

        graph = build_or_load_graph(jax.random.PRNGKey(0), ds.base,
                                    f"{args.ckpt_dir}/graph_base.npz",
                                    args.graph_r, args.graph_l)
        storage_dir = f"{args.ckpt_dir}/storage"
        seg = BaseSegment(graph=graph, codes=jnp.asarray(codes),
                          vectors=None, layout=args.codes,
                          generation=0, dim_hint=ds.dim)
        seg_path = write_segment(storage_dir, seg, model=model)
        fault_hook, slow_ms = None, 0.0
        if plan is not None:
            slow_ms = plan.slow_read_ms
            if plan.io_fault_p > 0:
                fault_hook = plan.io_fault()
                retry = retry or RetryPolicy()
                print(f"[serve] chaos: transient read fault p="
                      f"{plan.io_fault_p} injected on segment reads")
            if plan.corrupt_record:
                vid = segfmt.corrupt_record(seg_path, seed=plan.seed)
                print(f"[serve] chaos: silently corrupted record {vid} "
                      f"in {seg_path}")
        engine = DiskEngine.open(
            storage_dir, lut_fn=lut_fn, cache_mb=args.cache_mb,
            io_threads=args.io_threads, retry=retry,
            fault_hook=fault_hook, slow_read_ms=slow_ms,
            on_fallback=lambda g, e: print(
                f"[serve] disk: generation {g} failed header verification "
                f"({e}) — falling back"))
        print(f"[serve] disk: gen {engine.generation} segment "
              f"{os.path.getsize(engine.path)/1e6:.1f}MB on storage, "
              f"cache {len(engine.cache)}/{engine.cache.capacity} records "
              f"({args.cache_mb}MB budget), {args.io_threads} io threads")
    else:
        graph = build_or_load_graph(jax.random.PRNGKey(0), ds.base,
                                    f"{args.ckpt_dir}/graph_base.npz",
                                    args.graph_r, args.graph_l)
        if args.scenario == "hybrid":
            engine = HybridEngine(graph, codes, lut_fn, vectors=ds.base)
        else:
            engine = InMemoryEngine(graph, codes, lut_fn)

    if args.port_stdin:
        print(f"[serve] reading {ds.dim}-d queries from stdin "
              f"(one per line; EOF to stop)")
        for line in sys.stdin:
            try:
                vals = np.fromiter(line.split(), dtype=np.float32)
            except ValueError:
                print(f"!! expected {ds.dim} floats, got unparseable input")
                continue
            if vals.size != ds.dim:
                print(f"!! expected {ds.dim} floats, got {vals.size}")
                continue
            t0 = time.perf_counter()
            res = engine.search(jnp.asarray(vals)[None], k=args.k, h=args.h,
                                expand=args.expand, entries=args.entries,
                                prune_eps=args.prune_eps)
            dt = (time.perf_counter() - t0) * 1e3
            ids = np.asarray(res.ids[0]).tolist()
            print(f"ids={ids} dists={np.asarray(res.dists[0]).round(3).tolist()} "
                  f"({dt:.1f} ms, {int(res.hops[0])} hops)")
        return

    policy = DegradationPolicy()
    skw = policy.apply(engine, args.degrade_level, h=args.h,
                       expand=args.expand, entries=args.entries,
                       prune_eps=args.prune_eps)
    if args.deadline_ms and not isinstance(engine, ShardedEngine):
        # the graph-free exhaustive scan has no rounds to budget; its
        # deadline story is the quorum merge below
        mr = calibrate_max_rounds(engine, ds.queries,
                                  args.deadline_ms / 1e3, k=args.k, **skw)
        skw["max_rounds"] = mr
        print(f"[serve] deadline {args.deadline_ms}ms → max_rounds={mr}")
    if plan is not None and hasattr(engine, "n_shards"):
        skw["alive"] = list(plan.alive(engine.n_shards))
        dead = engine.n_shards - sum(skw["alive"])
        msg = f"[serve] chaos: {dead}/{engine.n_shards} shard(s) dead"
        if isinstance(engine, ShardedGraphEngine):
            skw["shard_latency_s"] = list(plan.latencies(engine.n_shards))
            if args.deadline_ms:
                skw["deadline_s"] = args.deadline_ms / 1e3
                msg += (f", stragglers {list(plan.straggler_shards)} at "
                        f"{plan.straggler_latency_s*1e3:.0f}ms vs "
                        f"{args.deadline_ms}ms deadline quorum")
        print(msg)

    gt, _ = knn_ids(ds.base, ds.queries, args.k)
    qps, res = measure_qps(lambda q: engine.search(q, k=args.k, **skw),
                           ds.queries)
    rounds = (f"rounds={float(res.rounds.mean()):.1f} "
              if res.rounds is not None else "")
    trunc = (f"truncated={float(np.asarray(res.truncated).mean()):.2f} "
             if res.truncated is not None else "")
    degr = "DEGRADED " if res.degraded else ""
    print(f"[serve] {args.scenario}: recall@{args.k}="
          f"{recall_at_k(res.ids, gt, args.k):.4f} qps={qps:.1f} "
          f"hops={float(res.hops.mean()):.1f} {rounds}{trunc}{degr}"
          f"resident={engine.memory_bytes()/1e6:.1f}MB")
    if args.scenario == "disk":
        io = engine.last_io
        print(f"[serve] disk io: cache_hit_rate={io['cache_hit_rate']:.3f} "
              f"bytes_read={io['bytes_read']} n_reads={io['n_reads']} "
              f"io_wait={io['io_wait_s']*1e3:.1f}ms "
              f"retries={io['n_retries']}")


if __name__ == "__main__":
    main()
