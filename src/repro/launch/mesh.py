"""Production mesh builders (dry-run + real-cluster entry point).

FUNCTIONS, not module constants — importing this module never touches jax
device state (the brief's requirement). Axis semantics:

  pod    — inter-pod data parallelism (DCN-connected slices)
  data   — intra-pod data / FSDP axis (batch, parameter shards)
  model  — tensor/expert/table parallel axis

Hardware constants for the roofline model (TPU v5e per chip).
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType

# TPU v5e (the assignment's target; used by benchmarks/roofline.py)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
HBM_BW = 819e9                # B/s per chip
ICI_BW = 50e9                 # B/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Whatever devices exist locally (tests, examples): (1, n) mesh."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)


def data_axes(mesh) -> tuple[str, ...]:
    """All batch-parallel axes present in `mesh` (pod included if any)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
