"""din [arXiv:1706.06978; paper]
embed_dim=18 seq_len=100 attn_mlp=80-40 mlp=200-80 target-attention.
Item vocab: Amazon(Electronics) 63001 goods as in the paper."""
from repro.configs import base
from repro.models.recsys import DINConfig


def make_config() -> DINConfig:
    return DINConfig(name="din", n_items=63001, embed_dim=18, seq_len=100,
                     attn_mlp=(80, 40), mlp=(200, 80))


def make_reduced() -> DINConfig:
    return DINConfig(name="din-reduced", n_items=300, embed_dim=8, seq_len=12,
                     attn_mlp=(16, 8), mlp=(16, 8))


base.register(base.ArchSpec(
    arch_id="din", family="recsys", make_config=make_config,
    make_reduced=make_reduced, shapes=base.RECSYS_SHAPES,
    source="arXiv:1706.06978; paper"))
