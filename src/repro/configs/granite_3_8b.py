"""granite-3-8b [hf:ibm-granite/granite-3.0-2b-base family; hf]
40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155 — dense GQA LM."""
import jax.numpy as jnp
from repro.configs import base
from repro.models.transformer import LMConfig


def make_config() -> LMConfig:
    return LMConfig(name="granite-3-8b", n_layers=40, d_model=4096,
                    n_heads=32, n_kv_heads=8, d_head=128, d_ff=12800,
                    vocab=49155, microbatches=16)


def make_reduced() -> LMConfig:
    return LMConfig(name="granite-3-8b-reduced", n_layers=2, d_model=64,
                    n_heads=4, n_kv_heads=2, d_head=16, d_ff=192, vocab=256,
                    microbatches=1, remat=False, dtype=jnp.float32)


base.register(base.ArchSpec(
    arch_id="granite-3-8b", family="lm", make_config=make_config,
    make_reduced=make_reduced, shapes=base.LM_SHAPES,
    source="hf:ibm-granite/granite-3.0-2b-base; hf"))
