"""bert4rec [arXiv:1904.06690; paper]
embed_dim=64 n_blocks=2 n_heads=2 seq_len=200, bidirectional self-attn.
Item vocab: ML-20M (26744 items) as in the paper's largest benchmark."""
from repro.configs import base
from repro.models.recsys import Bert4RecConfig


def make_config() -> Bert4RecConfig:
    return Bert4RecConfig(name="bert4rec", n_items=26744, embed_dim=64,
                          n_blocks=2, n_heads=2, seq_len=200)


def make_reduced() -> Bert4RecConfig:
    return Bert4RecConfig(name="bert4rec-reduced", n_items=500, embed_dim=16,
                          n_blocks=2, n_heads=2, seq_len=20)


base.register(base.ArchSpec(
    arch_id="bert4rec", family="recsys", make_config=make_config,
    make_reduced=make_reduced, shapes=base.RECSYS_SHAPES,
    source="arXiv:1904.06690; paper"))
