"""gat-cora [arXiv:1710.10903; paper]
2L d_hidden=8 n_heads=8 attention aggregator (Cora: 1433 feats, 7 classes)."""
from repro.configs import base
from repro.models.gnn import GATConfig


def make_config() -> GATConfig:
    return GATConfig(name="gat-cora", d_in=1433, d_hidden=8, n_heads=8,
                     n_layers=2, n_classes=7)


def make_reduced() -> GATConfig:
    return GATConfig(name="gat-cora-reduced", d_in=32, d_hidden=4, n_heads=2,
                     n_layers=2, n_classes=4)


base.register(base.ArchSpec(
    arch_id="gat-cora", family="gnn", make_config=make_config,
    make_reduced=make_reduced, shapes=base.GNN_SHAPES,
    source="arXiv:1710.10903; paper",
    notes="minibatch_lg/ogb_products reuse the same 2L-GAT with the shape's "
          "d_feat (the paper's model is feature-width agnostic)"))
