"""llama3-405b [arXiv:2407.21783; unverified]
126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256."""
import jax.numpy as jnp
from repro.configs import base
from repro.models.transformer import LMConfig


def make_config() -> LMConfig:
    return LMConfig(name="llama3-405b", n_layers=126, d_model=16384,
                    n_heads=128, n_kv_heads=8, d_head=128, d_ff=53248,
                    vocab=128256, rope_theta=500000.0,
                    # 405B memory engineering (EXPERIMENTS.md §Perf):
                    microbatches=16, opt_slot_dtype=jnp.bfloat16,
                    grad_dtype=jnp.bfloat16, layer_block=7)


def make_reduced() -> LMConfig:
    return LMConfig(name="llama3-405b-reduced", n_layers=3, d_model=128,
                    n_heads=8, n_kv_heads=2, d_head=16, d_ff=416, vocab=512,
                    microbatches=2, remat=True, dtype=jnp.float32)


base.register(base.ArchSpec(
    arch_id="llama3-405b", family="lm", make_config=make_config,
    make_reduced=make_reduced, shapes=base.LM_SHAPES,
    source="arXiv:2407.21783; unverified"))
