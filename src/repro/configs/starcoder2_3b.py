"""starcoder2-3b [arXiv:2402.19173; hf]
30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152 — GQA, RoPE."""
import jax.numpy as jnp
from repro.configs import base
from repro.models.transformer import LMConfig


def make_config() -> LMConfig:
    return LMConfig(name="starcoder2-3b", n_layers=30, d_model=3072,
                    n_heads=24, n_kv_heads=2, d_head=128, d_ff=12288,
                    vocab=49152, microbatches=16)


def make_reduced() -> LMConfig:
    return LMConfig(name="starcoder2-3b-reduced", n_layers=2, d_model=96,
                    n_heads=6, n_kv_heads=2, d_head=16, d_ff=384, vocab=256,
                    microbatches=1, remat=False, dtype=jnp.float32)


base.register(base.ArchSpec(
    arch_id="starcoder2-3b", family="lm", make_config=make_config,
    make_reduced=make_reduced, shapes=base.LM_SHAPES,
    source="arXiv:2402.19173; hf"))
