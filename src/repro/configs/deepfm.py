"""deepfm [arXiv:1703.04247; paper]
n_sparse=39 embed_dim=10 mlp=400-400-400, FM interaction (Criteo).
Field vocabs: hashed Criteo layout ~1.1M total features (paper §IV)."""
from repro.configs import base
from repro.models.recsys import DeepFMConfig

# 13 numeric fields bucketized + 26 categorical; hashed sizes sum ≈ 1.09M
_ROWS = tuple([64] * 13 + [
    1461, 584, 10_131_227 // 100, 2_202_608 // 100, 306, 24, 12518, 634, 4,
    93146, 5684, 8_351_593 // 100, 3195, 28, 14993, 5_461_306 // 100, 11,
    5653, 2173, 4, 7_046_547 // 100, 18, 16, 286181, 105, 142572,
])


def make_config() -> DeepFMConfig:
    return DeepFMConfig(name="deepfm", row_counts=_ROWS, embed_dim=10,
                        mlp=(400, 400, 400))


def make_reduced() -> DeepFMConfig:
    return DeepFMConfig(name="deepfm-reduced", row_counts=tuple([50] * 8),
                        embed_dim=4, mlp=(16, 16))


base.register(base.ArchSpec(
    arch_id="deepfm", family="recsys", make_config=make_config,
    make_reduced=make_reduced, shapes=base.RECSYS_SHAPES,
    source="arXiv:1703.04247; paper"))
