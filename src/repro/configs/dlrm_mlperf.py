"""dlrm-mlperf [arXiv:1906.00091; paper] — MLPerf DLRM (Criteo 1TB).
n_dense=13 n_sparse=26 embed_dim=128 bot=13-512-256-128
top=1024-1024-512-256-1 dot interaction. Table rows: official MLPerf
day-count cardinalities (≈188M rows, ≈24B embedding params)."""
from repro.configs import base
from repro.models.recsys import DLRMConfig

# MLPerf v1.0 DLRM Criteo-1TB per-table cardinalities
_ROWS = (39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
         2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
         25641295, 39664984, 585935, 12972, 108, 36)


def make_config() -> DLRMConfig:
    return DLRMConfig(name="dlrm-mlperf", n_dense=13, row_counts=_ROWS,
                      embed_dim=128, bot_mlp=(512, 256, 128),
                      top_mlp=(1024, 1024, 512, 256, 1))


def make_reduced() -> DLRMConfig:
    return DLRMConfig(name="dlrm-reduced", n_dense=13,
                      row_counts=tuple([100] * 6), embed_dim=16,
                      bot_mlp=(32, 16), top_mlp=(32, 16, 1))


base.register(base.ArchSpec(
    arch_id="dlrm-mlperf", family="recsys", make_config=make_config,
    make_reduced=make_reduced, shapes=base.RECSYS_SHAPES,
    source="arXiv:1906.00091; paper"))
