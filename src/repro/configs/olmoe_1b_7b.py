"""olmoe-1b-7b [arXiv:2409.02060; hf]
16L d_model=2048 16H (GQA kv=16) d_ff=1024 vocab=50304, MoE 64e top-8."""
import jax.numpy as jnp
from repro.configs import base
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig


def make_config() -> LMConfig:
    return LMConfig(name="olmoe-1b-7b", n_layers=16, d_model=2048,
                    n_heads=16, n_kv_heads=16, d_head=128, d_ff=1024,
                    vocab=50304,
                    moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024),
                    microbatches=4)


def make_reduced() -> LMConfig:
    return LMConfig(name="olmoe-1b-7b-reduced", n_layers=2, d_model=64,
                    n_heads=4, n_kv_heads=4, d_head=16, d_ff=64, vocab=256,
                    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64,
                                  group_size=64),
                    microbatches=1, remat=False, dtype=jnp.float32)


base.register(base.ArchSpec(
    arch_id="olmoe-1b-7b", family="lm", make_config=make_config,
    make_reduced=make_reduced, shapes=base.LM_SHAPES,
    source="arXiv:2409.02060; hf"))
