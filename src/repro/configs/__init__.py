"""Per-architecture configs + registry (--arch <id>)."""
from repro.configs.base import ArchSpec, ShapeSpec, get_arch, list_archs  # noqa: F401
