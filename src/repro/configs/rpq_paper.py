"""rpq (the paper's own system) as an 11th selectable arch.

train  : 500K×128 quantizer training step (paper §8.1 training subset)
serve  : batched ADC beam-search serving over a 1M-code index
The dry-run cells prove the RPQ data-parallel layout shards to 512 chips.
"""
import dataclasses

from repro.configs import base
from repro.core.quantizer import RPQConfig


@dataclasses.dataclass(frozen=True)
class RPQArchConfig:
    name: str
    quant: RPQConfig
    n_base: int
    n_train: int
    beam_h: int = 32
    graph_degree: int = 64


def make_config() -> RPQArchConfig:
    return RPQArchConfig(name="rpq", quant=RPQConfig(dim=128, m=16, k=256),
                         n_base=1_000_000, n_train=500_000)


def make_reduced() -> RPQArchConfig:
    return RPQArchConfig(name="rpq-reduced",
                         quant=RPQConfig(dim=32, m=4, k=32),
                         n_base=2000, n_train=1000, beam_h=8,
                         graph_degree=8)


RPQ_SHAPES = (
    base.ShapeSpec("quant_train", "train",
                   dict(batch=8192, routing_batch=4096, h=16)),
    base.ShapeSpec("serve_1m", "serve",
                   dict(n_base=1_000_000, query_batch=4096, k=10)),
    base.ShapeSpec("encode_bulk", "serve", dict(batch=1_000_000)),
    base.ShapeSpec("adc_bulk", "retrieval",
                   dict(n_codes=1_000_000, query_batch=1024)),
    # graph-ROUTED sharded serving: per-shard Vamana beam search inside
    # shard_map (search/engine.sharded_graph_topk), R=32 adjacency
    base.ShapeSpec("sharded_graph", "serve",
                   dict(n_base=1_000_000, query_batch=256, k=10, h=32,
                        r=32)),
    # same routing scenario in the FAST-SCAN layout (DESIGN.md §8):
    # 4-bit packed codes (M/2 bytes/row resident) + uint8 QuantizedLUTs
    base.ShapeSpec("sharded_graph_fs4", "serve",
                   dict(n_base=1_000_000, query_batch=256, k=10, h=32,
                        r=32)),
    # FRONTIER-BATCHED routing (DESIGN.md §9): expand=4 beam over an R=64
    # subgraph — every round is one E·R = 256-wide fused hop-ADC call
    base.ShapeSpec("sharded_graph_wide", "serve",
                   dict(n_base=1_000_000, query_batch=256, k=10, h=32,
                        r=64, expand=4)),
)

base.register(base.ArchSpec(
    arch_id="rpq", family="rpq", make_config=make_config,
    make_reduced=make_reduced, shapes=RPQ_SHAPES,
    source="this paper"))
