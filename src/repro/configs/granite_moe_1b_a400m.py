"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
24L d_model=1024 16H (GQA kv=8) d_ff=512 vocab=49155, MoE 32e top-8."""
import jax.numpy as jnp
from repro.configs import base
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig


def make_config() -> LMConfig:
    return LMConfig(name="granite-moe-1b-a400m", n_layers=24, d_model=1024,
                    n_heads=16, n_kv_heads=8, d_head=64, d_ff=512,
                    vocab=49155,
                    moe=MoEConfig(n_experts=32, top_k=8, d_ff_expert=512),
                    microbatches=4)


def make_reduced() -> LMConfig:
    return LMConfig(name="granite-moe-1b-a400m-reduced", n_layers=2,
                    d_model=64, n_heads=4, n_kv_heads=2, d_head=16, d_ff=64,
                    vocab=256,
                    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64,
                                  group_size=64),
                    microbatches=1, remat=False, dtype=jnp.float32)


base.register(base.ArchSpec(
    arch_id="granite-moe-1b-a400m", family="lm", make_config=make_config,
    make_reduced=make_reduced, shapes=base.LM_SHAPES,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf"))
