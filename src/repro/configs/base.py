"""Arch registry: --arch <id> → (family, config, shapes).

Every assigned architecture registers here with its exact published config
and its own input-shape set (the brief's 40 cells). `reduced()` returns the
small same-family config used by the CPU smoke tests; the FULL configs are
touched only via ShapeDtypeStruct in the dry-run.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable, Optional

_REGISTRY: dict[str, "ArchSpec"] = {}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode | serve | retrieval | graph
    dims: dict
    note: str = ""


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str        # lm | gnn | recsys
    make_config: Callable[[], Any]
    make_reduced: Callable[[], Any]
    shapes: tuple[ShapeSpec, ...]
    source: str        # citation tag from the assignment
    notes: str = ""


def register(spec: ArchSpec):
    _REGISTRY[spec.arch_id] = spec
    return spec


_MODULES = [
    "granite_3_8b", "llama3_405b", "starcoder2_3b", "granite_moe_1b_a400m",
    "olmoe_1b_7b", "gat_cora", "bert4rec", "deepfm", "din", "dlrm_mlperf",
    "rpq_paper",
]


def _ensure_loaded():
    for m in _MODULES:
        importlib.import_module(f"repro.configs.{m}")


def get_arch(arch_id: str) -> ArchSpec:
    _ensure_loaded()
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


# Shared LM shape set (the brief: seq_len × global_batch per mode)
LM_SHAPES = (
    ShapeSpec("train_4k", "train", dict(seq_len=4096, global_batch=256)),
    ShapeSpec("prefill_32k", "prefill", dict(seq_len=32768, global_batch=32)),
    ShapeSpec("decode_32k", "decode", dict(seq_len=32768, global_batch=128)),
    ShapeSpec("long_500k", "decode", dict(seq_len=524288, global_batch=1),
              note="decode-only is O(S)/token, runnable for full attention; "
                   "500k PREFILL would need sub-quadratic attention "
                   "(DESIGN.md §5)"),
)

GNN_SHAPES = (
    ShapeSpec("full_graph_sm", "graph",
              dict(n_nodes=2708, n_edges=10556, d_feat=1433)),
    ShapeSpec("minibatch_lg", "graph",
              dict(n_nodes=232965, n_edges=114615892, batch_nodes=1024,
                   fanout=(15, 10))),
    ShapeSpec("ogb_products", "graph",
              dict(n_nodes=2449029, n_edges=61859140, d_feat=100)),
    ShapeSpec("molecule", "graph",
              dict(n_nodes=30, n_edges=64, batch=128)),
)

RECSYS_SHAPES = (
    ShapeSpec("train_batch", "train", dict(batch=65536)),
    ShapeSpec("serve_p99", "serve", dict(batch=512)),
    ShapeSpec("serve_bulk", "serve", dict(batch=262144)),
    ShapeSpec("retrieval_cand", "retrieval", dict(batch=1, n_candidates=1_000_000)),
)
