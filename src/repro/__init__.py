"""repro: Routing-guided learned Product Quantization (RPQ) for graph-based ANNS.

A production-grade JAX framework reproducing and extending

    Yue et al., "Routing-Guided Learned Product Quantization for Graph-Based
    Approximate Nearest Neighbor Search" (PVLDB / CS.IR 2023).

Package layout
--------------
core/      the paper's contribution (differentiable quantizer, feature
           extractor, joint training)
pq/        baseline quantizers (PQ, OPQ, Catalyst-like)
graphs/    proximity-graph construction (kNN, Vamana, HNSW, NSG)
search/    batched beam-search routing + serving engines
kernels/   Pallas TPU kernels for the PQ hot loops (ADC scan, pairwise)
models/    assigned architecture zoo (LM dense/MoE, GNN, recsys)
data/      synthetic datasets, ground truth, input pipeline
dist/      sharding rules, checkpointing, fault tolerance, compression
configs/   per-architecture configs (--arch registry)
launch/    mesh / dryrun / train / serve drivers
"""

__version__ = "1.0.0"

from repro import _compat as _compat  # noqa: E402  (jax forward-compat shims)

_compat.apply()
