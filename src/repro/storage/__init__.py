"""All-in-storage serving tier (DESIGN.md §14).

Graph adjacency + PQ codes live in one mmap-able segment file; DRAM holds
only per-query LUTs, entry points, and a bounded hot-vertex cache. The
pieces compose bottom-up: ``format`` (record layout + CRC'd header +
generation fallback) → ``reader`` (thread-pooled pread with retry/chaos
seams) → ``cache``/``prefetch`` (BFS-seeded LRU + double-buffered frontier
fetch) → ``engine`` (the protocol-compatible DiskEngine).
"""

from repro.storage.format import (SegmentFormatError, SegmentHeader,
                                  all_generations, corrupt_header,
                                  corrupt_record, open_segment,
                                  read_header, record_bytes_for,
                                  segment_path, write_segment)
from repro.storage.reader import AsyncSegmentReader
from repro.storage.cache import HotVertexCache
from repro.storage.prefetch import FrontierPrefetcher, PendingFetch
from repro.storage.engine import DiskEngine

__all__ = [
    "SegmentFormatError", "SegmentHeader", "all_generations",
    "corrupt_header", "corrupt_record", "open_segment", "read_header",
    "record_bytes_for", "segment_path", "write_segment",
    "AsyncSegmentReader", "HotVertexCache", "FrontierPrefetcher",
    "PendingFetch", "DiskEngine",
]
