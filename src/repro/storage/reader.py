"""Async record reader: a thread pool over pread, retry on every read.

The I/O half of the storage tier (DESIGN.md §14). A beam round asks for a
batch of vertex records (the round's E·R candidate ids); the reader splits
the batch across ``io_threads`` workers, each issuing positional
``os.pread`` calls — no shared file offset, no locking — and reassembles
``(adjacency, codes)`` arrays in request order. ``submit`` returns a
Future so the prefetcher (:mod:`repro.storage.prefetch`) can keep round
N's reads in flight while round N−1's scoring computes; ``read_records``
is the synchronous convenience over it.

Resilience wiring (DESIGN.md §13) on REAL reads:

* every worker chunk runs under ``dist.retry.call_with_retry`` — a
  :class:`~repro.dist.retry.TransientIOError` (chaos-injected or real) is
  retried with exponential backoff before it can fail the round;
* the chaos ``fault_hook`` (``ChaosPlan.io_fault()``) is invoked once per
  worker chunk BEFORE its preads, so ``--chaos io=0.05`` exercises this
  path exactly like checkpoint reads;
* ``slow_read_ms`` models device latency with a real ``time.sleep`` per
  chunk — genuinely overlappable wall-clock, which is what lets the
  prefetch benchmarks measure compute/I/O overlap honestly on a
  page-cached CI host where raw preads cost microseconds.

Counters (``bytes_read``, ``n_reads``, ``n_retries``, ``io_busy_s``) feed
the bench's bytes-read/hit-rate rows and the measured-I/O adapter on
``HybridEngine.io_time``.
"""

from __future__ import annotations

import os
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Optional

import numpy as np

from repro.dist import retry as _retry
from repro.storage.format import SegmentHeader


class AsyncSegmentReader:
    """Thread-pooled positional reads of per-vertex records.

    Args:
      path:        segment file (``storage.format`` layout).
      header:      its verified :class:`SegmentHeader`.
      io_threads:  worker threads; a batch is split into that many chunks.
      retry:       :class:`repro.dist.retry.RetryPolicy` wrapped around
                   every chunk read (None = fail fast).
      fault_hook:  chaos seam — called with the path once per chunk; may
                   raise :class:`TransientIOError` (``ChaosPlan.io_fault``).
      slow_read_ms: modeled per-batch device latency (a real sleep inside
                   each worker chunk, so it overlaps with host compute).
    """

    def __init__(self, path: str, header: SegmentHeader, *,
                 io_threads: int = 4,
                 retry: Optional[_retry.RetryPolicy] = None,
                 fault_hook: Optional[Callable[[str], None]] = None,
                 slow_read_ms: float = 0.0):
        self.path = path
        self.header = header
        self.io_threads = max(1, int(io_threads))
        self.retry = retry
        self.fault_hook = fault_hook
        self.slow_read_ms = float(slow_read_ms)
        self._fd = os.open(path, os.O_RDONLY)
        self._pool = ThreadPoolExecutor(
            max_workers=self.io_threads,
            thread_name_prefix="seg-reader")
        self._lock = threading.Lock()
        self.bytes_read = 0
        self.n_reads = 0          # individual record preads issued
        self.n_batches = 0
        self.n_retries = 0
        self.io_busy_s = 0.0      # summed worker wall time (not wall-clock)

    # -- internals ---------------------------------------------------------

    def _n_chunks(self, size: int) -> int:
        """A batch claims only HALF the workers: the double-buffered engine
        keeps two batches in flight, and if one batch's chunks saturated
        the pool the next batch would queue entirely behind it — the
        buffers would serialize and the overlap would evaporate exactly
        when io ≈ compute, the regime prefetch exists for."""
        return max(1, min(self.io_threads // 2, size))

    def _read_chunk(self, ids: np.ndarray,
                    t_issue: Optional[float] = None) -> bytes:
        """One worker's share: seeded faults, modeled latency, preads."""
        t0 = time.perf_counter()

        def attempt() -> bytes:
            if self.fault_hook is not None:
                self.fault_hook(self.path)
            if self.slow_read_ms > 0.0:
                # a device's latency clock starts when the request is
                # ISSUED, not when a worker thread wins the GIL and picks
                # the task up — sleep to the absolute deadline so queue/
                # GIL handoff delays eat into the modeled latency instead
                # of stacking on top of it
                deadline = ((t_issue if t_issue is not None else t0)
                            + self.slow_read_ms / 1e3)
                left = deadline - time.perf_counter()
                if left > 0.0:
                    time.sleep(left)
            rb = self.header.record_bytes
            out = bytearray(len(ids) * rb)
            for j, vid in enumerate(ids):
                raw = os.pread(self._fd, rb, self.header.record_offset(
                    int(vid)))
                if len(raw) != rb:
                    raise _retry.TransientIOError(
                        f"{self.path}: short read of record {int(vid)} "
                        f"({len(raw)}/{rb} bytes)")
                out[j * rb:(j + 1) * rb] = raw
            return bytes(out)

        if self.retry is None:
            raw, retries = attempt(), 0
        else:
            raw, retries = _retry.call_with_retry(
                attempt, policy=self.retry,
                retry_on=(_retry.TransientIOError,),
                seed=int(ids[0]) if len(ids) else 0)
        with self._lock:
            self.bytes_read += len(raw)
            self.n_reads += len(ids)
            self.n_retries += retries
            self.io_busy_s += time.perf_counter() - t0
        return raw

    def _gather(self, ids: np.ndarray):
        ids = np.asarray(ids, np.int64)
        if ids.size == 0:
            return (np.zeros((0, self.header.r), np.int32),
                    np.zeros((0, self.header.code_width), np.uint8))
        if ids.min() < 0 or ids.max() >= self.header.n:
            raise ValueError(
                f"record ids out of range [0, {self.header.n}): "
                f"{ids[(ids < 0) | (ids >= self.header.n)]}")
        t_issue = time.perf_counter()
        chunks = np.array_split(ids, self._n_chunks(ids.size))
        futs = [self._pool.submit(self._read_chunk, c, t_issue)
                for c in chunks]
        raw = b"".join(f.result() for f in futs)
        with self._lock:
            self.n_batches += 1
        return self.header.parse_records(raw, ids.size)

    # -- public API --------------------------------------------------------

    def submit(self, ids) -> Future:
        """Issue an async batch read. The Future resolves to
        ``(adjacency (B, R) int32, codes (B, code_width) uint8)`` in
        request order.

        The split + chunk submission happens HERE, in the caller's thread
        (cheap: an ``array_split`` and a few queue puts), so the worker
        sleeps/preads start immediately and overlap the caller's compute.
        A dispatch-thread hop would make the issue itself contend for the
        GIL with scoring — measurably inflating effective I/O latency in
        the pipelined engine. The last-finishing chunk's done-callback
        reassembles and parses the batch; out-of-range ids raise here,
        synchronously."""
        ids = np.asarray(ids, np.int64).copy()
        fut: Future = Future()
        if ids.size == 0:
            fut.set_result(
                (np.zeros((0, self.header.r), np.int32),
                 np.zeros((0, self.header.code_width), np.uint8)))
            return fut
        if ids.min() < 0 or ids.max() >= self.header.n:
            raise ValueError(
                f"record ids out of range [0, {self.header.n}): "
                f"{ids[(ids < 0) | (ids >= self.header.n)]}")
        t_issue = time.perf_counter()
        chunks = np.array_split(ids, self._n_chunks(ids.size))
        futs = [self._pool.submit(self._read_chunk, c, t_issue)
                for c in chunks]
        pending = [len(futs)]
        done_lock = threading.Lock()

        def _one_done(_f) -> None:
            with done_lock:
                pending[0] -= 1
                if pending[0]:
                    return
            try:
                raw = b"".join(f.result() for f in futs)
                with self._lock:
                    self.n_batches += 1
                fut.set_result(self.header.parse_records(raw, ids.size))
            except BaseException as e:   # surfaced via Future.result()
                fut.set_exception(e)

        for f in futs:
            f.add_done_callback(_one_done)
        return fut

    def read_records(self, ids):
        """Synchronous batch read (same return as :meth:`submit`)."""
        return self._gather(np.asarray(ids, np.int64))

    def stats(self) -> dict:
        with self._lock:
            return {"bytes_read": self.bytes_read, "n_reads": self.n_reads,
                    "n_batches": self.n_batches,
                    "n_retries": self.n_retries,
                    "io_busy_s": self.io_busy_s}

    def reset_stats(self) -> None:
        with self._lock:
            self.bytes_read = self.n_reads = 0
            self.n_batches = self.n_retries = 0
            self.io_busy_s = 0.0

    def close(self) -> None:
        self._pool.shutdown(wait=True)
        if self._fd is not None:
            os.close(self._fd)
            self._fd = None

    def __enter__(self) -> "AsyncSegmentReader":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
