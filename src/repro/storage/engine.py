"""DiskEngine: graph-routed ANN serving with graph + codes ALL in storage.

The sixth serving engine (DESIGN.md §14), speaking the same ``search()``
protocol as the five resident ones. DRAM holds only the per-query LUTs,
the entry points, and the bounded hot-vertex cache; every beam round
fetches its candidate records (adjacency + codes in one slab,
``storage/format.py``) from the segment file through the async reader —
the AiSAQ layout, where the index's DRAM footprint is O(cache), not O(N).

Because per-round host I/O cannot live inside a jitted XLA while-loop, the
beam here is a host-side loop with vectorized numpy scoring (bit-faithful
to the kernels' ADC semantics: f32 LUT gather-sum for u8, exact int32
accumulation + affine dequant for fs4). The loop has two modes:

* **serial** (``overlap=False``) — each round fetches, then scores:
  wall ≈ rounds × (io + compute). The honest baseline.
* **pipelined** (``overlap=True``, default) — double-buffered: each
  iteration first issues the NEXT round's reads — the frontier selected
  from the beam as it stands BEFORE this round's scores merge (one round
  stale) — then waits on this round's in-flight records and scores them.
  Round N+1's I/O thus overlaps round N's ADC compute:
  wall ≈ rounds × max(io, compute). Staleness can reorder expansions
  (recall stays within a point of serial — asserted in
  benchmarks/disk_serving.py), and when the stale guess yields nothing
  the loop falls back to a fresh post-merge selection, so it terminates
  exactly when serial does: no unexpanded beam entry left.

Tombstones, per-call budgets (``max_rounds`` / ``max_n_dist`` with honest
``truncated`` flags), multi-entry seeding (``entries=S`` starts the beam
on the BFS-from-medoid cache seeds — the graph's top layer, already
DRAM-resident), and partial-prefix hop pruning (``prune_eps`` /
``m_prefix``) all ride along, so the degradation ladder
(search/degrade.py) drives this engine unchanged.
"""

from __future__ import annotations

import os
import time
from typing import Callable, Optional

import numpy as np
import jax.numpy as jnp

from repro.index.segment import Tombstones
from repro.pq.pack import QuantizedLUT
from repro.search.beam import SearchResult
from repro.storage import format as segfmt
from repro.storage.cache import HotVertexCache
from repro.storage.prefetch import FrontierPrefetcher
from repro.storage.reader import AsyncSegmentReader

_INF = np.float32(np.inf)


def _host_luts(luts):
    """Device LUTs → host tuple ``(tables, scale, bias, m, packed)``."""
    if isinstance(luts, QuantizedLUT):
        return (np.asarray(luts.lut), np.asarray(luts.scale, np.float32),
                np.asarray(luts.bias, np.float32), int(luts.lut.shape[1]),
                True)
    t = np.asarray(luts, np.float32)
    return t, None, None, int(t.shape[1]), False


def _score(hl, qq: np.ndarray, codes: np.ndarray,
           m_prefix: int = 0) -> np.ndarray:
    """ADC distances for flattened (query, candidate) pairs.

    Args:
      hl:    the :func:`_host_luts` tuple.
      qq:    (T,) query index per pair.
      codes: (T, code_width) raw record code bytes.
      m_prefix: score only the first P subspaces (hop-pruning lower
        bound); 0 = all M.

    u8 matches the f32 LUT gather-sum oracle; fs4 matches the fast-scan
    contract exactly — int32 accumulation of uint8 LUT entries, one
    affine dequant ``scale·acc + M·bias`` per output (kernels/ref.py).
    An fs4 PREFIX still dequants with the FULL ``M·bias`` term (bias is
    per-query, not per-subspace — the ``quantize_luts`` convention).
    """
    tables, scale, bias, m, packed = hl
    if packed:
        lo, hi = codes & 0x0F, codes >> 4
        sub = np.empty((codes.shape[0], 2 * codes.shape[1]), np.uint8)
        sub[:, 0::2], sub[:, 1::2] = lo, hi
        sub = sub[:, :m]
    else:
        sub = codes
    mp = m_prefix if m_prefix else m
    gathered = tables[qq[:, None], np.arange(mp)[None, :],
                      sub[:, :mp].astype(np.int64)]
    if packed:
        acc = gathered.astype(np.int64).sum(axis=1)
        return (scale[qq] * acc.astype(np.float32)
                + np.float32(m) * bias[qq]).astype(np.float32)
    return gathered.astype(np.float32).sum(axis=1)


def _merge_beam(beam_ids, beam_d, beam_exp, cand_q, cand_ids, cand_d):
    """Fold scored candidates into the (sorted) beam, keeping width h."""
    q, h = beam_ids.shape
    counts = np.bincount(cand_q, minlength=q)
    cmax = int(counts.max()) if counts.size else 0
    if cmax == 0:
        return beam_ids, beam_d, beam_exp
    pad_ids = np.full((q, cmax), -1, np.int64)
    pad_d = np.full((q, cmax), _INF, np.float32)
    order = np.argsort(cand_q, kind="stable")
    cq = cand_q[order]
    col = np.arange(cq.size) - np.repeat(np.cumsum(counts) - counts, counts)
    pad_ids[cq, col] = cand_ids[order]
    pad_d[cq, col] = cand_d[order]
    all_ids = np.concatenate([beam_ids, pad_ids], axis=1)
    all_d = np.concatenate([beam_d, pad_d], axis=1)
    all_exp = np.concatenate([beam_exp, np.zeros((q, cmax), bool)], axis=1)
    keep = np.argsort(all_d, axis=1, kind="stable")[:, :h]
    rows = np.arange(q)[:, None]
    return (np.take_along_axis(all_ids, keep, 1),
            np.take_along_axis(all_d, keep, 1),
            np.take_along_axis(all_exp, keep, 1))


class DiskEngine:
    """All-in-storage serving over one generation's segment file.

    Build via :meth:`open` (newest intact generation + quantizer sidecar)
    or directly from a path/header when the caller manages those.

    Attributes:
      header:     the verified :class:`~repro.storage.format.SegmentHeader`.
      lut_fn:     (Q, D) queries → LUTs in the segment's layout.
      prefetcher: cache-fronted async record fetch.
      tombstones: optional deleted-id bitset (:meth:`delete` creates one).
      overlap:    default pipelining mode for :meth:`search`.
      last_io:    per-search I/O accounting (wall/io_wait/bytes/cache/...).
    """

    def __init__(self, path: str, header: segfmt.SegmentHeader,
                 lut_fn: Callable, *,
                 cache_records: int = 2048, io_threads: int = 4,
                 retry=None, fault_hook=None, slow_read_ms: float = 0.0,
                 seed_cache: bool = True, overlap: bool = True,
                 tombstones: Optional[Tombstones] = None):
        self.path = path
        self.header = header
        self.lut_fn = lut_fn
        self.overlap = bool(overlap)
        self.tombstones = tombstones
        self.reader = AsyncSegmentReader(
            path, header, io_threads=io_threads, retry=retry,
            fault_hook=fault_hook, slow_read_ms=slow_read_ms)
        self.cache = HotVertexCache(cache_records)
        self.prefetcher = FrontierPrefetcher(self.reader, self.cache)
        self._seed_order = np.asarray([header.medoid], np.int64)
        if seed_cache and cache_records > 0:
            self._seed_order = self.cache.seed_bfs(
                self.reader, header.medoid)
        self.last_io: dict = {}

    # -- lifecycle ---------------------------------------------------------

    @classmethod
    def open(cls, directory: str, generation: Optional[int] = None, *,
             lut_fn: Optional[Callable] = None,
             cache_mb: Optional[float] = None, cache_records: int = 2048,
             io_threads: int = 4, retry=None, fault_hook=None,
             slow_read_ms: float = 0.0, seed_cache: bool = True,
             overlap: bool = True, on_fallback=None) -> "DiskEngine":
        """Open the newest INTACT (or a given) generation under
        ``directory`` — a corrupt header falls back generation-by-
        generation exactly like ``index.segment.load_segment``
        (``on_fallback(generation, error)`` observes each skip).

        ``lut_fn=None`` rebuilds it from the ``gen_*.model.npz`` sidecar
        that ``write_segment(..., model=)`` wrote (quantized LUTs for fs4
        segments) — a fully self-contained, vector-free restore.
        ``cache_mb`` sizes the hot-vertex cache by DRAM budget and
        overrides ``cache_records``.
        """
        path, header = segfmt.open_segment(directory, generation,
                                           on_fallback=on_fallback)
        if lut_fn is None:
            mpath = segfmt.model_path(directory, header.generation)
            if not os.path.exists(mpath):
                raise ValueError(
                    f"no quantizer sidecar at {mpath} — pass lut_fn= or "
                    f"write the segment with write_segment(..., model=)")
            from repro.pq import base as pqbase
            with np.load(mpath) as z:
                model = pqbase.QuantizerModel(
                    r=jnp.asarray(z["r"], jnp.float32),
                    codebooks=jnp.asarray(z["codebooks"], jnp.float32))
            quantize = header.layout == "fs4"

            def lut_fn(q, _model=model, _quant=quantize):
                return pqbase.build_lut(_model, q, quantize=_quant)
        if cache_mb is not None:
            cache_records = int(cache_mb * 1e6) // max(1,
                                                       header.record_bytes)
        return cls(path, header, lut_fn, cache_records=cache_records,
                   io_threads=io_threads, retry=retry,
                   fault_hook=fault_hook, slow_read_ms=slow_read_ms,
                   seed_cache=seed_cache, overlap=overlap)

    def close(self) -> None:
        self.reader.close()

    def __enter__(self) -> "DiskEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def generation(self) -> int:
        return self.header.generation

    @property
    def n(self) -> int:
        return self.header.n

    def delete(self, ids) -> int:
        """Tombstone ids (lazy bitset over the segment's rows)."""
        if self.tombstones is None:
            self.tombstones = Tombstones(self.header.n)
        return self.tombstones.add(ids)

    def memory_bytes(self) -> int:
        # DRAM-resident serving state: the hot-vertex cache (+ tombstone
        # words); adjacency, codes, and vectors all live in storage
        resident = len(self.cache) * self.header.record_bytes
        if self.tombstones is not None:
            resident += self.tombstones._words.nbytes
        return resident

    # -- search ------------------------------------------------------------

    def _entries(self, entries: int) -> np.ndarray:
        """Entry vertices: the medoid, then the next S−1 BFS cache seeds
        (the graph's top layer — already resident, zero extra I/O).
        Tombstoned seeds are skipped over, not merely dropped: a deleted
        medoid must not sever routing while any other seed survives, so
        the first S ALIVE vertices of the BFS order serve as entries."""
        order = self._seed_order
        if order.size == 0:
            order = np.asarray([self.header.medoid], np.int64)
        if self.tombstones is not None:
            alive = ~self.tombstones.contains(order)
            if alive.any():
                order = order[alive]
        return np.unique(order[:max(1, int(entries))])

    def search(self, queries, *, k: int = 10, h: int = 32,
               max_steps: int = 512, expand: int = 1, entries: int = 1,
               prune_eps: float = 0.0, m_prefix: int = 0,
               max_rounds=None, max_n_dist=None,
               overlap: Optional[bool] = None) -> SearchResult:
        """Batched storage-backed beam search (engine protocol).

        ``max_rounds``/``max_n_dist`` are per-call budgets: an exhausted
        query freezes its frontier and reports ``truncated=True`` with
        its best-so-far answer — the jitted beam's honesty contract.
        ``overlap`` overrides the engine's default pipelining mode (the
        serial baseline the overlap benchmark compares against).
        """
        t_start = time.perf_counter()
        stats0 = self.prefetcher.stats()
        queries = jnp.atleast_2d(jnp.asarray(queries, jnp.float32))
        hl = _host_luts(self.lut_fn(queries))
        nq, n = int(queries.shape[0]), self.header.n
        mt = hl[3]
        mp = 0
        if prune_eps > 0.0 and mt >= 2:
            mp = m_prefix if m_prefix > 0 else max(1, mt // 4)
            mp = max(1, min(mp, mt - 1))
        use_overlap = self.overlap if overlap is None else bool(overlap)
        budget_rounds = max_steps if max_rounds is None else min(
            int(max_rounds), int(max_steps))

        beam_ids = np.full((nq, h), -1, np.int64)
        beam_d = np.full((nq, h), _INF, np.float32)
        beam_exp = np.zeros((nq, h), bool)
        visited = np.zeros((nq, n), bool)
        known: dict = {}            # vid -> (adj row, code row), per search
        hops = np.zeros((nq,), np.int64)
        n_dist = np.zeros((nq,), np.float64)
        rounds = np.zeros((nq,), np.int64)
        truncated = np.zeros((nq,), bool)
        exhausted = np.zeros((nq,), bool)   # budget-frozen queries

        def absorb(ids, adj, codes):
            for j, vid in enumerate(ids):
                known[int(vid)] = (adj[j], codes[j])

        def score_and_merge(cand_q, cand_ids):
            """Score scheduled (query, vid) pairs (prefix-gated when
            pruning) and fold the survivors into the beam."""
            nonlocal beam_ids, beam_d, beam_exp
            if cand_q.size == 0:
                return
            if self.tombstones is not None:
                dead = self.tombstones.contains(cand_ids)
                if dead.any():      # dead rows are never scored or kept
                    cand_q, cand_ids = cand_q[~dead], cand_ids[~dead]
                    if cand_q.size == 0:
                        return
            codes = np.stack([known[int(v)][1] for v in cand_ids])
            if mp:
                part = _score(hl, cand_q, codes, m_prefix=mp)
                est = part * (mt / mp)
                thresh = beam_d[cand_q, h - 1]
                keep = ~np.isfinite(thresh) | (
                    est <= (1.0 + prune_eps) * thresh)
                np.add.at(n_dist, cand_q, mp / mt)
                cand_q, cand_ids = cand_q[keep], cand_ids[keep]
                codes = codes[keep]
                if cand_q.size == 0:
                    return
                np.add.at(n_dist, cand_q, 1.0 - mp / mt)
            else:
                np.add.at(n_dist, cand_q, 1.0)
            d = _score(hl, cand_q, codes)
            beam_ids, beam_d, beam_exp = _merge_beam(
                beam_ids, beam_d, beam_exp, cand_q, cand_ids, d)

        def select_frontier():
            """Pick each query's best ≤``expand`` unexpanded beam entries
            (budget-frozen queries excluded), mark them expanded, and
            return ``((cand_q, cand_v), fetch_ids, active)`` — the
            scheduled pairs, the ids whose records we still need, and
            which queries expanded anything this round."""
            mask = ~beam_exp & np.isfinite(beam_d) & ~exhausted[:, None]
            if max_n_dist is not None:
                over = n_dist >= max_n_dist
                cut = over & ~exhausted & mask.any(axis=1)
                truncated[cut] = True
                exhausted[:] |= over
                mask &= ~exhausted[:, None]
            empty = (np.zeros((0,), np.int64), np.zeros((0,), np.int64))
            if not mask.any():
                return empty, empty[0], np.zeros((nq,), bool)
            # beam rows are dist-sorted, so a stable sort of ~mask keeps
            # the first `expand` True positions in best-first order
            sel = np.argsort(~mask, axis=1, kind="stable")[:, :expand]
            rows = np.arange(nq)[:, None]
            valid = mask[rows, sel]
            beam_exp[rows, sel] |= valid
            active = valid.any(axis=1)
            hops[:] += valid.sum(axis=1)
            cand_q_list, cand_v_list = [], []
            for qi in np.flatnonzero(active):
                fr = beam_ids[qi, sel[qi][valid[qi]]]
                nbr = np.concatenate([known[int(v)][0] for v in fr])
                nbr = np.unique(nbr[(nbr >= 0) & (nbr < n)])
                nbr = nbr[~visited[qi, nbr]]
                visited[qi, nbr] = True
                cand_q_list.append(np.full(nbr.size, qi, np.int64))
                cand_v_list.append(nbr.astype(np.int64))
            cand_q = (np.concatenate(cand_q_list) if cand_q_list
                      else empty[0])
            cand_v = (np.concatenate(cand_v_list) if cand_v_list
                      else empty[1])
            fetch = np.unique(cand_v)
            if fetch.size:
                fetch = np.asarray(
                    [v for v in fetch if int(v) not in known], np.int64)
            return (cand_q, cand_v), fetch, active

        # seed the beam: entry records come through the prefetcher (cache
        # hits for BFS-seeded vertices), scored like any candidate
        entry = self._entries(entries)
        absorb(*self.prefetcher.fetch(entry))
        visited[:, entry] = True
        score_and_merge(np.repeat(np.arange(nq), entry.size),
                        np.tile(entry, nq))

        pending = None      # (PendingFetch | None, cand_q, cand_v, active)
        round_i = 0
        while round_i < budget_rounds:
            if pending is None:
                (cand_q, cand_v), fetch, active = select_frontier()
                if not active.any():
                    break
                pending = (self.prefetcher.prefetch(fetch)
                           if fetch.size else None, cand_q, cand_v, active)
            pf, cand_q, cand_v, active = pending
            pending = None
            if use_overlap and pf is not None and (
                    pf.future is None or pf.future.done()):
                # the reads already landed (fast storage / big compute):
                # merge first and select FRESH — staleness is only worth
                # paying when there is actual I/O latency left to hide
                absorb(*self.prefetcher.collect(pf))
                score_and_merge(cand_q, cand_v)
                rounds[:] += active
                round_i += 1
                continue
            if use_overlap:
                # double-buffer: issue round N+1's reads (stale, pre-merge
                # frontier) BEFORE waiting on / scoring round N
                npairs, nfetch, nactive = select_frontier()
                if nactive.any():
                    next_pf = (self.prefetcher.prefetch(nfetch)
                               if nfetch.size else None)
                    pending = (next_pf, npairs[0], npairs[1], nactive)
            if pf is not None:
                absorb(*self.prefetcher.collect(pf))
            score_and_merge(cand_q, cand_v)
            rounds[:] += active
            round_i += 1
        else:
            # round budget exhausted with frontier work still pending
            left = ~beam_exp & np.isfinite(beam_d) & ~exhausted[:, None]
            truncated[:] |= left.any(axis=1)
        if pending is not None:     # drain an in-flight fetch cleanly
            if pending[0] is not None:
                absorb(*self.prefetcher.collect(pending[0]))
            truncated[:] |= pending[3]

        out_ids = beam_ids[:, :k].astype(np.int32)
        out_d = beam_d[:, :k]
        out_ids = np.where(np.isfinite(out_d), out_ids, -1)
        wall = time.perf_counter() - t_start
        s1 = self.prefetcher.stats()
        hits = s1["cache_hits"] - stats0["cache_hits"]
        miss = s1["cache_misses"] - stats0["cache_misses"]
        self.last_io = {
            "wall_s": wall,
            "io_wait_s": s1["io_wait_s"] - stats0["io_wait_s"],
            "bytes_read": s1["bytes_read"] - stats0["bytes_read"],
            "n_reads": s1["n_reads"] - stats0["n_reads"],
            "n_batches": s1["n_batches"] - stats0["n_batches"],
            "n_retries": s1["n_retries"] - stats0["n_retries"],
            "cache_hits": hits, "cache_misses": miss,
            "cache_hit_rate": hits / (hits + miss) if hits + miss else 0.0,
            "rounds_total": int(round_i), "overlap": use_overlap,
        }
        return SearchResult(
            jnp.asarray(out_ids), jnp.asarray(out_d),
            hops=jnp.asarray(hops, jnp.int32),
            n_dist=jnp.asarray(np.rint(n_dist), jnp.int32),
            rounds=jnp.asarray(rounds, jnp.int32),
            truncated=jnp.asarray(truncated),
            degraded=False)
