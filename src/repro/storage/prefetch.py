"""Double-buffered frontier prefetch: round N's reads under round N−1's
compute.

The overlap half of the storage tier (DESIGN.md §14). A storage-backed
beam round is two phases — fetch the frontier's candidate records, then
score them — and run serially the round costs ``io + compute``. The
prefetcher turns that into ``max(io, compute)``: the engine calls
:meth:`prefetch` for the NEXT round's ids (selected one round stale, see
``storage/engine.py``) BEFORE scoring the in-flight round, so the reader's
threads fill the next buffer while the host's ADC gather runs.

The cache sits in front of every fetch: ``prefetch`` partitions the
request into cache hits (served immediately, zero I/O) and misses (one
async reader batch), and ``collect`` reassembles them in request order and
inserts the fresh records — so hot top-layer vertices never hit the disk
twice regardless of which round asks.

``io_wait_s`` accumulates only the time ``collect`` actually BLOCKED on
the in-flight Future — the measured, post-overlap I/O stall that
``HybridEngine.io_time(..., measured_io_s=)`` cross-checks against the
closed-form model.
"""

from __future__ import annotations

import dataclasses
import time
from concurrent.futures import Future
from typing import Optional

import numpy as np

from repro.storage.cache import HotVertexCache
from repro.storage.reader import AsyncSegmentReader


@dataclasses.dataclass
class PendingFetch:
    """One in-flight round: the request order, its cache hits, and the
    Future covering the misses (None = fully cache-served)."""

    ids: np.ndarray                 # requested ids, dedup'd, request order
    hits: dict                      # vid -> (adj_row, code_row)
    missing: np.ndarray
    future: Optional[Future]


class FrontierPrefetcher:
    """Cache-fronted async fetch of per-vertex records."""

    def __init__(self, reader: AsyncSegmentReader,
                 cache: Optional[HotVertexCache] = None):
        self.reader = reader
        self.cache = cache if cache is not None else HotVertexCache(0)
        self.io_wait_s = 0.0        # blocked time in collect() (post-overlap)
        self.n_prefetches = 0

    def prefetch(self, ids) -> PendingFetch:
        """Issue the next round's reads: cache hits resolve now, misses go
        to the reader's thread pool. Returns the token ``collect`` needs."""
        ids = np.unique(np.asarray(ids, np.int64))
        hits, missing = self.cache.get_many(ids)
        fut = self.reader.submit(missing) if missing.size else None
        self.n_prefetches += 1
        return PendingFetch(ids=ids, hits=hits, missing=missing, future=fut)

    def collect(self, pending: PendingFetch):
        """Wait for the in-flight reads and assemble ``(ids, adjacency,
        codes)`` in request order; fresh records enter the cache."""
        if pending.future is not None:
            t0 = time.perf_counter()
            madj, mcodes = pending.future.result()
            self.io_wait_s += time.perf_counter() - t0
            self.cache.put_many(pending.missing, madj, mcodes)
            fresh = {int(v): (madj[j], mcodes[j])
                     for j, v in enumerate(pending.missing)}
        else:
            fresh = {}
        hdr = self.reader.header
        b = pending.ids.size
        adj = np.empty((b, hdr.r), np.int32)
        codes = np.empty((b, hdr.code_width), np.uint8)
        for j, vid in enumerate(pending.ids):
            row = pending.hits.get(int(vid)) or fresh[int(vid)]
            adj[j], codes[j] = row
        return pending.ids, adj, codes

    def fetch(self, ids):
        """Synchronous fetch — ``collect(prefetch(ids))`` (the serial
        baseline path; identical records, no overlap)."""
        return self.collect(self.prefetch(ids))

    def stats(self) -> dict:
        return {"io_wait_s": self.io_wait_s,
                "n_prefetches": self.n_prefetches,
                **{f"cache_{k}": v for k, v in self.cache.stats().items()},
                **self.reader.stats()}

    def reset_stats(self) -> None:
        self.io_wait_s = 0.0
        self.n_prefetches = 0
        self.cache.reset_stats()
        self.reader.reset_stats()
