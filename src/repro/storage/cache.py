"""LRU hot-vertex cache, BFS-seeded from the medoid.

The DRAM half of the storage tier's working set (DESIGN.md §14): a bounded
map ``vertex id → (adjacency row, code row)``. Graph-routed search traffic
is wildly skewed — every query enters at the medoid and fans out through
the graph's "top layers", so the few thousand vertices within a couple of
hops of the entry point appear in almost every query's early rounds.
:meth:`HotVertexCache.seed_bfs` pre-loads exactly that set (breadth-first
from the medoid until the budget fills), and LRU keeps whatever else the
live traffic re-touches.

Counters are first-class: ``hits`` / ``misses`` (record granularity) and
the hit rate feed the bench's per-row cache accounting, and a cached hit
is a read that never reached the reader — so (cache hits × record_bytes)
+ reader ``bytes_read`` is the total record traffic either way.
"""

from __future__ import annotations

from collections import OrderedDict

import numpy as np

from repro.storage.format import SegmentHeader


class HotVertexCache:
    """Bounded LRU of per-vertex records.

    Args:
      capacity: maximum records held (0 disables — every get misses).
    """

    def __init__(self, capacity: int):
        self.capacity = max(0, int(capacity))
        self._map: OrderedDict = OrderedDict()   # LRU half
        self._pinned: dict = {}                  # BFS seeds, never evicted
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.seeded = 0

    @classmethod
    def from_bytes(cls, budget_bytes: int,
                   header: SegmentHeader) -> "HotVertexCache":
        """Size by a DRAM budget: floor(budget / record_bytes) records."""
        return cls(int(budget_bytes) // max(1, header.record_bytes))

    def __len__(self) -> int:
        return len(self._map) + len(self._pinned)

    def __contains__(self, vid) -> bool:
        return int(vid) in self._pinned or int(vid) in self._map

    # -- read/write --------------------------------------------------------

    def get_many(self, ids):
        """Partition a request: ``(found: {vid: (adj, codes)}, missing)``.

        Hits move to MRU position; counters update per record requested
        (``ids`` should already be deduplicated by the caller).
        """
        found, missing = {}, []
        for vid in np.asarray(ids, np.int64):
            vid = int(vid)
            rec = self._pinned.get(vid)
            if rec is None:
                rec = self._map.get(vid)
                if rec is not None:
                    self._map.move_to_end(vid)
            if rec is None:
                self.misses += 1
                missing.append(vid)
            else:
                self.hits += 1
                found[vid] = rec
        return found, np.asarray(missing, np.int64)

    def put_many(self, ids, adj, codes) -> None:
        """Insert freshly-read records ((B, R) adjacency, (B, W) codes)
        into the LRU half, evicting past its share of capacity. Pinned
        (BFS-seeded) records are never evicted — a beam search streams
        ~every record it touches exactly once, which would otherwise flush
        the hot top layers right before the next query re-enters at the
        medoid (sequential-scan LRU pathology)."""
        lru_cap = self.capacity - len(self._pinned)
        if lru_cap <= 0:
            return
        for j, vid in enumerate(np.asarray(ids, np.int64)):
            vid = int(vid)
            if vid in self._pinned:
                continue
            if vid in self._map:
                self._map.move_to_end(vid)
                continue
            self._map[vid] = (adj[j], codes[j])
            if len(self._map) > lru_cap:
                self._map.popitem(last=False)
                self.evictions += 1

    # -- seeding -----------------------------------------------------------

    def seed_bfs(self, reader, medoid: int, *,
                 budget: int = 0) -> np.ndarray:
        """Pre-load and PIN the graph's top layers: BFS from ``medoid``
        through the on-disk adjacency until ``budget`` records (default:
        half the capacity — the other half stays LRU for live traffic)
        are resident. Pinned records are exempt from eviction: they are
        the set every query's early rounds touch, and the cache exists to
        keep exactly them DRAM-resident. Returns the seeded ids in BFS
        order — the natural multi-entry set for
        :class:`~repro.storage.engine.DiskEngine` (``entries=S`` starts
        the beam on the first S of them).

        Seeding reads THROUGH ``reader`` (levels fetched as batches), so
        its bytes land in the reader's counters like any other traffic.
        """
        budget = min(budget or self.capacity // 2, self.capacity)
        n = reader.header.n
        if budget <= 0 or n == 0:
            return np.zeros((0,), np.int64)
        seen = {int(medoid)}
        order = []
        frontier = np.asarray([int(medoid)], np.int64)
        while frontier.size and len(order) < budget:
            frontier = frontier[:budget - len(order)]
            adj, codes = reader.read_records(frontier)
            for j, vid in enumerate(frontier):
                self._pinned[int(vid)] = (adj[j], codes[j])
            order.extend(int(v) for v in frontier)
            nxt = np.unique(adj[(adj >= 0) & (adj < n)])
            frontier = np.asarray(
                [int(v) for v in nxt if int(v) not in seen], np.int64)
            seen.update(int(v) for v in frontier)
        self.seeded = len(order)
        return np.asarray(order, np.int64)

    # -- accounting --------------------------------------------------------

    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {"capacity": self.capacity, "resident": len(self),
                "pinned": len(self._pinned),
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "seeded": self.seeded,
                "hit_rate": self.hit_rate()}

    def reset_stats(self) -> None:
        self.hits = self.misses = self.evictions = 0
