"""On-disk segment format: per-vertex records in one mmap-able file.

The all-in-storage serving tier (DESIGN.md §14, AiSAQ — PAPERS.md arxiv
2404.06004) keeps the Vamana adjacency AND the packed PQ codes entirely on
disk; only the per-query LUTs, the entry points, and a bounded hot-vertex
cache stay DRAM-resident. The unit of I/O is the per-vertex RECORD — one
contiguous slab holding the vertex's R int32 neighbor ids followed by its
code bytes (u8 or fs4-packed, exactly the bytes a :class:`repro.index
.segment.BaseSegment` carries) — so a single read yields both what a beam
round needs to SCORE the vertex (codes) and what a later round needs to
EXPAND it (adjacency): expansion never costs a second read.

File layout (``gen_<generation:08d>.seg``):

    [ header page: HEADER_SIZE bytes                                  ]
    [   MAGIC (8) | json_len u32 LE | json_crc32 u32 LE | json | pad  ]
    [ records: n × record_bytes, 8-byte aligned                       ]

The JSON header carries {n, r, code_width, layout, generation, dim,
medoid, record_bytes, data_crc32} and is CRC-checked on open — a torn or
corrupted header raises :class:`SegmentFormatError`, which
:func:`open_segment` turns into newest-intact-generation fallback (the
same discipline as ``index.segment.load_segment``). ``data_crc32`` covers
the whole record region for offline audits (:meth:`SegmentHeader
.verify_data`); per-record reads do not re-hash — the hot path trusts the
device, the drills corrupt on purpose (:func:`corrupt_record`).

Segments are written ATOMICALLY (tmp + ``os.replace``) and are immutable
per generation: a consolidation writes ``gen_00000001.seg`` next to
``gen_00000000.seg``, readers open the newest intact one. ``write_segment
(..., model=)`` drops a ``gen_*.model.npz`` sidecar (rotation + codebooks)
so :meth:`repro.storage.engine.DiskEngine.open` restores self-contained.
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
import zlib
from typing import Callable, Optional

import numpy as np

MAGIC = b"RGPQSEG1"
HEADER_SIZE = 4096
FORMAT_VERSION = 1
_SEG_RE = re.compile(r"^gen_(\d{8})\.seg$")


class SegmentFormatError(ValueError):
    """The segment file's header (or size) fails verification."""


def record_bytes_for(r: int, code_width: int) -> int:
    """Bytes per vertex record: R int32 neighbors + code bytes, padded to
    8-byte alignment so mmap'd int32 views stay aligned."""
    raw = 4 * int(r) + int(code_width)
    return (raw + 7) // 8 * 8


@dataclasses.dataclass(frozen=True)
class SegmentHeader:
    """Decoded, verified header of one segment file."""

    n: int
    r: int                 # graph degree (neighbor slots per record)
    code_width: int        # code bytes per vertex (M for u8, ceil(M/2) fs4)
    layout: str            # "u8" | "fs4"
    generation: int
    dim: int               # original vector dimensionality (metadata only)
    medoid: int            # DRAM-resident entry point
    record_bytes: int
    data_crc32: int
    version: int = FORMAT_VERSION

    @property
    def data_bytes(self) -> int:
        return self.n * self.record_bytes

    @property
    def file_bytes(self) -> int:
        return HEADER_SIZE + self.data_bytes

    def record_offset(self, vid: int) -> int:
        return HEADER_SIZE + vid * self.record_bytes

    def parse_records(self, raw: bytes, count: int):
        """(count · record_bytes) raw bytes → ((count, R) int32 adjacency,
        (count, code_width) uint8 codes) — the one decode used by reader,
        cache seeding, and the round-trip tests alike."""
        a = np.frombuffer(raw, np.uint8).reshape(count, self.record_bytes)
        adj = a[:, :4 * self.r].copy().view(np.int32).reshape(count, self.r)
        codes = a[:, 4 * self.r:4 * self.r + self.code_width].copy()
        return adj, codes

    def verify_data(self, path: str) -> None:
        """Offline audit: re-hash the whole record region against the
        header's ``data_crc32`` (not on the hot path)."""
        crc = 0
        with open(path, "rb") as f:
            f.seek(HEADER_SIZE)
            while True:
                chunk = f.read(1 << 20)
                if not chunk:
                    break
                crc = zlib.crc32(chunk, crc)
        if crc != self.data_crc32:
            raise SegmentFormatError(
                f"{path}: record region crc32 {crc:#010x} != header "
                f"{self.data_crc32:#010x} — segment data is corrupt")


def segment_path(directory: str, generation: int) -> str:
    return os.path.join(directory, f"gen_{int(generation):08d}.seg")


def model_path(directory: str, generation: int) -> str:
    return os.path.join(directory, f"gen_{int(generation):08d}.model.npz")


def all_generations(directory: str) -> list:
    """Sorted generations with a segment file under ``directory``."""
    if not os.path.isdir(directory):
        return []
    gens = []
    for name in os.listdir(directory):
        m = _SEG_RE.match(name)
        if m:
            gens.append(int(m.group(1)))
    return sorted(gens)


def write_segment(directory: str, seg, model=None) -> str:
    """Serialize a :class:`repro.index.segment.BaseSegment` into the
    record format, atomically (tmp + ``os.replace``). Returns the path.

    Only the adjacency and codes are written — the float vectors stay
    wherever the snapshot keeps them; this tier serves without them.
    ``model`` (a ``pq.base.QuantizerModel``) lands in a sidecar npz so a
    reader can rebuild the LUT function with no caller-side state.
    """
    neighbors = np.asarray(seg.graph.neighbors, np.int32)
    codes = np.ascontiguousarray(np.asarray(seg.codes), dtype=np.uint8)
    n, r = neighbors.shape
    if codes.shape[0] != n:
        raise ValueError(f"codes rows {codes.shape[0]} != graph rows {n}")
    code_width = codes.shape[1]
    rb = record_bytes_for(r, code_width)
    records = np.zeros((n, rb), np.uint8)
    records[:, :4 * r] = neighbors.view(np.uint8).reshape(n, 4 * r)
    records[:, 4 * r:4 * r + code_width] = codes
    raw = records.tobytes()

    meta = {"version": FORMAT_VERSION, "n": n, "r": r,
            "code_width": code_width, "layout": str(seg.layout),
            "generation": int(seg.generation),
            "dim": int(seg.dim), "medoid": int(seg.graph.medoid),
            "record_bytes": rb, "data_crc32": zlib.crc32(raw)}
    blob = json.dumps(meta).encode()
    if len(blob) > HEADER_SIZE - 16:
        raise ValueError(f"segment header json too large: {len(blob)}")
    header = bytearray(HEADER_SIZE)
    header[:8] = MAGIC
    header[8:12] = np.uint32(len(blob)).tobytes()
    header[12:16] = np.uint32(zlib.crc32(blob)).tobytes()
    header[16:16 + len(blob)] = blob

    os.makedirs(directory, exist_ok=True)
    final = segment_path(directory, seg.generation)
    tmp = final + f".tmp-{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(bytes(header))
        f.write(raw)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)
    if model is not None:
        np.savez(model_path(directory, seg.generation),
                 r=np.asarray(model.r, np.float32),
                 codebooks=np.asarray(model.codebooks, np.float32))
    return final


def read_header(path: str) -> SegmentHeader:
    """Parse + verify a segment file's header page.

    Raises :class:`SegmentFormatError` on a missing/short header, wrong
    magic, CRC mismatch, or a file shorter than the records the header
    promises — every way a torn write or bit flip can present.
    """
    try:
        with open(path, "rb") as f:
            head = f.read(HEADER_SIZE)
    except OSError as e:
        raise SegmentFormatError(f"{path}: unreadable header: {e}") from e
    if len(head) < HEADER_SIZE:
        raise SegmentFormatError(
            f"{path}: truncated header ({len(head)} < {HEADER_SIZE} bytes)")
    if head[:8] != MAGIC:
        raise SegmentFormatError(
            f"{path}: bad magic {head[:8]!r} (want {MAGIC!r})")
    blob_len = int(np.frombuffer(head[8:12], np.uint32)[0])
    want_crc = int(np.frombuffer(head[12:16], np.uint32)[0])
    if blob_len > HEADER_SIZE - 16:
        raise SegmentFormatError(f"{path}: header json length {blob_len} "
                                 f"exceeds the header page")
    blob = head[16:16 + blob_len]
    if zlib.crc32(blob) != want_crc:
        raise SegmentFormatError(
            f"{path}: header json crc32 {zlib.crc32(blob):#010x} != "
            f"recorded {want_crc:#010x} — header is corrupt")
    try:
        meta = json.loads(blob.decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise SegmentFormatError(f"{path}: header json unparseable: "
                                 f"{e}") from e
    hdr = SegmentHeader(**{f.name: meta[f.name] for f in
                           dataclasses.fields(SegmentHeader)})
    if os.path.getsize(path) < hdr.file_bytes:
        raise SegmentFormatError(
            f"{path}: file holds {os.path.getsize(path)} bytes but the "
            f"header promises {hdr.file_bytes} — records truncated")
    return hdr


def open_segment(directory: str, generation: Optional[int] = None, *,
                 on_fallback: Optional[Callable] = None):
    """Open the newest INTACT (or a specific) generation's segment file.

    Returns ``(path, header)``. Mirrors ``index.segment.load_segment``'s
    fallback contract: with ``generation=None`` a segment whose header
    fails verification does not poison the open — the loader walks
    generations newest-first, calling ``on_fallback(generation, error)``
    per rejected file, and raises only when none survives. An explicit
    ``generation`` never falls back.
    """
    if generation is not None:
        path = segment_path(directory, generation)
        if not os.path.exists(path):
            raise FileNotFoundError(
                f"no segment for generation {generation} under "
                f"{directory!r} (available: {all_generations(directory)})")
        return path, read_header(path)
    gens = all_generations(directory)
    if not gens:
        raise FileNotFoundError(f"no segment files under {directory!r}")
    failures = []
    for gen in reversed(gens):
        path = segment_path(directory, gen)
        try:
            return path, read_header(path)
        except SegmentFormatError as e:
            failures.append((gen, e))
            if on_fallback is not None:
                on_fallback(gen, e)
    detail = "; ".join(f"gen {g}: {e}" for g, e in failures)
    raise RuntimeError(
        f"no intact segment under {directory!r} — every generation failed "
        f"header verification: {detail}")


# --------------------------------------------------------------------------
# Chaos helpers (DESIGN.md §13/§14): deliberate, seeded corruption of the
# on-disk segment, for the resilience drills. Both flip bytes IN PLACE —
# unlike snapshot corruption there is no container checksum to stay
# consistent with; the header CRC (or a verify_data audit) is the only
# detector, which is exactly the layer the drills exercise.
# --------------------------------------------------------------------------

def corrupt_header(path: str, *, seed: int = 0) -> int:
    """Flip one byte inside the header's json region. Returns the offset."""
    rng = np.random.default_rng(seed)
    blob_len = max(1, int(np.frombuffer(
        open(path, "rb").read(12)[8:12], np.uint32)[0]))
    off = 16 + int(rng.integers(blob_len))
    with open(path, "r+b") as f:
        f.seek(off)
        b = f.read(1)
        f.seek(off)
        f.write(bytes([b[0] ^ 0xFF]))
    return off


def corrupt_record(path: str, vid: Optional[int] = None, *,
                   seed: int = 0) -> int:
    """Flip one byte inside vertex ``vid``'s record (random vertex when
    None). The header stays intact — this is SILENT data corruption, the
    kind only ``verify_data`` (or a recall drill) can observe. Returns the
    corrupted vertex id."""
    hdr = read_header(path)
    rng = np.random.default_rng(seed)
    if vid is None:
        vid = int(rng.integers(hdr.n))
    off = hdr.record_offset(vid) + int(rng.integers(hdr.record_bytes))
    with open(path, "r+b") as f:
        f.seek(off)
        b = f.read(1)
        f.seek(off)
        f.write(bytes([b[0] ^ 0xFF]))
    return int(vid)
