"""Deadline-aware degradation ladder (DESIGN.md §13).

When a serving deadline tightens — a straggler shard, a slow disk, a load
spike — the right response is not a timeout error but a CHEAPER answer:
every rung below trades a known quantity of recall for a known quantity of
compute, in a fixed order, so operators reason about "level 3" instead of
a combinatorial knob space.

The ladder steps down the adaptive-routing configuration (DESIGN.md §11)
first — those knobs buy recall with extra work, so they are the first
work to shed — then drops the exact-rerank pass, then the delta scan:

* **L0** — full configuration, nothing shed.
* **L1** — frontier batching off (``expand=1``): one expansion per round,
  the smallest per-round distance bill.
* **L2** — multi-entry seeding off (``entries=1``): skip the coarse-index
  probe, route from the medoid alone.
* **L3** — aggressive hop pruning (``prune_eps`` raised to
  :data:`AGGRESSIVE_PRUNE_EPS`): the partial-LUT lower bound gates more
  full scores, accepting more wrong prunes.
* **L4** — exact rerank off (``rerank=-1``): answer straight from the ADC
  beam (engines without a rerank pass ignore this rung).
* **L5** — delta scan off (``skip_delta=True``): fresh inserts go
  invisible until the next consolidation (StreamingEngine only).

Rungs are CUMULATIVE: level 3 applies L1+L2+L3. :meth:`DegradationPolicy
.apply` filters the overrides against the target engine's ``search``
signature, so one policy drives every engine — a rung an engine cannot
express is simply skipped there. Compute budgets (``max_rounds`` /
``max_n_dist``) are orthogonal: the ladder changes WHAT work a round does,
budgets bound HOW MANY rounds run; launch/serve.py applies both.
"""

from __future__ import annotations

import dataclasses
import inspect
from typing import Optional

AGGRESSIVE_PRUNE_EPS = 0.5

# rung → the search-kwarg overrides it adds (cumulative over lower rungs)
_LADDER: tuple[dict, ...] = (
    {},                                        # L0: full
    {"expand": 1},                             # L1: no frontier batching
    {"entries": 1},                            # L2: no multi-entry seeding
    {"prune_eps": AGGRESSIVE_PRUNE_EPS,        # L3: aggressive hop pruning
     "m_prefix": 0},                           #     (auto prefix split)
    {"rerank": -1},                            # L4: no exact rerank
    {"skip_delta": True},                      # L5: no delta scan
)

MAX_LEVEL = len(_LADDER) - 1


@dataclasses.dataclass(frozen=True)
class DegradationPolicy:
    """Maps a degradation level to concrete ``search()`` overrides.

    ``max_level`` clamps how far down the ladder this deployment is willing
    to go (e.g. a freshness-critical service sets ``max_level=4`` so the
    delta scan never drops). ``prune_eps`` overrides the L3 epsilon.
    """

    max_level: int = MAX_LEVEL
    prune_eps: float = AGGRESSIVE_PRUNE_EPS

    def __post_init__(self):
        if not 0 <= self.max_level <= MAX_LEVEL:
            raise ValueError(
                f"max_level must be in [0, {MAX_LEVEL}], got "
                f"{self.max_level}")

    def clamp(self, level: int) -> int:
        return max(0, min(int(level), self.max_level))

    def overrides(self, level: int) -> dict:
        """Cumulative search-kwarg overrides for ``level`` (clamped)."""
        out: dict = {}
        for rung in _LADDER[:self.clamp(level) + 1]:
            out.update(rung)
        if "prune_eps" in out:
            out["prune_eps"] = self.prune_eps
        return out

    def apply(self, engine, level: int, **search_kwargs) -> dict:
        """Final kwargs for ``engine.search``: the caller's kwargs with the
        level's overrides ON TOP, filtered to the parameters this engine's
        ``search`` actually accepts — one ladder, five engines."""
        params = inspect.signature(engine.search).parameters
        merged = dict(search_kwargs)
        for key, val in self.overrides(level).items():
            if key in params:
                merged[key] = val
        return merged

    def search(self, engine, queries, *, level: int = 0, **search_kwargs):
        """``engine.search`` at a degradation level."""
        return engine.search(queries,
                             **self.apply(engine, level, **search_kwargs))


def recommend_level(policy: DegradationPolicy, *, observed_s: float,
                    deadline_s: float, current: int = 0,
                    headroom: float = 0.8) -> int:
    """One-step ladder controller: step DOWN a rung when the observed batch
    latency exceeds the deadline, step back UP when it clears the deadline
    with ``headroom`` to spare (hysteresis — the gap between the two
    thresholds keeps the level from oscillating every batch)."""
    if observed_s > deadline_s:
        return policy.clamp(current + 1)
    if observed_s < headroom * deadline_s:
        return policy.clamp(current - 1)
    return policy.clamp(current)
