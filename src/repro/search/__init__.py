"""Routing + serving: batched beam search, ADC, engines, metrics."""
from repro.search.beam import (  # noqa: F401
    beam_search, beam_search_trace, SearchResult, Trace,
    make_exact_dist_fn, make_adc_dist_fn,
)
