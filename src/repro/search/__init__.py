"""Routing + serving: batched beam search, ADC distance functions, the four
serving engines (in-memory / hybrid / sharded-scan / sharded-graph), and
evaluation metrics.

Public surface:

* :mod:`repro.search.beam`    — jitted batched beam search (+ traced
  variant for the paper's Def. 6 routing features) and pluggable distance
  functions (exact, ADC; fused hop-ADC Pallas kernel on TPU).
* :mod:`repro.search.seed`    — PQ-hash multi-entry seeding (adaptive
  routing, DESIGN.md §11): a PQTable-style coarse index over the resident
  codes that turns each query's LUT into S near-query beam entry points.
* :mod:`repro.search.engine`  — ``InMemoryEngine`` / ``HybridEngine`` /
  ``ShardedEngine`` / ``ShardedGraphEngine`` plus the shard_map scatter
  bodies they (and launch/cells.py) compile.
* :mod:`repro.search.degrade` — the deadline-aware degradation ladder
  (DESIGN.md §13): numbered recall-for-compute rungs over the adaptive
  routing knobs, rerank and delta scan.
* :mod:`repro.search.metrics` — recall@k and QPS measurement.
"""
from repro.search.beam import (  # noqa: F401
    beam_search, beam_search_trace, SearchResult, Trace,
    make_exact_dist_fn, make_adc_dist_fn, make_lb_scale_fn,
)
from repro.search.seed import (  # noqa: F401
    SeedIndex, auto_m_hash, build_seed_index, seed_entries_from,
)
from repro.search.engine import (  # noqa: F401
    HybridEngine, InMemoryEngine, ShardedEngine, ShardedGraphEngine,
)
from repro.search.degrade import (  # noqa: F401
    DegradationPolicy, recommend_level,
)
from repro.search.metrics import (  # noqa: F401
    live_ground_truth, measure_qps, recall_at_k,
)
