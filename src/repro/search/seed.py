"""PQ-hash multi-entry seeding — the coarse half of adaptive routing
(DESIGN.md §11; PQTable, PAPERS.md arxiv 1704.06556).

The classic beam starts every query at the one medoid and spends its first
~half of the walk just escaping the medoid's neighborhood. This module
builds a PQTable-style coarse index over the RESIDENT PQ codes — no extra
training, no new quantizer — and turns a query's own LUT into S near-query
entry points:

* **Hash buckets** keyed on the first ``m_hash`` subquantizer codes: bucket
  key = base-K positional fold ``sum_j code_j · K^j``. The QUERY side gets
  its key for free from the LUT it already built — ``argmin_k lut[j, k]``
  IS the sub-code the quantizer would assign the query's j-th subvector
  (same codebook, same metric), so hashing costs one argmin over the first
  ``m_hash`` LUT rows. Rows landing in the same bucket agree with the query
  on their first sub-codes — cheap coarse locality.
* **Pivot fallback**: ``n_pivots`` rows strided across the corpus are
  ALWAYS appended to the candidate set, so an empty/thin bucket degrades to
  bulk-ADC-over-sampled-pivots instead of failing (and a full bucket still
  gains corpus-wide diversity).

``seed_entries`` scores bucket ∪ pivots with the full LUT in one bulk ADC
gather, dedupes, and returns the fixed-shape (Q, S) top-S ids —
``beam_search``'s multi-entry ``entry`` argument. Invalid lanes are -1
(the beam treats them as padding). Tombstoned candidates (streaming) score
``DEAD_ENTRY_DIST``: live seeds always outrank them, but an all-dead
candidate set still returns finite entries that route, exactly like the
classic deleted-medoid case.

Everything here is fixed-shape: bucket table (K^m_hash, bucket_cap) with -1
padding, candidate set (bucket_cap + n_pivots) per query — shard_map- and
jit-friendly, so the sharded engines seed per-shard INSIDE the scatter
body (``seed_entries_from`` is the functional core they compile).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.pq.pack import QuantizedLUT
from repro.search.beam import DEAD_ENTRY_DIST, INF, _bit_get, \
    _first_occurrence

# Bucket-count ceiling for auto_m_hash: K^m_hash buckets ≤ this. 4096
# int32×bucket_cap rows is ≤ 512 KiB at the default cap — resident
# everywhere — while K=64 still gets 2 hashed subspaces and K=16 gets 3.
MAX_BUCKETS = 4096


def auto_m_hash(m: int, k: int, max_buckets: int = MAX_BUCKETS) -> int:
    """Largest prefix length t ∈ [1, min(m, 4)] with K^t ≤ max_buckets."""
    t = 1
    while t < min(m, 4) and k ** (t + 1) <= max_buckets:
        t += 1
    return t


@dataclasses.dataclass(frozen=True)
class SeedIndex:
    """Coarse index over one corpus (or one shard's rows).

    Attributes:
      table:  (K^m_hash, bucket_cap) int32 bucket members, -1 padded.
      pivots: (P,) int32 strided sample rows (always-valid fallback).
      codes:  (N, M) int32 UNPACKED resident codes (scores the candidates;
              for fs4 corpora this is the unpacked copy — N·M·4 bytes,
              small next to the vectors the corpus already dropped).
      k:      codebook size the keys are folded in (static).
      m_hash: hashed prefix length (static).
    """
    table: jax.Array
    pivots: jax.Array
    codes: jax.Array
    k: int
    m_hash: int

    @property
    def n_candidates(self) -> int:
        """Candidates scored per query (bucket_cap + n_pivots)."""
        return int(self.table.shape[1] + self.pivots.shape[0])

    def seed_entries(self, luts, s: int,
                     tombstones: Optional[jax.Array] = None) -> jax.Array:
        """(Q, S) int32 entry sets for this query batch (-1 = no seed)."""
        return seed_entries_from(self.table, self.pivots, self.codes, luts,
                                 tombstones, k=self.k, m_hash=self.m_hash,
                                 s=s)


def build_seed_index(codes, *, k: Optional[int] = None,
                     m_hash: Optional[int] = None, bucket_cap: int = 16,
                     n_pivots: int = 32,
                     max_buckets: int = MAX_BUCKETS) -> SeedIndex:
    """Build the coarse index from UNPACKED (N, M) codes (host, numpy).

    ``k=None`` derives the codebook size from the codes themselves
    (max + 1) — build and query side must fold keys in the SAME base, and
    the query side must argmin only the first k LUT columns (quantize_luts
    zero-pads fs4 tables to 16 columns; an argmin over the padding would
    always pick it). Bucket overflow keeps the FIRST bucket_cap members
    (row order — Vamana medoid-adjacent rows come early on no particular
    schedule; any stable subset works, the pivots add diversity anyway).
    """
    codes_np = np.asarray(codes)
    n, m = codes_np.shape
    if n == 0:
        raise ValueError("build_seed_index: empty corpus")
    if k is None:
        k = int(codes_np.max()) + 1
    if m_hash is None:
        m_hash = auto_m_hash(m, k, max_buckets)
    m_hash = max(1, min(m_hash, m))
    nb = k ** m_hash
    radix = k ** np.arange(m_hash, dtype=np.int64)
    key = (codes_np[:, :m_hash].astype(np.int64) * radix).sum(axis=1)
    order = np.argsort(key, kind="stable")
    sk = key[order]
    # rank of each row within its (sorted) bucket run, fully vectorized
    rank = np.arange(n) - np.searchsorted(sk, sk, side="left")
    table = np.full((nb, bucket_cap), -1, np.int32)
    keep = rank < bucket_cap
    table[sk[keep], rank[keep]] = order[keep].astype(np.int32)
    n_pivots = max(1, min(n_pivots, n))
    stride = max(1, n // n_pivots)
    pivots = np.arange(0, n, stride, dtype=np.int32)[:n_pivots]
    return SeedIndex(jnp.asarray(table), jnp.asarray(pivots),
                     jnp.asarray(codes_np, jnp.int32), k, m_hash)


def _query_keys(luts, k: int, m_hash: int) -> jax.Array:
    """(Q,) bucket keys from the LUTs the caller already built: per hashed
    subspace, the argmin LUT column is the sub-code the quantizer would
    assign the query's subvector. Works on both layouts — the u8 table's
    argmin is the same heuristic in the quantized metric. Columns ≥ k are
    sliced off FIRST (fs4 tables are zero-padded to 16 — padding would
    argmin-win)."""
    lut = luts.lut if isinstance(luts, QuantizedLUT) else luts
    sub = jnp.argmin(lut[:, :m_hash, :k].astype(jnp.int32)
                     if lut.dtype == jnp.uint8 else lut[:, :m_hash, :k],
                     axis=-1).astype(jnp.int32)
    # int32 is exact: K^m_hash ≤ MAX_BUCKETS (auto_m_hash enforces it).
    radix = k ** jnp.arange(m_hash, dtype=jnp.int32)
    return jnp.sum(sub * radix, axis=1)


def _candidate_dists(codes: jax.Array, cand: jax.Array, luts) -> jax.Array:
    """Full-LUT ADC of each query's candidate rows: (Q, C) f32. cand must
    already be masked to valid rows (callers gather row 0 for pads and inf
    the result)."""
    rows = codes[cand]                                     # (Q, C, M)
    if isinstance(luts, QuantizedLUT):
        m = luts.lut.shape[1]
        vals = jnp.take_along_axis(
            luts.lut.astype(jnp.int32)[:, None],           # (Q, 1, M, 16)
            rows[..., None], axis=3)[..., 0]               # (Q, C, M)
        acc = jnp.sum(vals, axis=-1)
        return (luts.scale[:, None] * acc.astype(jnp.float32)
                + m * luts.bias[:, None])
    vals = jnp.take_along_axis(luts[:, None], rows[..., None],
                               axis=3)[..., 0]             # (Q, C, M)
    return jnp.sum(vals.astype(jnp.float32), axis=-1)


@functools.partial(jax.jit, static_argnames=("k", "m_hash", "s"))
def seed_entries_from(table, pivots, codes, luts, tombstones=None, *,
                      k: int, m_hash: int, s: int) -> jax.Array:
    """Functional core of :meth:`SeedIndex.seed_entries` — raw arrays in,
    (Q, S) int32 entry sets out. This is what the sharded engines call
    inside ``shard_map`` with per-shard table/pivots/codes blocks.

    Per query: bucket members ∪ pivots (fixed width C = bucket_cap +
    n_pivots) → dedupe → one bulk full-LUT ADC → tombstone-aware top-S.
    Lanes that found no candidate return -1 (never happens in practice:
    the pivots are always valid when S ≤ n_pivots).
    """
    nq = jax.tree.leaves(luts)[0].shape[0]
    n = codes.shape[0]
    bkey = _query_keys(luts, k, m_hash)                    # (Q,)
    bucket = table[bkey]                                   # (Q, cap)
    cand = jnp.concatenate(
        [bucket, jnp.broadcast_to(pivots[None], (nq, pivots.shape[0]))],
        axis=1)                                            # (Q, C)
    ok = (cand >= 0) & (cand < n)
    uniq = jax.vmap(_first_occurrence)(cand, ok)
    d = _candidate_dists(codes, jnp.where(uniq, cand, 0), luts)
    d = jnp.where(uniq, d, INF)
    if tombstones is not None:
        dead = (_bit_get(tombstones, jnp.where(ok, cand, 0)).astype(bool)
                & ok)
        d = jnp.where(uniq & dead, DEAD_ENTRY_DIST, d)
    neg, order = jax.lax.top_k(-d, s)
    sd = -neg
    return jnp.where(sd < INF, jnp.take_along_axis(cand, order, axis=1),
                     -1).astype(jnp.int32)
