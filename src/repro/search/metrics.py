"""Evaluation metrics: recall@k and QPS timing (paper §8.1)."""

from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


def recall_at_k(pred_ids, gt_ids, k: int) -> float:
    """Paper Eq. 1: |R ∩ R̃| / k, averaged over queries.

    Args:
      pred_ids: (Q, ≥k) predicted ids; only the first k columns count and
        order within them is irrelevant (set intersection). Sentinel /
        padding ids (-1 from partial_merge, N from the beam) never match
        real ground-truth ids, so padded rows simply score lower.
      gt_ids:   (Q, k) exact nearest-neighbor ids (graphs.knn.knn_ids).
      k:        cutoff; must be ≤ gt_ids.shape[1].

    Returns:
      Mean recall in [0, 1] as a python float.
    """
    pred = np.asarray(pred_ids)[:, :k]
    gt = np.asarray(gt_ids)[:, :k]
    hits = 0
    for p, g in zip(pred, gt):
        hits += len(set(p.tolist()) & set(g.tolist()))
    return hits / (k * len(gt))


def live_ground_truth(vectors, live_gids, queries, k: int) -> np.ndarray:
    """Exact top-k over the LIVE subset of a churned corpus, in GLOBAL ids.

    The one implementation of the streaming-evaluation idiom (serve.py
    churn loop, benchmarks/streaming.py, examples/streaming.py): restrict
    ``vectors`` (indexed by global id) to ``live_gids``, brute-force the
    ground truth there, and translate the subset indices back to global
    ids so the result compares directly against a StreamingEngine's
    returned ids with :func:`recall_at_k`.

    Args:
      vectors:   (N, D) array-like, row = vector of global id.
      live_gids: (L,) global ids currently live (bool masks: pass
        ``np.flatnonzero(mask)``).
      queries:   (Q, D) query batch.
      k:         neighbors per query.

    Returns:
      (Q, k) int64 global ids of the exact nearest live rows.
    """
    from repro.graphs.knn import knn_ids

    gids = np.asarray(live_gids)
    gt, _ = knn_ids(jnp.asarray(np.asarray(vectors)[gids]),
                    jnp.asarray(queries, jnp.float32), k)
    return gids[np.asarray(gt)]


def measure_qps(search_fn: Callable, queries, *, repeats: int = 3,
                warmup: int = 1) -> tuple[float, object]:
    """Throughput of a batched search callable, compile time excluded.

    Runs ``search_fn(queries)`` ``warmup`` times untimed (jit compilation,
    caches), then ``repeats`` timed runs with ``jax.block_until_ready`` so
    async dispatch can't fake speed. QPS = n_queries / mean wall time of
    one batch — batch throughput, not single-query latency.

    Returns:
      (qps, last_result) — the result is returned so callers can score
      recall on exactly what was timed.
    """
    nq = jax.tree.leaves(queries)[0].shape[0]
    out = None
    for _ in range(warmup):
        out = search_fn(queries)
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = search_fn(queries)
        jax.block_until_ready(out)
    dt = (time.perf_counter() - t0) / repeats
    return nq / dt, out
