"""Evaluation metrics: recall@k and QPS timing (paper §8.1)."""

from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


def recall_at_k(pred_ids, gt_ids, k: int) -> float:
    """Eq. 1: |R ∩ R̃| / k, averaged over queries.

    pred_ids (Q, ≥k), gt_ids (Q, k). Sentinel/padding ids never match gt.
    """
    pred = np.asarray(pred_ids)[:, :k]
    gt = np.asarray(gt_ids)[:, :k]
    hits = 0
    for p, g in zip(pred, gt):
        hits += len(set(p.tolist()) & set(g.tolist()))
    return hits / (k * len(gt))


def measure_qps(search_fn: Callable, queries, *, repeats: int = 3,
                warmup: int = 1) -> tuple[float, object]:
    """QPS of a jitted batched search callable. Returns (qps, last_result)."""
    nq = jax.tree.leaves(queries)[0].shape[0]
    out = None
    for _ in range(warmup):
        out = search_fn(queries)
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = search_fn(queries)
        jax.block_until_ready(out)
    dt = (time.perf_counter() - t0) / repeats
    return nq / dt, out
