"""Serving engines (paper §7): in-memory, SSD-hybrid (DiskANN), and the two
sharded scatter-gather scenarios (exhaustive scan and graph-routed).

All engines route with PQ-ADC distances. They accept any quantizer exposing
the (codes, lut_fn) protocol — classic PQ / OPQ (pq.base.QuantizerModel),
the learned RPQ (core.rpq), or Catalyst.

All beam-routed engines also thread ``expand`` (frontier batching,
DESIGN.md §9): each beam round expands E nodes through one E·R-wide fused
hop-ADC call, and results report ``rounds`` (sequential trips) next to
``hops`` (expansions).

* :class:`InMemoryEngine` — codes + codebook + PG in RAM; next-hop selection
  and the final top-k use ONLY PQ distances (no rerank). Memory = N·M bytes
  + graph.
* :class:`HybridEngine` — DiskANN: codes + codebook in RAM; full vectors +
  PG "on SSD". Routing uses ADC; every expansion costs one simulated SSD
  read (the node's 4 KiB block holds its vector + adjacency, as in DiskANN's
  disk layout); the final candidates are re-ranked with exact distances.
  IO time is modeled as reads × latency (default 100 µs, ~NVMe) — reported
  separately from compute time so real-hardware numbers can be projected.
* :class:`ShardedEngine` — multi-device scatter-gather SCAN: codes
  (+ vectors) row-sharded over the mesh via dist.sharding.rpq_rows_spec;
  each shard exhaustively scans its rows with the ADC kernel and returns a
  LOCAL top-k, merged with dist.fault.partial_merge so a dead/straggler
  shard degrades recall instead of failing the query.
* :class:`ShardedGraphEngine` — multi-device graph ROUTING (DESIGN.md §6):
  each shard owns a contiguous row range AND an independent Vamana subgraph
  over it (graphs/partition.py); the batched beam search runs inside
  shard_map, per-hop distances come from the fused hop-ADC Pallas kernel on
  TPU, optional DiskANN-style local exact rerank, same partial_merge
  gather. O(hops·R) distance work per shard per query instead of O(N/S).

The per-shard bodies below are the ONE implementation of each scatter-
gather pattern — launch/cells.py's adc_bulk / serve_1m / sharded_graph
dry-run cells compile these same functions, and launch/serve.py serves
them for real.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro._compat import shard_map
from repro.dist import sharding as shd
from repro.dist.fault import partial_merge
from repro.graphs.adjacency import Graph
from repro.graphs.partition import PartitionedGraph
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.pq.pack import QuantizedLUT
from repro.search import beam
from repro.search.beam import SearchResult

# Layout dispatch: every engine accepts EITHER the classic u8 layout
# ((N, M) byte codes + (Q, M, K) f32 LUTs) or the fast-scan fs4 layout
# ((N, ceil(M/2)) packed nibble codes + pq.pack.QuantizedLUT uint8 tables,
# DESIGN.md §8). The lut_fn's return type is the single source of truth —
# a QuantizedLUT means the codes are packed; no separate flag to desync.


def _is_packed(luts) -> bool:
    return isinstance(luts, QuantizedLUT)


def _bulk_adc(codes_l, luts) -> jax.Array:
    """(n_local, M|Mb) codes × (Q,...) LUTs → (Q, n_local) ADC distances,
    dispatching on layout (the one switch for the scan engines)."""
    if _is_packed(luts):
        return kops.adc_scan_fs(codes_l, luts.lut, luts.scale, luts.bias)
    return kref.adc_scan_batch_ref(codes_l, luts)


def _lut_specs(luts):
    """Replicated shard_map in_specs for a LUT input that may be a plain
    (Q, M, K) array or a QuantizedLUT pytree."""
    return jax.tree.map(lambda a: P(*([None] * jnp.ndim(a))), luts)


def _cached_dist_fn(cache: dict, codes_p, luts):
    """Per-layout hop dist fn, cached so beam_search's jit sees ONE static
    callable per layout (u8 vs fs4-packed, decided by the lut type)."""
    packed = _is_packed(luts)
    fn = cache.get(packed)
    if fn is None:
        fn = beam.make_adc_dist_fn(codes_p, packed=packed)
        cache[packed] = fn
    return fn


@dataclasses.dataclass
class InMemoryEngine:
    graph: Graph
    codes: jax.Array                  # (N, M) compact codes
    lut_fn: Callable                  # (Q, D) queries -> (Q, M, K) LUTs
    entry_fn: Optional[Callable] = None  # queries -> (Q,) entries (HNSW descend)

    def __post_init__(self):
        self._codes_p = kops.pad_sentinel_row(self.codes)
        self._dist_fns = {}

    def search(self, queries: jax.Array, *, k: int = 10, h: int = 32,
               max_steps: int = 512, expand: int = 1) -> SearchResult:
        luts = self.lut_fn(queries)
        dist_fn = _cached_dist_fn(self._dist_fns, self._codes_p, luts)
        entry = (self.entry_fn(queries) if self.entry_fn is not None
                 else self.graph.medoid)
        res = beam.beam_search(self.graph.neighbors, entry, luts,
                               dist_fn, h=h, max_steps=max_steps,
                               expand=expand)
        return SearchResult(res.ids[:, :k], res.dists[:, :k], res.hops,
                            res.n_dist, res.rounds)

    def memory_bytes(self) -> int:
        return (self.codes.size * self.codes.dtype.itemsize
                + self.graph.neighbors.size * 4)


@dataclasses.dataclass
class HybridEngine:
    """DiskANN-style: ADC routing + exact rerank from "SSD" vectors."""
    graph: Graph
    codes: jax.Array
    lut_fn: Callable
    vectors: jax.Array                # (N, D) original vectors ("on SSD")
    io_latency_s: float = 100e-6     # per 4 KiB node read (NVMe-class)
    entry_fn: Optional[Callable] = None

    def __post_init__(self):
        self._codes_p = kops.pad_sentinel_row(self.codes)
        self._vec_p = kops.pad_sentinel_row(
            jnp.asarray(self.vectors, jnp.float32))
        self._dist_fns = {}

    def search(self, queries: jax.Array, *, k: int = 10, h: int = 32,
               max_steps: int = 512, rerank: int = 0,
               expand: int = 1) -> SearchResult:
        """rerank = how many beam candidates to re-rank exactly (0 → h)."""
        rerank = rerank or h
        k = min(k, rerank)  # cannot return more results than candidates
        luts = self.lut_fn(queries)
        dist_fn = _cached_dist_fn(self._dist_fns, self._codes_p, luts)
        entry = (self.entry_fn(queries) if self.entry_fn is not None
                 else self.graph.medoid)
        res = beam.beam_search(self.graph.neighbors, entry, luts,
                               dist_fn, h=h, max_steps=max_steps,
                               expand=expand)
        ids, dists = _exact_rerank(self._vec_p, queries, res.ids, rerank, k)
        return SearchResult(ids, dists, res.hops, res.n_dist, res.rounds)

    def io_time(self, res: SearchResult, *, expand: int = 1) -> jax.Array:
        """Modeled SSD time per query: one 4 KiB block read per expansion,
        but with frontier batching (``expand=E``) the ≤E reads of a round
        are issued CONCURRENTLY — DiskANN's beam-width IO batching — so the
        wall-clock is ROUNDS × latency, not hops × latency. Uses the
        measured per-query round count when the result carries one, else
        the ceil(hops/E) model."""
        if res.rounds is not None:
            rounds = res.rounds.astype(jnp.float32)
        else:
            rounds = jnp.ceil(res.hops.astype(jnp.float32) / expand)
        return rounds * self.io_latency_s

    def memory_bytes(self) -> int:
        # resident = codes (+ codebook, negligible); graph+vectors on SSD
        return self.codes.size * self.codes.dtype.itemsize


@partial(jax.jit, static_argnames=("rerank", "k"))
def _exact_rerank(vec_p, queries, cand_ids, rerank: int, k: int):
    cand = cand_ids[:, :rerank]
    v = vec_p[cand]                                       # (Q, rerank, D)
    d = jnp.sum((v - queries[:, None, :]) ** 2, axis=-1)
    d = jnp.where(cand == vec_p.shape[0] - 1, jnp.inf, d)
    neg, order = jax.lax.top_k(-d, k)
    return jnp.take_along_axis(cand, order, axis=1), -neg


# ==========================================================================
# Sharded scatter-gather substrate (shared by ShardedEngine AND the
# launch/cells.py adc_bulk / serve_1m dry-run cells)
# ==========================================================================

flat_shard_index = shd.flat_shard_index  # the one definition of shard order


def _local_adc_topk(codes_l, luts, *, mesh, axes, n_local: int, k: int,
                    n_valid: Optional[int]):
    """One shard's scatter half: ADC-scan my rows, return LOCAL top-k with
    GLOBAL ids. (1, Q, k) leading shard axis for the gather."""
    d = _bulk_adc(codes_l, luts)                          # (Q, N_local)
    shard = flat_shard_index(mesh, axes)
    if n_valid is not None:  # mask divisibility-padding rows
        gid_row = shard * n_local + jnp.arange(n_local)
        d = jnp.where(gid_row[None, :] < n_valid, d, jnp.inf)
    neg, ids = jax.lax.top_k(-d, k)
    return (ids + shard * n_local)[None], (-neg)[None]


def _local_adc_serve(codes_l, vectors_l, luts, queries, *, mesh, axes,
                     n_local: int, k: int, shortlist: int,
                     n_valid: Optional[int]):
    """Scatter half with DiskANN-style local refinement: ADC shortlist →
    exact rerank against my vector rows → LOCAL top-k, global ids."""
    d = _bulk_adc(codes_l, luts)                          # (Q, N_local)
    shard = flat_shard_index(mesh, axes)
    if n_valid is not None:
        gid_row = shard * n_local + jnp.arange(n_local)
        d = jnp.where(gid_row[None, :] < n_valid, d, jnp.inf)
    _, cand = jax.lax.top_k(-d, shortlist)                # ADC shortlist
    cv = vectors_l[cand]                                  # (Q, shortlist, D)
    exact = jnp.sum((cv - queries[:, None, :]) ** 2, -1)
    if n_valid is not None:
        exact = jnp.where(cand + shard * n_local < n_valid, exact, jnp.inf)
    neg, order = jax.lax.top_k(-exact, k)
    gids = jnp.take_along_axis(cand, order, axis=1) + shard * n_local
    return gids[None], (-neg)[None]


def sharded_adc_scan(mesh, axes: tuple, codes, luts, *, k: int,
                     n_valid: Optional[int] = None):
    """Scatter: row-sharded (N, M) codes × replicated (Q, M, K) LUTs →
    per-shard (n_shards, Q, k) global ids + ADC distances.

    O(shards·k) gather traffic instead of the (Q, N) distance matrix
    (GSPMD's sharded top_k gathered it: 8.2 GB/dev → MBs)."""
    n_local = codes.shape[0] // shd.axis_size(mesh, axes)
    body = partial(_local_adc_topk, mesh=mesh, axes=axes, n_local=n_local,
                   k=k, n_valid=n_valid)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(axes, None), _lut_specs(luts)),
        out_specs=(P(axes, None, None), P(axes, None, None)))(codes, luts)


def sharded_adc_serve(mesh, axes: tuple, codes, vectors, luts, queries, *,
                      k: int, shortlist: int, n_valid: Optional[int] = None):
    """Scatter with local exact rerank (serve_1m): row-sharded codes AND
    vectors; each shard reranks its own ADC shortlist from its local vector
    rows — the DiskANN shortlist pattern distributed faiss-style."""
    n_local = codes.shape[0] // shd.axis_size(mesh, axes)
    body = partial(_local_adc_serve, mesh=mesh, axes=axes, n_local=n_local,
                   k=k, shortlist=min(shortlist, n_local), n_valid=n_valid)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(axes, None), P(axes, None), _lut_specs(luts),
                  P(None, None)),
        out_specs=(P(axes, None, None), P(axes, None, None)))(
            codes, vectors, luts, queries)


def merge_shard_topk(gids, dists, k: int):
    """Gather: (n_shards, Q, k_s) per-shard shortlists → global (Q, k)
    top-k. The in-jit, all-shards-alive merge; ShardedEngine uses
    dist.fault.partial_merge on the host instead to tolerate dead shards."""
    q = gids.shape[1]
    ds = dists.transpose(1, 0, 2).reshape(q, -1)
    is_ = gids.transpose(1, 0, 2).reshape(q, -1)
    neg, order = jax.lax.top_k(-ds, k)
    return jnp.take_along_axis(is_, order, axis=1), -neg


@dataclasses.dataclass
class ShardedEngine:
    """Scatter-gather serving over a device mesh (exhaustive ADC scan).

    Codes (and, when ``vectors`` is given, full vectors for the hybrid
    local-rerank scenario) are row-sharded across every mesh axis via
    dist.sharding.rpq_rows_spec. A query broadcasts its LUTs, every shard
    scans its rows and answers a local top-k, and the host merges the
    shard shortlists with dist.fault.partial_merge — shards reported dead
    via ``alive`` are simply dropped from the merge (graceful recall
    degradation, never a failed query).
    """
    codes: jax.Array                  # (N, M) compact codes
    lut_fn: Callable                  # (Q, D) queries -> (Q, M, K) LUTs
    vectors: Optional[jax.Array] = None   # (N, D): enables local exact rerank
    mesh: Optional[jax.sharding.Mesh] = None
    shortlist_mult: int = 4           # rerank shortlist = mult × k

    def __post_init__(self):
        if self.mesh is None:
            self.mesh = jax.make_mesh((len(jax.devices()),), ("data",))
        self._axes = shd.row_axes(self.mesh)
        self.n_shards = shd.axis_size(self.mesh, self._axes)
        self.n = int(self.codes.shape[0])
        rows = shd.named(self.mesh, shd.rpq_rows_spec(self.mesh))
        codes = jnp.asarray(self.codes)
        self._codes_bytes = codes.size * codes.dtype.itemsize
        self._codes_s = jax.device_put(
            kops.pad_rows_to_multiple(codes, self.n_shards), rows)
        self.codes = self._codes_s   # drop the unsharded copy
        self._vec_bytes = 0
        if self.vectors is not None:
            vec = jnp.asarray(self.vectors, jnp.float32)
            self._vec_bytes = vec.size * 4
            self._vec_s = jax.device_put(
                kops.pad_rows_to_multiple(vec, self.n_shards), rows)
            self.vectors = self._vec_s

    def _scatter(self, luts, queries, k: int):
        if not hasattr(self, "_jit_cache"):
            self._jit_cache = {}
        fn = self._jit_cache.get(k)
        if fn is None:
            if self.vectors is None:
                fn = jax.jit(lambda codes, luts: sharded_adc_scan(
                    self.mesh, self._axes, codes, luts, k=k, n_valid=self.n))
            else:
                fn = jax.jit(lambda codes, vec, luts, q: sharded_adc_serve(
                    self.mesh, self._axes, codes, vec, luts, q, k=k,
                    shortlist=self.shortlist_mult * k, n_valid=self.n))
            self._jit_cache[k] = fn
        if self.vectors is None:
            return fn(self._codes_s, luts)
        return fn(self._codes_s, self._vec_s, luts, queries)

    def search(self, queries: jax.Array, *, k: int = 10,
               alive: Optional[Sequence[bool]] = None,
               h: Optional[int] = None,
               expand: Optional[int] = None) -> SearchResult:
        """Exhaustive sharded scan (``h``/``expand`` accepted for
        engine-protocol compatibility and ignored — there is no beam)."""
        del h, expand
        queries = jnp.atleast_2d(jnp.asarray(queries, jnp.float32))
        n_local = self._codes_s.shape[0] // self.n_shards
        kk = min(k, n_local)
        luts = jax.tree.map(jnp.asarray, self.lut_fn(queries))
        gids, dists = self._scatter(luts, queries, kk)
        gids, dists = np.asarray(gids), np.asarray(dists)
        if alive is None:
            alive = [True] * self.n_shards
        ids, ds = partial_merge(list(gids), list(dists), alive, k)
        q = queries.shape[0]
        scanned = n_local * sum(bool(a) for a in alive)
        return SearchResult(jnp.asarray(ids), jnp.asarray(ds),
                            hops=jnp.zeros((q,), jnp.int32),
                            n_dist=jnp.full((q,), scanned, jnp.int32),
                            rounds=jnp.zeros((q,), jnp.int32))

    def memory_bytes(self) -> int:
        # UNPADDED sizes: what the index costs, not the divisibility slack
        return self._codes_bytes + self._vec_bytes


# ==========================================================================
# Graph-routed sharded serving (DESIGN.md §6): every shard runs the batched
# beam search over its OWN Vamana subgraph inside shard_map. Shared by
# ShardedGraphEngine, launch/serve.py --scenario sharded-graph, and the
# sharded_graph dry-run cell in launch/cells.py.
# ==========================================================================

def _shard_codes_pad(codes_l: jax.Array) -> jax.Array:
    """(1, n_local, M) shard block → (n_local + 1, M) sentinel-padded codes
    for beam.make_adc_dist_fn (sentinel row never read: beam masks ids)."""
    return kops.pad_sentinel_row(codes_l[0])


def _local_beam(neighbors_l, medoid_l, codes_l, luts, *, h: int,
                max_steps: int, backend: str, expand: int):
    """Route over THIS shard's subgraph with ADC distances (u8 or fs4-
    packed layout, decided by the lut type). Returns the raw per-shard
    beam result (local ids)."""
    dist_fn = beam.make_adc_dist_fn(_shard_codes_pad(codes_l),
                                    packed=_is_packed(luts), backend=backend)
    return beam.beam_search(neighbors_l[0], medoid_l[0], luts, dist_fn,
                            h=h, max_steps=max_steps, expand=expand)


def _mask_to_global(ids, dists, *, mesh, axes, n_local: int, n_valid: int):
    """Local beam ids → global ids; sentinel slots and divisibility-padding
    rows become (-1, +inf) so the host merge never sees them."""
    shard = flat_shard_index(mesh, axes)
    n_valid_local = jnp.clip(n_valid - shard * n_local, 0, n_local)
    ok = (ids < n_valid_local) & jnp.isfinite(dists)
    gids = jnp.where(ok, ids + shard * n_local, -1)
    return gids, jnp.where(ok, dists, jnp.inf)


def _local_graph_topk(neighbors_l, medoid_l, codes_l, luts, *, mesh, axes,
                      n_local: int, k: int, h: int, max_steps: int,
                      n_valid: int, backend: str, expand: int):
    """One shard's scatter half: beam-search my subgraph, return LOCAL
    top-k with GLOBAL ids. (1, Q, k) leading shard axis for the gather."""
    res = _local_beam(neighbors_l, medoid_l, codes_l, luts, h=h,
                      max_steps=max_steps, backend=backend, expand=expand)
    gids, d = _mask_to_global(res.ids[:, :k], res.dists[:, :k], mesh=mesh,
                              axes=axes, n_local=n_local, n_valid=n_valid)
    return gids[None], d[None], res.hops[None], res.n_dist[None], \
        res.rounds[None]


def _local_graph_serve(neighbors_l, medoid_l, codes_l, vectors_l, luts,
                       queries, *, mesh, axes, n_local: int, k: int, h: int,
                       shortlist: int, max_steps: int, n_valid: int,
                       backend: str, expand: int):
    """Scatter half with DiskANN-style local refinement: beam shortlist →
    exact rerank against my vector rows → LOCAL top-k, global ids."""
    res = _local_beam(neighbors_l, medoid_l, codes_l, luts, h=h,
                      max_steps=max_steps, backend=backend, expand=expand)
    cand = jnp.minimum(res.ids[:, :shortlist], n_local)   # clamp sentinel
    vec_p = kops.pad_sentinel_row(vectors_l[0])
    cv = vec_p[cand]                                      # (Q, shortlist, D)
    exact = jnp.sum((cv - queries[:, None, :]) ** 2, -1)
    exact = jnp.where(jnp.isfinite(res.dists[:, :shortlist]), exact, jnp.inf)
    neg, order = jax.lax.top_k(-exact, k)
    ids = jnp.take_along_axis(cand, order, axis=1)
    gids, d = _mask_to_global(ids, -neg, mesh=mesh, axes=axes,
                              n_local=n_local, n_valid=n_valid)
    return gids[None], d[None], res.hops[None], res.n_dist[None], \
        res.rounds[None]


def sharded_graph_topk(mesh, axes: tuple, neighbors, medoids, codes, luts, *,
                       k: int, h: int = 32, max_steps: int = 512,
                       n_valid: Optional[int] = None, backend: str = "auto",
                       expand: int = 1):
    """Scatter: shard-stacked independent subgraphs × replicated LUTs →
    per-shard (S, Q, k) GLOBAL ids + ADC distances (+ (S, Q)
    hops/n_dist/rounds).

    Args:
      mesh/axes:  device mesh and the row-sharding axes (shd.row_axes).
      neighbors:  (S, n_local, R) stacked local adjacency (graphs/partition).
      medoids:    (S,) local entry vertices.
      codes:      (S, n_local, M) shard-stacked compact codes.
      luts:       (Q, M, K) query LUTs, replicated to every shard.
      k:          per-shard shortlist size (the gather is O(S·k)/query).
      h/max_steps: beam width and round cap of each LOCAL beam search.
      n_valid:    total REAL rows (masks the last shard's padding).
      backend:    per-hop distance backend (beam.make_adc_dist_fn).
      expand:     frontier batch size E of each local beam (DESIGN.md §9) —
                  every round scores one E·R-wide fused hop-ADC call
                  instead of E narrow ones.

    Each shard routes ONLY over its own subgraph — no inter-shard edges, no
    mid-search collectives; the only cross-device traffic is the O(S·Q·k)
    shortlist gather (vs. O(Q·N/S) for the scan engine's full distances).
    """
    s = shd.axis_size(mesh, axes)
    n_local = neighbors.shape[1]
    body = partial(_local_graph_topk, mesh=mesh, axes=axes, n_local=n_local,
                   k=k, h=h, max_steps=max_steps,
                   n_valid=s * n_local if n_valid is None else n_valid,
                   backend=backend, expand=expand)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(axes, None, None), P(axes), P(axes, None, None),
                  _lut_specs(luts)),
        out_specs=(P(axes, None, None), P(axes, None, None),
                   P(axes, None), P(axes, None), P(axes, None)))(
            neighbors, medoids, codes, luts)


def sharded_graph_serve(mesh, axes: tuple, neighbors, medoids, codes,
                        vectors, luts, queries, *, k: int, h: int = 32,
                        shortlist: int = 0, max_steps: int = 512,
                        n_valid: Optional[int] = None,
                        backend: str = "auto", expand: int = 1):
    """Scatter with local exact rerank: like :func:`sharded_graph_topk` but
    every shard re-ranks its beam shortlist against its resident vector
    rows (S, n_local, D) before answering — the DiskANN shortlist pattern
    with the SSD replaced by the shard's own HBM."""
    s = shd.axis_size(mesh, axes)
    n_local = neighbors.shape[1]
    body = partial(_local_graph_serve, mesh=mesh, axes=axes,
                   n_local=n_local, k=k, h=h,
                   shortlist=min(shortlist or h, h), max_steps=max_steps,
                   n_valid=s * n_local if n_valid is None else n_valid,
                   backend=backend, expand=expand)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(axes, None, None), P(axes), P(axes, None, None),
                  P(axes, None, None), _lut_specs(luts), P(None, None)),
        out_specs=(P(axes, None, None), P(axes, None, None),
                   P(axes, None), P(axes, None), P(axes, None)))(
            neighbors, medoids, codes, vectors, luts, queries)


def _stack_rows(x: jax.Array, n_shards: int, n_local: int) -> jax.Array:
    """(N, ...) global rows → (S, n_local, ...) shard-stacked, zero-padded."""
    pad = n_shards * n_local - x.shape[0]
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)
    return x.reshape((n_shards, n_local) + x.shape[1:])


@dataclasses.dataclass
class ShardedGraphEngine:
    """Graph-ROUTED scatter-gather serving over a device mesh.

    Where :class:`ShardedEngine` exhaustively scans every shard's rows, this
    engine routes: the dataset is partitioned into contiguous per-shard row
    ranges with an independent Vamana subgraph per shard
    (graphs/partition.py), and every query's beam search runs *inside*
    ``shard_map`` — each shard walks its own subgraph with ADC distances
    (per-hop hot loop = the fused hop-ADC Pallas kernel on TPU), optionally
    exact-reranks its beam against its resident vector rows (DiskANN-style),
    and answers a LOCAL top-k with GLOBAL ids. The host merges shard
    shortlists with ``dist.fault.partial_merge``: a dead shard's row range
    drops out of the answer (graceful recall degradation), the query never
    fails.

    Per-query distance work is O(hops·R) per shard instead of O(N/S), so
    this is the scenario that scales ROUTING — not just scanning — with the
    mesh. Recall is within a few points of a single-device in-memory beam
    at equal width, because every shard is searched and the merge keeps the
    global best (the partition can only *split* a query's true neighborhood
    across shards, each of which still finds its part).

    Attributes:
      graph:    PartitionedGraph over the same row order as ``codes``.
      codes:    (N, M) compact codes (global row order).
      lut_fn:   (Q, D) queries → (Q, M, K) LUTs.
      vectors:  optional (N, D) full vectors; enables local exact rerank.
      mesh:     device mesh (default: all local devices on one axis).
      backend:  per-hop kernel dispatch, see beam.make_adc_dist_fn.
    """
    graph: PartitionedGraph
    codes: jax.Array
    lut_fn: Callable
    vectors: Optional[jax.Array] = None
    mesh: Optional[jax.sharding.Mesh] = None
    backend: str = "auto"

    def __post_init__(self):
        if self.mesh is None:
            self.mesh = jax.make_mesh((len(jax.devices()),), ("data",))
        self._axes = shd.row_axes(self.mesh)
        self.n_shards = shd.axis_size(self.mesh, self._axes)
        if self.n_shards != self.graph.n_shards:
            raise ValueError(
                f"graph has {self.graph.n_shards} shards but the mesh has "
                f"{self.n_shards} — partition with n_shards="
                f"{self.n_shards}")
        self.n = int(self.graph.n)
        if int(self.codes.shape[0]) != self.n:
            raise ValueError(f"codes rows {self.codes.shape[0]} != "
                             f"graph rows {self.n}")
        n_local = self.graph.n_local
        rows3 = shd.named(self.mesh, shd.rpq_shard_stack_spec(self.mesh))
        rows1 = shd.named(self.mesh, shd.rpq_shard_stack_spec(self.mesh, 1))
        codes = jnp.asarray(self.codes)
        self._codes_bytes = codes.size * codes.dtype.itemsize
        self._codes_s = jax.device_put(
            _stack_rows(codes, self.n_shards, n_local), rows3)
        self.codes = self._codes_s
        self._nbrs_s = jax.device_put(self.graph.neighbors, rows3)
        self._medoids_s = jax.device_put(self.graph.medoids, rows1)
        self._vec_bytes = 0
        if self.vectors is not None:
            vec = jnp.asarray(self.vectors, jnp.float32)
            self._vec_bytes = vec.size * 4
            self._vec_s = jax.device_put(
                _stack_rows(vec, self.n_shards, n_local), rows3)
            self.vectors = self._vec_s
        self._jit_cache = {}

    def _scatter(self, luts, queries, k: int, h: int, max_steps: int,
                 expand: int):
        fn = self._jit_cache.get((k, h, max_steps, expand))
        if fn is None:
            if self.vectors is None:
                fn = jax.jit(lambda nb, md, cd, lu: sharded_graph_topk(
                    self.mesh, self._axes, nb, md, cd, lu, k=k, h=h,
                    max_steps=max_steps, n_valid=self.n,
                    backend=self.backend, expand=expand))
            else:
                fn = jax.jit(
                    lambda nb, md, cd, vc, lu, q: sharded_graph_serve(
                        self.mesh, self._axes, nb, md, cd, vc, lu, q, k=k,
                        h=h, shortlist=h, max_steps=max_steps,
                        n_valid=self.n, backend=self.backend,
                        expand=expand))
            self._jit_cache[(k, h, max_steps, expand)] = fn
        if self.vectors is None:
            return fn(self._nbrs_s, self._medoids_s, self._codes_s, luts)
        return fn(self._nbrs_s, self._medoids_s, self._codes_s, self._vec_s,
                  luts, queries)

    def search(self, queries: jax.Array, *, k: int = 10, h: int = 32,
               max_steps: int = 512, expand: int = 1,
               alive: Optional[Sequence[bool]] = None) -> SearchResult:
        """Route every query on every (alive) shard, merge the shortlists.

        ``hops``/``n_dist`` report the SUM over alive shards — the total
        work the mesh did for the query, comparable to a single-device
        beam's counters. ``rounds`` reports the MAX over alive shards: the
        shards route concurrently, so the slowest shard's sequential trip
        count is the query's latency proxy.
        """
        queries = jnp.atleast_2d(jnp.asarray(queries, jnp.float32))
        kk = min(k, h, self.graph.n_local)
        luts = jax.tree.map(jnp.asarray, self.lut_fn(queries))
        gids, dists, hops, ndist, rounds = self._scatter(
            luts, queries, kk, h, max_steps, expand)
        gids, dists = np.asarray(gids), np.asarray(dists)
        if alive is None:
            alive = [True] * self.n_shards
        ids, ds = partial_merge(list(gids), list(dists), alive, k)
        mask = np.asarray(alive, bool)
        hops = np.asarray(hops)[mask].sum(0)
        ndist = np.asarray(ndist)[mask].sum(0)
        rounds = np.asarray(rounds)[mask].max(0)
        return SearchResult(jnp.asarray(ids), jnp.asarray(ds),
                            hops=jnp.asarray(hops, jnp.int32),
                            n_dist=jnp.asarray(ndist, jnp.int32),
                            rounds=jnp.asarray(rounds, jnp.int32))

    def memory_bytes(self) -> int:
        # UNPADDED codes + per-shard adjacency (+ vectors when resident)
        return (self._codes_bytes
                + self.graph.neighbors.size * 4 + self._vec_bytes)
