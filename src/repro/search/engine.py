"""Serving engines (paper §7): in-memory, SSD-hybrid (DiskANN), and the two
sharded scatter-gather scenarios (exhaustive scan and graph-routed).

All engines route with PQ-ADC distances. They accept any quantizer exposing
the (codes, lut_fn) protocol — classic PQ / OPQ (pq.base.QuantizerModel),
the learned RPQ (core.rpq), or Catalyst.

All beam-routed engines also thread ``expand`` (frontier batching,
DESIGN.md §9): each beam round expands E nodes through one E·R-wide fused
hop-ADC call, and results report ``rounds`` (sequential trips) next to
``hops`` (expansions).

They additionally thread the adaptive-routing knobs (DESIGN.md §11):
``entries=S`` seeds each query's beam with S near-query entry points from a
PQ-hash coarse index over the resident codes (search/seed.py — built
lazily on the first seeded search, per shard for the sharded engines), and
``prune_eps=ε`` gates each round's full-LUT scoring behind a partial-LUT
lower bound (``m_prefix`` subspaces, default half). ``entries=1,
prune_eps=0`` (the defaults) is bit-identical to the classic beam.

* :class:`InMemoryEngine` — codes + codebook + PG in RAM; next-hop selection
  and the final top-k use ONLY PQ distances (no rerank). Memory = N·M bytes
  + graph.
* :class:`HybridEngine` — DiskANN: codes + codebook in RAM; full vectors +
  PG "on SSD". Routing uses ADC; every expansion costs one simulated SSD
  read (the node's 4 KiB block holds its vector + adjacency, as in DiskANN's
  disk layout); the final candidates are re-ranked with exact distances.
  IO time is modeled as reads × latency (default 100 µs, ~NVMe) — reported
  separately from compute time so real-hardware numbers can be projected.
* :class:`ShardedEngine` — multi-device scatter-gather SCAN: codes
  (+ vectors) row-sharded over the mesh via dist.sharding.rpq_rows_spec;
  each shard exhaustively scans its rows with the ADC kernel and returns a
  LOCAL top-k, merged with dist.fault.partial_merge so a dead/straggler
  shard degrades recall instead of failing the query.
* :class:`ShardedGraphEngine` — multi-device graph ROUTING (DESIGN.md §6):
  each shard owns a contiguous row range AND an independent Vamana subgraph
  over it (graphs/partition.py); the batched beam search runs inside
  shard_map, per-hop distances come from the fused hop-ADC Pallas kernel on
  TPU, optional DiskANN-style local exact rerank, same partial_merge
  gather. O(hops·R) distance work per shard per query instead of O(N/S).

The per-shard bodies below are the ONE implementation of each scatter-
gather pattern — launch/cells.py's adc_bulk / serve_1m / sharded_graph
dry-run cells compile these same functions, and launch/serve.py serves
them for real.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro._compat import shard_map
from repro.dist import sharding as shd
from repro.dist.fault import partial_merge, resolve_quorum
from repro.graphs.adjacency import Graph
from repro.graphs.partition import PartitionedGraph
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.pq.pack import QuantizedLUT, unpack_codes
from repro.search import beam
from repro.search import seed as sseed
from repro.search.beam import SearchResult

# Layout dispatch: every engine accepts EITHER the classic u8 layout
# ((N, M) byte codes + (Q, M, K) f32 LUTs) or the fast-scan fs4 layout
# ((N, ceil(M/2)) packed nibble codes + pq.pack.QuantizedLUT uint8 tables,
# DESIGN.md §8). The lut_fn's return type is the single source of truth —
# a QuantizedLUT means the codes are packed; no separate flag to desync.


def _is_packed(luts) -> bool:
    return isinstance(luts, QuantizedLUT)


def _bulk_adc(codes_l, luts) -> jax.Array:
    """(n_local, M|Mb) codes × (Q,...) LUTs → (Q, n_local) ADC distances,
    dispatching on layout (the one switch for the scan engines)."""
    if _is_packed(luts):
        return kops.adc_scan_fs(codes_l, luts.lut, luts.scale, luts.bias)
    return kref.adc_scan_batch_ref(codes_l, luts)


def _lut_specs(luts):
    """Replicated shard_map in_specs for a LUT input that may be a plain
    (Q, M, K) array or a QuantizedLUT pytree."""
    return jax.tree.map(lambda a: P(*([None] * jnp.ndim(a))), luts)


def _cached_dist_fn(cache: dict, codes_p, luts, m_prefix: int = 0):
    """Per-(layout, prefix) hop dist fn, cached so beam_search's jit sees
    ONE static callable per layout (u8 vs fs4-packed, decided by the lut
    type) and per partial-LUT prefix (``m_prefix>0`` builds the hop-pruning
    lower-bound fn, DESIGN.md §11)."""
    packed = _is_packed(luts)
    fn = cache.get((packed, m_prefix))
    if fn is None:
        fn = beam.make_adc_dist_fn(codes_p, packed=packed,
                                   m_prefix=m_prefix)
        cache[(packed, m_prefix)] = fn
    return fn


def _cached_scale_fn(cache: dict, luts, m_prefix: int):
    """Per-(layout, prefix) extrapolation-calibration fn
    (``beam.make_lb_scale_fn``), cached for the same static-identity reason
    as ``_cached_dist_fn`` — beam_search's jit must see ONE callable per
    configuration or every search recompiles."""
    packed = _is_packed(luts)
    key = ("cal", packed, m_prefix)
    fn = cache.get(key)
    if fn is None:
        fn = beam.make_lb_scale_fn(packed=packed, m_prefix=m_prefix)
        cache[key] = fn
    return fn


def _lut_m(luts) -> int:
    """Number of subquantizers M from either LUT layout."""
    return (luts.lut if _is_packed(luts) else luts).shape[1]


def _prune_cfg(luts, prune_eps: float, m_prefix: int) -> tuple:
    """Resolve the hop-pruning statics (m_prefix, m_total) for beam_search:
    ε ≤ 0 disables — (0, 0), the bit-identical path; ``m_prefix=0``
    auto-picks a QUARTER of the subspaces (an M=1 corpus can never prune).
    The gate extrapolates the prefix to a full-distance estimate, so a
    short prefix keeps the partial pass cheap — empirically M/4 prunes as
    accurately as M/2 at half the partial-pass cost (DESIGN.md §11)."""
    if prune_eps <= 0:
        return 0, 0
    mt = _lut_m(luts)
    if mt < 2:
        return 0, 0
    mp = m_prefix if m_prefix > 0 else max(1, mt // 4)
    return max(1, min(mp, mt - 1)), mt


@dataclasses.dataclass
class InMemoryEngine:
    graph: Graph
    codes: jax.Array                  # (N, M) compact codes
    lut_fn: Callable                  # (Q, D) queries -> (Q, M, K) LUTs
    entry_fn: Optional[Callable] = None  # queries -> (Q,) entries (HNSW descend)

    def __post_init__(self):
        self._codes_p = kops.pad_sentinel_row(self.codes)
        self._dist_fns = {}
        self._seedix = None

    def _seed_index(self, luts) -> sseed.SeedIndex:
        """Coarse seeding index over the resident codes, built lazily on
        the first ``entries>1`` search (the lut type reveals the layout:
        fs4 corpora unpack once, host-side)."""
        if self._seedix is None:
            codes = jnp.asarray(self.codes)
            if _is_packed(luts):
                codes = unpack_codes(codes, _lut_m(luts))
            self._seedix = sseed.build_seed_index(np.asarray(codes))
        return self._seedix

    def search(self, queries: jax.Array, *, k: int = 10, h: int = 32,
               max_steps: int = 512, expand: int = 1, entries: int = 1,
               prune_eps: float = 0.0, m_prefix: int = 0,
               max_rounds=None, max_n_dist=None) -> SearchResult:
        """``max_rounds``/``max_n_dist`` are per-call deadline budgets
        (DESIGN.md §13): traced round / distance-evaluation caps; an
        exhausted query returns best-so-far with ``truncated=True``."""
        luts = self.lut_fn(queries)
        dist_fn = _cached_dist_fn(self._dist_fns, self._codes_p, luts)
        mp, mt = _prune_cfg(luts, prune_eps, m_prefix)
        lb_fn = (_cached_dist_fn(self._dist_fns, self._codes_p, luts, mp)
                 if mp else None)
        cal_fn = _cached_scale_fn(self._dist_fns, luts, mp) if mp else None
        seed_cost = jnp.int32(0)
        if entries > 1:
            ix = self._seed_index(luts)
            entry = ix.seed_entries(luts, entries)
            seed_cost = jnp.int32(ix.n_candidates)
        else:
            entry = (self.entry_fn(queries) if self.entry_fn is not None
                     else self.graph.medoid)
        res = beam.beam_search(self.graph.neighbors, entry, luts,
                               dist_fn, h=h, max_steps=max_steps,
                               expand=expand, lb_dist_fn=lb_fn,
                               m_prefix=mp, m_total=mt,
                               prune_eps=prune_eps if mp else 0.0,
                               lb_scale_fn=cal_fn,
                               max_rounds=max_rounds, max_n_dist=max_n_dist)
        return SearchResult(res.ids[:, :k], res.dists[:, :k], res.hops,
                            res.n_dist + seed_cost, res.rounds,
                            res.truncated)

    def memory_bytes(self) -> int:
        return (self.codes.size * self.codes.dtype.itemsize
                + self.graph.neighbors.size * 4)


@dataclasses.dataclass
class HybridEngine:
    """DiskANN-style: ADC routing + exact rerank from "SSD" vectors."""
    graph: Graph
    codes: jax.Array
    lut_fn: Callable
    vectors: jax.Array                # (N, D) original vectors ("on SSD")
    io_latency_s: float = 100e-6     # per 4 KiB node read (NVMe-class)
    entry_fn: Optional[Callable] = None

    def __post_init__(self):
        self._codes_p = kops.pad_sentinel_row(self.codes)
        self._vec_p = kops.pad_sentinel_row(
            jnp.asarray(self.vectors, jnp.float32))
        self._dist_fns = {}
        self._seedix = None

    def _seed_index(self, luts) -> sseed.SeedIndex:
        if self._seedix is None:
            codes = jnp.asarray(self.codes)
            if _is_packed(luts):
                codes = unpack_codes(codes, _lut_m(luts))
            self._seedix = sseed.build_seed_index(np.asarray(codes))
        return self._seedix

    def search(self, queries: jax.Array, *, k: int = 10, h: int = 32,
               max_steps: int = 512, rerank: int = 0, expand: int = 1,
               entries: int = 1, prune_eps: float = 0.0,
               m_prefix: int = 0, max_rounds=None,
               max_n_dist=None) -> SearchResult:
        """rerank = how many beam candidates to re-rank exactly (0 → h;
        NEGATIVE skips the exact rerank entirely and answers from ADC
        distances — degradation-ladder level 4, DESIGN.md §13, saving the
        rerank's "SSD" vector reads under a tight deadline).
        ``max_rounds``/``max_n_dist``: traced per-call deadline budgets;
        exhausted queries return best-so-far with ``truncated=True``."""
        skip_rerank = rerank < 0
        rerank = h if rerank <= 0 else rerank
        k = min(k, rerank)  # cannot return more results than candidates
        luts = self.lut_fn(queries)
        dist_fn = _cached_dist_fn(self._dist_fns, self._codes_p, luts)
        mp, mt = _prune_cfg(luts, prune_eps, m_prefix)
        lb_fn = (_cached_dist_fn(self._dist_fns, self._codes_p, luts, mp)
                 if mp else None)
        cal_fn = _cached_scale_fn(self._dist_fns, luts, mp) if mp else None
        seed_cost = jnp.int32(0)
        if entries > 1:
            ix = self._seed_index(luts)
            entry = ix.seed_entries(luts, entries)
            seed_cost = jnp.int32(ix.n_candidates)
        else:
            entry = (self.entry_fn(queries) if self.entry_fn is not None
                     else self.graph.medoid)
        res = beam.beam_search(self.graph.neighbors, entry, luts,
                               dist_fn, h=h, max_steps=max_steps,
                               expand=expand, lb_dist_fn=lb_fn,
                               m_prefix=mp, m_total=mt,
                               prune_eps=prune_eps if mp else 0.0,
                               lb_scale_fn=cal_fn,
                               max_rounds=max_rounds, max_n_dist=max_n_dist)
        if skip_rerank:
            ids, dists = res.ids[:, :k], res.dists[:, :k]
        else:
            ids, dists = _exact_rerank(self._vec_p, queries, res.ids,
                                       rerank, k)
        return SearchResult(ids, dists, res.hops, res.n_dist + seed_cost,
                            res.rounds, res.truncated)

    def io_time(self, res: SearchResult, *, expand: int = 1,
                entries: int = 1, io_fault_p: float = 0.0,
                retry=None, measured_io_s=None) -> jax.Array:
        """Modeled SSD time per query: one 4 KiB block read per expansion,
        but with frontier batching (``expand=E``) the ≤E reads of a round
        are issued CONCURRENTLY — DiskANN's beam-width IO batching — so the
        wall-clock is ROUNDS × latency, not hops × latency. Uses the
        measured per-query round count when the result carries one, else
        the ceil(hops/E) model.

        Multi-entry seeding (``entries>1``) charges ONE extra batched read:
        the bucket-probe candidates are contiguous small rows fetched in a
        single IO burst (the same batching model as a round's ≤E
        concurrent block reads), not a read per entry.

        ``io_fault_p``/``retry`` extend the model with transient-fault
        recovery (DESIGN.md §13): each round's batched read independently
        fails with probability ``io_fault_p`` per attempt and is retried
        under ``retry`` (a ``dist.retry.RetryPolicy``) — the per-read cost
        becomes the closed-form expected time over attempts + nominal
        backoff sleeps (``dist.retry.expected_retry_time_s``), so the
        resilience bench's retry-overhead rows are deterministic.

        ``measured_io_s`` swaps the model for a MEASUREMENT: pass a real
        storage tier's batch-total I/O stall (``DiskEngine.last_io
        ["io_wait_s"]``) and the per-query charge becomes that total
        amortized over the batch — the model stays the no-storage
        fallback, and benchmarks/disk_serving.py cross-checks the two."""
        if measured_io_s is not None:
            q = int(res.hops.shape[0])
            return jnp.full((q,), jnp.float32(measured_io_s / max(1, q)))
        if res.rounds is not None:
            rounds = res.rounds.astype(jnp.float32)
        else:
            rounds = jnp.ceil(res.hops.astype(jnp.float32) / expand)
        if entries > 1:
            rounds = rounds + jnp.float32(1.0)
        per_read = self.io_latency_s
        if io_fault_p > 0.0 and retry is not None:
            from repro.dist.retry import expected_retry_time_s
            per_read = expected_retry_time_s(retry, self.io_latency_s,
                                             io_fault_p)
        return rounds * jnp.float32(per_read)

    def memory_bytes(self) -> int:
        # resident = codes (+ codebook, negligible); graph+vectors on SSD
        return self.codes.size * self.codes.dtype.itemsize


@partial(jax.jit, static_argnames=("rerank", "k"))
def _exact_rerank(vec_p, queries, cand_ids, rerank: int, k: int):
    cand = cand_ids[:, :rerank]
    v = vec_p[cand]                                       # (Q, rerank, D)
    d = jnp.sum((v - queries[:, None, :]) ** 2, axis=-1)
    d = jnp.where(cand == vec_p.shape[0] - 1, jnp.inf, d)
    neg, order = jax.lax.top_k(-d, k)
    return jnp.take_along_axis(cand, order, axis=1), -neg


# ==========================================================================
# Sharded scatter-gather substrate (shared by ShardedEngine AND the
# launch/cells.py adc_bulk / serve_1m dry-run cells)
# ==========================================================================

flat_shard_index = shd.flat_shard_index  # the one definition of shard order


def _local_adc_topk(codes_l, luts, *, mesh, axes, n_local: int, k: int,
                    n_valid: Optional[int]):
    """One shard's scatter half: ADC-scan my rows, return LOCAL top-k with
    GLOBAL ids. (1, Q, k) leading shard axis for the gather."""
    d = _bulk_adc(codes_l, luts)                          # (Q, N_local)
    shard = flat_shard_index(mesh, axes)
    if n_valid is not None:  # mask divisibility-padding rows
        gid_row = shard * n_local + jnp.arange(n_local)
        d = jnp.where(gid_row[None, :] < n_valid, d, jnp.inf)
    neg, ids = jax.lax.top_k(-d, k)
    return (ids + shard * n_local)[None], (-neg)[None]


def _local_adc_serve(codes_l, vectors_l, luts, queries, *, mesh, axes,
                     n_local: int, k: int, shortlist: int,
                     n_valid: Optional[int]):
    """Scatter half with DiskANN-style local refinement: ADC shortlist →
    exact rerank against my vector rows → LOCAL top-k, global ids."""
    d = _bulk_adc(codes_l, luts)                          # (Q, N_local)
    shard = flat_shard_index(mesh, axes)
    if n_valid is not None:
        gid_row = shard * n_local + jnp.arange(n_local)
        d = jnp.where(gid_row[None, :] < n_valid, d, jnp.inf)
    _, cand = jax.lax.top_k(-d, shortlist)                # ADC shortlist
    cv = vectors_l[cand]                                  # (Q, shortlist, D)
    exact = jnp.sum((cv - queries[:, None, :]) ** 2, -1)
    if n_valid is not None:
        exact = jnp.where(cand + shard * n_local < n_valid, exact, jnp.inf)
    neg, order = jax.lax.top_k(-exact, k)
    gids = jnp.take_along_axis(cand, order, axis=1) + shard * n_local
    return gids[None], (-neg)[None]


def sharded_adc_scan(mesh, axes: tuple, codes, luts, *, k: int,
                     n_valid: Optional[int] = None):
    """Scatter: row-sharded (N, M) codes × replicated (Q, M, K) LUTs →
    per-shard (n_shards, Q, k) global ids + ADC distances.

    O(shards·k) gather traffic instead of the (Q, N) distance matrix
    (GSPMD's sharded top_k gathered it: 8.2 GB/dev → MBs)."""
    n_local = codes.shape[0] // shd.axis_size(mesh, axes)
    body = partial(_local_adc_topk, mesh=mesh, axes=axes, n_local=n_local,
                   k=k, n_valid=n_valid)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(axes, None), _lut_specs(luts)),
        out_specs=(P(axes, None, None), P(axes, None, None)))(codes, luts)


def sharded_adc_serve(mesh, axes: tuple, codes, vectors, luts, queries, *,
                      k: int, shortlist: int, n_valid: Optional[int] = None):
    """Scatter with local exact rerank (serve_1m): row-sharded codes AND
    vectors; each shard reranks its own ADC shortlist from its local vector
    rows — the DiskANN shortlist pattern distributed faiss-style."""
    n_local = codes.shape[0] // shd.axis_size(mesh, axes)
    body = partial(_local_adc_serve, mesh=mesh, axes=axes, n_local=n_local,
                   k=k, shortlist=min(shortlist, n_local), n_valid=n_valid)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(axes, None), P(axes, None), _lut_specs(luts),
                  P(None, None)),
        out_specs=(P(axes, None, None), P(axes, None, None)))(
            codes, vectors, luts, queries)


def merge_shard_topk(gids, dists, k: int):
    """Gather: (n_shards, Q, k_s) per-shard shortlists → global (Q, k)
    top-k. The in-jit, all-shards-alive merge; ShardedEngine uses
    dist.fault.partial_merge on the host instead to tolerate dead shards."""
    q = gids.shape[1]
    ds = dists.transpose(1, 0, 2).reshape(q, -1)
    is_ = gids.transpose(1, 0, 2).reshape(q, -1)
    neg, order = jax.lax.top_k(-ds, k)
    return jnp.take_along_axis(is_, order, axis=1), -neg


@dataclasses.dataclass
class ShardedEngine:
    """Scatter-gather serving over a device mesh (exhaustive ADC scan).

    Codes (and, when ``vectors`` is given, full vectors for the hybrid
    local-rerank scenario) are row-sharded across every mesh axis via
    dist.sharding.rpq_rows_spec. A query broadcasts its LUTs, every shard
    scans its rows and answers a local top-k, and the host merges the
    shard shortlists with dist.fault.partial_merge — shards reported dead
    via ``alive`` are simply dropped from the merge (graceful recall
    degradation, never a failed query).
    """
    codes: jax.Array                  # (N, M) compact codes
    lut_fn: Callable                  # (Q, D) queries -> (Q, M, K) LUTs
    vectors: Optional[jax.Array] = None   # (N, D): enables local exact rerank
    mesh: Optional[jax.sharding.Mesh] = None
    shortlist_mult: int = 4           # rerank shortlist = mult × k

    def __post_init__(self):
        if self.mesh is None:
            self.mesh = jax.make_mesh((len(jax.devices()),), ("data",))
        self._axes = shd.row_axes(self.mesh)
        self.n_shards = shd.axis_size(self.mesh, self._axes)
        self.n = int(self.codes.shape[0])
        rows = shd.named(self.mesh, shd.rpq_rows_spec(self.mesh))
        codes = jnp.asarray(self.codes)
        self._codes_bytes = codes.size * codes.dtype.itemsize
        self._codes_s = jax.device_put(
            kops.pad_rows_to_multiple(codes, self.n_shards), rows)
        self.codes = self._codes_s   # drop the unsharded copy
        self._vec_bytes = 0
        if self.vectors is not None:
            vec = jnp.asarray(self.vectors, jnp.float32)
            self._vec_bytes = vec.size * 4
            self._vec_s = jax.device_put(
                kops.pad_rows_to_multiple(vec, self.n_shards), rows)
            self.vectors = self._vec_s

    def _scatter(self, luts, queries, k: int):
        if not hasattr(self, "_jit_cache"):
            self._jit_cache = {}
        fn = self._jit_cache.get(k)
        if fn is None:
            if self.vectors is None:
                fn = jax.jit(lambda codes, luts: sharded_adc_scan(
                    self.mesh, self._axes, codes, luts, k=k, n_valid=self.n))
            else:
                fn = jax.jit(lambda codes, vec, luts, q: sharded_adc_serve(
                    self.mesh, self._axes, codes, vec, luts, q, k=k,
                    shortlist=self.shortlist_mult * k, n_valid=self.n))
            self._jit_cache[k] = fn
        if self.vectors is None:
            return fn(self._codes_s, luts)
        return fn(self._codes_s, self._vec_s, luts, queries)

    def search(self, queries: jax.Array, *, k: int = 10,
               alive: Optional[Sequence[bool]] = None,
               h: Optional[int] = None,
               expand: Optional[int] = None,
               entries: Optional[int] = None,
               prune_eps: Optional[float] = None,
               m_prefix: Optional[int] = None) -> SearchResult:
        """Exhaustive sharded scan (``h``/``expand``/``entries``/
        ``prune_eps``/``m_prefix`` accepted for engine-protocol
        compatibility and ignored — there is no beam to seed or prune)."""
        del h, expand, entries, prune_eps, m_prefix
        queries = jnp.atleast_2d(jnp.asarray(queries, jnp.float32))
        n_local = self._codes_s.shape[0] // self.n_shards
        kk = min(k, n_local)
        luts = jax.tree.map(jnp.asarray, self.lut_fn(queries))
        gids, dists = self._scatter(luts, queries, kk)
        gids, dists = np.asarray(gids), np.asarray(dists)
        if alive is None:
            alive = [True] * self.n_shards
        merged = partial_merge(list(gids), list(dists), alive, k)
        q = queries.shape[0]
        # n_dist counts REAL rows scanned: each alive shard scanned its
        # slice of the n corpus rows — the divisibility-padding rows it
        # also touched are +inf-masked sentinels, not distance work
        scanned = sum(
            max(0, min(self.n - i * n_local, n_local))
            for i, a in enumerate(alive) if a)
        return SearchResult(jnp.asarray(merged.ids), jnp.asarray(merged.dists),
                            hops=jnp.zeros((q,), jnp.int32),
                            n_dist=jnp.full((q,), scanned, jnp.int32),
                            rounds=jnp.zeros((q,), jnp.int32),
                            truncated=jnp.zeros((q,), bool),
                            degraded=merged.degraded)

    def memory_bytes(self) -> int:
        # UNPADDED sizes: what the index costs, not the divisibility slack
        return self._codes_bytes + self._vec_bytes


# ==========================================================================
# Graph-routed sharded serving (DESIGN.md §6): every shard runs the batched
# beam search over its OWN Vamana subgraph inside shard_map. Shared by
# ShardedGraphEngine, launch/serve.py --scenario sharded-graph, and the
# sharded_graph dry-run cell in launch/cells.py.
# ==========================================================================

def _shard_codes_pad(codes_l: jax.Array) -> jax.Array:
    """(1, n_local, M) shard block → (n_local + 1, M) sentinel-padded codes
    for beam.make_adc_dist_fn (sentinel row never read: beam masks ids)."""
    return kops.pad_sentinel_row(codes_l[0])


def _local_beam(neighbors_l, medoid_l, codes_l, luts, *, h: int,
                max_steps: int, backend: str, expand: int,
                seed_l=None, seed_cfg=None, prune_eps: float = 0.0,
                m_prefix: int = 0, max_rounds=None, max_n_dist=None):
    """Route over THIS shard's subgraph with ADC distances (u8 or fs4-
    packed layout, decided by the lut type). Returns the raw per-shard
    beam result (local ids).

    ``seed_l`` = (table, pivots, codes) shard blocks (leading shard axis 1)
    with ``seed_cfg`` = (k, m_hash, entries) statics: each shard seeds its
    local beam from its OWN coarse index — no cross-shard traffic, the
    seeding runs inside the scatter body. ``prune_eps``/``m_prefix``
    compile the partial-LUT hop-pruning pass into the local beam
    (DESIGN.md §11). Seeded searches fold the probe's scored candidates
    into ``n_dist``."""
    codes_p = _shard_codes_pad(codes_l)
    packed = _is_packed(luts)
    dist_fn = beam.make_adc_dist_fn(codes_p, packed=packed, backend=backend)
    mp, mt = _prune_cfg(luts, prune_eps, m_prefix)
    lb_fn = (beam.make_adc_dist_fn(codes_p, packed=packed, backend=backend,
                                   m_prefix=mp) if mp else None)
    cal_fn = (beam.make_lb_scale_fn(packed=packed, m_prefix=mp)
              if mp else None)
    seed_cost = 0
    if seed_l is not None:
        sk, smh, n_entries = seed_cfg
        tbl, piv, scodes = seed_l
        entry = sseed.seed_entries_from(tbl[0], piv[0], scodes[0], luts,
                                        k=sk, m_hash=smh, s=n_entries)
        seed_cost = int(tbl.shape[2] + piv.shape[1])
    else:
        entry = medoid_l[0]
    res = beam.beam_search(neighbors_l[0], entry, luts, dist_fn,
                           h=h, max_steps=max_steps, expand=expand,
                           lb_dist_fn=lb_fn, m_prefix=mp, m_total=mt,
                           prune_eps=prune_eps if mp else 0.0,
                           lb_scale_fn=cal_fn,
                           max_rounds=max_rounds, max_n_dist=max_n_dist)
    if seed_cost:
        res = res._replace(n_dist=res.n_dist + jnp.int32(seed_cost))
    return res


def _split_budget(rest: tuple, budget_cfg: tuple):
    """Peel the trailing traced budget scalars off a shard_map body's
    ``*rest`` (appended after the regular inputs by the wrappers below;
    ``budget_cfg`` = (has_max_rounds, has_max_n_dist) statics)."""
    nb = sum(bool(b) for b in budget_cfg)
    if not nb:
        return rest, None, None
    rest, tail = rest[:-nb], list(rest[-nb:])
    mr = tail.pop(0) if budget_cfg[0] else None
    mnd = tail.pop(0) if budget_cfg[1] else None
    return rest, mr, mnd


def _mask_to_global(ids, dists, *, mesh, axes, n_local: int, n_valid: int):
    """Local beam ids → global ids; sentinel slots and divisibility-padding
    rows become (-1, +inf) so the host merge never sees them."""
    shard = flat_shard_index(mesh, axes)
    n_valid_local = jnp.clip(n_valid - shard * n_local, 0, n_local)
    ok = (ids < n_valid_local) & jnp.isfinite(dists)
    gids = jnp.where(ok, ids + shard * n_local, -1)
    return gids, jnp.where(ok, dists, jnp.inf)


def _local_graph_topk(neighbors_l, medoid_l, codes_l, *rest, mesh, axes,
                      n_local: int, k: int, h: int, max_steps: int,
                      n_valid: int, backend: str, expand: int,
                      seed_cfg=None, prune_eps: float = 0.0,
                      m_prefix: int = 0, budget_cfg=(False, False)):
    """One shard's scatter half: beam-search my subgraph, return LOCAL
    top-k with GLOBAL ids. (1, Q, k) leading shard axis for the gather.
    ``rest`` is (luts,) classically, (table, pivots, seed_codes, luts)
    when per-shard seeding rides along (``seed_cfg`` set), with the traced
    deadline-budget scalars appended last per ``budget_cfg``."""
    rest, max_rounds, max_n_dist = _split_budget(rest, budget_cfg)
    seed_l = rest[:3] if seed_cfg is not None else None
    luts = rest[-1]
    res = _local_beam(neighbors_l, medoid_l, codes_l, luts, h=h,
                      max_steps=max_steps, backend=backend, expand=expand,
                      seed_l=seed_l, seed_cfg=seed_cfg,
                      prune_eps=prune_eps, m_prefix=m_prefix,
                      max_rounds=max_rounds, max_n_dist=max_n_dist)
    gids, d = _mask_to_global(res.ids[:, :k], res.dists[:, :k], mesh=mesh,
                              axes=axes, n_local=n_local, n_valid=n_valid)
    return gids[None], d[None], res.hops[None], res.n_dist[None], \
        res.rounds[None], res.truncated[None]


def _local_graph_serve(neighbors_l, medoid_l, codes_l, vectors_l, *rest,
                       mesh, axes, n_local: int, k: int, h: int,
                       shortlist: int, max_steps: int, n_valid: int,
                       backend: str, expand: int, seed_cfg=None,
                       prune_eps: float = 0.0, m_prefix: int = 0,
                       budget_cfg=(False, False)):
    """Scatter half with DiskANN-style local refinement: beam shortlist →
    exact rerank against my vector rows → LOCAL top-k, global ids.
    ``rest`` is (luts, queries), preceded by the three seed blocks when
    ``seed_cfg`` is set (as in :func:`_local_graph_topk`), with the traced
    deadline-budget scalars appended last per ``budget_cfg``."""
    rest, max_rounds, max_n_dist = _split_budget(rest, budget_cfg)
    seed_l = rest[:3] if seed_cfg is not None else None
    luts, queries = rest[-2], rest[-1]
    res = _local_beam(neighbors_l, medoid_l, codes_l, luts, h=h,
                      max_steps=max_steps, backend=backend, expand=expand,
                      seed_l=seed_l, seed_cfg=seed_cfg,
                      prune_eps=prune_eps, m_prefix=m_prefix,
                      max_rounds=max_rounds, max_n_dist=max_n_dist)
    cand = jnp.minimum(res.ids[:, :shortlist], n_local)   # clamp sentinel
    vec_p = kops.pad_sentinel_row(vectors_l[0])
    cv = vec_p[cand]                                      # (Q, shortlist, D)
    exact = jnp.sum((cv - queries[:, None, :]) ** 2, -1)
    exact = jnp.where(jnp.isfinite(res.dists[:, :shortlist]), exact, jnp.inf)
    neg, order = jax.lax.top_k(-exact, k)
    ids = jnp.take_along_axis(cand, order, axis=1)
    gids, d = _mask_to_global(ids, -neg, mesh=mesh, axes=axes,
                              n_local=n_local, n_valid=n_valid)
    return gids[None], d[None], res.hops[None], res.n_dist[None], \
        res.rounds[None], res.truncated[None]


def sharded_graph_topk(mesh, axes: tuple, neighbors, medoids, codes, luts, *,
                       k: int, h: int = 32, max_steps: int = 512,
                       n_valid: Optional[int] = None, backend: str = "auto",
                       expand: int = 1, seed_stack=None, seed_k: int = 0,
                       seed_m_hash: int = 0, entries: int = 1,
                       prune_eps: float = 0.0, m_prefix: int = 0,
                       max_rounds=None, max_n_dist=None):
    """Scatter: shard-stacked independent subgraphs × replicated LUTs →
    per-shard (S, Q, k) GLOBAL ids + ADC distances (+ (S, Q)
    hops/n_dist/rounds).

    Args:
      mesh/axes:  device mesh and the row-sharding axes (shd.row_axes).
      neighbors:  (S, n_local, R) stacked local adjacency (graphs/partition).
      medoids:    (S,) local entry vertices.
      codes:      (S, n_local, M) shard-stacked compact codes.
      luts:       (Q, M, K) query LUTs, replicated to every shard.
      k:          per-shard shortlist size (the gather is O(S·k)/query).
      h/max_steps: beam width and round cap of each LOCAL beam search.
      n_valid:    total REAL rows (masks the last shard's padding).
      backend:    per-hop distance backend (beam.make_adc_dist_fn).
      expand:     frontier batch size E of each local beam (DESIGN.md §9) —
                  every round scores one E·R-wide fused hop-ADC call
                  instead of E narrow ones.
      seed_stack: optional (table (S, B, C), pivots (S, P), codes
                  (S, n_local, M)) shard-stacked coarse-index arrays
                  (seed.build_seed_index per shard) with ``seed_k``/
                  ``seed_m_hash`` their shared statics: each shard seeds
                  ``entries`` local entry points inside its scatter body
                  (DESIGN.md §11).
      prune_eps/m_prefix: partial-LUT hop pruning of each local beam
                  (ε = 0 off — bit-identical).
      max_rounds/max_n_dist: traced per-call deadline budgets of each
                  local beam (DESIGN.md §13), replicated to every shard
                  (spec P()); None compiles out — bit-identical.

    Each shard routes ONLY over its own subgraph — no inter-shard edges, no
    mid-search collectives; the only cross-device traffic is the O(S·Q·k)
    shortlist gather (vs. O(Q·N/S) for the scan engine's full distances).
    The sixth output is the per-shard (S, Q) ``truncated`` flags.
    """
    s = shd.axis_size(mesh, axes)
    n_local = neighbors.shape[1]
    seeding = seed_stack is not None and entries > 1
    budget_cfg = (max_rounds is not None, max_n_dist is not None)
    body = partial(_local_graph_topk, mesh=mesh, axes=axes, n_local=n_local,
                   k=k, h=h, max_steps=max_steps,
                   n_valid=s * n_local if n_valid is None else n_valid,
                   backend=backend, expand=expand,
                   seed_cfg=(seed_k, seed_m_hash, entries) if seeding
                   else None, prune_eps=prune_eps, m_prefix=m_prefix,
                   budget_cfg=budget_cfg)
    ins = [neighbors, medoids, codes]
    specs = [P(axes, None, None), P(axes), P(axes, None, None)]
    if seeding:
        ins += list(seed_stack)
        specs += [P(axes, None, None), P(axes, None), P(axes, None, None)]
    ins.append(luts)
    specs.append(_lut_specs(luts))
    for b in (max_rounds, max_n_dist):
        if b is not None:
            ins.append(jnp.asarray(b, jnp.int32))
            specs.append(P())
    return shard_map(
        body, mesh=mesh, in_specs=tuple(specs),
        out_specs=(P(axes, None, None), P(axes, None, None),
                   P(axes, None), P(axes, None), P(axes, None),
                   P(axes, None)))(*ins)


def sharded_graph_serve(mesh, axes: tuple, neighbors, medoids, codes,
                        vectors, luts, queries, *, k: int, h: int = 32,
                        shortlist: int = 0, max_steps: int = 512,
                        n_valid: Optional[int] = None,
                        backend: str = "auto", expand: int = 1,
                        seed_stack=None, seed_k: int = 0,
                        seed_m_hash: int = 0, entries: int = 1,
                        prune_eps: float = 0.0, m_prefix: int = 0,
                        max_rounds=None, max_n_dist=None):
    """Scatter with local exact rerank: like :func:`sharded_graph_topk` but
    every shard re-ranks its beam shortlist against its resident vector
    rows (S, n_local, D) before answering — the DiskANN shortlist pattern
    with the SSD replaced by the shard's own HBM. Adaptive-routing kwargs
    (``seed_stack``/``entries``/``prune_eps``/``m_prefix``) and the traced
    deadline budgets (``max_rounds``/``max_n_dist``) as in
    :func:`sharded_graph_topk`."""
    s = shd.axis_size(mesh, axes)
    n_local = neighbors.shape[1]
    seeding = seed_stack is not None and entries > 1
    budget_cfg = (max_rounds is not None, max_n_dist is not None)
    body = partial(_local_graph_serve, mesh=mesh, axes=axes,
                   n_local=n_local, k=k, h=h,
                   shortlist=min(shortlist or h, h), max_steps=max_steps,
                   n_valid=s * n_local if n_valid is None else n_valid,
                   backend=backend, expand=expand,
                   seed_cfg=(seed_k, seed_m_hash, entries) if seeding
                   else None, prune_eps=prune_eps, m_prefix=m_prefix,
                   budget_cfg=budget_cfg)
    ins = [neighbors, medoids, codes, vectors]
    specs = [P(axes, None, None), P(axes), P(axes, None, None),
             P(axes, None, None)]
    if seeding:
        ins += list(seed_stack)
        specs += [P(axes, None, None), P(axes, None), P(axes, None, None)]
    ins += [luts, queries]
    specs += [_lut_specs(luts), P(None, None)]
    for b in (max_rounds, max_n_dist):
        if b is not None:
            ins.append(jnp.asarray(b, jnp.int32))
            specs.append(P())
    return shard_map(
        body, mesh=mesh, in_specs=tuple(specs),
        out_specs=(P(axes, None, None), P(axes, None, None),
                   P(axes, None), P(axes, None), P(axes, None),
                   P(axes, None)))(*ins)


def _stack_rows(x: jax.Array, n_shards: int, n_local: int) -> jax.Array:
    """(N, ...) global rows → (S, n_local, ...) shard-stacked, zero-padded."""
    pad = n_shards * n_local - x.shape[0]
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)
    return x.reshape((n_shards, n_local) + x.shape[1:])


@dataclasses.dataclass
class ShardedGraphEngine:
    """Graph-ROUTED scatter-gather serving over a device mesh.

    Where :class:`ShardedEngine` exhaustively scans every shard's rows, this
    engine routes: the dataset is partitioned into contiguous per-shard row
    ranges with an independent Vamana subgraph per shard
    (graphs/partition.py), and every query's beam search runs *inside*
    ``shard_map`` — each shard walks its own subgraph with ADC distances
    (per-hop hot loop = the fused hop-ADC Pallas kernel on TPU), optionally
    exact-reranks its beam against its resident vector rows (DiskANN-style),
    and answers a LOCAL top-k with GLOBAL ids. The host merges shard
    shortlists with ``dist.fault.partial_merge``: a dead shard's row range
    drops out of the answer (graceful recall degradation), the query never
    fails.

    Per-query distance work is O(hops·R) per shard instead of O(N/S), so
    this is the scenario that scales ROUTING — not just scanning — with the
    mesh. Recall is within a few points of a single-device in-memory beam
    at equal width, because every shard is searched and the merge keeps the
    global best (the partition can only *split* a query's true neighborhood
    across shards, each of which still finds its part).

    Attributes:
      graph:    PartitionedGraph over the same row order as ``codes``.
      codes:    (N, M) compact codes (global row order).
      lut_fn:   (Q, D) queries → (Q, M, K) LUTs.
      vectors:  optional (N, D) full vectors; enables local exact rerank.
      mesh:     device mesh (default: all local devices on one axis).
      backend:  per-hop kernel dispatch, see beam.make_adc_dist_fn.
    """
    graph: PartitionedGraph
    codes: jax.Array
    lut_fn: Callable
    vectors: Optional[jax.Array] = None
    mesh: Optional[jax.sharding.Mesh] = None
    backend: str = "auto"

    def __post_init__(self):
        if self.mesh is None:
            self.mesh = jax.make_mesh((len(jax.devices()),), ("data",))
        self._axes = shd.row_axes(self.mesh)
        self.n_shards = shd.axis_size(self.mesh, self._axes)
        if self.n_shards != self.graph.n_shards:
            raise ValueError(
                f"graph has {self.graph.n_shards} shards but the mesh has "
                f"{self.n_shards} — partition with n_shards="
                f"{self.n_shards}")
        self.n = int(self.graph.n)
        if int(self.codes.shape[0]) != self.n:
            raise ValueError(f"codes rows {self.codes.shape[0]} != "
                             f"graph rows {self.n}")
        n_local = self.graph.n_local
        rows3 = shd.named(self.mesh, shd.rpq_shard_stack_spec(self.mesh))
        rows1 = shd.named(self.mesh, shd.rpq_shard_stack_spec(self.mesh, 1))
        codes = jnp.asarray(self.codes)
        self._codes_bytes = codes.size * codes.dtype.itemsize
        self._codes_s = jax.device_put(
            _stack_rows(codes, self.n_shards, n_local), rows3)
        self.codes = self._codes_s
        self._nbrs_s = jax.device_put(self.graph.neighbors, rows3)
        self._medoids_s = jax.device_put(self.graph.medoids, rows1)
        self._vec_bytes = 0
        if self.vectors is not None:
            vec = jnp.asarray(self.vectors, jnp.float32)
            self._vec_bytes = vec.size * 4
            self._vec_s = jax.device_put(
                _stack_rows(vec, self.n_shards, n_local), rows3)
            self.vectors = self._vec_s
        self._jit_cache = {}
        self._seedstk = None

    def _seed_stack(self, luts):
        """Per-shard coarse seeding indexes, built lazily on the first
        ``entries>1`` search: one seed.build_seed_index over each shard's
        LOCAL rows (padding rows of the last shard excluded — a beam must
        never START on padding), stacked to (S, ...) arrays and device_put
        with the shard-stack layout. ``k``/``m_hash`` are shared across
        shards so one static shard_map body serves all of them."""
        if self._seedstk is None:
            codes = np.asarray(jax.device_get(self._codes_s))  # (S, nl, .)
            if _is_packed(luts):
                m = _lut_m(luts)
                codes = np.stack([np.asarray(unpack_codes(jnp.asarray(c), m))
                                  for c in codes])
            s, nl = codes.shape[:2]
            k = int(codes.max()) + 1
            m_hash = sseed.auto_m_hash(codes.shape[2], k)
            tbls, pivs = [], []
            for i in range(s):
                real = max(1, min(self.n - i * nl, nl))
                ix = sseed.build_seed_index(codes[i, :real], k=k,
                                            m_hash=m_hash)
                tbls.append(np.asarray(ix.table))
                pivs.append(np.asarray(ix.pivots))
            pw = max(p.shape[0] for p in pivs)
            pivs = [np.pad(p, (0, pw - p.shape[0]), constant_values=-1)
                    for p in pivs]
            rows3 = shd.named(self.mesh, shd.rpq_shard_stack_spec(self.mesh))
            rows2 = shd.named(self.mesh,
                              shd.rpq_shard_stack_spec(self.mesh, 2))
            self._seedstk = (
                jax.device_put(jnp.asarray(np.stack(tbls)), rows3),
                jax.device_put(jnp.asarray(np.stack(pivs)), rows2),
                jax.device_put(jnp.asarray(codes, jnp.int32), rows3),
                k, m_hash)
        return self._seedstk

    def _scatter(self, luts, queries, k: int, h: int, max_steps: int,
                 expand: int, entries: int, prune_eps: float,
                 m_prefix: int, max_rounds=None, max_n_dist=None):
        # budgets are TRACED — the cache keys on their PRESENCE (a distinct
        # compiled body with/without the check), never on their values, so
        # sweeping a deadline hits one cache entry
        key = (k, h, max_steps, expand, entries, prune_eps, m_prefix,
               max_rounds is not None, max_n_dist is not None)
        seed_stack = seed_k = seed_m_hash = None
        if entries > 1:
            *seed_stack, seed_k, seed_m_hash = self._seed_stack(luts)
            seed_stack = tuple(seed_stack)
        fn = self._jit_cache.get(key)
        if fn is None:
            adaptive = dict(entries=entries, prune_eps=prune_eps,
                            m_prefix=m_prefix, seed_k=seed_k or 0,
                            seed_m_hash=seed_m_hash or 0)
            if self.vectors is None:
                fn = jax.jit(
                    lambda nb, md, cd, lu, seed, mr, mnd: sharded_graph_topk(
                        self.mesh, self._axes, nb, md, cd, lu, k=k, h=h,
                        max_steps=max_steps, n_valid=self.n,
                        backend=self.backend, expand=expand,
                        seed_stack=seed, max_rounds=mr, max_n_dist=mnd,
                        **adaptive))
            else:
                fn = jax.jit(
                    lambda nb, md, cd, vc, lu, q, seed, mr, mnd:
                    sharded_graph_serve(
                        self.mesh, self._axes, nb, md, cd, vc, lu, q, k=k,
                        h=h, shortlist=h, max_steps=max_steps,
                        n_valid=self.n, backend=self.backend,
                        expand=expand, seed_stack=seed, max_rounds=mr,
                        max_n_dist=mnd, **adaptive))
            self._jit_cache[key] = fn
        if self.vectors is None:
            return fn(self._nbrs_s, self._medoids_s, self._codes_s, luts,
                      seed_stack, max_rounds, max_n_dist)
        return fn(self._nbrs_s, self._medoids_s, self._codes_s, self._vec_s,
                  luts, queries, seed_stack, max_rounds, max_n_dist)

    def search(self, queries: jax.Array, *, k: int = 10, h: int = 32,
               max_steps: int = 512, expand: int = 1,
               alive: Optional[Sequence[bool]] = None, entries: int = 1,
               prune_eps: float = 0.0, m_prefix: int = 0,
               max_rounds=None, max_n_dist=None,
               deadline_s: Optional[float] = None,
               quorum: Optional[int] = None,
               shard_latency_s: Optional[Sequence[float]] = None
               ) -> SearchResult:
        """Route every query on every (alive) shard, merge the shortlists.

        ``hops``/``n_dist`` report the SUM over alive shards — the total
        work the mesh did for the query, comparable to a single-device
        beam's counters. ``rounds`` reports the MAX over alive shards: the
        shards route concurrently, so the slowest shard's sequential trip
        count is the query's latency proxy. ``entries``/``prune_eps``/
        ``m_prefix`` are the adaptive-routing knobs (DESIGN.md §11),
        applied PER SHARD: every shard seeds its local beam from its own
        coarse index and prunes its own hops.

        ``max_rounds``/``max_n_dist`` are per-call compute budgets applied
        to EVERY shard's local beam (traced — sweeping them never
        retraces). ``deadline_s``+``shard_latency_s`` model the quorum
        merge (DESIGN.md §13): shards whose modeled latency exceeds the
        straggler deadline are charged as dead for this call — provided at
        least ``quorum`` (default: majority of alive) fast shards remain;
        otherwise the fastest ``quorum`` alive shards are kept even past
        the deadline (quorum outranks deadline). ``truncated`` is
        any-over-merged-shards; ``degraded`` is True whenever the answer
        merged fewer shards than were declared alive, or none at all.
        """
        queries = jnp.atleast_2d(jnp.asarray(queries, jnp.float32))
        kk = min(k, h, self.graph.n_local)
        luts = jax.tree.map(jnp.asarray, self.lut_fn(queries))
        gids, dists, hops, ndist, rounds, trunc = self._scatter(
            luts, queries, kk, h, max_steps, expand, entries, prune_eps,
            m_prefix, max_rounds=max_rounds, max_n_dist=max_n_dist)
        gids, dists = np.asarray(gids), np.asarray(dists)
        if alive is None:
            alive = [True] * self.n_shards
        alive = list(alive)
        quorum_degraded = False
        if deadline_s is not None or quorum is not None:
            lat = (list(shard_latency_s) if shard_latency_s is not None
                   else [0.0] * self.n_shards)
            decision = resolve_quorum(alive, lat, deadline_s, quorum)
            alive = list(decision.alive)
            quorum_degraded = decision.degraded
        merged = partial_merge(list(gids), list(dists), alive, k)
        mask = np.asarray(alive, bool)
        q = queries.shape[0]
        if mask.any():
            hops = np.asarray(hops)[mask].sum(0)
            ndist = np.asarray(ndist)[mask].sum(0)
            rounds = np.asarray(rounds)[mask].max(0)
            trunc = np.asarray(trunc)[mask].any(0)
        else:  # every shard dead: sentinel answer, zero-work counters
            hops = np.zeros((q,), np.int32)
            ndist = np.zeros((q,), np.int32)
            rounds = np.zeros((q,), np.int32)
            trunc = np.zeros((q,), bool)
        return SearchResult(jnp.asarray(merged.ids), jnp.asarray(merged.dists),
                            hops=jnp.asarray(hops, jnp.int32),
                            n_dist=jnp.asarray(ndist, jnp.int32),
                            rounds=jnp.asarray(rounds, jnp.int32),
                            truncated=jnp.asarray(trunc),
                            degraded=bool(merged.degraded or quorum_degraded))

    def memory_bytes(self) -> int:
        # UNPADDED codes + per-shard adjacency (+ vectors when resident)
        return (self._codes_bytes
                + self.graph.neighbors.size * 4 + self._vec_bytes)
