"""Serving engines (paper §7): in-memory and SSD-hybrid (DiskANN) scenarios.

Both engines route with PQ-ADC distances over a proximity graph. They accept
any quantizer exposing the (codes, lut_fn) protocol — classic PQ / OPQ
(pq.base.QuantizerModel), the learned RPQ (core.rpq), or Catalyst.

* :class:`InMemoryEngine` — codes + codebook + PG in RAM; next-hop selection
  and the final top-k use ONLY PQ distances (no rerank). Memory = N·M bytes
  + graph.
* :class:`HybridEngine` — DiskANN: codes + codebook in RAM; full vectors +
  PG "on SSD". Routing uses ADC; every expansion costs one simulated SSD
  read (the node's 4 KiB block holds its vector + adjacency, as in DiskANN's
  disk layout); the final candidates are re-ranked with exact distances.
  IO time is modeled as reads × latency (default 100 µs, ~NVMe) — reported
  separately from compute time so real-hardware numbers can be projected.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.graphs.adjacency import Graph
from repro.search import beam
from repro.search.beam import SearchResult


def _pad_codes(codes: jax.Array) -> jax.Array:
    return jnp.concatenate(
        [codes, jnp.zeros((1, codes.shape[1]), codes.dtype)], axis=0)


def _pad_vectors(x: jax.Array) -> jax.Array:
    return jnp.concatenate([x, jnp.zeros((1, x.shape[1]), x.dtype)], axis=0)


@dataclasses.dataclass
class InMemoryEngine:
    graph: Graph
    codes: jax.Array                  # (N, M) compact codes
    lut_fn: Callable                  # (Q, D) queries -> (Q, M, K) LUTs
    entry_fn: Optional[Callable] = None  # queries -> (Q,) entries (HNSW descend)

    def __post_init__(self):
        self._codes_p = _pad_codes(self.codes)
        self._dist_fn = beam.make_adc_dist_fn(self._codes_p)

    def search(self, queries: jax.Array, *, k: int = 10, h: int = 32,
               max_steps: int = 512) -> SearchResult:
        luts = self.lut_fn(queries)
        entry = (self.entry_fn(queries) if self.entry_fn is not None
                 else self.graph.medoid)
        res = beam.beam_search(self.graph.neighbors, entry, luts,
                               self._dist_fn, h=h, max_steps=max_steps)
        return SearchResult(res.ids[:, :k], res.dists[:, :k], res.hops,
                            res.n_dist)

    def memory_bytes(self) -> int:
        return (self.codes.size * self.codes.dtype.itemsize
                + self.graph.neighbors.size * 4)


@dataclasses.dataclass
class HybridEngine:
    """DiskANN-style: ADC routing + exact rerank from "SSD" vectors."""
    graph: Graph
    codes: jax.Array
    lut_fn: Callable
    vectors: jax.Array                # (N, D) original vectors ("on SSD")
    io_latency_s: float = 100e-6     # per 4 KiB node read (NVMe-class)
    entry_fn: Optional[Callable] = None

    def __post_init__(self):
        self._codes_p = _pad_codes(self.codes)
        self._vec_p = _pad_vectors(jnp.asarray(self.vectors, jnp.float32))
        self._dist_fn = beam.make_adc_dist_fn(self._codes_p)

    def search(self, queries: jax.Array, *, k: int = 10, h: int = 32,
               max_steps: int = 512, rerank: int = 0) -> SearchResult:
        """rerank = how many beam candidates to re-rank exactly (0 → h)."""
        rerank = rerank or h
        k = min(k, rerank)  # cannot return more results than candidates
        luts = self.lut_fn(queries)
        entry = (self.entry_fn(queries) if self.entry_fn is not None
                 else self.graph.medoid)
        res = beam.beam_search(self.graph.neighbors, entry, luts,
                               self._dist_fn, h=h, max_steps=max_steps)
        ids, dists = _exact_rerank(self._vec_p, queries, res.ids, rerank, k)
        return SearchResult(ids, dists, res.hops, res.n_dist)

    def io_time(self, res: SearchResult) -> jax.Array:
        """Modeled SSD time per query: one 4 KiB block read per expansion."""
        return res.hops.astype(jnp.float32) * self.io_latency_s

    def memory_bytes(self) -> int:
        # resident = codes (+ codebook, negligible); graph+vectors on SSD
        return self.codes.size * self.codes.dtype.itemsize


@partial(jax.jit, static_argnames=("rerank", "k"))
def _exact_rerank(vec_p, queries, cand_ids, rerank: int, k: int):
    cand = cand_ids[:, :rerank]
    v = vec_p[cand]                                       # (Q, rerank, D)
    d = jnp.sum((v - queries[:, None, :]) ** 2, axis=-1)
    d = jnp.where(cand == vec_p.shape[0] - 1, jnp.inf, d)
    neg, order = jax.lax.top_k(-d, k)
    return jnp.take_along_axis(cand, order, axis=1), -neg
