"""Batched graph beam search — the routing engine (paper §3.1, Alg. 2 core).

TPU/JAX adaptation (DESIGN.md §3): instead of a scalar CPU heap per query we
run a *fixed-shape* best-first beam entirely in `jax.lax`:

* beam = three (h,) arrays (ids, dists, expanded) kept sorted by merge+top_k;
* visited set = uint32 bitset (N/32 words) — O(1) membership, vmappable;
* one `while_loop` per batch; vmapped lanes step together until all converge
  (the classic SIMD-ification of best-first search);
* distances come from a pluggable `dist_fn` (ADC LUT gather or exact), so the
  same engine serves PQ-routing and exact-routing.

`beam_search_trace` additionally records the ranked candidate beam at every
hop — exactly the paper's Definition 6 routing features.
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

INF = jnp.float32(jnp.inf)


class SearchResult(NamedTuple):
    ids: jax.Array     # (Q, h) int32 ascending by dist (sentinel-padded)
    dists: jax.Array   # (Q, h) f32
    hops: jax.Array    # (Q,) int32 — number of node expansions
    n_dist: jax.Array  # (Q,) int32 — number of distance computations


class Trace(NamedTuple):
    beam_ids: jax.Array    # (Q, T, h) beam AFTER each hop's merge
    beam_dists: jax.Array  # (Q, T, h)
    hop_valid: jax.Array   # (Q, T) bool — hop actually happened
    result: SearchResult


def _bit_get(bits: jax.Array, idx: jax.Array) -> jax.Array:
    return (bits[idx >> 5] >> (idx & 31)) & 1


def _scatter_or(bits, word, mask):
    """OR `mask[i]` into `bits[word[i]]` (duplicate-safe), vectorized.

    jnp has no scatter-or primitive, and the old O(R) ``fori_loop`` of
    read-modify-writes serialized the visited-set update on every hop of
    every query. Vectorized equivalent: single-bit masks whose (word, bit)
    pairs are distinct sum to their OR, so deduplicate repeated entries
    (each mask[i] is one bit — equal masks in the same word are the only
    collision case), scatter-ADD into a zero array (one XLA scatter), and
    OR the per-word contribution into ``bits``.
    """
    r = word.shape[0]
    # drop duplicates of an earlier (word, mask) pair — strictly-lower
    # triangular compare over the ≤R entries, O(R²) lanes, no loop
    same = (word[:, None] == word[None, :]) & (mask[:, None] == mask[None, :])
    first = ~jnp.any(same & (jnp.arange(r)[:, None] > jnp.arange(r)[None, :]),
                     axis=1)
    contrib = jnp.zeros_like(bits).at[word].add(
        jnp.where(first, mask, jnp.uint32(0)))
    return bits | contrib


def _single_query(neighbors: jax.Array, entry: jax.Array, qdata,
                  dist_fn: Callable, h: int, max_steps: int,
                  trace_len: int = 0):
    """Search for ONE query; built to be vmapped. Returns result (+trace)."""
    n = neighbors.shape[0]
    r = neighbors.shape[1]
    nwords = (n + 32) // 32 + 1

    ids0 = jnp.full((h,), n, jnp.int32).at[0].set(entry)
    d_entry = dist_fn(qdata, entry[None])[0]
    dists0 = jnp.full((h,), INF).at[0].set(d_entry)
    exp0 = jnp.ones((h,), bool).at[0].set(False)
    visited0 = _scatter_or(jnp.zeros((nwords,), jnp.uint32),
                           (entry >> 5)[None], (jnp.uint32(1) << (entry & 31).astype(jnp.uint32))[None])

    do_trace = trace_len > 0
    tb_ids0 = jnp.full((max(trace_len, 1), h), n, jnp.int32)
    tb_d0 = jnp.full((max(trace_len, 1), h), INF)
    tb_v0 = jnp.zeros((max(trace_len, 1),), bool)

    def cond(state):
        step, ids, dists, exp, visited, hops, ndist, tbi, tbd, tbv = state
        return jnp.logical_and(step < max_steps, jnp.any(~exp & (dists < INF)))

    def body(state):
        step, ids, dists, exp, visited, hops, ndist, tbi, tbd, tbv = state
        # 1. pick best unexpanded beam entry
        cand = jnp.where(~exp & (dists < INF), dists, INF)
        sel = jnp.argmin(cand)
        exp = exp.at[sel].set(True)
        hops = hops + 1
        # 2. expand: gather neighbors, drop pads & visited
        nbr = neighbors[ids[sel]]                       # (R,)
        valid = nbr < n
        seen = _bit_get(visited, jnp.where(valid, nbr, 0)).astype(bool)
        fresh = valid & ~seen
        visited = _scatter_or(
            visited, jnp.where(fresh, nbr, n) >> 5,
            jnp.where(fresh, jnp.uint32(1) << (nbr & 31).astype(jnp.uint32), jnp.uint32(0)))
        nd = dist_fn(qdata, jnp.where(fresh, nbr, 0))
        nd = jnp.where(fresh, nd, INF)
        ndist = ndist + jnp.sum(fresh.astype(jnp.int32))
        # 3. merge beam ∪ neighbors, keep top-h by distance
        all_ids = jnp.concatenate([ids, jnp.where(fresh, nbr, n)])
        all_d = jnp.concatenate([dists, nd])
        all_e = jnp.concatenate([exp, jnp.zeros((r,), bool)])
        neg, order = jax.lax.top_k(-all_d, h)
        ids = all_ids[order]
        dists = -neg
        exp = all_e[order] | (dists == INF)
        # 4. trace the ranked candidate beam (paper Def. 6); steps beyond
        #    trace_len must NOT clobber the last recorded slot
        if do_trace:
            ti = jnp.minimum(step, trace_len - 1)
            in_range = step < trace_len
            tbi = tbi.at[ti].set(jnp.where(in_range, ids, tbi[ti]))
            tbd = tbd.at[ti].set(jnp.where(in_range, dists, tbd[ti]))
            tbv = tbv.at[ti].set(tbv[ti] | in_range)
        return (step + 1, ids, dists, exp, visited, hops, ndist, tbi, tbd, tbv)

    state = (jnp.int32(0), ids0, dists0, exp0, visited0,
             jnp.int32(0), jnp.int32(1), tb_ids0, tb_d0, tb_v0)
    step, ids, dists, exp, visited, hops, ndist, tbi, tbd, tbv = \
        jax.lax.while_loop(cond, body, state)
    res = (ids, dists, hops, ndist)
    return res + ((tbi, tbd, tbv) if do_trace else ())


@functools.partial(jax.jit, static_argnames=("dist_fn", "h", "max_steps"))
def beam_search(neighbors: jax.Array, entry: jax.Array, qdatas,
                dist_fn: Callable, *, h: int = 32,
                max_steps: int = 256) -> SearchResult:
    """Batched beam search.

    Args:
      neighbors: (N, R) padded adjacency (sentinel N).
      entry:     () int32 entry vertex (shared) — the PG medoid.
      qdatas:    per-query pytree, leading axis Q (e.g. LUTs (Q, M, K) for ADC
                 routing or raw queries (Q, D) for exact routing).
      dist_fn:   (qdata, ids (B,)) -> (B,) f32 distances for one query.
      h:         beam width (the paper's global candidate set size).
      max_steps: hop cap (safety for pathological graphs).
    """
    entry = jnp.asarray(entry, jnp.int32)
    nq = jax.tree.leaves(qdatas)[0].shape[0]
    entries = jnp.broadcast_to(entry, (nq,)) if entry.ndim == 0 else entry
    fn = lambda e, qd: _single_query(neighbors, e, qd, dist_fn, h, max_steps)
    ids, dists, hops, ndist = jax.vmap(fn)(entries, qdatas)
    return SearchResult(ids, dists, hops, ndist)


@functools.partial(jax.jit, static_argnames=("dist_fn", "h", "max_steps", "trace_len"))
def beam_search_trace(neighbors: jax.Array, entry: jax.Array, qdatas,
                      dist_fn: Callable, *, h: int = 32, max_steps: int = 256,
                      trace_len: int = 64) -> Trace:
    """Beam search that also records the ranked beam at every hop."""
    entry = jnp.asarray(entry, jnp.int32)
    nq = jax.tree.leaves(qdatas)[0].shape[0]
    entries = jnp.broadcast_to(entry, (nq,)) if entry.ndim == 0 else entry
    fn = lambda e, qd: _single_query(neighbors, e, qd, dist_fn, h, max_steps,
                                     trace_len=trace_len)
    ids, dists, hops, ndist, tbi, tbd, tbv = jax.vmap(fn)(entries, qdatas)
    return Trace(tbi, tbd, tbv, SearchResult(ids, dists, hops, ndist))


# --------------------------------------------------------------------------
# Distance functions
# --------------------------------------------------------------------------

def make_exact_dist_fn(vectors: jax.Array) -> Callable:
    """qdata = query vector (D,). vectors must be (N+1, D) sentinel-padded."""
    def dist_fn(q, ids):
        v = vectors[ids]
        return jnp.sum((v - q[None, :]) ** 2, axis=-1)
    return dist_fn


def make_adc_dist_fn(codes: jax.Array, *, packed: bool = False,
                     backend: str = "auto") -> Callable:
    """qdata = LUT (M, K) — or a per-query ``pq.pack.QuantizedLUT``
    ((M, 16) u8 lut, scale, bias) when ``packed=True``. codes must be
    (N+1, M) sentinel-padded (fs4: (N+1, ceil(M/2)) packed bytes).

    Backend dispatch for the per-hop hot loop (kernels.ops semantics):

    * CPU (``backend="auto"`` off-TPU, or ``"ref"``): a jnp gather — the
      per-hop read is tiny (R ≤ 64 rows) and XLA fuses it. The fs4 path
      nibble-unpacks the gathered bytes and accumulates the uint8 LUT in
      int32 before the one affine dequant.
    * TPU (``"auto"`` on-TPU, or ``"pallas"``/``"interpret"``): the fused
      hop-ADC Pallas kernel (kernels/hop_adc.py; packed twin for fs4) —
      neighbor-row gather and LUT reduce in ONE kernel, so the gathered
      codes never round-trip HBM. The kernel is batched over queries;
      under beam_search's vmap the per-query call batches into the
      kernel's query grid axis.
    """
    use_fused = backend in ("pallas", "interpret") or (
        backend == "auto" and jax.default_backend() == "tpu")
    if packed:
        if use_fused:
            from repro.kernels import ops

            def dist_fn(qlut, ids):
                return ops.hop_adc_fs(codes, ids[None], qlut.lut[None],
                                      qlut.scale[None], qlut.bias[None],
                                      backend=backend)[0]
            return dist_fn

        def dist_fn(qlut, ids):
            lut, scale, bias = qlut                   # (M, 16) u8, (), ()
            m = lut.shape[0]
            p = codes[ids].astype(jnp.int32)          # (B, ceil(M/2))
            nib = jnp.stack([p & 0xF, (p >> 4) & 0xF], axis=-1)
            c = nib.reshape(p.shape[0], -1)[:, :m]    # (B, M)
            vals = lut.astype(jnp.int32)[jnp.arange(m)[None, :], c]
            acc = jnp.sum(vals, axis=-1)              # (B,) int32, exact
            return scale * acc.astype(jnp.float32) + m * bias
        return dist_fn

    m = codes.shape[1]
    if use_fused:
        from repro.kernels import ops

        def dist_fn(lut, ids):
            return ops.hop_adc(codes, ids[None], lut[None],
                               backend=backend)[0]
        return dist_fn

    def dist_fn(lut, ids):
        c = codes[ids].astype(jnp.int32)              # (B, M)
        vals = lut[jnp.arange(m)[None, :], c]         # (B, M)
        return jnp.sum(vals, axis=-1)
    return dist_fn
