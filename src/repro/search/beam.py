"""Batched graph beam search — the routing engine (paper §3.1, Alg. 2 core).

TPU/JAX adaptation (DESIGN.md §3, §9): instead of a scalar CPU heap per query
we run a *fixed-shape* best-first beam entirely in `jax.lax`:

* beam = three (h,) arrays (ids, dists, expanded) kept sorted by merge+top_k;
* visited set = uint32 bitset (N/32 words) — O(1) membership, vmappable;
* one `while_loop` per batch; vmapped lanes step together until all converge
  (the classic SIMD-ification of best-first search);
* distances come from a pluggable `dist_fn` (ADC LUT gather or exact), so the
  same engine serves PQ-routing and exact-routing.

**Frontier batching** (`expand=E`, DESIGN.md §9): every `while_loop` round
expands the E best unexpanded beam entries at once — their E·R neighbor ids
are deduplicated (against each other and the visited bitset; width-adaptive
first-occurrence, sort-based once the frontier outgrows the all-pairs
compare's sweet spot) and scored in ONE `dist_fn` call, then merged in a
single (h + E·R)-wide top-k.
This is DiskANN's beam-width trick aimed at the TPU's expensive medium: the
kernel invocation. Sequential trip count drops from `hops` to `rounds`
(≈ hops/E) and the vmapped lockstep-convergence tail shrinks with it.
`expand=1` (the default) is bit-identical to the classic one-hop-per-step
beam. `SearchResult.rounds` reports the measured round count.

**Tombstones** (streaming deletes, DESIGN.md §10): `beam_search(...,
tombstones=bitset)` takes a uint32 bitset over vertex ids (same word layout
as the visited set) and masks every tombstoned frontier distance to +inf —
a deleted vertex is never expanded, never ranks, and is scrubbed from the
returned beam (sentinel id, +inf dist). The bitset is a TRACED argument, so
churning deletes never re-trigger jit (unlike baking the mask into
`dist_fn`, which is a static jit argument). A tombstoned ENTRY vertex gets a
large-but-finite distance instead, so the search still starts and routes
off it (it is scrubbed from the results like any other tombstone).

**Multi-entry seeding** (adaptive routing, DESIGN.md §11): ``entry`` may be
a (Q, S) per-query entry SET instead of one shared/per-query vertex —
``search/seed.py`` produces such sets from a PQ-hash coarse index. The S
entries are deduplicated, scored in one ``dist_fn`` call, sorted, and
installed as the initial beam; invalid lanes (sentinel ``-1`` padding from
the seeder) start expanded at +inf, and each tombstoned entry individually
gets ``DEAD_ENTRY_DIST`` (so an all-tombstoned entry set still routes off
its best dead entry, exactly like the classic dead-medoid case). ``S=1``
is bit-identical to the classic single-entry beam.

**Probabilistic hop pruning** (DESIGN.md §11): with ``lb_dist_fn`` (a
partial-LUT distance over the first ``m_prefix < m_total`` subspaces —
``make_adc_dist_fn(m_prefix=)``) and ``prune_eps > 0``, every round first
scores the frontier's LOWER BOUND ``d_m′`` (per-subspace LUT entries are
non-negative, so ``d_m′ ≤ d_M``), extrapolates it to a full-distance
estimate ``d̂ = d_m′ · cal``, and only full-scores candidates with
``d̂ · (1 + ε) ≤ τ``, where τ is the current worst beam distance. The
estimate (not the raw bound) drives the gate: the bound sits well below
the full sum, so comparing IT to a full-distance τ would prune almost
nothing — extrapolation prunes like the full distance would at m′/M of
the cost, mis-pruning with small ε-bounded probability (hence
"probabilistic"). The extrapolation factor ``cal`` defaults to the
uniform-mass ratio ``M/m′``, but that overshoots on anisotropic data
(leading subspaces carry MORE than m′/M of the distance mass, so the
estimate comes out too large and over-prunes); pass
``lb_scale_fn = make_lb_scale_fn(...)`` to calibrate it per query from
the query's own LUT mass instead. Pruned lanes are masked to the
sentinel — shapes never change, so churn never retraces. ``prune_eps=0``
disables the pass entirely (bit-identical).
``n_dist`` then counts full-LUT-equivalents: each partial score adds
``m_prefix / m_total`` of a distance evaluation, each full score adds one.

**Deadline budgets** (resilience, DESIGN.md §13): ``max_rounds`` /
``max_n_dist`` bound the per-call compute — rounds and (full-LUT-equivalent)
distance evaluations respectively. Both are TRACED scalars, so sweeping a
deadline never retraces, and both gate only the ``while_loop`` *condition*:
under ``vmap``, JAX's while_loop batching masks the whole carry for any lane
whose own cond is false, so an exhausted query freezes — best-so-far beam,
honest counters — while other lanes keep stepping, with zero body-side
masking. The early exit is fixed-shape (the beam arrays never change size);
``SearchResult.truncated`` flags every query that stopped with unexpanded
finite candidates still pending — whether the round budget, the n_dist
budget, or ``max_steps`` cut it off. ``None`` (the default) compiles the
check out entirely: bit-identical to the pre-budget beam, the same
zero-cost-when-off contract as ``expand=1`` and ``prune_eps=0``.

`beam_search_trace` additionally records the ranked candidate beam at every
round — exactly the paper's Definition 6 routing features.
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

INF = jnp.float32(jnp.inf)

# Distance assigned to a tombstoned ENTRY vertex: large enough that any real
# candidate outranks it, finite so the while_loop still expands it (an +inf
# entry would end the search before the first hop — the "deleted medoid"
# case must keep routing).
DEAD_ENTRY_DIST = jnp.float32(1e30)


class SearchResult(NamedTuple):
    ids: jax.Array     # (Q, h) int32 ascending by dist (sentinel-padded)
    dists: jax.Array   # (Q, h) f32
    hops: jax.Array    # (Q,) int32 — number of node expansions
    n_dist: jax.Array  # (Q,) int32 — number of distance computations
    # (Q,) int32 — while_loop rounds (sequential trips). With expand=E each
    # round expands up to E nodes, so rounds ∈ [ceil(hops/E), hops]; at
    # expand=1, rounds == hops. None for results that never ran a beam
    # (hand-built tuples, pure-scan engines).
    rounds: Optional[jax.Array] = None
    # (Q,) bool — True where the search stopped with unexpanded finite
    # candidates still pending (a deadline budget or max_steps cut it off):
    # the beam is an honest best-so-far, not a converged answer. None for
    # results that never ran a beam.
    truncated: Optional[jax.Array] = None
    # Host-side python bool set by the sharded engines: True when the
    # answer is known incomplete at the SERVING layer (dead shards dropped
    # from the merge, stragglers charged dead by the quorum deadline).
    # None for single-process engines and raw beam results.
    degraded: Optional[bool] = None


class Trace(NamedTuple):
    beam_ids: jax.Array    # (Q, T, h) beam AFTER each round's merge
    beam_dists: jax.Array  # (Q, T, h)
    hop_valid: jax.Array   # (Q, T) bool — round actually happened
    result: SearchResult


def _bit_get(bits: jax.Array, idx: jax.Array) -> jax.Array:
    return (bits[idx >> 5] >> (idx & 31)) & 1


# Width where the sort-based first-occurrence overtakes the all-pairs
# compare. Measured on the CPU CI host (Q=200 vmapped): all-pairs 4.1 ms vs
# sort 19.5 ms at W=256, 61 ms vs 40 ms at W=512 — quadratic lanes are
# VPU/SIMD-parallel and beat the sort's large constant until W ≈ 256-512;
# past that the O(W log W) sort keeps very wide frontiers cheap.
_SORT_DEDUP_MIN_W = 257


def _first_occurrence(idx: jax.Array, on: jax.Array) -> jax.Array:
    """True for the FIRST ``on`` lane holding each distinct id, else False.

    Width-adaptive (see ``_SORT_DEDUP_MIN_W``): up to W = 256 the strictly-
    lower-triangular all-pairs compare (the pre-PR ``_scatter_or`` idiom,
    O(W²) lanes but embarrassingly lane-parallel); beyond that, stable-
    argsort the ids (off lanes pushed to +max so they sort last), mark lanes
    equal to their sorted predecessor as duplicates, and scatter the flags
    back — O(W log W), so frontier dedup stays cheap however wide
    ``expand``·R grows.
    """
    w = idx.shape[0]
    idx = idx.astype(jnp.int32)
    if w < _SORT_DEDUP_MIN_W:
        same = (idx[:, None] == idx[None, :]) & on[None, :]
        tri = jnp.arange(w)[:, None] > jnp.arange(w)[None, :]
        return on & ~jnp.any(same & tri, axis=1)
    key = jnp.where(on, idx, jnp.int32(2**31 - 1))
    order = jnp.argsort(key)                      # stable → first = lowest lane
    sk = key[order]
    first_sorted = jnp.concatenate(
        [jnp.ones((1,), bool), sk[1:] != sk[:-1]])
    return jnp.zeros((w,), bool).at[order].set(first_sorted) & on


def _scatter_bits(bits: jax.Array, idx: jax.Array, on: jax.Array) -> jax.Array:
    """OR bit ``idx[i]`` into the bitset for every ``on`` lane.

    Precondition: the ``on`` lanes hold DISTINCT ids. Then every (word, bit)
    contribution is unique, so a single scatter-ADD into a zero array equals
    the (missing) scatter-OR primitive.
    """
    word = jnp.where(on, idx >> 5, 0)
    mask = jnp.where(on, jnp.uint32(1) << (idx & 31).astype(jnp.uint32),
                     jnp.uint32(0))
    return bits | jnp.zeros_like(bits).at[word].add(mask)


def _scatter_or(bits: jax.Array, idx: jax.Array, on: jax.Array) -> jax.Array:
    """OR bit ``idx[i]`` into the bitset for every ``on`` lane, duplicate-safe
    (sort-based first-occurrence dedup + one scatter-add)."""
    return _scatter_bits(bits, idx, _first_occurrence(idx, on))


def _single_query(neighbors: jax.Array, entries: jax.Array, qdata,
                  dist_fn: Callable, h: int, max_steps: int,
                  trace_len: int = 0, expand: int = 1,
                  tombstones: Optional[jax.Array] = None,
                  lb_dist_fn: Optional[Callable] = None,
                  m_prefix: int = 0, m_total: int = 0,
                  prune_eps: float = 0.0,
                  lb_scale_fn: Optional[Callable] = None,
                  max_rounds: Optional[jax.Array] = None,
                  max_n_dist: Optional[jax.Array] = None):
    """Search for ONE query; built to be vmapped. ``entries`` is the (S,)
    per-query entry set (S=1 ≡ the classic single-entry beam, bit-identical).
    ``max_rounds`` / ``max_n_dist`` are TRACED deadline budgets gating only
    the loop condition (see module docstring). Returns result (+trace)."""
    n = neighbors.shape[0]
    r = neighbors.shape[1]
    e = max(1, min(expand, h))
    s = entries.shape[0]
    # hop pruning is compiled in only when fully configured; prune_eps=0 is
    # the documented OFF switch (bit-identical to the unpruned beam)
    prune = (lb_dist_fn is not None and prune_eps > 0.0
             and 0 < m_prefix < m_total)
    if prune:
        # extrapolation factor d̂ = d_m′ · cal, folded together with (1+ε)
        # into one loop-invariant gate scale. Per-query calibration
        # (lb_scale_fn) corrects the uniform M/m′ ratio for anisotropic
        # subspace masses — computed ONCE per query, outside the loop.
        cal = (lb_scale_fn(qdata) if lb_scale_fn is not None
               else jnp.float32(m_total) / jnp.float32(m_prefix))
        gate_scale = cal * jnp.float32(1.0 + prune_eps)
    # sentinel-inclusive id range is [0, n]: word(n) = n//32, so n//32 + 1
    # words always suffice ((n+31)//32 + 1 is a safe ceiling of that; the
    # old (n+32)//32 + 1 over-allocated a word for most n)
    nwords = (n + 31) // 32 + 1

    def is_dead(idx: jax.Array) -> jax.Array:
        # bitset lookup guarded to [0, n): sentinel slots and padding lanes
        # read bit 0's word but their result is never used un-masked
        safe = jnp.where(idx < n, idx, 0)
        return _bit_get(tombstones, safe).astype(bool) & (idx < n)

    if s == 1:
        # the classic single-entry init, op for op (bit-identity contract)
        entry = entries[0]
        ids0 = jnp.full((h,), n, jnp.int32).at[0].set(entry)
        d_entry = dist_fn(qdata, entries)[0]
        if tombstones is not None:
            d_entry = jnp.where(is_dead(entry), DEAD_ENTRY_DIST, d_entry)
        dists0 = jnp.full((h,), INF).at[0].set(d_entry)
        exp0 = jnp.ones((h,), bool).at[0].set(False)
        visited0 = _scatter_or(jnp.zeros((nwords,), jnp.uint32), entries,
                               jnp.ones((1,), bool))
        n_seeds = jnp.int32(1)
    else:
        # multi-entry init: dedupe the set, score every distinct valid
        # entry in ONE dist_fn call, sort, install as the initial beam
        sh = min(s, h)
        ok = (entries >= 0) & (entries < n)
        uniq = _first_occurrence(entries, ok)
        d_ent = dist_fn(qdata, jnp.where(uniq, entries, 0))
        d_ent = jnp.where(uniq, d_ent, INF)
        if tombstones is not None:
            # per-entry DEAD_ENTRY_DIST: a dead seed still routes (finite)
            # but any live seed outranks it; all-dead falls back to pure
            # DEAD_ENTRY_DIST routing like the classic deleted-medoid case
            d_ent = jnp.where(uniq & is_dead(entries), DEAD_ENTRY_DIST,
                              d_ent)
        neg, order = jax.lax.top_k(-d_ent, s)
        sd = -neg
        sids = jnp.where(sd < INF, entries[order], n)
        ids0 = jnp.full((h,), n, jnp.int32).at[:sh].set(sids[:sh])
        dists0 = jnp.full((h,), INF).at[:sh].set(sd[:sh])
        exp0 = jnp.ones((h,), bool).at[:sh].set(sd[:sh] == INF)
        visited0 = _scatter_bits(jnp.zeros((nwords,), jnp.uint32), entries,
                                 uniq)
        n_seeds = jnp.sum(uniq.astype(jnp.int32))

    do_trace = trace_len > 0
    tb_ids0 = jnp.full((max(trace_len, 1), h), n, jnp.int32)
    tb_d0 = jnp.full((max(trace_len, 1), h), INF)
    tb_v0 = jnp.zeros((max(trace_len, 1),), bool)

    def cond(state):
        step, ids, dists, exp, visited, hops, ndist, tbi, tbd, tbv = state
        live = jnp.logical_and(step < max_steps,
                               jnp.any(~exp & (dists < INF)))
        # deadline budgets (None compiles out — bit-identical): checked
        # before each round, so rounds never exceeds max_rounds and n_dist
        # overshoots its cap by at most one round's frontier. Under vmap
        # the while_loop batching rule freezes the whole carry of a lane
        # whose cond is false, so an exhausted query keeps its best-so-far
        # beam while the rest of the batch keeps stepping.
        if max_rounds is not None:
            live = jnp.logical_and(live, step < max_rounds)
        if max_n_dist is not None:
            # loop-internal ndist is in SUBSPACE units when pruning is on
            # (converted back after the loop); scale the cap to match
            cap = jnp.int32(max_n_dist) * (jnp.int32(m_total) if prune
                                           else jnp.int32(1))
            live = jnp.logical_and(live, ndist < cap)
        return live

    def body(state):
        step, ids, dists, exp, visited, hops, ndist, tbi, tbd, tbv = state
        # 1. pick the best `e` unexpanded beam entries (e=1 ≡ argmin; top_k
        #    breaks ties toward the lowest index, like argmin)
        cand = jnp.where(~exp & (dists < INF), dists, INF)
        neg_sel, sel = jax.lax.top_k(-cand, e)
        sel_ok = -neg_sel < INF                    # lanes actually selected
        # non-ok lanes are already expanded or INF slots (exp True by the
        # merge invariant below), so the unconditional set is a no-op there
        exp = exp.at[sel].set(True)
        hops = hops + jnp.sum(sel_ok.astype(jnp.int32))
        # 2. expand the frontier: gather e·R neighbor ids, drop pads,
        #    visited vertices, and (e>1) cross-row duplicates
        nbr = neighbors[jnp.where(sel_ok, ids[sel], 0)]      # (e, R)
        flat = nbr.reshape(e * r)
        valid = (sel_ok[:, None] & (nbr < n)).reshape(e * r)
        seen = _bit_get(visited, jnp.where(valid, flat, 0)).astype(bool)
        fresh = valid & ~seen
        if e > 1:
            # two frontier rows may share a neighbor; keep the first lane
            # (then every fresh id is distinct — _scatter_bits suffices)
            fresh = _first_occurrence(flat, fresh)
            visited = _scatter_bits(visited, flat, fresh)
        else:
            # legacy semantics exactly: fresh keeps theoretical in-row dups
            # (scored twice, like the pre-PR beam), dedup only inside the
            # duplicate-safe scatter — bit-identical regression contract
            visited = _scatter_or(visited, flat, fresh)
        # 3. ONE dist_fn call for the whole e·R frontier (on TPU: one fused
        #    hop-ADC kernel invocation instead of e narrow ones)
        if prune:
            # probabilistic gate: score the frontier on the first m_prefix
            # subspaces only (a certified lower bound — d_m′ ≤ d_M, every
            # LUT entry ≥ 0), EXTRAPOLATE it to a full-distance estimate
            # d̂ = d_m′·cal (cal = calibrated or uniform M/m′ mass ratio,
            # hoisted above the loop), and full-score just the lanes whose
            # estimate beats the worst beam slot by margin ε. The raw bound
            # prunes only ~nothing (it sits far below any full-distance τ);
            # the extrapolation prunes like the full distance would, at
            # m′/M of the cost — mistaken prunes are possible (hence
            # "probabilistic"), bounded by ε. τ = INF while the
            # beam is unfilled, so nothing is pruned before the beam warms
            # up. Pruned lanes stay VISITED — churn never retraces them —
            # and mask to the sentinel, so shapes never change. n_dist here
            # is in SUBSPACE units (every fresh lane paid m_prefix, kept
            # lanes m_total on top); it is converted back to
            # full-LUT-equivalents after the loop.
            tau = dists[h - 1]
            d_lb = lb_dist_fn(qdata, jnp.where(fresh, flat, 0))
            keep = fresh & (d_lb * gate_scale <= tau)
            nd = dist_fn(qdata, jnp.where(keep, flat, 0))
            nd = jnp.where(keep, nd, INF)
            ndist = ndist + (m_prefix * jnp.sum(fresh.astype(jnp.int32))
                             + m_total * jnp.sum(keep.astype(jnp.int32)))
            front = keep
        else:
            nd = dist_fn(qdata, jnp.where(fresh, flat, 0))
            nd = jnp.where(fresh, nd, INF)
            ndist = ndist + jnp.sum(fresh.astype(jnp.int32))
            front = fresh
        if tombstones is not None:
            # tombstoned neighbors were scored (counted in ndist — the
            # kernel did the work) but rank +inf: marked expanded by the
            # merge invariant, so routing never continues THROUGH them
            nd = jnp.where(is_dead(flat), INF, nd)
        # 4. merge beam ∪ frontier in a single (h + e·R)-wide top-k
        all_ids = jnp.concatenate([ids, jnp.where(front, flat, n)])
        all_d = jnp.concatenate([dists, nd])
        all_e = jnp.concatenate([exp, jnp.zeros((e * r,), bool)])
        neg, order = jax.lax.top_k(-all_d, h)
        ids = all_ids[order]
        dists = -neg
        exp = all_e[order] | (dists == INF)
        # 5. trace the ranked candidate beam (paper Def. 6); rounds beyond
        #    trace_len must NOT clobber the last recorded slot
        if do_trace:
            ti = jnp.minimum(step, trace_len - 1)
            in_range = step < trace_len
            tbi = tbi.at[ti].set(jnp.where(in_range, ids, tbi[ti]))
            tbd = tbd.at[ti].set(jnp.where(in_range, dists, tbd[ti]))
            tbv = tbv.at[ti].set(tbv[ti] | in_range)
        return (step + 1, ids, dists, exp, visited, hops, ndist, tbi, tbd, tbv)

    ndist0 = jnp.int32(m_total) * n_seeds if prune else n_seeds
    state = (jnp.int32(0), ids0, dists0, exp0, visited0,
             jnp.int32(0), ndist0, tb_ids0, tb_d0, tb_v0)
    step, ids, dists, exp, visited, hops, ndist, tbi, tbd, tbv = \
        jax.lax.while_loop(cond, body, state)
    if prune:
        # subspace units → full-LUT-equivalents (ceil: a lone partial score
        # still counts as work done)
        ndist = (ndist + jnp.int32(m_total - 1)) // jnp.int32(m_total)
    # honest truncation flag: unexpanded finite candidates still pending
    # means SOMETHING stopped us short of convergence (budget or max_steps)
    # — the beam is best-so-far, not the converged answer. Computed before
    # the tombstone scrub: the pending frontier, not the scrub, decides it.
    truncated = jnp.any(~exp & (dists < INF))
    if tombstones is not None:
        # scrub: a tombstoned id (incl. a dead entry at DEAD_ENTRY_DIST)
        # NEVER appears in the returned beam, at any width
        dead = is_dead(ids)
        ids = jnp.where(dead, n, ids)
        dists = jnp.where(dead, INF, dists)
    res = (ids, dists, hops, ndist, step, truncated)
    return res + ((tbi, tbd, tbv) if do_trace else ())


def _normalize_entries(entry: jax.Array, nq: int) -> jax.Array:
    """Canonicalize ``entry`` to a (Q, S) per-query entry-set matrix:
    () shared vertex → (Q, 1); (Q,) per-query vertex → (Q, 1); (Q, S)
    entry sets pass through. S=1 runs the classic single-entry init."""
    entry = jnp.asarray(entry, jnp.int32)
    if entry.ndim == 0:
        return jnp.broadcast_to(entry, (nq, 1))
    if entry.ndim == 1:
        return entry[:, None]
    return entry


@functools.partial(jax.jit,
                   static_argnames=("dist_fn", "h", "max_steps", "expand",
                                    "lb_dist_fn", "m_prefix", "m_total",
                                    "prune_eps", "lb_scale_fn"))
def beam_search(neighbors: jax.Array, entry: jax.Array, qdatas,
                dist_fn: Callable, *, h: int = 32,
                max_steps: int = 256, expand: int = 1,
                tombstones: Optional[jax.Array] = None,
                lb_dist_fn: Optional[Callable] = None,
                m_prefix: int = 0, m_total: int = 0,
                prune_eps: float = 0.0,
                lb_scale_fn: Optional[Callable] = None,
                max_rounds=None, max_n_dist=None) -> SearchResult:
    """Batched beam search.

    Args:
      neighbors: (N, R) padded adjacency (sentinel N).
      entry:     () int32 entry vertex (shared) — the PG medoid; or (Q,)
                 per-query entries; or a (Q, S) per-query entry SET
                 (multi-entry seeding, DESIGN.md §11 — search/seed.py
                 produces these; lanes < 0 or ≥ N are ignored padding).
      qdatas:    per-query pytree, leading axis Q (e.g. LUTs (Q, M, K) for ADC
                 routing or raw queries (Q, D) for exact routing).
      dist_fn:   (qdata, ids (B,)) -> (B,) f32 distances for one query; B is
                 the frontier width expand·R.
      h:         beam width (the paper's global candidate set size).
      max_steps: ROUND cap (safety for pathological graphs). With expand=E a
                 round expands up to E nodes, so the hop budget it implies is
                 max_steps·E.
      expand:    frontier batch size E — nodes expanded per round
                 (DESIGN.md §9). 1 (default) is the classic, bit-identical
                 best-first beam; larger E trades a few wasted expansions for
                 ~E× fewer sequential trips.
      tombstones: optional (W,) uint32 deleted-vertex bitset, shared across
                 the batch (streaming deletes, DESIGN.md §10): bit i set ⇒
                 vertex i ranks +inf, is never expanded, and is scrubbed
                 from the returned beam. W must cover ids [0, N) — the
                 visited-set sizing (N+31)//32 + 1 always does. Traced (not
                 static): updating the bitset between calls never re-jits.
      lb_dist_fn / m_prefix / m_total / prune_eps: probabilistic hop pruning
                 (DESIGN.md §11). ``lb_dist_fn`` scores the first
                 ``m_prefix`` of ``m_total`` subspaces
                 (``make_adc_dist_fn(m_prefix=)``) — a certified lower
                 bound d_m′ ≤ d_M; each round full-scores only frontier
                 lanes whose EXTRAPOLATED estimate satisfies
                 ``d_m′·cal·(1+ε) ≤ τ`` (τ = worst beam distance). All
                 four must be set; ``prune_eps=0`` (default) compiles the
                 pass out — bit-identical to the unpruned beam.
      lb_scale_fn: optional per-query extrapolation calibration
                 (``make_lb_scale_fn``): qdata -> scalar cal ≥ 1. Default
                 None uses the uniform mass ratio cal = M/m′, which
                 over-prunes on anisotropic data (DESIGN.md §11).
      max_rounds / max_n_dist: per-call deadline budgets (DESIGN.md §13) —
                 a round cap and a distance-evaluation cap (full-LUT
                 equivalents; under hop pruning the n_dist overshoot is at
                 most one round's frontier). TRACED scalars shared across
                 the batch: sweeping a deadline never retraces, and the
                 early exit is fixed-shape. An exhausted query returns its
                 best-so-far beam with ``truncated=True``; ``None``
                 (default) compiles the check out — bit-identical to the
                 unbudgeted beam.
    """
    nq = jax.tree.leaves(qdatas)[0].shape[0]
    entries = _normalize_entries(entry, nq)
    fn = lambda e, qd: _single_query(neighbors, e, qd, dist_fn, h, max_steps,
                                     expand=expand, tombstones=tombstones,
                                     lb_dist_fn=lb_dist_fn,
                                     m_prefix=m_prefix, m_total=m_total,
                                     prune_eps=prune_eps,
                                     lb_scale_fn=lb_scale_fn,
                                     max_rounds=max_rounds,
                                     max_n_dist=max_n_dist)
    ids, dists, hops, ndist, rounds, truncated = jax.vmap(fn)(entries, qdatas)
    return SearchResult(ids, dists, hops, ndist, rounds, truncated)


@functools.partial(jax.jit, static_argnames=("dist_fn", "h", "max_steps",
                                             "trace_len", "expand",
                                             "lb_dist_fn", "m_prefix",
                                             "m_total", "prune_eps",
                                             "lb_scale_fn"))
def beam_search_trace(neighbors: jax.Array, entry: jax.Array, qdatas,
                      dist_fn: Callable, *, h: int = 32, max_steps: int = 256,
                      trace_len: int = 64, expand: int = 1,
                      tombstones: Optional[jax.Array] = None,
                      lb_dist_fn: Optional[Callable] = None,
                      m_prefix: int = 0, m_total: int = 0,
                      prune_eps: float = 0.0,
                      lb_scale_fn: Optional[Callable] = None,
                      max_rounds=None, max_n_dist=None) -> Trace:
    """Beam search that also records the ranked beam at every round.

    ``hop_valid[q, t]`` flags ROUNDS (while_loop trips): with expand=E one
    valid slot covers up to E expansions, and the flagged prefix counts
    min(rounds, trace_len) — at expand=1 that is min(hops, trace_len).
    """
    nq = jax.tree.leaves(qdatas)[0].shape[0]
    entries = _normalize_entries(entry, nq)
    fn = lambda e, qd: _single_query(neighbors, e, qd, dist_fn, h, max_steps,
                                     trace_len=trace_len, expand=expand,
                                     tombstones=tombstones,
                                     lb_dist_fn=lb_dist_fn,
                                     m_prefix=m_prefix, m_total=m_total,
                                     prune_eps=prune_eps,
                                     lb_scale_fn=lb_scale_fn,
                                     max_rounds=max_rounds,
                                     max_n_dist=max_n_dist)
    ids, dists, hops, ndist, rounds, truncated, tbi, tbd, tbv = \
        jax.vmap(fn)(entries, qdatas)
    return Trace(tbi, tbd, tbv,
                 SearchResult(ids, dists, hops, ndist, rounds, truncated))


# --------------------------------------------------------------------------
# Distance functions
# --------------------------------------------------------------------------

def make_exact_dist_fn(vectors: jax.Array) -> Callable:
    """qdata = query vector (D,). vectors must be (N+1, D) sentinel-padded."""
    def dist_fn(q, ids):
        v = vectors[ids]
        return jnp.sum((v - q[None, :]) ** 2, axis=-1)
    return dist_fn


def make_lb_scale_fn(*, packed: bool = False, m_prefix: int) -> Callable:
    """Per-query calibration of the hop-pruning extrapolation factor.

    qdata matches ``make_adc_dist_fn``: a LUT (M, K), or a per-query
    ``pq.pack.QuantizedLUT`` when ``packed=True``. Returns a scalar
    ``cal ≥ 1`` — the estimate of ``E[d_M] / E[d_m′]`` under
    code-independent subspace draws: the ratio of the full LUT's mean mass
    to the first-``m_prefix`` rows' mean mass. The naive uniform ratio
    ``M/m′`` assumes every subspace carries equal distance mass; on
    anisotropic data (decaying spectrum) the LEADING subspaces carry more,
    so the uniform extrapolation overshoots and over-prunes — this ratio is
    the data-corrected replacement, free to compute (the query already
    built the LUT) and exact in expectation when sub-codes are uniform.
    Clamped below at 1 so d̂ never drops under the certified bound d_m′.
    """
    if packed:
        def scale_fn(qlut):
            lut, scale, bias = qlut             # (M, 16) u8, (), ()
            m = lut.shape[0]
            # zero padding in unused LUT columns deflates every row's mean
            # by the same K/16 factor — it cancels in the ratio
            rm = jnp.mean(lut.astype(jnp.float32), axis=-1)   # (M,)
            num = scale * jnp.sum(rm) + m * bias
            den = scale * jnp.sum(rm[:m_prefix]) + m_prefix * bias
            return jnp.maximum(num / jnp.maximum(den, jnp.float32(1e-20)),
                               jnp.float32(1.0))
        return scale_fn

    def scale_fn(lut):
        rm = jnp.mean(lut, axis=-1)                           # (M,)
        return jnp.maximum(jnp.sum(rm) / jnp.maximum(jnp.sum(rm[:m_prefix]),
                                                     jnp.float32(1e-20)),
                           jnp.float32(1.0))
    return scale_fn


def make_adc_dist_fn(codes: jax.Array, *, packed: bool = False,
                     backend: str = "auto",
                     tombstones: Optional[jax.Array] = None,
                     m_prefix: int = 0) -> Callable:
    """qdata = LUT (M, K) — or a per-query ``pq.pack.QuantizedLUT``
    ((M, 16) u8 lut, scale, bias) when ``packed=True``. codes must be
    (N+1, M) sentinel-padded (fs4: (N+1, ceil(M/2)) packed bytes).

    ``tombstones`` (optional (W,) uint32 bitset over ids [0, N)) bakes a
    deleted-vertex mask into the dist fn: tombstoned ids return +inf.
    Because dist fns are STATIC jit arguments, each distinct bitset makes a
    distinct callable — fine for a frozen snapshot, wrong for churn. A
    streaming caller should pass ``beam_search(..., tombstones=)`` instead,
    where the bitset is traced, updates never re-jit, a tombstoned ENTRY
    still routes (DEAD_ENTRY_DIST), and the returned beam is scrubbed.
    The baked mask has neither entry rescue nor scrub: a search ENTERED at
    a tombstoned vertex sees d_entry = +inf and terminates empty, so don't
    point it at a graph whose entry may be deleted.

    The ids vector is ONE beam frontier — width R classically, E·R under
    multi-expansion (``beam_search(expand=E)``); the fused kernels auto-tune
    their query tile to the width (kernels/hop_adc.py).

    Backend dispatch for the per-hop hot loop (kernels.ops semantics):

    * CPU (``backend="auto"`` off-TPU, or ``"ref"``): a jnp gather — the
      per-round read is small (≤ E·R rows) and XLA fuses it. The fs4 path
      nibble-unpacks the gathered bytes and accumulates the uint8 LUT in
      int32 before the one affine dequant.
    * TPU (``"auto"`` on-TPU, or ``"pallas"``/``"interpret"``): the fused
      hop-ADC Pallas kernel (kernels/hop_adc.py; packed twin for fs4) —
      neighbor-row gather and LUT reduce in ONE kernel, so the gathered
      codes never round-trip HBM. The kernel is batched over queries;
      under beam_search's vmap the per-query call batches into the
      kernel's query grid axis.

    ``m_prefix > 0`` makes a PARTIAL-LUT distance over only the first
    ``m_prefix`` subspaces — a lower bound on the full distance (every LUT
    entry is a squared subdistance ≥ 0; fs4 dequant uses ``m_prefix · bias``
    with bias ≥ 0, so the bound also holds in the quantized metric). This is
    the ``lb_dist_fn`` for ``beam_search`` hop pruning. ``m_prefix=0`` (or
    ≥ M) is the full distance, code path untouched.
    """
    if tombstones is not None:
        ts = jnp.asarray(tombstones, jnp.uint32)
        inner = make_adc_dist_fn(codes, packed=packed, backend=backend,
                                 m_prefix=m_prefix)
        n = codes.shape[0] - 1              # codes are sentinel-padded

        def dist_fn(qdata, ids):
            d = inner(qdata, ids)
            dead = (_bit_get(ts, jnp.where(ids < n, ids, 0)).astype(bool)
                    & (ids < n))
            return jnp.where(dead, INF, d)
        return dist_fn

    use_fused = backend in ("pallas", "interpret") or (
        backend == "auto" and jax.default_backend() == "tpu")
    if packed:
        if use_fused:
            from repro.kernels import ops

            def dist_fn(qlut, ids):
                return ops.hop_adc_fs(codes, ids[None], qlut.lut[None],
                                      qlut.scale[None], qlut.bias[None],
                                      backend=backend, m_prefix=m_prefix)[0]
            return dist_fn

        def dist_fn(qlut, ids):
            lut, scale, bias = qlut                   # (M, 16) u8, (), ()
            m = lut.shape[0]
            mp = m_prefix if 0 < m_prefix < m else m
            p = codes[ids].astype(jnp.int32)          # (B, ceil(M/2))
            nib = jnp.stack([p & 0xF, (p >> 4) & 0xF], axis=-1)
            c = nib.reshape(p.shape[0], -1)[:, :mp]   # (B, mp)
            vals = lut.astype(jnp.int32)[jnp.arange(mp)[None, :], c]
            acc = jnp.sum(vals, axis=-1)              # (B,) int32, exact
            return scale * acc.astype(jnp.float32) + mp * bias
        return dist_fn

    m = codes.shape[1]
    mp = m_prefix if 0 < m_prefix < m else m
    if use_fused:
        from repro.kernels import ops

        def dist_fn(lut, ids):
            return ops.hop_adc(codes, ids[None], lut[None],
                               backend=backend, m_prefix=m_prefix)[0]
        return dist_fn

    if mp < m:
        def dist_fn(lut, ids):
            c = codes[ids].astype(jnp.int32)[:, :mp]  # (B, mp)
            vals = lut[jnp.arange(mp)[None, :], c]    # (B, mp)
            return jnp.sum(vals, axis=-1)
        return dist_fn

    def dist_fn(lut, ids):
        c = codes[ids].astype(jnp.int32)              # (B, M)
        vals = lut[jnp.arange(m)[None, :], c]         # (B, M)
        return jnp.sum(vals, axis=-1)
    return dist_fn
