"""Architecture zoo: LM (dense/MoE), GNN (GAT), recsys (DLRM/DeepFM/DIN/BERT4Rec)."""
