"""Mixture-of-Experts FFN with GShard-style einsum dispatch (TPU-idiomatic).

Top-k routing with per-group expert capacity. The dispatch/combine tensors
are one-hot over (expert, capacity-slot) and contract on the MXU; under SPMD
the (tokens→experts) re-layout lowers to the classic MoE all-to-all on the
`model` (expert-parallel) mesh axis. Group size bounds the dispatch tensor:
total one-hot footprint = T × S_group × k × capacity_factor elements.

Priority: choice-rank major (all tokens' 1st choices beat any 2nd choice),
matching GShard; overflow tokens are dropped (their combine weight is 0).

Aux loss: Switch-style load balancing  E · Σ_e f_e · p_e.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers as nn


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    group_size: int = 1024
    aux_coef: float = 0.01


def init_moe(key, cfg: MoEConfig, d_model: int, n_layers: int,
             dtype=jnp.bfloat16):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    e, f = cfg.n_experts, cfg.d_ff_expert
    return {
        "router": nn.dense_init(k1, d_model, e, jnp.float32, stacked=n_layers),
        "w1": nn.uniform_init(k2, (n_layers, e, d_model, f),
                              (d_model ** -0.5), dtype),
        "w3": nn.uniform_init(k3, (n_layers, e, d_model, f),
                              (d_model ** -0.5), dtype),
        "w2": nn.uniform_init(k4, (n_layers, e, f, d_model),
                              (f ** -0.5), dtype),
    }


class MoEOut(NamedTuple):
    y: jax.Array
    aux_loss: jax.Array


def moe_ffn(x: jax.Array, w, cfg: MoEConfig) -> MoEOut:
    """x: (T, D) token slab (one layer's weights w, unstacked).

    Returns mixed expert outputs (T, D) + the load-balancing aux loss.
    """
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    s = min(cfg.group_size, t)
    g = t // s
    cap = int(s * k * cfg.capacity_factor / e) + 1

    xg = x.reshape(g, s, d)
    logits = (xg.astype(jnp.float32) @ w["router"])            # (G, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)            # (G, S, k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)

    # position assignment, choice-rank major: (G, k, S, E) cumsum over (k, S)
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)   # (G, S, k, E)
    oh_rank = onehot.transpose(0, 2, 1, 3).reshape(g, k * s, e)  # rank-major
    pos_rank = jnp.cumsum(oh_rank, axis=1) - oh_rank             # excl. cumsum
    pos = (pos_rank.reshape(g, k, s, e).transpose(0, 2, 1, 3)
           * onehot).sum(-1)                                     # (G, S, k)
    keep = pos < cap

    gate_vals = gate_vals * keep.astype(gate_vals.dtype)
    pos_i = jnp.where(keep, pos, cap).astype(jnp.int32)
    pos_oh = jax.nn.one_hot(pos_i, cap, dtype=jnp.float32)
    # dispatch (G, S, E, C): sum over the k choices (disjoint slots)
    dispatch = jnp.einsum("gske,gskc->gsec", onehot * keep[..., None], pos_oh)
    combine = jnp.einsum("gske,gskc->gsec",
                         onehot * gate_vals[..., None], pos_oh)

    xin = jnp.einsum("gsec,gsd->egcd", dispatch.astype(x.dtype), xg)  # (E,G,C,D)
    h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xin, w["w1"])
                    .astype(jnp.float32)).astype(x.dtype) \
        * jnp.einsum("egcd,edf->egcf", xin, w["w3"])
    yout = jnp.einsum("egcf,efd->egcd", h, w["w2"])                  # (E,G,C,D)
    y = jnp.einsum("gsec,egcd->gsd", combine.astype(x.dtype), yout)

    # Switch aux loss: fraction routed vs mean router prob, per expert
    frac = jnp.mean(onehot.sum(2), axis=(0, 1)) / k
    pmean = jnp.mean(probs, axis=(0, 1))
    aux = cfg.aux_coef * e * jnp.sum(frac * pmean)
    return MoEOut(y=y.reshape(t, d), aux_loss=aux)
