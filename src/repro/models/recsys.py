"""Recsys architecture family: DLRM, DeepFM, DIN, BERT4Rec (assigned pool).

The shared hot path is the sparse embedding lookup. JAX has no native
EmbeddingBag — per the brief, we BUILD it: `jnp.take` + `jax.ops.segment_sum`
(multi-hot bags) or plain take (one-hot fields). All four models store their
categorical tables as ONE concatenated mega-table with per-field row offsets
(the classic DLRM layout) so the distribution layer can row-shard a single
array over the `model` axis (dist/sharding.py implements the mod-sharded
lookup: local gather + psum ≡ TorchRec's all-to-all).

`retrieval_cand` (1 query × 1M candidates) is scored two ways:
  * exact dot product (baseline, one GEMV), and
  * the paper's technique: PQ-compressed candidate embeddings scanned with
    the Pallas ADC kernel — this is RPQ's serving kernel applied verbatim
    (DESIGN.md §5), reported as the beyond-paper optimized variant.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import adam, constant_schedule
from repro.models import layers as nn


# --------------------------------------------------------------------------
# EmbeddingBag substrate
# --------------------------------------------------------------------------

def embedding_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    """One-hot fields: (rows, D) × (..., F) → (..., F, D)."""
    return jnp.take(table, ids, axis=0)


def embedding_bag(table: jax.Array, ids: jax.Array, bag_ids: jax.Array,
                  n_bags: int, mode: str = "sum") -> jax.Array:
    """Multi-hot EmbeddingBag: gather + segment-reduce.

    ids (T,) row ids, bag_ids (T,) bag assignment → (n_bags, D).
    """
    vals = jnp.take(table, ids, axis=0)
    out = jax.ops.segment_sum(vals, bag_ids, num_segments=n_bags)
    if mode == "mean":
        cnt = jax.ops.segment_sum(jnp.ones((ids.shape[0], 1), vals.dtype),
                                  bag_ids, num_segments=n_bags)
        out = out / jnp.maximum(cnt, 1.0)
    return out


def make_mega_table(key, row_counts: Sequence[int], dim: int,
                    dtype=jnp.float32, pad_rows_to: int = 512):
    """Rows padded to a mesh-divisible multiple (512 = max device count);
    padding rows are unreachable via per-field offsets."""
    total = int(sum(row_counts))
    total = ((total + pad_rows_to - 1) // pad_rows_to) * pad_rows_to
    table = nn.uniform_init(key, (total, dim), 1.0 / np.sqrt(dim), dtype)
    return table, field_offsets(row_counts)


def field_offsets(row_counts: Sequence[int]) -> jax.Array:
    """Static per-field row offsets into the mega-table (NOT a parameter:
    integer arrays must stay out of the grad pytree)."""
    off = np.concatenate([[0], np.cumsum(row_counts)[:-1]]).astype(np.int64)
    return jnp.asarray(off, jnp.int32)


def field_lookup(table: jax.Array, offsets: jax.Array, ids: jax.Array
                 ) -> jax.Array:
    """ids (B, F) per-field local ids → (B, F, D) via the mega-table."""
    return embedding_lookup(table, ids + offsets[None, :])


# --------------------------------------------------------------------------
# DLRM (Naumov et al. 2019, MLPerf config)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str
    n_dense: int
    row_counts: tuple[int, ...]   # 26 tables (Criteo 1TB)
    embed_dim: int
    bot_mlp: tuple[int, ...]
    top_mlp: tuple[int, ...]
    dtype: Any = jnp.float32

    @property
    def n_sparse(self) -> int:
        return len(self.row_counts)


def init_dlrm(key, cfg: DLRMConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    table, _ = make_mega_table(k1, cfg.row_counts, cfg.embed_dim, cfg.dtype)
    n_f = cfg.n_sparse + 1
    n_int = n_f * (n_f - 1) // 2
    return {
        "table": table,
        "bot": nn.mlp_stack(k2, [cfg.n_dense, *cfg.bot_mlp], cfg.dtype),
        "top": nn.mlp_stack(k3, [n_int + cfg.bot_mlp[-1], *cfg.top_mlp], cfg.dtype),
    }


def dlrm_forward(cfg: DLRMConfig, params, dense: jax.Array, sparse: jax.Array,
                 *, lookup_fn=field_lookup) -> jax.Array:
    """dense (B, 13), sparse (B, 26) int32 → logits (B,)."""
    offsets = field_offsets(cfg.row_counts)
    d = nn.mlp_apply(params["bot"], dense, final_act=True)     # (B, D)
    emb = lookup_fn(params["table"], offsets, sparse)          # (B, 26, D)
    feats = jnp.concatenate([d[:, None, :], emb], axis=1)      # (B, 27, D)
    inter = jnp.einsum("bfd,bgd->bfg", feats, feats)           # (B, 27, 27)
    iu = jnp.triu_indices(feats.shape[1], k=1)
    flat = inter[:, iu[0], iu[1]]                              # (B, 351)
    top_in = jnp.concatenate([d, flat], axis=1)
    return nn.mlp_apply(params["top"], top_in)[:, 0]


# --------------------------------------------------------------------------
# DeepFM (Guo et al. 2017)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DeepFMConfig:
    name: str
    row_counts: tuple[int, ...]   # 39 fields (Criteo)
    embed_dim: int
    mlp: tuple[int, ...]
    dtype: Any = jnp.float32

    @property
    def n_fields(self) -> int:
        return len(self.row_counts)


def init_deepfm(key, cfg: DeepFMConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    table, _ = make_mega_table(k1, cfg.row_counts, cfg.embed_dim, cfg.dtype)
    table_lin, _ = make_mega_table(k2, cfg.row_counts, 1, cfg.dtype)
    return {
        "table": table, "table_lin": table_lin,
        "deep": nn.mlp_stack(k3, [cfg.n_fields * cfg.embed_dim, *cfg.mlp, 1],
                             cfg.dtype),
    }


def deepfm_forward(cfg: DeepFMConfig, params, sparse: jax.Array,
                   *, lookup_fn=field_lookup) -> jax.Array:
    offsets = field_offsets(cfg.row_counts)
    emb = lookup_fn(params["table"], offsets, sparse)             # (B, F, D)
    lin = lookup_fn(params["table_lin"], offsets, sparse)[..., 0]
    # FM 2nd order: ½[(Σv)² − Σv²]
    s = jnp.sum(emb, axis=1)
    fm2 = 0.5 * jnp.sum(s * s - jnp.sum(emb * emb, axis=1), axis=-1)
    deep = nn.mlp_apply(params["deep"], emb.reshape(emb.shape[0], -1))[:, 0]
    return jnp.sum(lin, axis=1) + fm2 + deep


# --------------------------------------------------------------------------
# DIN (Zhou et al. 2018)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DINConfig:
    name: str
    n_items: int
    embed_dim: int
    seq_len: int
    attn_mlp: tuple[int, ...]
    mlp: tuple[int, ...]
    dtype: Any = jnp.float32


def init_din(key, cfg: DINConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    table, _ = make_mega_table(k1, [cfg.n_items], cfg.embed_dim, cfg.dtype)
    d = cfg.embed_dim
    return {
        "table": table,
        "attn": nn.mlp_stack(k2, [4 * d, *cfg.attn_mlp, 1], cfg.dtype),
        "mlp": nn.mlp_stack(k3, [3 * d, *cfg.mlp, 1], cfg.dtype),
    }


def din_forward(cfg: DINConfig, params, hist: jax.Array, hist_mask: jax.Array,
                target: jax.Array, *, lookup_fn=None) -> jax.Array:
    """hist (B, S) item ids, hist_mask (B, S) bool, target (B,) → logits."""
    table = params["table"]
    he = jnp.take(table, hist, axis=0)                 # (B, S, D)
    te = jnp.take(table, target, axis=0)               # (B, D)
    tb = jnp.broadcast_to(te[:, None, :], he.shape)
    att_in = jnp.concatenate([he, tb, he - tb, he * tb], axis=-1)
    w = nn.mlp_apply(params["attn"], att_in)[..., 0]   # (B, S)
    w = jnp.where(hist_mask, w, -1e30)
    w = jax.nn.softmax(w, axis=-1)
    interest = jnp.einsum("bs,bsd->bd", w, he)
    out_in = jnp.concatenate([interest, te, interest * te], axis=-1)
    return nn.mlp_apply(params["mlp"], out_in)[:, 0]


# --------------------------------------------------------------------------
# BERT4Rec (Sun et al. 2019)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Bert4RecConfig:
    name: str
    n_items: int
    embed_dim: int
    n_blocks: int
    n_heads: int
    seq_len: int
    dtype: Any = jnp.float32

    @property
    def mask_token(self) -> int:
        return self.n_items  # vocab = n_items + 1 (mask)

    @property
    def vocab_padded(self) -> int:
        """Item vocab (+mask) padded to 256 for mesh divisibility; padded
        rows are masked out of the MLM softmax."""
        return ((self.n_items + 1 + 255) // 256) * 256


def init_bert4rec(key, cfg: Bert4RecConfig):
    keys = jax.random.split(key, 8)
    d, l = cfg.embed_dim, cfg.n_blocks
    return {
        "item_emb": nn.uniform_init(keys[0], (cfg.vocab_padded, d),
                                    d ** -0.5, cfg.dtype),
        "pos_emb": nn.uniform_init(keys[1], (cfg.seq_len, d), 0.02, cfg.dtype),
        "wq": nn.dense_init(keys[2], d, d, cfg.dtype, stacked=l),
        "wk": nn.dense_init(keys[3], d, d, cfg.dtype, stacked=l),
        "wv": nn.dense_init(keys[4], d, d, cfg.dtype, stacked=l),
        "wo": nn.dense_init(keys[5], d, d, cfg.dtype, stacked=l),
        "w1": nn.dense_init(keys[6], d, 4 * d, cfg.dtype, stacked=l),
        "w2": nn.dense_init(keys[7], 4 * d, d, cfg.dtype, stacked=l),
        "ln1": jnp.ones((l, d), cfg.dtype), "ln1b": jnp.zeros((l, d), cfg.dtype),
        "ln2": jnp.ones((l, d), cfg.dtype), "ln2b": jnp.zeros((l, d), cfg.dtype),
    }


def bert4rec_encode(cfg: Bert4RecConfig, params, items: jax.Array,
                    pad_mask: jax.Array) -> jax.Array:
    """items (B, S) (mask_token allowed), pad_mask (B, S) → (B, S, D)."""
    b, s = items.shape
    d, h = cfg.embed_dim, cfg.n_heads
    x = params["item_emb"][items] + params["pos_emb"][None, :s]

    def block(x, w):
        hn = nn.layernorm(x, w["ln1"], w["ln1b"])
        q = (hn @ w["wq"]).reshape(b, s, h, d // h)
        k = (hn @ w["wk"]).reshape(b, s, h, d // h)
        v = (hn @ w["wv"]).reshape(b, s, h, d // h)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(d // h)
        scores = jnp.where(pad_mask[:, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores.astype(jnp.float32), -1).astype(x.dtype)
        o = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b, s, d)
        x = x + o @ w["wo"]
        hn = nn.layernorm(x, w["ln2"], w["ln2b"])
        return x + jax.nn.gelu((hn @ w["w1"]).astype(jnp.float32)).astype(x.dtype) @ w["w2"], None

    stacked = {k: params[k] for k in
               ("wq", "wk", "wv", "wo", "w1", "w2", "ln1", "ln1b", "ln2", "ln2b")}
    # remat: at train_batch=65536 the un-checkpointed f32 attention probs
    # stack to 2.6 GB/dev per block (§Perf iter 9b)
    blk = jax.remat(lambda xx, ww: block(xx, ww)[0])
    x, _ = jax.lax.scan(lambda c, w: (blk(c, w), None), x, stacked)
    return x


def bert4rec_mlm_loss(cfg: Bert4RecConfig, params, items, pad_mask,
                      mlm_positions, mlm_labels, logit_pspec=None):
    """Masked-item prediction: positions (B, P) into the sequence.

    logit_pspec: optional PartitionSpec pinning the (B, P, V) logits (batch
    over dp, vocab over model) — without it GSPMD replicates the MLM logits
    (26 GB/dev at batch 65536; EXPERIMENTS §Perf)."""
    h = bert4rec_encode(cfg, params, items, pad_mask)
    sel = jnp.take_along_axis(h, mlm_positions[..., None], axis=1)  # (B,P,D)
    logits = (sel @ params["item_emb"].T).astype(jnp.float32)
    if logit_pspec is not None:
        logits = jax.lax.with_sharding_constraint(logits, logit_pspec)
    vocab_iota = jnp.arange(cfg.vocab_padded)
    if cfg.vocab_padded != cfg.n_items + 1:
        logits = jnp.where((vocab_iota > cfg.n_items)[None, None, :], -1e30,
                           logits)
    # NLL via iota-compare (NOT take_along_axis: a label gather over the
    # model-sharded vocab dim makes GSPMD replicate the logits — 26 GB/dev
    # at batch 65536; elementwise select shards cleanly. §Perf iter 9)
    lse = jax.nn.logsumexp(logits, axis=-1)
    label_logit = jnp.sum(
        jnp.where(vocab_iota[None, None, :] == mlm_labels[..., None],
                  logits, 0.0), axis=-1)
    nll = lse - label_logit
    valid = mlm_labels >= 0
    nll = jnp.where(valid, nll, 0.0)
    return jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1)


# --------------------------------------------------------------------------
# Retrieval scoring (retrieval_cand shape) — exact and PQ/ADC paths
# --------------------------------------------------------------------------

def score_candidates_exact(query_vec: jax.Array, cand_emb: jax.Array,
                           k: int = 100):
    """(D,) × (N, D) → top-k (scores, ids): one GEMV, the baseline."""
    scores = cand_emb @ query_vec
    vals, ids = jax.lax.top_k(scores, k)
    return vals, ids


def score_candidates_adc(lut: jax.Array, cand_codes: jax.Array, k: int = 100,
                         backend: str = "auto"):
    """The paper's kernel as a recsys scorer: (M,K) LUT × (N,M) codes.

    Distances ascend = similarity descends; returns top-k by −distance.
    """
    from repro.kernels import ops as kops
    d = kops.adc_scan(cand_codes, lut, backend=backend)
    vals, ids = jax.lax.top_k(-d, k)
    return -vals, ids


# --------------------------------------------------------------------------
# Shared training-step factory (BCE point-wise ranking)
# --------------------------------------------------------------------------

def bce_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    z = logits.astype(jnp.float32)
    return jnp.mean(jnp.maximum(z, 0) - z * labels
                    + jnp.log1p(jnp.exp(-jnp.abs(z))))


def make_bce_train_step(forward_fn, init_fn, lr: float = 1e-3):
    optimizer = adam(constant_schedule(lr))

    def train_step(params, opt_state, batch):
        def loss(p):
            return bce_loss(forward_fn(p, batch), batch["label"])
        l, g = jax.value_and_grad(loss)(params)
        params, opt_state = optimizer.update(g, opt_state, params)
        return params, opt_state, l

    return init_fn, train_step, optimizer.init
