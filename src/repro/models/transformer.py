"""Decoder-only LM family (dense GQA + optional MoE FFN).

Covers granite-3-8b, llama3-405b, starcoder2-3b (dense) and
granite-moe-1b-a400m, olmoe-1b-7b (MoE) from the assigned pool.

Engineering for the 512-chip dry-run:
* layer weights are stacked (L, ...) and consumed by `lax.scan` + `jax.remat`
  — HLO size is depth-independent; a 405B/126L train step compiles in ~3 s;
* `train_step` does gradient accumulation over `microbatches` with an inner
  scan (bounds live activations: one microbatch at a time);
* logits/vocab math runs in fp32; embeddings are input/output-tied
  (configurable) so the vocab matrix shards once over `model`;
* `decode_step` is flash-decoding-friendly: one token vs a (possibly huge)
  KV cache with a valid-length mask — O(S) per token, which is why the
  long_500k decode cells are runnable even for full-attention archs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import adam, constant_schedule
from repro.models import layers as nn
from repro.models.moe import MoEConfig, init_moe, moe_ffn


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    rope_theta: float = 10000.0
    moe: Optional[MoEConfig] = None
    dtype: Any = jnp.bfloat16
    tie_embeddings: bool = True
    # training-time knobs (the §Perf loop tunes these)
    microbatches: int = 1
    remat: bool = True
    opt_slot_dtype: Any = jnp.float32
    grad_dtype: Any = jnp.float32
    # flash-style chunked attention (0 = disabled, use full-score path)
    attn_q_chunk: int = 1024
    attn_kv_chunk: int = 1024
    # remat granularity: scan over n_layers/layer_block blocks, saving one
    # residual per BLOCK instead of per layer (126-layer 405B: 17 GB → 2.4 GB
    # of saved residuals at layer_block=7; §Perf hillclimb)
    layer_block: int = 1
    # unroll the outer block loop in Python instead of lax.scan. Measured
    # WORSE (all block gathers' live ranges overlap → 492 GB/dev on 405B);
    # kept as a knob for the §Perf log. Refuted hypothesis, iteration 3.
    unroll_blocks: bool = False
    # place an optimization_barrier on each scanned layer's weight slice:
    # stops GSPMD's slice(all-gather(stack)) rewrite, keeping the FSDP
    # all-gather PER-LAYER inside the loop (50 GB hoisted gather → one
    # layer's worth). §Perf hillclimb iteration 4.
    gather_barrier: bool = False
    # optional activation sharding hint: axis names for the batch dim of
    # (B, S, D) activations (set by launch/cells.py per mesh)
    act_batch_axes: Optional[tuple] = None
    # Megatron-style sequence parallelism: shard the residual stream's S dim
    # over this axis (attention all-gathers K/V per layer — 16 MB vs GBs of
    # activation stacks on 405B). §Perf hillclimb iteration 5.
    act_seq_axis: Optional[str] = None

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to 256 (TPU lane alignment + mesh divisibility;
        llama-3's 128256 is already such a padded figure). Padded logit
        columns are masked to −inf in lm_loss."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def param_count(self) -> int:
        d, f, l, v = self.d_model, self.d_ff, self.n_layers, self.vocab
        attn = d * self.n_heads * self.d_head * 2 \
            + d * self.n_kv_heads * self.d_head * 2
        if self.moe:
            ff = 3 * d * self.moe.d_ff_expert * self.moe.n_experts \
                + d * self.moe.n_experts
        else:
            ff = 3 * d * f
        return l * (attn + ff + 2 * d) + v * d + d

    @property
    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts only)."""
        if not self.moe:
            return self.param_count
        d, l = self.d_model, self.n_layers
        dense = self.param_count - l * 3 * d * self.moe.d_ff_expert * self.moe.n_experts
        return dense + l * 3 * d * self.moe.d_ff_expert * self.moe.top_k


def init_lm(key: jax.Array, cfg: LMConfig):
    keys = jax.random.split(key, 8)
    d, l = cfg.d_model, cfg.n_layers
    hq = cfg.n_heads * cfg.d_head
    hkv = cfg.n_kv_heads * cfg.d_head
    params = {
        "embed": nn.uniform_init(keys[0], (cfg.vocab_padded, d), d ** -0.5,
                                 cfg.dtype),
        "ln_f": jnp.ones((d,), cfg.dtype),
        "attn": {
            "wq": nn.dense_init(keys[1], d, hq, cfg.dtype, stacked=l),
            "wk": nn.dense_init(keys[2], d, hkv, cfg.dtype, stacked=l),
            "wv": nn.dense_init(keys[3], d, hkv, cfg.dtype, stacked=l),
            "wo": nn.dense_init(keys[4], hq, d, cfg.dtype, stacked=l),
        },
        "ln1": jnp.ones((l, d), cfg.dtype),
        "ln2": jnp.ones((l, d), cfg.dtype),
    }
    if cfg.moe:
        params["moe"] = init_moe(keys[5], cfg.moe, d, l, cfg.dtype)
    else:
        params["mlp"] = {
            "w1": nn.dense_init(keys[5], d, cfg.d_ff, cfg.dtype, stacked=l),
            "w3": nn.dense_init(keys[6], d, cfg.d_ff, cfg.dtype, stacked=l),
            "w2": nn.dense_init(keys[7], cfg.d_ff, d, cfg.dtype, stacked=l),
        }
    if not cfg.tie_embeddings:
        params["lm_head"] = nn.dense_init(keys[6], d, cfg.vocab_padded,
                                          cfg.dtype)
    return params


def _layer_weights(params, cfg: LMConfig):
    w = {"attn": params["attn"], "ln1": params["ln1"], "ln2": params["ln2"]}
    w["ffn"] = params["moe"] if cfg.moe else params["mlp"]
    return w


def _attend(cfg: LMConfig, q, k, v, *, causal=True, q_offset=0, kv_len=None):
    """Dispatch full-score vs chunked (flash-style) attention."""
    use_chunked = (cfg.attn_kv_chunk > 0
                   and k.shape[1] >= 2 * cfg.attn_kv_chunk
                   and q.shape[1] > 1)
    if use_chunked:
        return nn.chunked_gqa_attention(
            q, k, v, causal=causal, q_chunk=cfg.attn_q_chunk,
            kv_chunk=cfg.attn_kv_chunk, q_offset=q_offset, kv_len=kv_len)
    return nn.gqa_attention(q, k, v, causal=causal, q_offset=q_offset,
                            kv_len=kv_len)


def _constrain(cfg: LMConfig, x: jax.Array) -> jax.Array:
    if cfg.act_batch_axes is not None:
        rest = [None] * (x.ndim - 1)
        if cfg.act_seq_axis is not None and x.ndim >= 3:
            rest[0] = cfg.act_seq_axis
        x = jax.lax.with_sharding_constraint(
            x, jax.sharding.PartitionSpec(tuple(cfg.act_batch_axes), *rest))
    return x


def _one_layer(cfg: LMConfig, x: jax.Array, w, positions: jax.Array):
    """x: (B, S, D). Returns (x', aux_loss)."""
    b, s, d = x.shape
    x = _constrain(cfg, x)  # pin batch-sharding (GSPMD replicates otherwise:
    #                         measured 2.1 GB/dev score buffers, §Perf iter 2)
    if cfg.gather_barrier:
        w = jax.lax.optimization_barrier(w)
    h = nn.rmsnorm(x, w["ln1"])
    q = (h @ w["attn"]["wq"]).reshape(b, s, cfg.n_heads, cfg.d_head)
    k = (h @ w["attn"]["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = (h @ w["attn"]["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    q = nn.apply_rope(q, positions, cfg.rope_theta)
    k = nn.apply_rope(k, positions, cfg.rope_theta)
    o = _attend(cfg, q, k, v, causal=True)
    x = x + (o.reshape(b, s, -1) @ w["attn"]["wo"])
    h = nn.rmsnorm(x, w["ln2"])
    if cfg.moe:
        out = moe_ffn(h.reshape(b * s, d), w["ffn"], cfg.moe)
        return x + out.y.reshape(b, s, d), out.aux_loss
    return x + nn.swiglu(h, w["ffn"]["w1"], w["ffn"]["w3"], w["ffn"]["w2"]), \
        jnp.zeros((), jnp.float32)


def forward(cfg: LMConfig, params, tokens: jax.Array):
    """tokens (B, S) → logits (B, S, V) fp32, aux_loss."""
    b, s = tokens.shape
    x = _constrain(cfg, params["embed"][tokens].astype(cfg.dtype))
    positions = jnp.arange(s)[None, :]
    lw = _layer_weights(params, cfg)

    def one(xx, ww):
        return _one_layer(cfg, xx, ww, positions)

    if cfg.layer_block > 1 and cfg.n_layers % cfg.layer_block == 0:
        lb = cfg.layer_block
        lw = jax.tree.map(
            lambda a: a.reshape(cfg.n_layers // lb, lb, *a.shape[1:]), lw)

        # NESTED remat: outer remat saves only block boundaries; inner remat
        # makes the within-block backward recompute layer-by-layer (without
        # it, one block's backward holds 7 layers of attention internals —
        # measured 3.8 GB score stacks per block on 405B, §Perf iter 6).
        inner_one = jax.remat(one) if cfg.remat else one

        def block(xx, wb):
            def inner(c, w):
                xc, auxc = c
                xc, a = inner_one(xc, w)
                return (xc.astype(cfg.dtype), auxc + a), None
            (xx, a), _ = jax.lax.scan(
                inner, (xx, jnp.zeros((), jnp.float32)), wb)
            return xx, a
        step = jax.remat(block) if cfg.remat else block

        if cfg.unroll_blocks:
            aux = jnp.zeros((), jnp.float32)
            for bi in range(cfg.n_layers // lb):
                wb = jax.tree.map(lambda a: a[bi], lw)
                x, a = step(x, wb)
                x = x.astype(cfg.dtype)
                aux = aux + a
        else:
            def body(carry, w):
                x, aux = carry
                x, a = step(x, w)
                return (x.astype(cfg.dtype), aux + a), None
            (x, aux), _ = jax.lax.scan(
                body, (x, jnp.zeros((), jnp.float32)), lw)
    else:
        step = jax.remat(one) if cfg.remat else one

        def body(carry, w):
            x, aux = carry
            x, a = step(x, w)
            return (x.astype(cfg.dtype), aux + a), None

        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), lw)
    x = nn.rmsnorm(x, params["ln_f"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head).astype(jnp.float32)
    return logits, aux


def lm_loss(cfg: LMConfig, params, tokens: jax.Array, labels: jax.Array):
    logits, aux = forward(cfg, params, tokens)
    if cfg.vocab_padded != cfg.vocab:  # mask the padding columns
        pad_mask = jnp.arange(cfg.vocab_padded) >= cfg.vocab
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll) + aux


class TrainStepFns(NamedTuple):
    init: Any
    train_step: Any
    opt_init: Any


def make_train_step(cfg: LMConfig, lr: float = 3e-4, param_pspecs=None):
    """Returns (init_fn, train_step). train_step does microbatched grad
    accumulation + AdamW; everything shardable via in_shardings.

    param_pspecs: optional pytree of PartitionSpec matching params — pins
    the grad-accumulation scan carry's sharding (without it GSPMD may
    replicate the params-shaped carry over `model`: +45 GB/dev on 405B,
    §Perf iteration 6)."""
    optimizer = adam(constant_schedule(lr), slot_dtype=cfg.opt_slot_dtype)

    def _pin(gtree):
        if param_pspecs is None:
            return gtree
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s),
            gtree, param_pspecs)

    def train_step(params, opt_state, tokens, labels):
        mb = cfg.microbatches
        b = tokens.shape[0]
        tok_mb = tokens.reshape(mb, b // mb, -1)
        lab_mb = labels.reshape(mb, b // mb, -1)
        if cfg.act_batch_axes is not None:
            # the (B,) → (mb, B/mb) reshape must stay batch-sharded on dim 1
            mb_spec = jax.sharding.PartitionSpec(
                None, tuple(cfg.act_batch_axes), None)
            tok_mb = jax.lax.with_sharding_constraint(tok_mb, mb_spec)
            lab_mb = jax.lax.with_sharding_constraint(lab_mb, mb_spec)

        def mb_body(acc, inp):
            tok, lab = inp
            loss, g = jax.value_and_grad(
                lambda p: lm_loss(cfg, p, tok, lab))(params)
            acc = jax.tree.map(
                lambda a, gg: a + gg.astype(cfg.grad_dtype) / mb, acc, g)
            return _pin(acc), loss

        zero = _pin(jax.tree.map(
            lambda p: jnp.zeros(p.shape, cfg.grad_dtype), params))
        grads, losses = jax.lax.scan(mb_body, zero, (tok_mb, lab_mb))
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, jnp.mean(losses)

    return TrainStepFns(init=lambda key: init_lm(key, cfg),
                        train_step=train_step,
                        opt_init=optimizer.init)


# --------------------------------------------------------------------------
# Serving: prefill + decode with KV cache
# --------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array        # (L, B, Smax, Hkv, dh)
    v: jax.Array
    length: jax.Array   # () int32 — valid prefix


def init_cache(cfg: LMConfig, batch: int, max_len: int) -> KVCache:
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.d_head)
    return KVCache(k=jnp.zeros(shape, cfg.dtype), v=jnp.zeros(shape, cfg.dtype),
                   length=jnp.zeros((), jnp.int32))


def prefill(cfg: LMConfig, params, tokens: jax.Array, max_len: int):
    """tokens (B, S) → (logits of last position (B, V), filled KVCache)."""
    b, s = tokens.shape
    x = _constrain(cfg, params["embed"][tokens].astype(cfg.dtype))
    positions = jnp.arange(s)[None, :]
    cache = init_cache(cfg, b, max_len)

    def body(x, w):
        h = nn.rmsnorm(x, w["ln1"])
        q = (h @ w["attn"]["wq"]).reshape(b, s, cfg.n_heads, cfg.d_head)
        k = (h @ w["attn"]["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
        v = (h @ w["attn"]["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
        q = nn.apply_rope(q, positions, cfg.rope_theta)
        k = nn.apply_rope(k, positions, cfg.rope_theta)
        o = _attend(cfg, q, k, v, causal=True)
        x = x + (o.reshape(b, s, -1) @ w["attn"]["wo"])
        h2 = nn.rmsnorm(x, w["ln2"])
        if cfg.moe:
            out = moe_ffn(h2.reshape(b * s, -1), w["ffn"], cfg.moe)
            x = x + out.y.reshape(b, s, -1)
        else:
            x = x + nn.swiglu(h2, w["ffn"]["w1"], w["ffn"]["w3"], w["ffn"]["w2"])
        kc = jnp.zeros((b, max_len, cfg.n_kv_heads, cfg.d_head), cfg.dtype)
        vc = jnp.zeros((b, max_len, cfg.n_kv_heads, cfg.d_head), cfg.dtype)
        kc = jax.lax.dynamic_update_slice(kc, k, (0, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, 0, 0, 0))
        return x.astype(cfg.dtype), (kc, vc)

    x, (kcs, vcs) = jax.lax.scan(
        lambda c, w: body(c, w), x, _layer_weights(params, cfg))
    x = nn.rmsnorm(x[:, -1:], params["ln_f"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x[:, 0] @ head).astype(jnp.float32)
    return logits, KVCache(k=kcs, v=vcs, length=jnp.asarray(s, jnp.int32))


def decode_step(cfg: LMConfig, params, cache: KVCache, tokens: jax.Array):
    """One-token decode. tokens (B,) → (logits (B, V), updated cache).

    Attention runs against the full cache with a valid-length mask — O(S)
    compute/bytes per token (flash-decoding style; the softmax reduction is
    sharded over `model` along heads by SPMD).
    """
    b = tokens.shape[0]
    x = params["embed"][tokens][:, None, :].astype(cfg.dtype)   # (B, 1, D)
    pos = cache.length

    def body(x, inp):
        w, kc, vc = inp
        h = nn.rmsnorm(x, w["ln1"])
        q = (h @ w["attn"]["wq"]).reshape(b, 1, cfg.n_heads, cfg.d_head)
        k = (h @ w["attn"]["wk"]).reshape(b, 1, cfg.n_kv_heads, cfg.d_head)
        v = (h @ w["attn"]["wv"]).reshape(b, 1, cfg.n_kv_heads, cfg.d_head)
        q = nn.apply_rope(q, pos[None, None], cfg.rope_theta)
        k = nn.apply_rope(k, pos[None, None], cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice(kc, k, (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, pos, 0, 0))
        o = nn.gqa_attention(q, kc, vc, causal=False, q_offset=pos,
                             kv_len=pos + 1,
                             seq_shard_axis=cfg.act_seq_axis)
        x = x + (o.reshape(b, 1, -1) @ w["attn"]["wo"])
        h2 = nn.rmsnorm(x, w["ln2"])
        if cfg.moe:
            out = moe_ffn(h2.reshape(b, -1), w["ffn"], cfg.moe)
            x = x + out.y.reshape(b, 1, -1)
        else:
            x = x + nn.swiglu(h2, w["ffn"]["w1"], w["ffn"]["w3"], w["ffn"]["w2"])
        return x.astype(cfg.dtype), (kc, vc)

    x, (kcs, vcs) = jax.lax.scan(
        body, x, (_layer_weights(params, cfg), cache.k, cache.v))
    x = nn.rmsnorm(x, params["ln_f"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x[:, 0] @ head).astype(jnp.float32)
    return logits, KVCache(k=kcs, v=vcs, length=cache.length + 1)
