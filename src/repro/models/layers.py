"""Shared neural-net layers for the architecture zoo (pure-JAX pytrees).

Conventions
-----------
* params are nested dicts of jnp arrays; layer-stacked weights carry a
  leading (L, ...) axis and are consumed by `lax.scan` (keeps HLO size flat
  in depth — a 126-layer 405B train step compiles in seconds).
* compute dtype bf16, parameters bf16, reductions/softmax fp32.
* attention is GQA throughout (n_kv ≤ n_heads); decode takes a KV cache.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def uniform_init(key, shape, scale, dtype=jnp.bfloat16):
    return jax.random.uniform(key, shape, jnp.float32, -scale, scale).astype(dtype)


def dense_init(key, d_in, d_out, dtype=jnp.bfloat16, stacked: int = 0):
    shape = (stacked, d_in, d_out) if stacked else (d_in, d_out)
    return uniform_init(key, shape, 1.0 / np.sqrt(d_in), dtype)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    ms = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(ms + eps)).astype(x.dtype) * gamma


def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
              eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma + beta


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0
               ) -> jax.Array:
    """x: (..., S, H, d_head); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                                # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., S, d/2)
    cos = jnp.cos(ang)[..., None, :]                            # (..., S, 1, d/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# GQA attention (train / prefill / decode)
# --------------------------------------------------------------------------

def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool, q_offset: jax.Array | int = 0,
                  kv_len: Optional[jax.Array] = None,
                  seq_shard_axis: Optional[str] = None) -> jax.Array:
    """Grouped-query attention.

    q: (B, Sq, Hq, d), k/v: (B, Skv, Hkv, d) with Hq = G·Hkv.
    q_offset: absolute position of q[0] (decode: cache length).
    kv_len: optional valid-prefix length of k/v (masks cache tail).
    seq_shard_axis: pin the score matrix's Skv dim to this mesh axis —
      keeps decode attention as a SHARDED softmax (partial max/sum psums)
      instead of letting GSPMD all-gather the whole KV cache out of the
      layer scan (measured 33 GB/dev hoisted gather on 405B decode_32k;
      EXPERIMENTS §Perf iteration 8).
    Returns (B, Sq, Hq, d).
    """
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    scores = scores / np.sqrt(d)
    if seq_shard_axis is not None:
        scores = jax.lax.with_sharding_constraint(
            scores, jax.sharding.PartitionSpec(
                None, None, None, None, seq_shard_axis))
    qpos = jnp.asarray(q_offset) + jnp.arange(sq)
    kpos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask = kpos[None, :] <= qpos[:, None]
    if kv_len is not None:
        mask = mask & (kpos[None, :] < kv_len)
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, sq, hq, d)


def chunked_gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                          causal: bool, q_chunk: int = 2048,
                          kv_chunk: int = 1024,
                          q_offset: jax.Array | int = 0,
                          kv_len: Optional[jax.Array] = None) -> jax.Array:
    """Online-softmax (flash-style) GQA attention in pure lax.

    Never materializes the (Sq, Skv) score matrix: double scan over q-chunks
    (outer) and kv-chunks (inner) with running (max, sum, acc) — the TPU
    re-derivation of FlashAttention for XLA (DESIGN.md §3). Peak score
    buffer = (B, Hkv, G, q_chunk, kv_chunk) f32.
    """
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    nq = max(sq // q_chunk, 1)
    q_chunk = sq // nq
    nkv = max(skv // kv_chunk, 1)
    kv_chunk = skv // nkv

    qg = q.reshape(b, nq, q_chunk, hkv, g, d).astype(jnp.bfloat16)
    kc = k.reshape(b, nkv, kv_chunk, hkv, d).astype(jnp.bfloat16)
    vc = v.reshape(b, nkv, kv_chunk, hkv, d).astype(jnp.bfloat16)
    scale = 1.0 / np.sqrt(d)

    def q_step(_, qi):
        qblk = qg[:, qi]                                  # (B, qc, Hkv, G, d)
        qpos = jnp.asarray(q_offset) + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, ki):
            m, l, acc = carry
            kblk = kc[:, ki]
            vblk = vc[:, ki]
            s = jnp.einsum("bqkgd,bskd->bkgqs", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask = kpos[None, :] <= qpos[:, None]
            if kv_len is not None:
                mask = mask & (kpos[None, :] < kv_len)
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(jnp.bfloat16), vblk,
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (m_new, l, acc), None

        m0 = jnp.full((b, hkv, g, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_chunk, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      jnp.arange(nkv))
        out = acc / jnp.maximum(l, 1e-30)[..., None]      # (B, Hkv, G, qc, d)
        return None, out.transpose(0, 3, 1, 2, 4)          # (B, qc, Hkv, G, d)

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq))   # (nq, B, qc, ...)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, hq, d)
    return out.astype(q.dtype)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def swiglu(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array) -> jax.Array:
    h = jax.nn.silu((x @ w1).astype(jnp.float32)).astype(x.dtype) * (x @ w3)
    return h @ w2


def mlp_stack(key, sizes: list[int], dtype=jnp.float32):
    """Plain MLP params for recsys towers: [(w, b), ...]."""
    params = []
    for i in range(len(sizes) - 1):
        key, sub = jax.random.split(key)
        params.append({
            "w": dense_init(sub, sizes[i], sizes[i + 1], dtype),
            "b": jnp.zeros((sizes[i + 1],), dtype),
        })
    return params


def mlp_apply(params, x, *, final_act: bool = False):
    for i, lyr in enumerate(params):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(params) - 1 or final_act:
            x = jax.nn.relu(x)
    return x
