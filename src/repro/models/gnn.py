"""Graph attention network (GAT, Veličković et al. 2018) — assigned arch.

JAX has no CSR/CSC sparse; message passing IS part of the system here
(brief requirement): SDDMM-style edge scores + segment-softmax + scatter
aggregation, all via `jax.ops.segment_{sum,max}` over an edge-index list.

Shapes covered:
  full_graph_sm / ogb_products  — full-batch: edge list (2, E) + feats (N, F)
  minibatch_lg                  — fanout-sampled blocks from a real neighbor
                                  sampler (data/gnn_sampler.py)
  molecule                      — batched small graphs: padded edge lists +
                                  graph-id segment pooling

Distribution: edges shard over `data` (each shard owns a slice of the edge
list); segment reductions produce node-indexed partials that are psum-ed —
see dist/sharding.py. Nodes/features stay replicated (Cora…products fit).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import adam, constant_schedule
from repro.models import layers as nn


@dataclasses.dataclass(frozen=True)
class GATConfig:
    name: str
    d_in: int
    d_hidden: int          # per head
    n_heads: int
    n_layers: int = 2
    n_classes: int = 7
    negative_slope: float = 0.2
    dtype: Any = jnp.float32


def init_gat(key: jax.Array, cfg: GATConfig):
    layers = []
    d_in = cfg.d_in
    for li in range(cfg.n_layers):
        key, k1, k2, k3 = jax.random.split(key, 4)
        d_out = cfg.d_hidden if li < cfg.n_layers - 1 else cfg.n_classes
        heads = cfg.n_heads if li < cfg.n_layers - 1 else 1
        layers.append({
            "w": nn.dense_init(k1, d_in, heads * d_out, cfg.dtype),
            "a_src": nn.uniform_init(k2, (heads, d_out), 0.1, cfg.dtype),
            "a_dst": nn.uniform_init(k3, (heads, d_out), 0.1, cfg.dtype),
        })
        d_in = heads * d_out
    return {"layers": layers}


def gat_layer(w, x: jax.Array, src: jax.Array, dst: jax.Array, n_nodes: int,
              heads: int, d_out: int, slope: float, edge_mask=None):
    """One GAT layer via segment ops.

    x (N, F); src/dst (E,) int32 (padded edges point at node n_nodes-1 with
    edge_mask=False). Returns (N, heads*d_out).
    """
    h = (x @ w["w"]).reshape(-1, heads, d_out)               # (N, H, D)
    e_src = jnp.sum(h * w["a_src"][None], -1)                # (N, H)
    e_dst = jnp.sum(h * w["a_dst"][None], -1)
    logits = jax.nn.leaky_relu(e_src[src] + e_dst[dst], slope)  # (E, H)
    if edge_mask is not None:
        logits = jnp.where(edge_mask[:, None], logits, -1e30)
    # segment softmax over incoming edges of each dst
    lmax = jax.ops.segment_max(logits, dst, num_segments=n_nodes)
    lmax = jnp.where(jnp.isfinite(lmax), lmax, 0.0)
    ex = jnp.exp(logits - lmax[dst])
    if edge_mask is not None:
        ex = ex * edge_mask[:, None]
    denom = jax.ops.segment_sum(ex, dst, num_segments=n_nodes) + 1e-9
    alpha = ex / denom[dst]                                   # (E, H)
    msg = h[src] * alpha[..., None]                           # (E, H, D)
    out = jax.ops.segment_sum(msg, dst, num_segments=n_nodes)
    return out.reshape(n_nodes, heads * d_out)


def forward(cfg: GATConfig, params, x: jax.Array, src: jax.Array,
            dst: jax.Array, edge_mask=None):
    n = x.shape[0]
    for li, w in enumerate(params["layers"]):
        last = li == cfg.n_layers - 1
        heads = cfg.n_heads if not last else 1
        d_out = cfg.d_hidden if not last else cfg.n_classes
        x = gat_layer(w, x, src, dst, n, heads, d_out, cfg.negative_slope,
                      edge_mask)
        if not last:
            x = jax.nn.elu(x)
    return x                                                  # (N, n_classes)


def node_loss(cfg: GATConfig, params, x, src, dst, labels, label_mask,
              edge_mask=None):
    logits = forward(cfg, params, x, src, dst, edge_mask)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    nll = -jnp.take_along_axis(logp, labels[:, None], 1)[:, 0]
    w = label_mask.astype(jnp.float32)
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)


def make_train_step(cfg: GATConfig, lr: float = 5e-3):
    optimizer = adam(constant_schedule(lr))

    def train_step(params, opt_state, x, src, dst, labels, label_mask):
        loss, g = jax.value_and_grad(
            lambda p: node_loss(cfg, p, x, src, dst, labels, label_mask))(params)
        params, opt_state = optimizer.update(g, opt_state, params)
        return params, opt_state, loss

    return (lambda key: init_gat(key, cfg)), train_step, optimizer.init


# --------------------------------------------------------------------------
# Batched small graphs (molecule shape): graph-level prediction
# --------------------------------------------------------------------------

def graph_pool_loss(cfg: GATConfig, params, x, src, dst, graph_id,
                    n_graphs: int, y, edge_mask=None):
    """x (B·n, F) stacked node feats; graph_id (B·n,) → mean-pool logits."""
    h = forward(cfg, params, x, src, dst, edge_mask)
    pooled = jax.ops.segment_sum(h, graph_id, num_segments=n_graphs)
    cnt = jax.ops.segment_sum(jnp.ones((h.shape[0], 1)), graph_id,
                              num_segments=n_graphs)
    logits = (pooled / jnp.maximum(cnt, 1.0)).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, -1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], 1))


# --------------------------------------------------------------------------
# Neighbor sampler (minibatch_lg): real fanout sampling over CSR
# --------------------------------------------------------------------------

class SampledBlock(NamedTuple):
    """Fixed-shape fanout-sampled computation block (2-hop)."""
    feats: jax.Array      # (n_all, F) features of all touched nodes
    src: jax.Array        # (E_pad,) local ids into feats
    dst: jax.Array        # (E_pad,)
    edge_mask: jax.Array  # (E_pad,) bool
    seed_local: jax.Array  # (batch,) local ids of the seed nodes
    labels: jax.Array     # (batch,)


def sample_block(rng: np.random.Generator, indptr: np.ndarray,
                 indices: np.ndarray, feats: np.ndarray, labels: np.ndarray,
                 seeds: np.ndarray, fanouts: tuple[int, ...]) -> SampledBlock:
    """GraphSAGE-style fanout sampling (host-side, feeds the device step).

    Returns a block with exactly batch·(1+f1+f1·f2) node slots and
    batch·(f1+f1·f2) edge slots (padded), so the jitted step never recompiles.
    """
    layers = [seeds.astype(np.int64)]
    edges_src, edges_dst = [], []
    frontier = seeds.astype(np.int64)
    for f in fanouts:
        deg = indptr[frontier + 1] - indptr[frontier]
        off = (rng.random((len(frontier), f)) * np.maximum(deg, 1)[:, None]).astype(np.int64)
        nbr = indices[indptr[frontier][:, None] + off]        # (|F|, f)
        nbr[deg == 0] = frontier[deg == 0][:, None]           # isolated: self
        edges_src.append(nbr.reshape(-1))
        edges_dst.append(np.repeat(frontier, f))
        frontier = nbr.reshape(-1)
        layers.append(frontier)
    all_nodes, local = np.unique(np.concatenate(layers), return_inverse=False), None
    lookup = {g: i for i, g in enumerate(all_nodes)}
    to_local = np.vectorize(lookup.get)
    src = to_local(np.concatenate(edges_src))
    dst = to_local(np.concatenate(edges_dst))
    # fixed-size padding
    n_slots = len(seeds) * int(np.prod([1] + list(fanouts))) * 2
    e_slots = sum(len(seeds) * int(np.prod(fanouts[:i + 1]))
                  for i in range(len(fanouts)))
    pad_n = max(n_slots - len(all_nodes), 0)
    f_out = np.concatenate([feats[all_nodes],
                            np.zeros((pad_n, feats.shape[1]), feats.dtype)])
    mask = np.ones(e_slots, bool)
    mask[len(src):] = False
    src_p = np.full(e_slots, len(all_nodes) + pad_n - 1, np.int32)
    dst_p = np.full(e_slots, len(all_nodes) + pad_n - 1, np.int32)
    src_p[: len(src)] = src
    dst_p[: len(dst)] = dst
    return SampledBlock(
        feats=jnp.asarray(f_out), src=jnp.asarray(src_p), dst=jnp.asarray(dst_p),
        edge_mask=jnp.asarray(mask),
        seed_local=jnp.asarray(to_local(seeds.astype(np.int64)), jnp.int32),
        labels=jnp.asarray(labels[seeds], jnp.int32))
