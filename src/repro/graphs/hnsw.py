"""HNSW (Malkov & Yashunin 2018) — hierarchical PG, batched construction.

Construction deviation (documented): the reference implementation inserts
points one-by-one; we build each layer's adjacency with batched exact-kNN +
RobustPrune(α=1) over the layer members (the "select-neighbors heuristic" is
precisely the MRNG rule), with geometric layer membership n·p^ℓ. Navigation
semantics at search time are the standard ones: greedy descent through the
upper layers to find the layer-0 entry, then beam search at layer 0.
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.graphs.adjacency import Graph, find_medoid
from repro.graphs.knn import knn_ids
from repro.graphs.prune import prune_from_vectors
from repro.search.beam import beam_search, make_exact_dist_fn


class HNSW(NamedTuple):
    base: Graph                 # layer-0 graph over ALL points
    layers: tuple[Graph, ...]   # upper layers (local ids within the layer)
    members: tuple[jax.Array, ...]  # layer local id -> global id
    top_entry: jax.Array        # entry in the TOP layer's local ids


def _layer_graph(key, x_layer: jax.Array, m: int) -> Graph:
    n = x_layer.shape[0]
    k = min(max(2 * m, 8), n - 1)
    ids, _ = knn_ids(x_layer, x_layer, k, exclude_self=True)
    xp = jnp.concatenate([x_layer, jnp.zeros((1, x_layer.shape[1]), x_layer.dtype)])
    out = np.full((n, m), n, np.int32)
    batch = 2048
    for s in range(0, n, batch):
        node = jnp.arange(s, min(s + batch, n), dtype=jnp.int32)
        pruned = prune_from_vectors(xp, node, ids[s:s + batch], 1.0, m, n)
        out[s:s + batch] = np.asarray(pruned)
    return Graph(neighbors=jnp.asarray(out), medoid=find_medoid(x_layer))


def build_hnsw(key: jax.Array, x: jax.Array, *, m: int = 16,
               scale: int = 8, max_layers: int = 4) -> HNSW:
    """Build layered HNSW. Layer ℓ>0 has ~n/scale^ℓ members."""
    n = x.shape[0]
    x = jnp.asarray(x, jnp.float32)
    key, kperm = jax.random.split(key)
    perm = jax.random.permutation(kperm, n)

    base = _layer_graph(key, x, 2 * m)  # layer-0 uses 2M (HNSW convention)
    layers, members = [], []
    sz = n
    while len(layers) < max_layers - 1:
        sz = sz // scale
        if sz < max(2 * m + 2, 16):
            break
        memb = jnp.sort(perm[:sz])
        layers.append(_layer_graph(key, x[memb], m))
        members.append(memb)
    top = layers[-1].medoid if layers else base.medoid
    return HNSW(base=base, layers=tuple(layers), members=tuple(members),
                top_entry=top)


def descend(h: HNSW, queries: jax.Array, x: jax.Array) -> jax.Array:
    """Greedy h=1 descent through the upper layers → layer-0 entry ids.

    Exact distances are used in the upper layers (they are small and, in the
    paper's in-memory scenario, their vectors fit in RAM next to the codes).
    """
    nq = queries.shape[0]
    if not h.layers:
        return jnp.broadcast_to(h.base.medoid, (nq,))
    entry_local = jnp.broadcast_to(h.top_entry, (nq,))
    for li in range(len(h.layers) - 1, -1, -1):
        g, memb = h.layers[li], h.members[li]
        xl = x[memb]
        xlp = jnp.concatenate([xl, jnp.zeros((1, x.shape[1]), x.dtype)])
        res = beam_search(g.neighbors, entry_local, queries,
                          make_exact_dist_fn(xlp), h=1, max_steps=64)
        best_local = res.ids[:, 0]
        glob = memb[jnp.clip(best_local, 0, memb.shape[0] - 1)]
        if li == 0:
            return glob.astype(jnp.int32)
        # map global id into the next-lower layer's local id space
        lower = h.members[li - 1]
        entry_local = jnp.searchsorted(lower, glob).astype(jnp.int32)
        entry_local = jnp.clip(entry_local, 0, lower.shape[0] - 1)
    return glob.astype(jnp.int32)
