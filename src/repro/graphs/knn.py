"""Blocked exact k-nearest-neighbor graph (also the ground-truth engine).

Brute force in row blocks: distances via ‖a‖²−2abᵀ+‖b‖² matmuls so the whole
build is a few big GEMMs — minutes for 1M×128 on one host, trivially
data-parallel across devices (see dist/sharding.py: rows over `data`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("k", "block", "exclude_self"))
def knn_ids(x: jax.Array, q: jax.Array, k: int, *, block: int = 1024,
            exclude_self: bool = False) -> tuple[jax.Array, jax.Array]:
    """For each row of q (Q, D), the k nearest rows of x (N, D).

    Returns (ids (Q, k) int32, sqdists (Q, k) f32), ascending by distance.
    `exclude_self` masks exact index matches (for q == x graph builds).
    """
    n, d = x.shape
    qn, _ = q.shape
    x = x.astype(jnp.float32)
    q = q.astype(jnp.float32)
    x2 = jnp.sum(x * x, axis=1)

    q_pad = (-qn) % block
    qp = jnp.pad(q, ((0, q_pad), (0, 0)))
    nb = qp.shape[0] // block
    qb = qp.reshape(nb, block, d)
    base = jnp.arange(nb) * block

    def one(args):
        qi, off = args
        d2 = jnp.sum(qi * qi, 1)[:, None] - 2.0 * qi @ x.T + x2[None, :]
        if exclude_self:
            rows = off + jnp.arange(block)
            d2 = jnp.where(jnp.arange(n)[None, :] == rows[:, None], jnp.inf, d2)
        neg, ids = jax.lax.top_k(-d2, k)
        return ids.astype(jnp.int32), -neg

    ids, dist = jax.lax.map(one, (qb, base))
    return ids.reshape(-1, k)[:qn], dist.reshape(-1, k)[:qn]


def knn_graph(x: jax.Array, k: int, *, block: int = 1024):
    """Exact kNN adjacency (N, k) excluding self — builder substrate."""
    ids, dist = knn_ids(x, x, k, block=block, exclude_self=True)
    return ids, dist
