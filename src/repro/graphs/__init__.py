"""Proximity-graph construction: kNN substrate, Vamana, HNSW, NSG, and the
per-shard partitioned build for multi-device graph routing."""
from repro.graphs.adjacency import Graph, from_lists, find_medoid, degree_stats  # noqa: F401
from repro.graphs.knn import knn_ids, knn_graph  # noqa: F401
from repro.graphs.prune import robust_prune, prune_from_vectors  # noqa: F401
from repro.graphs.vamana import build_vamana  # noqa: F401
from repro.graphs.hnsw import build_hnsw, HNSW, descend  # noqa: F401
from repro.graphs.nsg import build_nsg  # noqa: F401
from repro.graphs.partition import (  # noqa: F401
    PartitionedGraph, build_partitioned_vamana, shard_bounds, shard_subgraph,
)
