"""Batched RobustPrune (DiskANN Alg. 2 / MRNG edge selection), jitted.

Given per-node candidate sets, iteratively keep the closest candidate p and
discard every candidate c with α·δ(p, c) ≤ δ(v, c) (p "occludes" c). α=1
gives the MRNG/NSG rule; α>1 (DiskANN default 1.2) keeps long-range edges.

Vectorized across a node batch with a fori_loop over the R slots — one XLA
program prunes 1k+ nodes at once (vs. the per-node scalar loop in the C++
implementations).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

INF = jnp.float32(jnp.inf)


@functools.partial(jax.jit, static_argnames=("r",))
def robust_prune(cand_ids: jax.Array, cand_dv: jax.Array, cand_pair: jax.Array,
                 alpha: float, r: int, sentinel: int) -> jax.Array:
    """Prune candidate sets to degree ≤ r.

    Args:
      cand_ids:  (B, C) int32 candidate ids (sentinel = invalid / padding).
      cand_dv:   (B, C) f32 distance candidate → node v.
      cand_pair: (B, C, C) f32 pairwise candidate distances.
      alpha:     occlusion factor (≥ 1).
      r:         max out-degree.
      sentinel:  id used for padding (== N).

    Returns: (B, r) int32 pruned neighbor ids (sentinel-padded).
    """
    b, c = cand_ids.shape
    valid0 = cand_ids != sentinel
    # mask duplicate ids (keep first occurrence of each id per row)
    sort_idx = jnp.argsort(cand_ids, axis=1)
    sorted_ids = jnp.take_along_axis(cand_ids, sort_idx, axis=1)
    dup_sorted = jnp.concatenate(
        [jnp.zeros((b, 1), bool), sorted_ids[:, 1:] == sorted_ids[:, :-1]], axis=1)
    dup = jnp.zeros((b, c), bool).at[
        jnp.arange(b)[:, None], sort_idx].set(dup_sorted)
    alive0 = valid0 & ~dup

    dv = jnp.where(alive0, cand_dv, INF)

    def body(slot, carry):
        alive, out = carry
        has = jnp.any(alive, axis=1)
        d = jnp.where(alive, dv, INF)
        pos = jnp.argmin(d, axis=1)                         # (B,)
        out = out.at[:, slot].set(jnp.where(has, pos, c))   # c == "none"
        d_pc = cand_pair[jnp.arange(b), pos, :]             # (B, C)
        occluded = alpha * d_pc <= cand_dv
        alive = alive & ~occluded & has[:, None]
        # the selected candidate occludes itself (d_pp = 0)
        alive = alive.at[jnp.arange(b), pos].set(False)
        return alive, out

    out0 = jnp.full((b, r), c, jnp.int32)
    _, out = jax.lax.fori_loop(0, r, body, (alive0, out0))
    padded_ids = jnp.concatenate(
        [cand_ids, jnp.full((b, 1), sentinel, jnp.int32)], axis=1)
    return jnp.take_along_axis(padded_ids, out, axis=1)


def prune_from_vectors(x: jax.Array, node_ids: jax.Array, cand_ids: jax.Array,
                       alpha: float, r: int, sentinel: int) -> jax.Array:
    """Convenience: gathers vectors and computes both distance tables.

    x must be sentinel-padded: x[(N+1), D] with x[N] finite (distances to the
    pad row are masked via the id check inside robust_prune).
    """
    xv = x[node_ids]                        # (B, D)
    xc = x[jnp.where(cand_ids == sentinel, 0, cand_ids)]  # (B, C, D)
    dv = jnp.sum((xc - xv[:, None, :]) ** 2, axis=-1)
    dv = jnp.where(cand_ids == sentinel, INF, dv)
    pair = jnp.sum((xc[:, :, None, :] - xc[:, None, :, :]) ** 2, axis=-1)
    return robust_prune(cand_ids, dv, pair, alpha, r, sentinel)
