"""Graph partitioning for multi-device serving (DESIGN.md §6).

The distributed-DiskANN layout: the dataset is split into contiguous
per-shard row ranges and an INDEPENDENT Vamana subgraph is built over each
shard's rows. Shard s owns global rows ``[s·n_local, min((s+1)·n_local, n))``
and its adjacency uses LOCAL ids in ``[0, n_local)`` with sentinel
``n_local``, so the whole partition stacks into one fixed-shape
``(n_shards, n_local, R)`` array that row-shards cleanly over a device mesh
(leading axis = shard axis, ``dist.sharding.rpq_rows_spec``-style).

Independent subgraphs (vs. a single edge-cut graph) mean a beam search never
crosses a shard boundary: each device routes purely locally and only the
per-shard top-k crosses the interconnect (O(shards·k) per query). The cost
is that every shard must be searched — recall comes from merging all local
answers, and a dead shard removes exactly its row range from the merged
result (graceful degradation via ``dist.fault.partial_merge``). This is the
partitioned PQ+PG layout of AiSAQ-style systems (see PAPERS.md).
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.graphs.adjacency import Graph

# NOTE: repro.graphs.vamana is imported lazily inside
# build_partitioned_vamana — search.engine imports this module for the
# PartitionedGraph type, and vamana itself imports search.beam, so a
# module-level import here would close an import cycle.


class PartitionedGraph(NamedTuple):
    """A stack of independent per-shard proximity graphs.

    Attributes:
      neighbors: (S, n_local, R) int32 adjacency per shard, LOCAL ids with
        sentinel ``n_local`` (pad rows — beyond a shard's real row count —
        are all-sentinel and unreachable).
      medoids:   (S,) int32 per-shard entry vertex, LOCAL id.
      n:         total number of REAL rows across all shards (the global
        dataset size before divisibility padding).
    """

    neighbors: jax.Array
    medoids: jax.Array
    n: int

    @property
    def n_shards(self) -> int:
        return self.neighbors.shape[0]

    @property
    def n_local(self) -> int:
        """Rows per shard including divisibility padding."""
        return self.neighbors.shape[1]

    @property
    def degree(self) -> int:
        return self.neighbors.shape[2]

    def shard_rows(self, s: int) -> tuple[int, int]:
        """Global [lo, hi) row range owned by shard ``s``."""
        lo = s * self.n_local
        return lo, min(lo + self.n_local, self.n)


def shard_bounds(n: int, n_shards: int) -> list[tuple[int, int]]:
    """Contiguous per-shard global row ranges [lo, hi).

    Every shard gets ``ceil(n / n_shards)`` row slots; the last shard(s) may
    own fewer real rows (the remainder is sentinel-padded, never fabricated).
    """
    n_local = -(-n // n_shards)
    return [(s * n_local, min((s + 1) * n_local, n)) for s in range(n_shards)]


def build_partitioned_vamana(key: jax.Array, x: jax.Array, n_shards: int, *,
                             r: int = 32, l: int = 64, alpha: float = 1.2,
                             passes: int = 2, batch: int = 1024,
                             verbose: bool = False) -> PartitionedGraph:
    """Partition ``x`` (N, D) into ``n_shards`` row ranges and build one
    independent Vamana graph per range.

    Returns a :class:`PartitionedGraph` whose stacked adjacency is ready to
    be device_put with a ``P(axes, None, None)`` sharding (leading axis =
    shard). Local ids map to global ids as ``gid = s * n_local + local``.
    """
    from repro.graphs.vamana import build_vamana

    n = int(x.shape[0])
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if n < n_shards:
        raise ValueError(f"cannot split {n} rows into {n_shards} shards")
    bounds = shard_bounds(n, n_shards)
    n_local = bounds[0][1] - bounds[0][0]

    nbrs = np.full((n_shards, n_local, r), n_local, np.int32)
    medoids = np.zeros((n_shards,), np.int32)
    for s, (lo, hi) in enumerate(bounds):
        ns = hi - lo
        if ns <= 1:
            # degenerate shard (n barely > (S-1)·n_local): nothing to route
            # over — all-sentinel adjacency, entry 0; the engine's validity
            # mask handles the rest (a 0-row shard contributes nothing)
            continue
        key, ks = jax.random.split(key)
        g = build_vamana(ks, x[lo:hi], r=r, l=l, alpha=alpha, passes=passes,
                         batch=batch, verbose=verbose)
        local = np.asarray(g.neighbors)
        # remap the subgraph's sentinel (ns) to the stacked sentinel (n_local)
        nbrs[s, :ns] = np.where(local >= ns, n_local, local)
        medoids[s] = int(g.medoid)
        if verbose:
            print(f"[partition] shard {s}: rows [{lo}, {hi}) "
                  f"medoid(local)={medoids[s]}")

    return PartitionedGraph(neighbors=jnp.asarray(nbrs),
                            medoids=jnp.asarray(medoids), n=n)


def shard_subgraph(pg: PartitionedGraph, s: int) -> Graph:
    """Extract shard ``s`` as a standalone single-device :class:`Graph`
    (debugging / per-shard inspection; sentinel stays ``n_local``)."""
    return Graph(neighbors=pg.neighbors[s], medoid=pg.medoids[s])
