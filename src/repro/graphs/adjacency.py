"""Padded fixed-degree adjacency — the PG representation the JAX engine uses.

Every proximity graph (Vamana/HNSW/NSG/kNN) is stored as an (N, R) int32
array of neighbor ids, padded with the sentinel ``N`` (one past the last
valid id). Fixed degree makes every gather shape static, which is what lets
the whole beam search jit into a single XLA program.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class Graph(NamedTuple):
    neighbors: jax.Array   # (N, R) int32, sentinel = N for padding
    medoid: jax.Array      # () int32 — entry vertex for routing

    @property
    def n(self) -> int:
        return self.neighbors.shape[0]

    @property
    def degree(self) -> int:
        return self.neighbors.shape[1]


def from_lists(lists: list[np.ndarray], r: int, medoid: int) -> Graph:
    """Ragged python neighbor lists → padded Graph."""
    n = len(lists)
    out = np.full((n, r), n, np.int32)
    for i, lst in enumerate(lists):
        lst = np.asarray(lst, np.int32)[:r]
        out[i, : len(lst)] = lst
    return Graph(neighbors=jnp.asarray(out), medoid=jnp.asarray(medoid, jnp.int32))


def degree_stats(g: Graph) -> dict:
    nb = np.asarray(g.neighbors)
    valid = (nb < g.n).sum(1)
    return {"mean": float(valid.mean()), "min": int(valid.min()),
            "max": int(valid.max()), "R": g.degree, "n": g.n}


def find_medoid(x: jax.Array, sample: int = 4096, key=None) -> jax.Array:
    """Vector closest to the dataset centroid (DiskANN's entry point)."""
    n = x.shape[0]
    if key is not None and n > sample:
        idx = jax.random.choice(key, n, (sample,), replace=False)
        xs = x[idx]
    else:
        idx = jnp.arange(min(n, sample))
        xs = x[: min(n, sample)]
    c = jnp.mean(x, axis=0)
    d = jnp.sum((xs - c) ** 2, axis=1)
    return idx[jnp.argmin(d)].astype(jnp.int32)


def symmetrize(neighbors: np.ndarray, r: int) -> np.ndarray:
    """Add reverse edges (dropping overflow) — used by graph builders."""
    n = neighbors.shape[0]
    lists: list[list[int]] = [list(row[row < n]) for row in neighbors]
    for i in range(n):
        for j in neighbors[i]:
            if j < n and i not in lists[j][:r]:
                if len(lists[j]) < r:
                    lists[j].append(i)
    out = np.full((n, r), n, np.int32)
    for i, lst in enumerate(lists):
        out[i, : min(len(lst), r)] = np.asarray(lst[:r], np.int32)
    return out
