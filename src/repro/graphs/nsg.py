"""NSG (Fu et al. 2017) — monotonic-path PG from a kNN graph, batched.

Faithful structure: candidates per node = exact kNN ∪ nodes visited by a
medoid-rooted search; MRNG occlusion rule (RobustPrune with α=1); explicit
connectivity repair via a BFS tree from the medoid (unreachable nodes get
attached to their nearest reachable neighbor), which is NSG's spanning-tree
step.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.graphs.adjacency import Graph, find_medoid
from repro.graphs.knn import knn_ids
from repro.graphs.prune import prune_from_vectors
from repro.search.beam import beam_search, make_exact_dist_fn


def build_nsg(key: jax.Array, x: jax.Array, *, r: int = 32, k: int = 64,
              search_l: int = 32, batch: int = 1024) -> Graph:
    n, d = x.shape
    x = jnp.asarray(x, jnp.float32)
    xp = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)])
    medoid = find_medoid(x)

    knn, _ = knn_ids(x, x, min(k, n - 1), exclude_self=True)
    knn_g = Graph(neighbors=knn, medoid=medoid)     # degree-k navigation graph
    dist_fn = make_exact_dist_fn(xp)

    nbrs = np.full((n, r), n, np.int32)
    n_pad = (-n) % batch
    order = np.concatenate([np.arange(n, dtype=np.int32),
                            np.zeros(n_pad, np.int32)])
    for s in range(0, len(order), batch):
        ids = order[s:s + batch]
        res = beam_search(knn_g.neighbors, medoid, x[ids], dist_fn,
                          h=search_l, max_steps=4 * search_l)
        cand = jnp.concatenate([knn[ids], res.ids], axis=1)
        cand = jnp.where(cand == jnp.asarray(ids)[:, None], n, cand)
        pruned = prune_from_vectors(xp, jnp.asarray(ids), cand, 1.0, r, n)
        nbrs[ids] = np.asarray(pruned)

    nbrs = _repair_connectivity(np.asarray(x), nbrs, int(medoid), r)
    return Graph(neighbors=jnp.asarray(nbrs), medoid=medoid)


def _repair_connectivity(x: np.ndarray, nbrs: np.ndarray, medoid: int,
                         r: int) -> np.ndarray:
    """BFS from medoid; attach unreachable components (NSG spanning tree)."""
    n = nbrs.shape[0]
    seen = np.zeros(n, bool)
    frontier = [medoid]
    seen[medoid] = True
    while frontier:
        nxt = nbrs[frontier].reshape(-1)
        nxt = nxt[nxt < n]
        nxt = nxt[~seen[nxt]]
        nxt = np.unique(nxt)
        seen[nxt] = True
        frontier = list(nxt)
    missing = np.nonzero(~seen)[0]
    if len(missing) == 0:
        return nbrs
    reach = np.nonzero(seen)[0]
    # nearest reachable node adopts each unreachable node (add forward edge)
    sub = reach[np.random.default_rng(0).permutation(len(reach))[:20000]]
    for i in missing:
        d = np.sum((x[sub] - x[i]) ** 2, axis=1)
        parent = int(sub[np.argmin(d)])
        row = nbrs[parent]
        slot = np.nonzero(row == n)[0]
        if len(slot):
            nbrs[parent, slot[0]] = i
        else:
            nbrs[parent, r - 1] = i
        seen[i] = True
    return nbrs
