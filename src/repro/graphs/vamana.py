"""Vamana graph construction (DiskANN, Jayaram Subramanya et al. 2019).

Batched adaptation: the sequential insert loop of the reference C++ becomes
rounds of (a) batched beam searches from the medoid to collect candidate
sets, (b) batched RobustPrune, (c) a reverse-edge pass with re-prune. Stale
reads within a batch are benign (the C++ multi-threaded builder has the same
property).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.graphs.adjacency import Graph, find_medoid
from repro.graphs.prune import prune_from_vectors
from repro.kernels.ops import pad_sentinel_row as _pad_vectors
from repro.search.beam import beam_search, make_exact_dist_fn


def build_vamana(key: jax.Array, x: jax.Array, *, r: int = 32, l: int = 64,
                 alpha: float = 1.2, passes: int = 2, batch: int = 1024,
                 verbose: bool = False) -> Graph:
    """Build a Vamana PG over x (N, D). Returns a padded-adjacency Graph."""
    n, d = x.shape
    x = jnp.asarray(x, jnp.float32)
    xp = _pad_vectors(x)
    dist_fn = make_exact_dist_fn(xp)
    medoid = find_medoid(x)

    key, kinit = jax.random.split(key)
    nbrs = np.array(
        jax.random.randint(kinit, (n, r), 0, n, jnp.int32))  # writable copy
    self_loop = nbrs == np.arange(n)[:, None]
    nbrs[self_loop] = (nbrs[self_loop] + 1) % n

    n_pad = (-n) % batch
    for p in range(passes):
        a = 1.0 if p == 0 else alpha
        key, kperm = jax.random.split(key)
        order = np.asarray(jax.random.permutation(kperm, n))
        order = np.concatenate([order, order[: n_pad]])
        for s in range(0, len(order), batch):
            ids = order[s:s + batch]
            g = jnp.asarray(nbrs)
            res = beam_search(g, medoid, x[ids], dist_fn, h=l, max_steps=4 * l)
            cand = jnp.concatenate([res.ids, g[ids]], axis=1)       # (B, L+R)
            cand = jnp.where(cand == jnp.asarray(ids)[:, None], n, cand)
            pruned = prune_from_vectors(xp, jnp.asarray(ids), cand, a, r, n)
            nbrs[ids] = np.asarray(pruned)
        # reverse-edge pass: j gains candidate i for every edge i→j
        nbrs = _reverse_pass(xp, nbrs, a, r, batch)
        if verbose:
            deg = (nbrs < n).sum(1)
            print(f"[vamana] pass {p}: mean degree {deg.mean():.1f}")

    return Graph(neighbors=jnp.asarray(nbrs), medoid=medoid)


def _reverse_pass(xp: jax.Array, nbrs: np.ndarray, alpha: float, r: int,
                  batch: int) -> np.ndarray:
    n = nbrs.shape[0]
    src = np.repeat(np.arange(n, dtype=np.int32), r)
    dst = nbrs.reshape(-1)
    keep = dst < n
    src, dst = src[keep], dst[keep]
    # group reverse candidates by destination, cap r per node
    order = np.argsort(dst, kind="stable")
    dst_s, src_s = dst[order], src[order]
    starts = np.searchsorted(dst_s, np.arange(n))
    ends = np.searchsorted(dst_s, np.arange(n) + 1)
    rev = np.full((n, r), n, np.int32)
    cnt = np.minimum(ends - starts, r)
    for i in range(n):  # cheap: pure indexing, no distance math
        if cnt[i]:
            rev[i, : cnt[i]] = src_s[starts[i]: starts[i] + cnt[i]]
    # re-prune nodes whose candidate set grew
    grew = np.nonzero(cnt > 0)[0].astype(np.int32)
    n_pad = (-len(grew)) % batch
    grew_p = np.concatenate([grew, grew[: n_pad]]) if len(grew) else grew
    for s in range(0, len(grew_p), batch):
        ids = grew_p[s:s + batch]
        cand = np.concatenate([nbrs[ids], rev[ids]], axis=1)
        cand[cand == ids[:, None]] = n
        pruned = prune_from_vectors(xp, jnp.asarray(ids), jnp.asarray(cand),
                                    alpha, r, n)
        nbrs[ids] = np.asarray(pruned)
    return nbrs
