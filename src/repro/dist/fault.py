"""Fault tolerance: failure injection, supervised restart, partial merge.

The serving-side counterpart to checkpoint/restore: a scatter-gather query
fans out to row shards; :func:`partial_merge` recombines whatever shard
shortlists actually arrived, so a dead or straggling shard degrades recall
(its rows simply go missing from the merged top-k) instead of failing the
query. The training-side counterpart is :func:`supervise`, which restarts a
crashed driver up to ``max_restarts`` times — paired with the fold_in(step)
RNG discipline in core/trainer.fit, a restart replays the exact key
sequence of the uninterrupted run.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np


class InjectedFailure(RuntimeError):
    """A deliberately injected crash (fault-tolerance drills)."""


@dataclasses.dataclass
class FailureInjector:
    """Raises :class:`InjectedFailure` when training reaches a given step.

    Drivers construct one per attempt; a restarted (i.e. replaced) node is
    built with ``fail_at_step=None`` so it does not re-crash at the same
    step (see launch/train.py).
    """

    fail_at_step: Optional[int] = None

    def maybe_fail(self, step: int) -> None:
        if self.fail_at_step is not None and step == self.fail_at_step:
            raise InjectedFailure(f"injected failure at step {step}")


def supervise(run: Callable[[], object], max_restarts: int = 0,
              on_restart: Optional[Callable[[int, BaseException], None]] = None,
              retry_on: tuple = (InjectedFailure,)):
    """Run ``run()`` under a restart supervisor.

    Returns ``(result, n_restarts)``. Only exceptions in ``retry_on`` are
    retried (default: injected failures — a genuine bug should crash loudly,
    not loop); anything else, or exhausting ``max_restarts``, propagates.
    """
    restarts = 0
    while True:
        try:
            return run(), restarts
        except retry_on as e:  # noqa: PERF203 - restart loop is cold
            restarts += 1
            if restarts > max_restarts:
                raise
            if on_restart is not None:
                on_restart(restarts, e)


def partial_merge(ids: Sequence, dists: Sequence, alive: Sequence[bool],
                  k: int):
    """Straggler-tolerant top-k merge of per-shard shortlists.

    Args:
      ids:   per-shard (Q, k_s) int arrays of GLOBAL candidate ids.
      dists: per-shard (Q, k_s) float distances (ascending = better).
      alive: per-shard liveness flags; dead shards are skipped entirely.
      k:     merged shortlist size.

    Returns:
      (ids (Q, k) int32, dists (Q, k) float32) merged by ascending distance.
      Rows are padded with (-1, +inf) if the surviving shards contribute
      fewer than ``k`` candidates. Raises ``RuntimeError`` when no shard is
      alive — an empty answer is an error, a partial answer is not.
    """
    live = [(np.asarray(i), np.asarray(d))
            for i, d, a in zip(ids, dists, alive) if a]
    if not live:
        raise RuntimeError("partial_merge: all shards dead/unreachable")
    cat_i = np.concatenate([i for i, _ in live], axis=1)
    cat_d = np.concatenate([d for _, d in live], axis=1).astype(np.float32)
    if cat_i.shape[1] < k:  # pad so top-k below is well-defined
        pad = k - cat_i.shape[1]
        cat_i = np.pad(cat_i, ((0, 0), (0, pad)), constant_values=-1)
        cat_d = np.pad(cat_d, ((0, 0), (0, pad)), constant_values=np.inf)
    order = np.argsort(cat_d, axis=1, kind="stable")[:, :k]
    return (np.take_along_axis(cat_i, order, axis=1).astype(np.int32),
            np.take_along_axis(cat_d, order, axis=1))
