"""Fault tolerance: failure injection, supervised restart, partial merge,
quorum resolution, and the seeded chaos plan.

The serving-side counterpart to checkpoint/restore: a scatter-gather query
fans out to row shards; :func:`partial_merge` recombines whatever shard
shortlists actually arrived, so a dead or straggling shard degrades recall
(its rows simply go missing from the merged top-k) instead of failing the
query. :func:`resolve_quorum` decides *which* shards count as arrived under
a straggler deadline — serve when ≥Q of S respond in time, charging the
stragglers as dead through the same merge path. The training-side
counterpart is :func:`supervise`, which restarts a crashed driver up to
``max_restarts`` times with exponential backoff + seeded jitter
(:mod:`repro.dist.retry`) — paired with the fold_in(step) RNG discipline in
core/trainer.fit, a restart replays the exact key sequence of the
uninterrupted run.

:class:`ChaosPlan` (DESIGN.md §13) is the seeded fault script the
resilience drills run against: dead shards, stragglers, transient I/O
errors, corrupted snapshot bytes, and crashes mid-consolidate/mid-refresh,
all reproducible from one seed, parseable from a ``serve.py --chaos`` spec
string.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, NamedTuple, Optional, Sequence

import numpy as np

from repro.dist.retry import (RetryPolicy, TransientIOError,
                              backoff_schedule)


class InjectedFailure(RuntimeError):
    """A deliberately injected crash (fault-tolerance drills)."""


@dataclasses.dataclass
class FailureInjector:
    """Raises :class:`InjectedFailure` when training reaches a given step.

    Drivers construct one per attempt; a restarted (i.e. replaced) node is
    built with ``fail_at_step=None`` so it does not re-crash at the same
    step (see launch/train.py).
    """

    fail_at_step: Optional[int] = None

    def maybe_fail(self, step: int) -> None:
        if self.fail_at_step is not None and step == self.fail_at_step:
            raise InjectedFailure(f"injected failure at step {step}")


# Restart backoff used by supervise() when the caller passes none: fast
# first retry (a restart already costs a re-init), exponential after, ±10%
# seeded jitter so a gang of restarting workers doesn't stampede in sync.
DEFAULT_RESTART_BACKOFF = RetryPolicy(max_attempts=2, base_delay_s=0.01,
                                      multiplier=2.0, max_delay_s=2.0,
                                      jitter=0.1)


def supervise(run: Callable[[], object], max_restarts: int = 0,
              on_restart: Optional[Callable[[int, BaseException], None]] = None,
              retry_on: tuple = (InjectedFailure,),
              backoff: Optional[RetryPolicy] = DEFAULT_RESTART_BACKOFF,
              seed: int = 0,
              sleep: Callable[[float], None] = time.sleep):
    """Run ``run()`` under a restart supervisor.

    Returns ``(result, n_restarts)``. Only exceptions in ``retry_on`` are
    retried (default: injected failures — a genuine bug should crash loudly,
    not loop); anything else, or exhausting ``max_restarts``, propagates.

    Restart r (1-indexed) sleeps ``backoff``'s r-th backoff delay first —
    exponential with seeded jitter, so crash loops don't hot-spin and
    the schedule replays deterministically from ``seed``. ``backoff=None``
    restarts immediately (the pre-§13 behavior); ``sleep`` is injectable
    for tests.
    """
    delays: list = []
    if backoff is not None and max_restarts > 0:
        delays = backoff_schedule(
            dataclasses.replace(backoff, max_attempts=max_restarts + 1),
            seed=seed if backoff.jitter else None)
    restarts = 0
    while True:
        try:
            return run(), restarts
        except retry_on as e:  # noqa: PERF203 - restart loop is cold
            restarts += 1
            if restarts > max_restarts:
                raise
            if on_restart is not None:
                on_restart(restarts, e)
            if delays:
                sleep(delays[restarts - 1])


class MergedTopK(NamedTuple):
    ids: np.ndarray     # (Q, k) int32 global ids, -1 padding
    dists: np.ndarray   # (Q, k) float32 ascending, +inf padding
    # True whenever any shard was dead/dropped — the answer may be missing
    # rows it would have had. All-dead yields full sentinel rows, NOT an
    # exception: under a deadline the serving layer must always answer.
    degraded: bool = False


def partial_merge(ids: Sequence, dists: Sequence, alive: Sequence[bool],
                  k: int) -> MergedTopK:
    """Straggler-tolerant top-k merge of per-shard shortlists.

    Args:
      ids:   per-shard (Q, k_s) int arrays of GLOBAL candidate ids.
      dists: per-shard (Q, k_s) float distances (ascending = better).
      alive: per-shard liveness flags; dead shards are skipped entirely.
      k:     merged shortlist size.

    Returns:
      ``MergedTopK(ids (Q, k) int32, dists (Q, k) f32, degraded)`` merged by
      ascending distance. Rows are padded with (-1, +inf) if the surviving
      shards contribute fewer than ``k`` candidates; ``degraded`` is True
      whenever any shard was dead. When NO shard is alive the merge still
      answers — all-sentinel rows with ``degraded=True`` — because a
      deadline-bound server must return *something* honest rather than
      throw (the caller sees -1 ids exactly like over-padded rows).
    """
    live = [(np.asarray(i), np.asarray(d))
            for i, d, a in zip(ids, dists, alive) if a]
    degraded = len(live) < len(list(alive))
    if not live:
        q = np.asarray(ids[0]).shape[0] if len(list(ids)) else 0
        return MergedTopK(np.full((q, k), -1, np.int32),
                          np.full((q, k), np.inf, np.float32), True)
    cat_i = np.concatenate([i for i, _ in live], axis=1)
    cat_d = np.concatenate([d for _, d in live], axis=1).astype(np.float32)
    if cat_i.shape[1] < k:  # pad so top-k below is well-defined
        pad = k - cat_i.shape[1]
        cat_i = np.pad(cat_i, ((0, 0), (0, pad)), constant_values=-1)
        cat_d = np.pad(cat_d, ((0, 0), (0, pad)), constant_values=np.inf)
    order = np.argsort(cat_d, axis=1, kind="stable")[:, :k]
    return MergedTopK(np.take_along_axis(cat_i, order, axis=1).astype(np.int32),
                      np.take_along_axis(cat_d, order, axis=1), degraded)


class QuorumDecision(NamedTuple):
    alive: list          # per-shard: counts toward the merge this query
    waited_s: float      # modeled gather wall time (slowest counted shard)
    degraded: bool       # any healthy shard charged dead (straggler or down)


def resolve_quorum(alive: Sequence[bool],
                   latency_s: Optional[Sequence[float]] = None,
                   deadline_s: Optional[float] = None,
                   quorum: Optional[int] = None) -> QuorumDecision:
    """Decide which shards count toward a merge under a straggler deadline.

    Serve when ≥Q of S shards respond within ``deadline_s``: shards over
    the deadline are charged as dead (their rows go missing — the existing
    :func:`partial_merge` degradation path). If fewer than Q make the
    deadline, wait for the fastest Q alive shards instead — quorum outranks
    the deadline, because an answer from too few shards is worse than a
    late one. ``quorum=None`` defaults to a majority of the alive shards.
    Pure host logic (latencies are modeled, e.g. from a chaos plan), so the
    policy is unit-testable at S=1 without any multi-device mesh.
    """
    alive = [bool(a) for a in alive]
    n_alive = sum(alive)
    if quorum is None:
        quorum = max(1, (n_alive + 1) // 2)
    if n_alive == 0:
        return QuorumDecision(alive, 0.0, True)
    if deadline_s is None or latency_s is None:
        return QuorumDecision(alive, 0.0, n_alive < len(alive))
    lat = np.asarray(latency_s, np.float64)
    within = [a and lat[i] <= deadline_s for i, a in enumerate(alive)]
    if sum(within) < quorum:
        # deadline leaves us under quorum: take the fastest Q alive shards
        order = sorted((i for i, a in enumerate(alive) if a),
                       key=lambda i: lat[i])[:min(quorum, n_alive)]
        within = [i in set(order) for i in range(len(alive))]
    waited = max((float(lat[i]) for i, w in enumerate(within) if w),
                 default=0.0)
    degraded = sum(within) < len(alive)
    return QuorumDecision(within, waited, degraded)


# --------------------------------------------------------------------------
# Chaos plan — the seeded fault script for resilience drills (DESIGN.md §13)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChaosPlan:
    """One seeded, declarative fault script.

    Spec string grammar (``serve.py --chaos``, comma/semicolon-separated):

    ``dead=0+2`` dead shard indices · ``straggler=1`` straggler indices ·
    ``straggler_ms=50`` straggler latency · ``latency_ms=2`` healthy-shard
    latency · ``io=0.05`` transient-read failure probability ·
    ``corrupt`` flip a byte in the latest snapshot ·
    ``corrupt_record`` flip a byte in a storage-segment record (silent —
    the header stays intact; only a data audit or recall drill sees it) ·
    ``slow_read=5`` per-read-batch storage latency in ms (a REAL sleep in
    the segment reader's workers — overlappable wall-clock) ·
    ``crash=consolidate|refresh`` injected crash phase · ``seed=7``.

    Everything downstream (jitter, fault draws, corrupted byte choice) is a
    pure function of ``seed``, so a drill and its assertions replay exactly.
    """

    seed: int = 0
    dead_shards: tuple = ()
    straggler_shards: tuple = ()
    straggler_latency_s: float = 0.050
    shard_latency_s: float = 0.002
    io_fault_p: float = 0.0
    corrupt_latest_snapshot: bool = False
    crash_phase: Optional[str] = None   # "consolidate" | "refresh"
    corrupt_record: bool = False        # storage tier: silent record flip
    slow_read_ms: float = 0.0           # storage tier: per-batch latency

    @classmethod
    def parse(cls, spec: str) -> "ChaosPlan":
        kw: dict = {}
        for tok in spec.replace(";", ",").split(","):
            tok = tok.strip()
            if not tok:
                continue
            key, _, val = tok.partition("=")
            if key == "dead":
                kw["dead_shards"] = tuple(int(v) for v in val.split("+"))
            elif key == "straggler":
                kw["straggler_shards"] = tuple(int(v) for v in val.split("+"))
            elif key == "straggler_ms":
                kw["straggler_latency_s"] = float(val) / 1e3
            elif key == "latency_ms":
                kw["shard_latency_s"] = float(val) / 1e3
            elif key == "io":
                kw["io_fault_p"] = float(val)
            elif key == "corrupt":
                kw["corrupt_latest_snapshot"] = True
            elif key == "corrupt_record":
                kw["corrupt_record"] = True
            elif key == "slow_read":
                kw["slow_read_ms"] = float(val)
            elif key == "crash":
                if val not in ("consolidate", "refresh"):
                    raise ValueError(f"--chaos: unknown crash phase {val!r}")
                kw["crash_phase"] = val
            elif key == "seed":
                kw["seed"] = int(val)
            else:
                raise ValueError(f"--chaos: unknown token {tok!r}")
        return cls(**kw)

    def alive(self, n_shards: int) -> list:
        """Per-shard liveness under this plan (dead shards are down)."""
        return [i not in set(self.dead_shards) for i in range(n_shards)]

    def latencies(self, n_shards: int) -> np.ndarray:
        """Modeled per-shard response latency: base, stragglers slower."""
        lat = np.full((n_shards,), self.shard_latency_s, np.float64)
        for i in self.straggler_shards:
            if i < n_shards:
                lat[i] = self.straggler_latency_s
        return lat

    def io_fault(self) -> Optional[Callable[[str], None]]:
        """Hook for checkpoint reads: raises TransientIOError with
        probability ``io_fault_p`` per call, seeded (install via
        ``checkpoint.set_io_fault_hook``)."""
        if self.io_fault_p <= 0.0:
            return None
        rng = np.random.default_rng(self.seed)

        def hook(path: str) -> None:
            if rng.random() < self.io_fault_p:
                raise TransientIOError(f"injected transient read fault: "
                                       f"{path}")
        return hook

    def consolidate_hook(self) -> Optional[Callable[[str], None]]:
        """Phase hook for ``index.consolidate(..., chaos=)``.

        ``crash=refresh`` raises at ``pre_snapshot`` (mid-refresh — nothing
        new is durable, the previous generation restores); ``consolidate``
        raises at ``post_snapshot`` (snapshot written, in-memory swap not
        reached — the classic crash-consistency window: EITHER generation
        restores intact).
        """
        if self.crash_phase is None:
            return None
        phase_at = ("pre_snapshot" if self.crash_phase == "refresh"
                    else "post_snapshot")

        def hook(phase: str) -> None:
            if phase == phase_at:
                raise InjectedFailure(
                    f"injected crash at {phase} (chaos crash="
                    f"{self.crash_phase})")
        return hook


def corrupt_snapshot(ckpt_dir: str, step: Optional[int] = None, *,
                     seed: int = 0) -> int:
    """Silently flip one byte inside a snapshot's array payload.

    Rewrites the ``.npz`` with the flipped array so the zip container's own
    CRC is CONSISTENT with the corrupt bytes — only the manifest-level
    CRC32 (``checkpoint.ChecksumError``) can catch it. A raw on-disk byte
    flip would be caught by ``zipfile`` first, which exercises the wrong
    layer: real silent corruption (bad DMA, bitrot past the container
    checksum, a buggy transform) presents exactly like this. Returns the
    corrupted step.
    """
    from repro.dist import checkpoint as ckpt

    if step is None:
        step = ckpt.latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir!r}")
    sdir = ckpt._step_dir(ckpt_dir, step)
    rng = np.random.default_rng(seed)
    npzs = sorted(f for f in os.listdir(sdir) if f.endswith(".npz"))
    if not npzs:
        raise FileNotFoundError(f"no array payloads under {sdir!r}")
    path = os.path.join(sdir, npzs[int(rng.integers(len(npzs)))])
    with np.load(path) as z:
        arrays = {k: z[k].copy() for k in z.files}
    # flip a byte in the largest array — the one a restore can least
    # afford to trust blindly
    name = max(arrays, key=lambda k: arrays[k].nbytes)
    buf = arrays[name].view(np.uint8).reshape(-1)
    i = int(rng.integers(buf.shape[0]))
    buf[i] ^= np.uint8(0xFF)
    np.savez(path, **arrays)
    return int(step)
