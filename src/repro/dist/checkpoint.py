"""Atomic, elastic, rotating checkpoints for arbitrary jax pytrees.

Layout: ``<dir>/step_00000123/`` holds, per saved tree, a ``<name>.json``
structure file and a ``<name>.npz`` of raw leaf buffers, plus ``_meta.json``.

* **atomic** — everything is written into a ``.tmp-*`` staging directory and
  ``os.replace``-renamed into place; a crash mid-save can never leave a
  half-written step visible to ``latest_step`` (readers either see the old
  complete step or the new complete step).
* **elastic** — leaves are stored as device-count-agnostic host buffers
  (raw bytes + dtype + shape), so a checkpoint written under 1 device
  restores bit-exactly under any mesh; callers re-shard with
  ``dist.sharding`` after restore.
* **rotating** — ``save(..., keep=N)`` prunes all but the newest N steps.

Non-array leaves (str/int/float/bool/None) round-trip through the JSON
structure file, so ``extra={"dataset": ..., "m": 8}`` metadata needs no
special casing. NamedTuple nodes restore as plain field dicts unless a
``like`` template supplies the concrete type.

* **verified** — every array node in the ``.json`` manifest carries the
  CRC32 of its raw bytes, checked on decode (DESIGN.md §13). The zip
  container has its own CRC, but it only covers the *container*: corruption
  introduced before ``save`` rewrote the zip (bad DMA, a buggy transform,
  bitrot on a re-packed copy) passes it — the manifest checksum is the
  end-to-end one. A mismatch raises :class:`ChecksumError`, which callers
  like ``index.segment.load_segment`` turn into generation fallback.
  Checkpoints written before this field simply skip the check.
"""

from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Any, Callable, NamedTuple, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.dist import retry as _retry

_STEP_PREFIX = "step_"


class ChecksumError(ValueError):
    """An array's bytes don't match the CRC32 its manifest recorded."""


class Dropped(NamedTuple):
    """Placeholder for an array skipped via ``restore(drop=...)``.

    Carries the manifest's shape/dtype so callers can size things (e.g.
    ``index.segment.load_segment(with_vectors=False)`` still knows D)
    without the bytes ever being read — npz members load lazily per key,
    so a dropped leaf costs zero I/O and zero DRAM.
    """

    shape: tuple
    dtype: str


# Chaos seam (DESIGN.md §13): drills install a hook that may raise
# TransientIOError before any step-directory read; `restore(retry=...)`
# wraps the read, so the retry path is exercised without monkeypatching
# the filesystem. None in production.
_IO_FAULT_HOOK: Optional[Callable[[str], None]] = None


def set_io_fault_hook(hook: Optional[Callable[[str], None]]) -> None:
    global _IO_FAULT_HOOK
    _IO_FAULT_HOOK = hook


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"{_STEP_PREFIX}{step:08d}")


def _is_array(obj) -> bool:
    return isinstance(obj, (np.ndarray, np.generic)) or (
        hasattr(obj, "shape") and hasattr(obj, "dtype")
        and hasattr(obj, "__array__"))


def _resolve_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _encode(obj, arrays: list) -> Any:
    if _is_array(obj):
        a = np.asarray(obj)
        raw = a.tobytes()
        arrays.append(np.frombuffer(raw, np.uint8))
        return {"kind": "array", "i": len(arrays) - 1,
                "dtype": str(a.dtype), "shape": list(a.shape),
                "crc32": zlib.crc32(raw)}
    if isinstance(obj, tuple) and hasattr(obj, "_fields"):  # NamedTuple
        return {"kind": "namedtuple", "name": type(obj).__name__,
                "fields": {f: _encode(getattr(obj, f), arrays)
                           for f in obj._fields}}
    if isinstance(obj, dict):
        return {"kind": "dict",
                "items": {str(k): _encode(v, arrays) for k, v in obj.items()}}
    if isinstance(obj, (list, tuple)):
        return {"kind": "list" if isinstance(obj, list) else "tuple",
                "items": [_encode(v, arrays) for v in obj]}
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return {"kind": "value", "v": obj}
    raise TypeError(f"checkpoint: cannot serialize leaf of type {type(obj)}")


def _decode(node, arrays, path: str = "", drop=()) -> Any:
    kind = node["kind"]
    if kind == "array":
        if path in drop:
            return Dropped(shape=tuple(node["shape"]),
                           dtype=str(node["dtype"]))
        buf = arrays[f"a{node['i']}"]
        raw = buf.tobytes()
        want = node.get("crc32")   # absent in pre-§13 checkpoints
        if want is not None:
            got = zlib.crc32(raw)
            if got != want:
                raise ChecksumError(
                    f"checkpoint array a{node['i']} "
                    f"(dtype={node['dtype']}, shape={node['shape']}): "
                    f"crc32 {got:#010x} != manifest {want:#010x} — "
                    "snapshot bytes are corrupt")
        a = np.frombuffer(raw, _resolve_dtype(node["dtype"]))
        return jnp.asarray(a.reshape(node["shape"]))
    if kind == "namedtuple":
        return {f: _decode(v, arrays, f"{path}/{f}", drop)
                for f, v in node["fields"].items()}
    if kind == "dict":
        return {k: _decode(v, arrays, f"{path}/{k}", drop)
                for k, v in node["items"].items()}
    if kind in ("list", "tuple"):
        seq = [_decode(v, arrays, f"{path}/{i}", drop)
               for i, v in enumerate(node["items"])]
        return seq if kind == "list" else tuple(seq)
    return node["v"]


def _restore_like(like, decoded) -> Any:
    """Re-impose ``like``'s container types (NamedTuples etc.) on a decoded
    tree; leaf VALUES always come from the checkpoint."""
    if like is None or _is_array(decoded) or not isinstance(
            decoded, (dict, list, tuple)):
        return decoded
    if isinstance(like, tuple) and hasattr(like, "_fields"):
        fields = (decoded["fields"] if isinstance(decoded, dict)
                  and "fields" in decoded else decoded)
        return type(like)(**{f: _restore_like(getattr(like, f), fields[f])
                             for f in like._fields})
    if isinstance(like, dict) and isinstance(decoded, dict):
        return {k: _restore_like(like[k], v) if k in like else v
                for k, v in decoded.items()}
    if isinstance(like, (list, tuple)) and isinstance(decoded, (list, tuple)):
        out = [_restore_like(l, d) for l, d in zip(like, decoded)]
        return type(like)(out) if isinstance(like, list) else tuple(out)
    return decoded


def save(directory: str, step: int, keep: Optional[int] = None,
         **trees) -> str:
    """Atomically write ``trees`` (params=..., opt=..., extra=...) at ``step``.

    Returns the final step directory. With ``keep=N``, prunes to the newest
    N steps afterwards.
    """
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f".tmp-{step:08d}-{os.getpid()}")
    if os.path.isdir(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    try:
        for name, tree in trees.items():
            arrays: list = []
            structure = _encode(tree, arrays)
            with open(os.path.join(tmp, f"{name}.json"), "w") as f:
                json.dump(structure, f)
            np.savez(os.path.join(tmp, f"{name}.npz"),
                     **{f"a{i}": a for i, a in enumerate(arrays)})
        with open(os.path.join(tmp, "_meta.json"), "w") as f:
            json.dump({"step": int(step), "trees": sorted(trees)}, f)
        final = _step_dir(directory, step)
        if os.path.isdir(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    finally:
        if os.path.isdir(tmp):
            shutil.rmtree(tmp)
    if keep is not None:
        for s in all_steps(directory)[:-keep]:
            shutil.rmtree(_step_dir(directory, s))
    return _step_dir(directory, step)


def all_steps(directory: str) -> list[int]:
    """Sorted list of complete checkpoint steps under ``directory``."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for d in os.listdir(directory):
        if d.startswith(_STEP_PREFIX) and os.path.isfile(
                os.path.join(directory, d, "_meta.json")):
            try:
                steps.append(int(d[len(_STEP_PREFIX):]))
            except ValueError:
                continue
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: Optional[int] = None,
            like: Optional[dict] = None,
            retry: Optional[_retry.RetryPolicy] = None,
            drop=()) -> dict:
    """Load a checkpoint: ``{"step": s, "<name>": tree, ...}``.

    ``step=None`` loads the latest; no checkpoints at all raises a clear
    ``FileNotFoundError("no checkpoints under <dir>")``, and an explicit
    ``step`` that doesn't exist raises one naming the steps that do. Every
    array is CRC32-verified against its manifest (:class:`ChecksumError`
    on mismatch — deterministic corruption, never retried).

    ``like={"<name>": template}`` re-imposes the template's container types
    (e.g. NamedTuple params / OptState) on the named trees; array values
    always come from the checkpoint and are returned as host-replicated
    ``jnp`` arrays, restorable under any device count (re-shard with
    dist.sharding afterwards).

    ``retry`` (a :class:`repro.dist.retry.RetryPolicy`) retries TRANSIENT
    read failures — ``TransientIOError`` (chaos-injected) and ``OSError``
    races on live directories — with exponential backoff, seeded by the
    step number so drills replay.

    ``drop`` names array leaves to SKIP materializing, as slash paths
    rooted at the tree name (``drop={"index/vectors"}``). A dropped leaf
    comes back as a :class:`Dropped` (shape, dtype) sentinel and its
    bytes are never read from the npz — the restore path for serving
    tiers that don't want N×D float vectors in DRAM.
    """
    steps = all_steps(directory)
    if step is None:
        if not steps:
            raise FileNotFoundError(f"no checkpoints under {directory!r}")
        step = steps[-1]
    elif step not in steps:
        raise FileNotFoundError(
            f"no checkpoint for step {step} under {directory!r} "
            f"(available: {steps if steps else 'none'})")
    sdir = _step_dir(directory, step)

    def _read() -> dict:
        if _IO_FAULT_HOOK is not None:
            _IO_FAULT_HOOK(sdir)
        with open(os.path.join(sdir, "_meta.json")) as f:
            meta = json.load(f)
        out: dict = {"step": meta["step"]}
        for name in meta["trees"]:
            with open(os.path.join(sdir, f"{name}.json")) as f:
                structure = json.load(f)
            with np.load(os.path.join(sdir, f"{name}.npz")) as arrays:
                decoded = _decode(structure, arrays, name, frozenset(drop))
            if like is not None and name in like:
                decoded = _restore_like(like[name], decoded)
            out[name] = decoded
        return out

    if retry is None:
        return _read()
    out, _ = _retry.call_with_retry(
        _read, policy=retry,
        retry_on=(_retry.TransientIOError, OSError), seed=int(step))
    return out
