"""Int8 gradient compression with error feedback (EF-SGD style).

Data-parallel training all-reduces a gradient pytree every step; for the
small RPQ quantizer that is cheap, but the same trainer drives the arch-zoo
models where the all-reduce is the bill. Each leaf is quantized to int8
with a single per-leaf scale (max-abs / 127); the quantization residual is
carried in a per-device error-feedback state and added back before the next
step's quantization, so the *accumulated* compressed gradient stays within
one quantization step of the true sum (the EF telescoping argument —
Karimireddy et al. 2019) instead of drifting by O(steps).

The (q, scale) pair is what would travel on the wire: 4 bytes/element →
1 byte + one f32 scale per leaf, a 4× collective-traffic cut.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_state(tree):
    """Zero error-feedback residuals, one f32 buffer per gradient leaf."""
    return jax.tree.map(lambda g: jnp.zeros(jnp.shape(g), jnp.float32), tree)


def quantize_leaf(g: jax.Array, err: jax.Array):
    """Quantize one leaf (with its EF residual folded in).

    Returns ``(q int8, scale f32 scalar, new_err f32)`` where
    ``dequantize_leaf(q, scale) + new_err == g + err`` exactly.
    """
    corrected = g.astype(jnp.float32) + err
    amax = jnp.max(jnp.abs(corrected))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
    new_err = corrected - q.astype(jnp.float32) * scale
    return q, scale, new_err


def dequantize_leaf(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(tree, state):
    """Compress a gradient pytree under error feedback.

    Returns ``((q_tree, scale_tree), new_state)`` — the pair mirrors the
    original tree structure and is what :func:`decompress_tree` consumes.
    """
    leaves, treedef = jax.tree.flatten(tree)
    err_leaves = treedef.flatten_up_to(state)
    out = [quantize_leaf(g, e) for g, e in zip(leaves, err_leaves)]
    q_tree = treedef.unflatten([o[0] for o in out])
    s_tree = treedef.unflatten([o[1] for o in out])
    new_state = treedef.unflatten([o[2] for o in out])
    return (q_tree, s_tree), new_state


def decompress_tree(compressed):
    q_tree, s_tree = compressed
    return jax.tree.map(dequantize_leaf, q_tree, s_tree)
