"""Retry with exponential backoff + seeded jitter, deadline-aware.

The one retry vocabulary for the whole repo (DESIGN.md §13): supervised
restart (`fault.supervise`), checkpoint reads (`checkpoint.restore`,
`index.segment.load_segment`), and the modeled DiskANN-hybrid I/O path
(`HybridEngine.io_time`) all share this module, so backoff behavior is
decided — and tested — in exactly one place.

Design points:

* **Seeded jitter.** `backoff_schedule(policy, seed=s)` is a pure function
  of (policy, seed): the same plan replays the same delays, so chaos drills
  (`fault.ChaosPlan`) and their assertions are deterministic. `seed=None`
  returns the nominal (un-jittered) schedule — what expectation models
  (`HybridEngine.io_time`) integrate over.
* **Deadline-aware attempt caps.** A `deadline_s` bounds *total* time spent
  (attempt latencies are the caller's; sleeps are ours): `call_with_retry`
  never starts a sleep that would cross the deadline — it re-raises the
  last error instead, so a caller with a 50 ms budget is never parked in a
  500 ms backoff.
* **Injectable clocks.** `sleep=`/`clock=` default to the real thing and are
  injectable for tests — the schedule is unit-tested with a fake sleep, no
  wall-clock flakiness.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence

import numpy as np


class TransientIOError(OSError):
    """A read/fetch failure worth retrying (injected by chaos drills)."""


class DeadlineExceeded(TimeoutError):
    """Retries stopped because the deadline left no room for another try."""


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff: delay_i = base · multiplier^i, capped, jittered.

    ``jitter`` is the symmetric relative amplitude: each delay is scaled by
    a seeded uniform draw from [1 - jitter, 1 + jitter] (full jitter would
    synchronize-at-zero; symmetric keeps the expectation at the nominal
    delay, which the I/O model relies on). ``deadline_s`` bounds the total
    time budget across all attempts (None = unbounded).
    """

    max_attempts: int = 3
    base_delay_s: float = 0.01
    multiplier: float = 2.0
    max_delay_s: float = 1.0
    jitter: float = 0.1
    deadline_s: Optional[float] = None

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("RetryPolicy: max_attempts must be >= 1")
        if not 0.0 <= self.jitter < 1.0:
            raise ValueError("RetryPolicy: jitter must be in [0, 1)")


def backoff_schedule(policy: RetryPolicy,
                     seed: Optional[int] = None) -> list:
    """The sleep before retry attempt i+1, for i in [0, max_attempts-1).

    Deterministic in (policy, seed); ``seed=None`` gives the nominal
    un-jittered exponential.
    """
    nominal = [min(policy.base_delay_s * policy.multiplier ** i,
                   policy.max_delay_s)
               for i in range(policy.max_attempts - 1)]
    if seed is None or policy.jitter == 0.0:
        return nominal
    rng = np.random.default_rng(seed)
    lo, hi = 1.0 - policy.jitter, 1.0 + policy.jitter
    return [d * float(rng.uniform(lo, hi)) for d in nominal]


def call_with_retry(fn: Callable[[], object], *,
                    policy: RetryPolicy,
                    retry_on: Sequence[type] = (TransientIOError,),
                    seed: Optional[int] = None,
                    sleep: Callable[[float], None] = time.sleep,
                    clock: Callable[[], float] = time.monotonic,
                    on_retry: Optional[Callable[[int, BaseException],
                                                None]] = None):
    """Call ``fn()`` with up to ``policy.max_attempts`` tries.

    Only exceptions matching ``retry_on`` are retried; anything else
    propagates immediately (a genuine bug should crash loudly, not loop).
    When a ``policy.deadline_s`` is set and the next backoff sleep would
    cross it, raises :class:`DeadlineExceeded` chained from the last error.
    Returns ``(result, n_retries)``.
    """
    retry_on = tuple(retry_on)
    delays = backoff_schedule(policy, seed)
    t0 = clock()
    for attempt in range(policy.max_attempts):
        try:
            return fn(), attempt
        except retry_on as e:  # noqa: PERF203 - retry loop is cold
            if attempt >= policy.max_attempts - 1:
                raise
            delay = delays[attempt]
            if (policy.deadline_s is not None
                    and clock() - t0 + delay > policy.deadline_s):
                raise DeadlineExceeded(
                    f"retry deadline {policy.deadline_s}s would be exceeded "
                    f"by a {delay:.3f}s backoff after attempt "
                    f"{attempt + 1}/{policy.max_attempts}") from e
            if on_retry is not None:
                on_retry(attempt + 1, e)
            sleep(delay)
    raise AssertionError("unreachable")  # pragma: no cover


def expected_retry_time_s(policy: RetryPolicy, attempt_latency_s: float,
                          fail_p: float) -> float:
    """Expected total time of one retried call under i.i.d. failures.

    Attempt a (0-indexed) runs with probability fail_p^a (all previous
    attempts failed) and costs ``attempt_latency_s``; the backoff sleep
    before it is paid with the same probability. A call whose final attempt
    also fails is still charged its full time (the caller then degrades or
    errors — the time was spent either way). This closed form is what
    ``HybridEngine.io_time`` adds per modeled read: deterministic, no
    sampling, exact in expectation under the policy's nominal schedule.
    """
    delays = backoff_schedule(policy, seed=None)
    total = 0.0
    for a in range(policy.max_attempts):
        p_reach = fail_p ** a
        total += p_reach * attempt_latency_s
        if a >= 1:
            total += p_reach * delays[a - 1]
    return total
