"""Distribution substrate.

* :mod:`repro.dist.sharding`    — mesh-aware PartitionSpec rules for every
  arch family (LM, GNN, recsys, RPQ) + pytree sharding helpers.
* :mod:`repro.dist.checkpoint`  — atomic / elastic / rotating checkpoints.
* :mod:`repro.dist.compression` — int8 gradient compression with error
  feedback (communication-efficient data parallelism).
* :mod:`repro.dist.fault`       — failure injection, supervised restart,
  straggler-tolerant partial top-k merge + quorum resolution for
  scatter-gather serving, and the seeded ChaosPlan fault script.
* :mod:`repro.dist.retry`       — exponential backoff + seeded jitter,
  deadline-aware retry; the one retry vocabulary for the repo.
"""

from repro.dist import (checkpoint, compression, fault, retry,  # noqa: F401
                        sharding)
