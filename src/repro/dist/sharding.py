"""Mesh-aware sharding rules for every arch family (the GSPMD layer).

Axis semantics follow launch/mesh.py: ``pod`` (DCN data parallel), ``data``
(intra-pod data/FSDP), ``model`` (tensor/expert/table/row parallel). Every
rule is divisibility-guarded: a dim that does not divide its mesh axes is
left unsharded instead of tripping XLA's uneven-sharding paths, so the same
rule set serves the 16×16 pod, the 2×16×16 multi-pod, and a laptop's
(1, n) host mesh.

Rules are *path-keyed* (``"table"``, ``"wq"``, ``"embed"`` ...), which makes
them apply uniformly to parameter trees AND to optimizer states whose inner
slots mirror the parameter tree (common.optim.OptState embeds the param
paths, so Adam moments inherit their parameter's sharding — FSDP slots for
free).
"""

from __future__ import annotations

import re
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes

_LAST_KEY_RE = re.compile(r"[A-Za-z_][A-Za-z_0-9]*")


def named(mesh, pspec: P) -> NamedSharding:
    """The one constructor everybody shares: pspec → NamedSharding."""
    return NamedSharding(mesh, pspec)


def axis_size(mesh, axes) -> int:
    """Total device count across ``axes`` (str | tuple | None)."""
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fit(mesh, axes, dim: int):
    """``axes`` if they evenly divide ``dim`` (else None → replicate)."""
    if axes is None:
        return None
    return axes if dim % axis_size(mesh, axes) == 0 else None


def row_axes(mesh) -> tuple:
    """All mesh axes in canonical (pod, data, model) order — the maximal
    row-sharding for big flat tables / code arrays."""
    axes = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    return axes if axes else tuple(mesh.axis_names)


def flat_shard_index(mesh, axes: tuple):
    """Row-major linear shard index over ``axes`` — only meaningful inside
    shard_map. The ONE definition of shard ordering: the scatter-gather
    engine derives global row ids from it and the dp trainer folds it into
    per-replica RNG keys; both must agree with how jax lays out
    ``P(axes)``-sharded rows."""
    idx = jnp.zeros((), jnp.int32)
    for a in axes:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    return idx


def _leaf_name(path: str) -> str:
    keys = _LAST_KEY_RE.findall(path)
    return keys[-1] if keys else ""


# --------------------------------------------------------------------------
# Pytree helpers
# --------------------------------------------------------------------------

def tree_pspecs(tree: Any, rule: Callable[[str, Any], P]):
    """Map ``rule(path_str, leaf) -> PartitionSpec`` over a pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: rule(jax.tree_util.keystr(kp), leaf), tree)


def tree_shardings(mesh, tree: Any, fn: Callable[[str, Any], P]):
    """Like :func:`tree_pspecs` but returns NamedShardings on ``mesh``."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: NamedSharding(
            mesh, fn(jax.tree_util.keystr(kp), leaf)), tree)


# --------------------------------------------------------------------------
# LM family — Megatron TP over `model`, FSDP over `data`, batch over dp axes
# --------------------------------------------------------------------------

def lm_batch_spec(mesh) -> P:
    """(B, ...) token batches: batch dim over all data-parallel axes."""
    return P(data_axes(mesh))


def lm_param_rule(mesh) -> Callable[[str, Any], P]:
    """Path-keyed rule for stacked (L, ...) LM weights.

    Column-parallel (wq/wk/wv/w1/w3) shard their OUTPUT dim over `model`
    and their input dim over `data` (FSDP); row-parallel (wo/w2) the
    transpose. Embeddings shard the vocab over `model` (the tied head then
    produces model-sharded logits). MoE expert stacks shard experts over
    `model` (expert parallelism). Everything 1-D (norms, scalars)
    replicates. All subject to divisibility.
    """

    def rule(path: str, leaf) -> P:
        shape = getattr(leaf, "shape", ())
        if len(shape) <= 1:
            return P()
        name = _leaf_name(path)
        if "embed" in path:                     # (Vpad, D)
            return P(_fit(mesh, "model", shape[0]),
                     _fit(mesh, "data", shape[1]))
        if "lm_head" in path:                   # (D, Vpad)
            return P(_fit(mesh, "data", shape[0]),
                     _fit(mesh, "model", shape[1]))
        if "router" in path:                    # (L, D, E)
            return P(None, None, _fit(mesh, "model", shape[2]))
        if "moe" in path and len(shape) == 4:   # (L, E, din, dout)
            if name in ("w1", "w3"):
                return P(None, _fit(mesh, "model", shape[1]),
                         _fit(mesh, "data", shape[2]), None)
            return P(None, _fit(mesh, "model", shape[1]), None,
                     _fit(mesh, "data", shape[3]))
        if name in ("wq", "wk", "wv", "w1", "w3") and len(shape) == 3:
            return P(None, _fit(mesh, "data", shape[1]),
                     _fit(mesh, "model", shape[2]))
        if name in ("wo", "w2") and len(shape) == 3:
            return P(None, _fit(mesh, "model", shape[1]),
                     _fit(mesh, "data", shape[2]))
        return P()

    return rule


def lm_shardings(mesh, cfg, params_shape, opt_shape):
    """(param shardings, optimizer-state shardings) for one LM config.

    The same path-keyed rule covers both trees: OptState's inner slots embed
    the parameter paths, so Adam moments co-shard with their parameters.
    """
    del cfg  # rules are shape/path-driven; cfg reserved for future overrides
    rule = lm_param_rule(mesh)
    return (tree_shardings(mesh, params_shape, rule),
            tree_shardings(mesh, opt_shape, rule))


def lm_cache_spec(mesh, batch: int, seq_len: int) -> P:
    """(L, B, S, Hkv, dh) KV-cache spec.

    Batched decode/prefill shards B over the dp axes and S over `model`
    (the sharded-softmax layout of layers.gqa_attention); single-sequence
    long-context decode (B=1) shards S over EVERY axis instead — element
    [2] of the returned spec is what cells.py pins decode attention to.
    """
    dp = data_axes(mesh)
    if batch % max(axis_size(mesh, dp), 1) == 0 and batch > 1:
        return P(None, dp, _fit(mesh, "model", seq_len), None, None)
    all_ax = row_axes(mesh)
    seq = _fit(mesh, all_ax, seq_len) or _fit(mesh, "model", seq_len)
    return P(None, None, seq, None, None)


# --------------------------------------------------------------------------
# GNN family — edge lists row-sharded over every axis (degree parallelism)
# --------------------------------------------------------------------------

def gnn_edge_spec(mesh) -> P:
    """1-D (E,) src/dst/mask arrays, padded to a device-count multiple by
    the pipeline, sharded over all axes."""
    return P(row_axes(mesh))


# --------------------------------------------------------------------------
# Recsys family — the mega-table is the only big tensor; row-shard it
# --------------------------------------------------------------------------

def _is_table(path: str) -> bool:
    return "table" in path or "item_emb" in path


def recsys_table_rule(mesh, table_axes: str = "model"
                      ) -> Callable[[str, Any], P]:
    axes = row_axes(mesh) if table_axes == "all" else ("model",)
    axes = tuple(a for a in axes if a in mesh.axis_names)

    def rule(path: str, leaf) -> P:
        shape = getattr(leaf, "shape", ())
        if len(shape) >= 1 and _is_table(path) and _fit(mesh, axes, shape[0]):
            return P(axes, *([None] * (len(shape) - 1)))
        return P()

    return rule


def recsys_shardings(mesh, params_shape, opt_shape, *,
                     table_axes: str = "model"):
    """(param, opt) shardings: embedding mega-tables row-sharded over
    ``table_axes`` ("model" = TorchRec-style table parallel; "all" = every
    axis, the DLRM layout), dense towers replicated. Optimizer slots
    co-shard with their parameters (path-keyed, as in lm_shardings)."""
    rule = recsys_table_rule(mesh, table_axes)
    return (tree_shardings(mesh, params_shape, rule),
            tree_shardings(mesh, opt_shape, rule))


# --------------------------------------------------------------------------
# RPQ (the paper's system) — tiny replicated quantizer, row-sharded codes
# --------------------------------------------------------------------------

def rpq_rows_spec(mesh) -> P:
    """(N, ...) code/vector arrays row-sharded over every mesh axis — the
    serving layout: each device owns N/n_devices rows and scans them
    locally (scatter-gather, search/engine.py)."""
    return P(row_axes(mesh))


def rpq_shard_stack_spec(mesh, ndim: int = 3) -> P:
    """(S, n_local, ...) shard-STACKED arrays (graph-routed serving): the
    leading axis is the shard axis, sharded over every mesh axis; inner
    axes (a shard's local rows/columns) are never split. This is the layout
    of graphs.partition.PartitionedGraph stacks and the per-shard code /
    vector blocks of search.engine.ShardedGraphEngine."""
    return P(row_axes(mesh), *([None] * (ndim - 1)))


def rpq_param_spec(mesh, params_shape):
    """RPQ quantizer params are ≤ a few MB — fully replicated, exactly like
    the serving layout (every shard builds LUTs locally)."""
    return tree_shardings(mesh, params_shape, lambda p, l: P())
