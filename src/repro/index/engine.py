"""StreamingEngine — live serving over a mutable (base + delta) index.

The query path (DESIGN.md §10) is two arms merged by one top-k:

* **base arm** — the ordinary batched beam search over the frozen base
  graph, with the tombstone bitset threaded through ``beam_search`` as a
  TRACED argument: deleted vertices rank +inf, are never expanded, and are
  scrubbed from the returned beam. Deletes therefore cost zero recompiles.
* **delta arm** — one bulk ADC scan over the (bounded, fixed-shape) delta
  codes; unoccupied slots and tombstoned delta rows mask to +inf. No graph
  is consulted: the delta is small by construction.

``insert`` batch-encodes through the SAME quantizer as the base segment
(pq.base / pq.pack — the codes protocol every read-only engine uses) and
``delete`` flips tombstone bits covering base and delta alike. The
``search`` signature matches the other engines, so launch/serve.py and the
benchmark harness drive it unchanged.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.index.delta import DeltaSegment
from repro.index.segment import BaseSegment, Tombstones, encode_codes
from repro.kernels import ops as kops
from repro.pq import base as pqbase
from repro.pq.pack import unpack_codes
from repro.search import beam
from repro.search import seed as sseed
from repro.search.beam import SearchResult
from repro.search.engine import (_bulk_adc, _cached_dist_fn,
                                 _cached_scale_fn, _prune_cfg)

INF = jnp.float32(jnp.inf)


@functools.partial(jax.jit, static_argnames=("k", "n_base"))
def _merge_delta(beam_ids, beam_dists, delta_codes, luts, live, *,
                 k: int, n_base: int):
    """Fuse the two arms: (Q, h) beam result over the base graph + one bulk
    ADC scan of the (C, W) delta codes → global (Q, k) top-k.

    Beam sentinel slots (id n_base — which in GLOBAL id space belongs to
    delta slot 0) are remapped to -1 BEFORE the concat, so a returned
    ``n_base`` always means "delta slot 0", never "empty". Any candidate
    whose distance is +inf (masked delta slot, scrubbed tombstone) also
    reports id -1 — a tombstoned id can never ride out on a padding slot.

    The delta arm concatenates FIRST: top_k breaks exact ADC ties toward
    the lowest lane, so a fresh insert outranks a base row with identical
    codes — read-your-writes for a query at the inserted vector (whose own
    encoding attains the minimum achievable ADC distance by construction).
    """
    ddist = _bulk_adc(delta_codes, luts)                   # (Q, C)
    ddist = jnp.where(live[None, :], ddist, INF)
    q, c = ddist.shape
    dgids = jnp.broadcast_to(n_base + jnp.arange(c, dtype=jnp.int32), (q, c))
    bids = jnp.where(beam_ids < n_base, beam_ids, -1)
    bdists = jnp.where(beam_ids < n_base, beam_dists, INF)
    all_ids = jnp.concatenate([dgids, bids], axis=1)
    all_d = jnp.concatenate([ddist, bdists], axis=1)
    all_ids = jnp.where(jnp.isfinite(all_d), all_ids, -1)
    neg, order = jax.lax.top_k(-all_d, k)
    return jnp.take_along_axis(all_ids, order, axis=1), -neg


@functools.partial(jax.jit, static_argnames=("k", "n_base"))
def _base_only_topk(beam_ids, beam_dists, *, k: int, n_base: int):
    """Degraded merge (skip_delta): base arm only, same sentinel semantics
    as :func:`_merge_delta` — beam sentinel slots (id ``n_base``) and any
    non-finite candidate report id -1, so skipping the delta scan can never
    leak a padding id or a scrubbed tombstone."""
    bids = jnp.where(beam_ids < n_base, beam_ids, -1)
    bdists = jnp.where(beam_ids < n_base, beam_dists, INF)
    bids = jnp.where(jnp.isfinite(bdists), bids, -1)
    neg, order = jax.lax.top_k(-bdists, k)
    return jnp.take_along_axis(bids, order, axis=1), -neg


@dataclasses.dataclass
class StreamingEngine:
    """Mutable index serving live queries under insert/delete churn.

    Global id space: ``[0, n_base)`` are base rows of the current
    generation, ``[n_base, n_base + delta_capacity)`` are delta slots.
    Consolidation REMAPS ids (compaction drops tombstoned rows); callers
    holding ids across a consolidate() must translate them through the
    returned ``old2new`` map.

    Attributes:
      base:           frozen :class:`BaseSegment` (current generation).
      model:          the quantizer every row is encoded with.
      delta_capacity: delta slot budget between consolidations.
      delta_degree:   greedy-link degree of the delta adjacency.
    """

    base: BaseSegment
    model: pqbase.QuantizerModel
    delta_capacity: int = 1024
    delta_degree: int = 8

    def __post_init__(self):
        self._install(self.base)

    def _install(self, seg: BaseSegment) -> None:
        """(Re)point serving state at a base segment — used by __init__ and
        by consolidate() when it swaps in the next generation."""
        self.base = seg
        self.delta = DeltaSegment(self.delta_capacity, seg.dim,
                                  seg.code_width, degree=self.delta_degree,
                                  code_dtype=np.asarray(seg.codes).dtype)
        self.tombstones = Tombstones(seg.n + self.delta_capacity)
        self._codes_p = kops.pad_sentinel_row(jnp.asarray(seg.codes))
        self._dist_fns: dict = {}
        self._entry = int(seg.graph.medoid)
        self._seedix = None       # coarse seeding index (built lazily)
        self._dirty = True        # delta/tombstone device caches stale

    # -- mutation ----------------------------------------------------------

    def insert(self, vectors) -> np.ndarray:
        """Encode + append a batch of new rows. Returns their GLOBAL ids.

        Raises :class:`repro.index.delta.DeltaFullError` when the delta is
        out of slots — consolidate() and retry.
        """
        vectors = np.atleast_2d(np.asarray(vectors, np.float32))
        codes = encode_codes(self.model, vectors, self.base.layout)
        slots = self.delta.append(vectors, codes)
        self._dirty = True
        return self.base.n + slots

    def delete(self, ids) -> int:
        """Tombstone ids (base or delta). Idempotent; returns how many were
        newly deleted. Deleting the current entry point (e.g. the medoid)
        re-anchors routing on a live vertex."""
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        occupied = self.base.n + self.delta.count
        if ids.size and ((ids < 0) | (ids >= occupied)).any():
            bad = ids[(ids < 0) | (ids >= occupied)]
            raise ValueError(
                f"delete: ids out of the occupied range [0, {occupied}): "
                f"{bad} (base rows {self.base.n}, delta count "
                f"{self.delta.count})")
        fresh = self.tombstones.add(ids)
        if fresh:
            self._dirty = True
        if self.tombstones.contains([self._entry])[0]:
            self._reselect_entry()
        return fresh

    def _reselect_entry(self) -> None:
        """Move the beam entry off a tombstoned vertex: prefer a live base
        neighbor of the old entry (stays near the centroid), else any live
        base row. All-base-dead keeps the old entry — the beam starts from
        it at a large-finite distance and scrubs it from results, so
        queries still answer from the delta arm."""
        n = self.base.n
        nbrs = np.asarray(self.base.graph.neighbors[self._entry])
        nbrs = nbrs[nbrs < n]
        live_nbrs = nbrs[~self.tombstones.contains(nbrs)]
        if live_nbrs.size:
            self._entry = int(live_nbrs[0])
            return
        live = np.flatnonzero(~self.tombstones.contains(np.arange(n)))
        if live.size:
            self._entry = int(live[0])

    def consolidate(self, *, key: Optional[jax.Array] = None,
                    alpha: float = 1.2, l: int = 48,
                    ckpt_dir: Optional[str] = None,
                    keep: Optional[int] = None,
                    refresh=None, chaos=None) -> dict:
        """Fold delta + tombstones into the next base generation (see
        :func:`repro.index.consolidate.consolidate`). ``refresh`` (True or
        a :class:`repro.index.refresh.RefreshConfig`) retrains the
        quantizer on the live graph and re-encodes the new generation.
        ``chaos`` is the fault-drill phase hook (DESIGN.md §13)."""
        from repro.index.consolidate import consolidate

        return consolidate(self, key=key, alpha=alpha, l=l,
                           ckpt_dir=ckpt_dir, keep=keep, refresh=refresh,
                           chaos=chaos)

    @classmethod
    def restore(cls, ckpt_dir: str,
                model: Optional[pqbase.QuantizerModel] = None, *,
                generation: Optional[int] = None, delta_capacity: int = 1024,
                delta_degree: int = 8, retry=None,
                on_fallback=None) -> "StreamingEngine":
        """Resume from the last (or a given) consolidated generation's
        atomic snapshot — delta and tombstones restart empty, exactly the
        state the snapshot froze.

        Snapshots written since codebook refresh (DESIGN.md §12) carry the
        quantizer the codes were encoded with, so ``model=None`` restores
        self-contained — REQUIRED after a refreshed consolidation, where no
        caller-held model is guaranteed to match the generation on disk. An
        explicit ``model`` overrides the stored one (legacy snapshots need
        it); the width/layout guard below catches the common mismatches
        (wrong M, u8 model against an fs4 snapshot).

        Every generation's arrays verify against the manifest CRC32s on
        read (DESIGN.md §13); with ``generation=None`` a corrupt or
        unreadable newest snapshot falls back generation-by-generation to
        the newest INTACT one (``on_fallback(generation, error)`` observes
        each skip), and ``retry`` (a :class:`repro.dist.retry.RetryPolicy`)
        re-reads transient I/O failures before declaring a generation bad.
        """
        from repro.index.segment import load_segment
        from repro.pq.pack import FS_K, packed_width

        seg, stored = load_segment(ckpt_dir, generation, with_model=True,
                                   retry=retry, on_fallback=on_fallback)
        if model is None:
            if stored is None:
                raise ValueError(
                    "restore: snapshot has no stored quantizer (pre-refresh "
                    "format) — pass the model the segment was encoded with")
            model = stored
        want = packed_width(model.m) if seg.layout == "fs4" else model.m
        if seg.code_width != want or (seg.layout == "fs4"
                                      and model.k > FS_K):
            raise ValueError(
                f"restore: quantizer (M={model.m}, K={model.k}) does not "
                f"match the {seg.layout} snapshot's code width "
                f"{seg.code_width} — pass the model the segment was "
                f"encoded with")
        return cls(seg, model, delta_capacity=delta_capacity,
                   delta_degree=delta_degree)

    # -- query -------------------------------------------------------------

    def lut_fn(self, queries):
        """Per-query LUTs in the base segment's layout (u8 → f32 tables,
        fs4 → QuantizedLUT) — the same (codes, lut_fn) protocol the
        read-only engines use."""
        return pqbase.build_lut(self.model, queries,
                                quantize=self.base.layout == "fs4")

    def _seed_index(self) -> sseed.SeedIndex:
        """Coarse seeding index over the BASE codes (the delta is tiny and
        bulk-scanned anyway), rebuilt per generation (_install resets it);
        tombstones are applied at QUERY time, so churn never rebuilds."""
        if self._seedix is None:
            codes = jnp.asarray(self.base.codes)
            if self.base.layout == "fs4":
                codes = unpack_codes(codes, self.model.m)
            self._seedix = sseed.build_seed_index(np.asarray(codes))
        return self._seedix

    def search(self, queries: jax.Array, *, k: int = 10, h: int = 32,
               max_steps: int = 512, expand: int = 1, entries: int = 1,
               prune_eps: float = 0.0, m_prefix: int = 0,
               max_rounds=None, max_n_dist=None,
               skip_delta: bool = False) -> SearchResult:
        """Serve a query batch over base ∪ delta minus tombstones.

        Guarantee: a tombstoned id is NEVER returned, at any beam width, in
        either code layout — the beam scrubs dead base ids, the delta mask
        kills dead/unoccupied slots, and the merge turns every non-finite
        candidate into id -1. Adaptive routing rides along (DESIGN.md §11):
        ``entries>1`` seeds from the base coarse index TOMBSTONE-AWARE
        (dead candidates score DEAD_ENTRY_DIST — live seeds outrank them,
        an all-dead candidate set still routes), ``prune_eps>0`` gates
        full-LUT scoring behind the partial-LUT lower bound.

        ``max_rounds``/``max_n_dist`` cap the base beam per call (traced —
        no retrace across values; capped queries report ``truncated``).
        ``skip_delta=True`` is the last degradation rung (DESIGN.md §13):
        the bulk delta scan is skipped and queries answer base-only — fresh
        inserts go invisible until the next consolidation, but the
        tombstone guarantee holds unchanged.
        """
        queries = jnp.atleast_2d(jnp.asarray(queries, jnp.float32))
        luts = self.lut_fn(queries)
        if self._dirty:
            # one device upload + O(cap) mask per MUTATION, not per query:
            # read-heavy stretches between churn batches reuse the caches
            slot = np.arange(self.delta.capacity)
            live = ((slot < self.delta.count)
                    & ~self.tombstones.contains(self.base.n + slot))
            self._live_dev = jnp.asarray(live)
            self._delta_codes_dev = jnp.asarray(self.delta.codes)
            self._ts_dev = self.tombstones.words
            self._dirty = False
        mp, mt = _prune_cfg(luts, prune_eps, m_prefix)
        lb_fn = (_cached_dist_fn(self._dist_fns, self._codes_p, luts, mp)
                 if mp else None)
        cal_fn = _cached_scale_fn(self._dist_fns, luts, mp) if mp else None
        seed_cost = 0
        if entries > 1:
            ix = self._seed_index()
            entry = ix.seed_entries(luts, entries, tombstones=self._ts_dev)
            seed_cost = ix.n_candidates
        else:
            entry = jnp.int32(self._entry)
        res = beam.beam_search(
            self.base.graph.neighbors, entry, luts,
            _cached_dist_fn(self._dist_fns, self._codes_p, luts), h=h,
            max_steps=max_steps, expand=expand, tombstones=self._ts_dev,
            lb_dist_fn=lb_fn, m_prefix=mp, m_total=mt,
            prune_eps=prune_eps if mp else 0.0, lb_scale_fn=cal_fn,
            max_rounds=max_rounds, max_n_dist=max_n_dist)
        if skip_delta:
            kk = min(k, h)
            ids, dists = _base_only_topk(res.ids, res.dists, k=kk,
                                         n_base=self.base.n)
            delta_cost = 0
        else:
            kk = min(k, h + self.delta.capacity)
            ids, dists = _merge_delta(
                res.ids, res.dists, self._delta_codes_dev, luts,
                self._live_dev, k=kk, n_base=self.base.n)
            delta_cost = self.delta.count
        # count only OCCUPIED delta slots as distance work: the fixed-shape
        # bulk scan touches every slot, but the unoccupied tail is
        # sentinel-masked padding, not scored candidates (same accounting
        # as the beam's sentinel lanes); the seed probe's candidates count
        n_dist = res.n_dist + jnp.int32(delta_cost + seed_cost)
        return SearchResult(ids, dists, res.hops, n_dist, res.rounds,
                            truncated=res.truncated)

    # -- accounting --------------------------------------------------------

    @property
    def generation(self) -> int:
        return self.base.generation

    @property
    def n_live(self) -> int:
        """Rows a query can currently return."""
        return self.base.n + self.delta.count - self.tombstones.count

    def memory_bytes(self) -> int:
        return (self.base.memory_bytes() + self.delta.memory_bytes()
                + self.tombstones._words.nbytes)
