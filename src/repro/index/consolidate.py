"""Consolidation: fold delta + tombstones into the next base generation.

The invariants (DESIGN.md §10):

* **compaction** — tombstoned rows are dropped and survivors renumber
  densely (base survivors first, in order; live delta rows after), so the
  new segment has no dead slots and the tombstone bitset restarts empty.
* **graph repair** — a surviving base row that lost an edge to a dropped
  neighbor re-prunes over that neighbor's own surviving out-edges (the
  FreshDiskANN delete-repair rule: route-through candidates replace the
  dead hop), so connectivity does not decay across generations.
* **delta fold-in** — each live delta vertex is alpha-pruned
  (graphs/prune.py RobustPrune) into the base neighborhoods from an exact
  candidate set (plus its greedy delta links), and its chosen neighbors
  re-prune with the new vertex as a candidate (reverse edges) — the same
  two-sided insert Vamana's builder does, one batch instead of a rebuild.
* **atomicity** — the new segment snapshots through dist/checkpoint.py's
  write-tmp-then-rename before the engine swaps generations, so a crash
  mid-consolidation leaves the previous generation restorable. With a
  codebook refresh the snapshot also carries the NEW quantizer, and the
  engine's model swaps together with the segment — strictly after the
  snapshot — so a crash anywhere in the refresh (including mid-retrain)
  leaves the previous generation restorable with its OLD codebooks.
* **codebook refresh** (DESIGN.md §12, ``refresh=``) — before re-encoding,
  :func:`repro.index.refresh.refresh_quantizer` retrains the quantizer on
  triplet + routing features of the LIVE base graph (tombstone-aware), and
  every surviving row (base + delta) is re-encoded with the new model, so
  the new generation's codes, seed hash table and LUT protocol all agree
  with the refreshed codebooks.

Candidate sets for the fold-in use exact distances over the full corpus
(`graphs/knn.knn_ids`) — right for the bounded deltas this subsystem
targets; a billion-row segment would swap in a beam-search candidate pass.
"""

from __future__ import annotations

from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.graphs.adjacency import Graph, find_medoid
from repro.graphs.knn import knn_ids
from repro.graphs.prune import prune_from_vectors
from repro.index.segment import BaseSegment, save_segment
from repro.kernels import ops as kops


def _batched_prune(xp, node_ids: np.ndarray, cand: np.ndarray, alpha: float,
                   r: int, sentinel: int, batch: int = 512) -> np.ndarray:
    """prune_from_vectors over row batches, padded to a fixed batch shape so
    the jitted RobustPrune traces once per (batch, C) — not per remainder."""
    n = len(node_ids)
    pad = (-n) % batch
    ids_p = np.concatenate([node_ids, np.repeat(node_ids[:1], pad)])
    cand_p = np.concatenate([cand, np.repeat(cand[:1], pad, axis=0)])
    out = np.empty((len(ids_p), r), np.int32)
    for s in range(0, len(ids_p), batch):
        out[s:s + batch] = np.asarray(prune_from_vectors(
            xp, jnp.asarray(ids_p[s:s + batch], jnp.int32),
            jnp.asarray(cand_p[s:s + batch], jnp.int32),
            alpha, r, sentinel))
    return out[:n]


def _compact_valid_first(cand: np.ndarray, width: int,
                         sentinel: int) -> np.ndarray:
    """(B, C) candidates with -1 invalids → (B, width): valid entries moved
    to the front (stable), truncated, invalids as ``sentinel``."""
    order = np.argsort(cand < 0, axis=1, kind="stable")
    packed = np.take_along_axis(cand, order, axis=1)[:, :width]
    return np.where(packed >= 0, packed, sentinel).astype(np.int32)


def consolidate(engine, *, key: Optional[jax.Array] = None,
                alpha: float = 1.2, l: int = 48,
                ckpt_dir: Optional[str] = None,
                keep: Optional[int] = None,
                refresh=None, chaos=None) -> dict:
    """Compact ``engine`` (a :class:`repro.index.engine.StreamingEngine`)
    into a fresh base generation and swap it in.

    ``refresh`` switches on the codebook-refresh arm (DESIGN.md §12):
    ``True`` uses the default :class:`repro.index.refresh.RefreshConfig`,
    or pass a config. The quantizer retrains on the live base graph
    (tombstone-aware routing + triplet features, warm-started from the
    current codebooks), all surviving rows re-encode with the new model,
    and model + segment swap in together — after the atomic snapshot.

    Returns a stats dict with ``old2new`` — the (n_base + delta_capacity,)
    global-id remap (-1 = dropped) callers need to translate ids held
    across the consolidation — plus ``refresh`` (the retrain report) when
    the refresh arm ran.

    ``chaos`` is the fault-drill phase hook (``dist.fault.ChaosPlan
    .consolidate_hook()``, DESIGN.md §13): called with ``"pre_snapshot"``
    just before the atomic save and ``"post_snapshot"`` just after it
    (before the in-memory swap). A hook that raises exercises the two
    crash-consistency windows — nothing-durable-yet vs
    snapshot-durable-but-unswapped — both of which must leave a restorable
    generation on disk.
    """
    del key  # deterministic: candidate sets are exact, no sampling
    base, delta, tombs = engine.base, engine.delta, engine.tombstones
    n_base, c_occ = base.n, delta.count
    r = base.graph.degree

    model_new, refresh_report = engine.model, None
    if refresh:
        from repro.index.refresh import RefreshConfig, refresh_quantizer
        rcfg = refresh if isinstance(refresh, RefreshConfig) else None
        model_new, refresh_report = refresh_quantizer(
            base, engine.model, tombstones=tombs._words, cfg=rcfg)

    live_b = ~tombs.contains(np.arange(n_base))
    live_d = ~tombs.contains(n_base + np.arange(c_occ))
    nb = int(live_b.sum())
    nd = int(live_d.sum())
    n_new = nb + nd
    if n_new == 0:
        raise ValueError("consolidate: every row is tombstoned — an empty "
                         "segment cannot serve; rebuild from new data")

    # ---- compaction: dense renumbering, gathered vectors + codes ---------
    old2new = np.full((n_base + delta.capacity,), -1, np.int64)
    old2new[np.flatnonzero(live_b)] = np.arange(nb)
    old2new[n_base + np.flatnonzero(live_d)] = nb + np.arange(nd)
    vec_new = np.concatenate([np.asarray(base.vectors)[live_b],
                              delta.vectors[:c_occ][live_d]])
    if refresh_report is not None:
        # refreshed codebooks: EVERY surviving row re-encodes (base + delta
        # alike — one quantizer per generation, never mixed codes)
        from repro.index.segment import encode_codes
        codes_new = encode_codes(model_new, vec_new, base.layout)
    else:
        codes_new = np.concatenate([np.asarray(base.codes)[live_b],
                                    delta.codes[:c_occ][live_d]])
    xp = kops.pad_sentinel_row(jnp.asarray(vec_new, jnp.float32))

    # ---- surviving base adjacency, dead edges repaired -------------------
    nbrs = np.full((n_new, r), n_new, np.int32)
    onb = np.asarray(base.graph.neighbors)
    rows = np.flatnonzero(live_b)                    # old id of new row i
    if nb:
        onbr = onb[rows]                             # (nb, R), sentinel n_base
        valid = onbr < n_base
        safe = np.where(valid, onbr, 0)
        mapped = np.where(valid, old2new[safe], -1)  # -1: dead or sentinel
        nbrs[:nb] = np.where(mapped >= 0, mapped, n_new)

        lost = valid & (old2new[safe] < 0)           # edges into dead rows
        repair = np.flatnonzero(lost.any(axis=1))    # new ids (order kept)
        if repair.size:
            # 2-hop through each dead neighbor: its surviving out-edges
            d_ids = np.where(lost[repair], safe[repair], 0)     # (B, R) old
            two = onb[d_ids]                                    # (B, R, R)
            tv = (two < n_base) & lost[repair][:, :, None]
            tmapped = np.where(tv, old2new[np.where(tv, two, 0)], -1)
            cand2 = _compact_valid_first(
                tmapped.reshape(len(repair), -1), 3 * r, n_new)
            cand = np.concatenate([nbrs[repair], cand2], axis=1)
            cand[cand == repair[:, None]] = n_new    # no self-edges
            nbrs[repair] = _batched_prune(xp, repair.astype(np.int32), cand,
                                          alpha, r, n_new)

    # ---- fold live delta vertices into the base neighborhoods ------------
    if nd:
        own = (nb + np.arange(nd)).astype(np.int32)
        dvec = delta.vectors[:c_occ][live_d]
        lc = min(max(l, r + 1), n_new)
        cand_knn, _ = knn_ids(jnp.asarray(vec_new), jnp.asarray(dvec), lc)
        cand_knn = np.asarray(cand_knn).astype(np.int64)   # includes self
        dnbr = delta.neighbors[:c_occ][live_d]             # greedy links
        dvalid = dnbr < delta.capacity
        dmapped = np.where(dvalid,
                           old2new[n_base + np.where(dvalid, dnbr, 0)], -1)
        cand = np.concatenate([cand_knn, dmapped], axis=1)
        cand[cand == own[:, None]] = -1
        cand = _compact_valid_first(cand, cand.shape[1], n_new)
        nbrs[own] = _batched_prune(xp, own, cand, alpha, r, n_new)

        # reverse edges: chosen neighbors re-prune with the new vertex
        src = np.repeat(own, r)
        dst = nbrs[own].reshape(-1)
        m = dst < n_new
        src, dst = src[m], dst[m]
        if dst.size:
            order = np.argsort(dst, kind="stable")
            dst_s, src_s = dst[order], src[order]
            uniq, starts = np.unique(dst_s, return_index=True)
            counts = np.diff(np.append(starts, len(dst_s)))
            rev = np.full((len(uniq), r), n_new, np.int32)
            for t in range(len(uniq)):
                cnt = min(int(counts[t]), r)
                rev[t, :cnt] = src_s[starts[t]:starts[t] + cnt]
            cand = np.concatenate([nbrs[uniq], rev], axis=1)
            cand[cand == uniq[:, None]] = n_new
            nbrs[uniq] = _batched_prune(xp, uniq.astype(np.int32), cand,
                                        alpha, r, n_new)

    # ---- new generation: medoid, snapshot, swap --------------------------
    medoid = find_medoid(jnp.asarray(vec_new))
    seg = BaseSegment(
        graph=Graph(neighbors=jnp.asarray(nbrs),
                    medoid=jnp.asarray(medoid, jnp.int32)),
        codes=jnp.asarray(codes_new), vectors=jnp.asarray(vec_new),
        layout=base.layout, generation=base.generation + 1)
    if chaos is not None:
        chaos("pre_snapshot")
    if ckpt_dir:
        # snapshot carries the (possibly refreshed) quantizer: restore() is
        # self-contained even after codebooks change across generations
        save_segment(ckpt_dir, seg, keep=keep, model=model_new)
    if chaos is not None:
        chaos("post_snapshot")
    # swap model + segment together, strictly AFTER the snapshot — a crash
    # anywhere above leaves the previous generation serving old codebooks
    engine.model = model_new
    engine._install(seg)
    stats = {"generation": seg.generation, "n": n_new,
             "dropped": int(tombs.count), "folded": nd, "old2new": old2new,
             "refreshed": refresh_report is not None}
    if refresh_report is not None:
        stats["refresh"] = refresh_report
    return stats
