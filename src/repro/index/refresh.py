"""Routing-guided codebook refresh for the streaming index (DESIGN.md §12).

The serving stack runs on statically trained PQ codes, but the paper's
contribution is the *learned* quantizer — trained on neighborhood and
routing features of the live proximity graph. This module closes that loop
at consolidation time (the FreshDiskANN generation boundary is the natural
retraining hook): :func:`refresh_quantizer` takes the CURRENT base segment
plus its tombstone bitset and produces a better quantizer for the next
generation, which :func:`repro.index.consolidate.consolidate` then uses to
re-encode every surviving row (base + delta), rebuild the u8/fs4 codes and
the PQ-hash seed table, snapshot the new generation WITH its codebooks, and
hot-swap model + segment atomically.

Two refinement stages, both warm-started from the serving codebooks:

1. **Lloyd warm start** (``kmeans_iters`` iterations): classic k-means over
   the LIVE rotated sub-vectors, initialized at the current codebooks.
   This is what absorbs distribution drift — cells migrate toward where
   the live data actually is — and it is cheap and monotone in distortion.
2. **Routing-guided gradient steps** (``steps`` Adam steps on the paper's
   joint loss): the existing data-parallel ``core/trainer.fit`` path with
   ``tombstones=`` — triplet anchors and routing-feature queries are drawn
   from live vertices of the live graph only (``core/features.py`` masks
   dead ids out of every neighborhood and every traced beam), so the
   quantizer is tuned for how queries actually route on THIS graph, not
   just for reconstruction.

Rotation handling: serving rotations stay frozen during a refresh (the
default — a refresh refines codebooks against drift; re-learning R is a
full retrain's job). Training runs on pre-rotated vectors ``x @ R.T`` with
``learn_rotation=False``; squared Euclidean distance is rotation-invariant,
so the live graph built over the original vectors is exactly as valid in
the rotated space, and the refreshed model keeps the original R.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import quantizer as Q
from repro.core import trainer as T
from repro.index.segment import BaseSegment
from repro.pq import base as pqbase
from repro.pq.kmeans import kmeans_multi


@dataclasses.dataclass(frozen=True)
class RefreshConfig:
    """Knobs for one codebook refresh (sized for a consolidation pause, not
    a from-scratch training run — tens of steps, small batches)."""

    steps: int = 40                 # routing-guided Adam steps (0 = Lloyd only)
    kmeans_iters: int = 5           # warm-started Lloyd iterations (0 = skip)
    lr: float = 1e-3
    triplet_batch: int = 256
    routing_batch: int = 256
    routing_pool_queries: int = 64
    routing_refresh_every: int = 20  # re-extract routing features this often
    beam_h: int = 8
    n_hops: int = 2
    k_pos: int = 10
    k_neg: int = 30
    use_routing: bool = True
    use_neighborhood: bool = True
    data_parallel: bool = False     # trainer's shard_map path (multi-device)
    max_sample: int = 20_000        # live-row cap for the Lloyd stage
    seed: int = 0
    verbose: bool = False


def _live_mask(tombstones: Optional[np.ndarray], n: int) -> np.ndarray:
    """(n,) bool live mask from uint32 bitset words (all-live when None)."""
    if tombstones is None:
        return np.ones((n,), bool)
    words = np.asarray(tombstones, np.uint32)
    ids = np.arange(n, dtype=np.int64)
    return ((words[ids >> 5] >> (ids & 31).astype(np.uint32)) & 1) == 0


def refresh_quantizer(base: BaseSegment, model: pqbase.QuantizerModel, *,
                      tombstones: Optional[np.ndarray] = None,
                      cfg: Optional[RefreshConfig] = None,
                      key: Optional[jax.Array] = None,
                      ) -> tuple[pqbase.QuantizerModel, dict]:
    """Retrain the quantizer against the LIVE rows of ``base``.

    Args:
      base:       the current (pre-compaction) base segment — its graph is
                  the live routing structure the features are sampled from.
      model:      the serving quantizer to warm-start from (its rotation is
                  kept; its codebooks are the starting point).
      tombstones: optional uint32 bitset words over the GLOBAL id space
                  (only bits < base.n matter here): dead vertices never
                  appear as anchors, positives/negatives, or routing
                  waypoints, and never contribute to the Lloyd stage.
      cfg:        :class:`RefreshConfig` (default: a CI-sized refresh).
      key:        PRNG key (default: from ``cfg.seed``).

    Returns:
      (new_model, report) — ``new_model`` shares ``model.r`` with fresh
      codebooks; ``report`` carries live counts and the mean squared
      reconstruction error over live rows before/after (the distortion the
      AiSAQ line argues is the resident artifact worth keeping small).
    """
    cfg = cfg if cfg is not None else RefreshConfig()
    key = key if key is not None else jax.random.PRNGKey(cfg.seed)
    n, d = base.n, base.dim
    m, k = model.m, model.k
    live = _live_mask(tombstones, n)
    n_live = int(live.sum())
    if n_live < k:
        raise ValueError(
            f"refresh_quantizer: only {n_live} live rows but K={k} codewords "
            f"per subspace — consolidate without refresh or add data")

    x = jnp.asarray(base.vectors, jnp.float32)
    xr = x @ model.r.T                       # train in the rotated space
    k_lloyd, k_fit = jax.random.split(key)

    live_idx = np.flatnonzero(live)
    if live_idx.size > cfg.max_sample:
        sel = np.random.default_rng(cfg.seed).choice(
            live_idx, cfg.max_sample, replace=False)
        live_idx = np.sort(sel)
    x_live = jnp.asarray(np.asarray(x)[live_idx])
    report = {"n_live": n_live, "steps": cfg.steps,
              "kmeans_iters": cfg.kmeans_iters,
              "distortion_before": float(pqbase.distortion(model, x_live))}

    # ---- stage 1: warm-started Lloyd on live rotated sub-vectors ---------
    codebooks = jnp.asarray(model.codebooks, jnp.float32)
    if cfg.kmeans_iters > 0:
        sub = jnp.asarray(np.asarray(xr)[live_idx]).reshape(
            live_idx.size, m, d // m).transpose(1, 0, 2)     # (M, L, dsub)
        codebooks = kmeans_multi(k_lloyd, sub, k, iters=cfg.kmeans_iters,
                                 init=codebooks)

    # ---- stage 2: routing-guided gradient steps on the live graph --------
    history: list = []
    if cfg.steps > 0:
        qcfg = Q.RPQConfig(dim=d, m=m, k=k, learn_rotation=False)
        tcfg = T.TrainConfig(
            steps=cfg.steps, lr=cfg.lr, triplet_batch=cfg.triplet_batch,
            routing_batch=cfg.routing_batch,
            routing_pool_queries=cfg.routing_pool_queries,
            refresh_every=cfg.routing_refresh_every, beam_h=cfg.beam_h,
            n_hops=cfg.n_hops, k_pos=cfg.k_pos, k_neg=cfg.k_neg,
            use_routing=cfg.use_routing,
            use_neighborhood=cfg.use_neighborhood,
            data_parallel=cfg.data_parallel,
            log_every=max(cfg.steps // 4, 1))
        state = T.fit(k_fit, qcfg, tcfg, xr, base.graph,
                      params=Q.init_params(qcfg, codebooks),
                      tombstones=tombstones, verbose=cfg.verbose)
        codebooks = state.params.codebooks
        history = state.history

    new_model = pqbase.QuantizerModel(r=model.r, codebooks=codebooks)
    report["distortion_after"] = float(pqbase.distortion(new_model, x_live))
    report["history"] = history
    return new_model, report
