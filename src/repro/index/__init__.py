"""Streaming mutable index (DESIGN.md §10): a frozen generation-numbered
base segment + bounded append-only delta + tombstone bitset, served live by
:class:`StreamingEngine` and folded together by :func:`consolidate`.

Public surface:

* :mod:`repro.index.segment` — :class:`BaseSegment` (frozen graph + codes +
  vectors), :class:`Tombstones`, atomic snapshot save/load.
* :mod:`repro.index.delta`   — :class:`DeltaSegment` bounded append-only
  rows with greedy links; :class:`DeltaFullError` on overflow.
* :mod:`repro.index.engine`  — :class:`StreamingEngine`: the other engines'
  ``search()`` protocol plus ``insert`` / ``delete`` / ``consolidate``.
* :mod:`repro.index.consolidate` — compaction + graph repair + delta
  fold-in + generation bump.
* :mod:`repro.index.refresh` — routing-guided codebook refresh at the
  generation boundary (:class:`RefreshConfig`, :func:`refresh_quantizer`).
"""
from repro.index.consolidate import consolidate  # noqa: F401
from repro.index.delta import DeltaFullError, DeltaSegment  # noqa: F401
from repro.index.engine import StreamingEngine  # noqa: F401
from repro.index.refresh import RefreshConfig, refresh_quantizer  # noqa: F401
from repro.index.segment import (  # noqa: F401
    BaseSegment, Tombstones, encode_codes, load_segment, save_segment,
)
