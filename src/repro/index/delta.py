"""Bounded append-only delta segment for the streaming index.

New rows land here (DESIGN.md §10): ``append`` stores the full vector, its
pre-encoded PQ codes, and greedily links the row into a small delta
adjacency (exact nearest neighbors among the rows already present, plus
capped reverse edges). The QUERY path never walks this adjacency — the
delta is bounded small precisely so one bulk ADC scan covers it — but
consolidation seeds each delta vertex's candidate set from it, so the
greedy links buy graph quality at fold-in time.

Capacity is a hard bound: the fixed array shapes are what keep the serving
path jit-stable (no retrace per insert), so overflowing raises
:class:`DeltaFullError` — the caller's cue to ``consolidate()``.
"""

from __future__ import annotations

import numpy as np


class DeltaFullError(RuntimeError):
    """Raised when an insert batch would exceed the delta capacity."""


class DeltaSegment:
    """Append-only row store: vectors + codes + greedy local adjacency.

    All state is host numpy (inserts are host-side mutations; the serving
    path snapshots ``codes`` into the jitted scan). Local ids are
    [0, capacity) with sentinel ``capacity`` padding the adjacency.
    """

    def __init__(self, capacity: int, dim: int, code_width: int, *,
                 degree: int = 8, code_dtype=np.uint8):
        if capacity < 1:
            raise ValueError(f"delta capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.degree = int(degree)
        self.vectors = np.zeros((capacity, dim), np.float32)
        # dtype follows the base segment's codes (uint8 for K <= 256 and
        # fs4 packed bytes, int32 beyond — pq.base.encode's convention)
        self.codes = np.zeros((capacity, code_width), code_dtype)
        self.neighbors = np.full((capacity, self.degree), capacity, np.int32)
        self.count = 0

    def append(self, vectors: np.ndarray, codes: np.ndarray) -> np.ndarray:
        """Append a batch; returns the assigned LOCAL slots (b,).

        Raises :class:`DeltaFullError` when the batch does not fit —
        consolidate the index to drain the delta, then retry.
        """
        vectors = np.atleast_2d(np.asarray(vectors, np.float32))
        codes = np.atleast_2d(np.asarray(codes))
        if not np.can_cast(codes.dtype, self.codes.dtype, casting="safe"):
            raise ValueError(
                f"delta codes are {self.codes.dtype} but the batch is "
                f"{codes.dtype} — encode with the base segment's quantizer")
        b = vectors.shape[0]
        if codes.shape[0] != b:
            raise ValueError(f"{b} vectors but {codes.shape[0]} code rows")
        if self.count + b > self.capacity:
            raise DeltaFullError(
                f"delta segment full: {self.count} occupied + {b} new > "
                f"capacity {self.capacity}; run consolidate() to fold the "
                f"delta into the base segment, then retry the insert")
        slots = np.arange(self.count, self.count + b)
        self.vectors[slots] = vectors
        self.codes[slots] = codes
        self._link(slots)
        self.count += b
        return slots

    def _link(self, slots: np.ndarray) -> None:
        """Greedy incremental linking: row i connects to its ``degree``
        exact-nearest predecessors (earlier rows, including earlier rows of
        the same batch), which gain a capped reverse edge."""
        new = self.vectors[slots]                              # (b, D)
        hi = slots[-1] + 1
        old = self.vectors[:hi]                                # (hi, D)
        # squared distances new × all rows up to the end of the batch
        d = (np.sum(new * new, 1)[:, None] - 2.0 * new @ old.T
             + np.sum(old * old, 1)[None, :])                  # (b, hi)
        for row, gi in enumerate(slots):
            cand = d[row, :gi]                  # strictly earlier rows
            if cand.size == 0:
                continue
            take = min(self.degree, cand.size)
            nbrs = np.argpartition(cand, take - 1)[:take].astype(np.int32)
            self.neighbors[gi, :take] = nbrs
            for j in nbrs:                       # capped reverse edges
                free = np.flatnonzero(self.neighbors[j] == self.capacity)
                if free.size:
                    self.neighbors[j, free[0]] = gi
                # full reverse lists drop the edge (the bulk scan, not the
                # adjacency, answers queries — quality only affects
                # consolidation seeding)

    def memory_bytes(self) -> int:
        return (self.vectors.size * 4 + self.codes.size
                + self.neighbors.size * 4)
