"""Frozen base segment + tombstone bitset for the streaming index.

The mutable-index layout (DESIGN.md §10) is the DiskANN-lineage
(FreshDiskANN / AiSAQ) segment model: one FROZEN, generation-numbered base
segment — a proximity graph over PQ codes, exactly what the read-only
engines serve — plus a bounded append-only delta (:mod:`repro.index.delta`)
and a tombstone bitset covering both. Nothing in the base segment is ever
mutated in place; deletes flip tombstone bits, inserts append to the delta,
and :mod:`repro.index.consolidate` folds both into a fresh base segment
with a bumped generation, snapshotted atomically via
:mod:`repro.dist.checkpoint`.
"""

from __future__ import annotations

import dataclasses
import zipfile
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.dist import checkpoint as ckpt
from repro.graphs.adjacency import Graph
from repro.pq import base as pqbase
from repro.pq import pack

LAYOUTS = ("u8", "fs4")


def encode_codes(model: pqbase.QuantizerModel, x, layout: str) -> np.ndarray:
    """(B, D) vectors → (B, M) u8 codes or (B, ceil(M/2)) fs4 packed bytes —
    the one encode path shared by base builds, delta inserts, and serve.py
    (reuses pq.base.encode / pq.pack.pack_codes)."""
    if layout not in LAYOUTS:
        raise ValueError(f"layout must be one of {LAYOUTS}, got {layout!r}")
    codes = pqbase.encode(model, jnp.asarray(x, jnp.float32))
    if layout == "fs4":
        if model.k > pack.FS_K:
            raise ValueError(
                f"fs4 layout needs K <= {pack.FS_K} sub-codewords, got "
                f"K={model.k} (train with pq.train_pq_fs4)")
        codes = pack.pack_codes(codes)
    return np.asarray(codes)


def bitset_words(capacity: int) -> int:
    """Words for a bitset over ids [0, capacity) — the sentinel-inclusive
    (n+31)//32 + 1 sizing shared with the beam's visited set, so one bitset
    serves both the global id space and any base-graph beam over it."""
    return (capacity + 31) // 32 + 1


class Tombstones:
    """Host-mutable deleted-id bitset over the global id space
    [0, n_base + delta_capacity).

    The words array is what jitted consumers take (``beam_search
    (tombstones=...)``): it is passed as a TRACED argument, so flipping bits
    between queries never recompiles. Adds are idempotent; ``count`` tracks
    distinct tombstoned ids.
    """

    def __init__(self, capacity: int):
        self.capacity = int(capacity)
        self._words = np.zeros((bitset_words(self.capacity),), np.uint32)
        self.count = 0

    def add(self, ids) -> int:
        """Set bits for ``ids`` (any int array-like). Returns how many were
        newly tombstoned (already-dead ids are a no-op, not an error)."""
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        if ids.size and ((ids < 0) | (ids >= self.capacity)).any():
            bad = ids[(ids < 0) | (ids >= self.capacity)]
            raise ValueError(
                f"tombstone ids out of range [0, {self.capacity}): {bad}")
        fresh = int(np.unique(ids[~self.contains(ids)]).size)
        np.bitwise_or.at(self._words, ids >> 5,
                         np.uint32(1) << (ids & 31).astype(np.uint32))
        self.count += fresh
        return fresh

    def contains(self, ids) -> np.ndarray:
        """Boolean mask: True where the id is tombstoned."""
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        if ids.size == 0:
            return np.zeros((0,), bool)
        return ((self._words[ids >> 5] >> (ids & 31).astype(np.uint32)) & 1
                ).astype(bool)

    @property
    def words(self) -> jax.Array:
        """(W,) uint32 device view for jitted consumers (fresh each call —
        the host array is the source of truth)."""
        return jnp.asarray(self._words)

    def clear(self) -> None:
        self._words[:] = 0
        self.count = 0


@dataclasses.dataclass
class BaseSegment:
    """One frozen, generation-numbered serving segment.

    Attributes:
      graph:      padded Vamana adjacency over the segment rows (sentinel n).
      codes:      (n, M) u8 codes or (n, ceil(M/2)) fs4 packed bytes — must
                  match ``layout``.
      vectors:    (n, D) f32 full vectors ("on SSD" in the DiskANN layout —
                  resident here; consolidation and exact rerank need them).
                  May be None for a code-only serving restore
                  (``load_segment(with_vectors=False)`` / the storage
                  tier); ``dim_hint`` then supplies D.
      layout:     "u8" | "fs4" (decides the LUT type the engine builds).
      generation: consolidation counter; doubles as the checkpoint step.
      dim_hint:   original dimensionality when ``vectors`` is None.
    """

    graph: Graph
    codes: jax.Array
    vectors: Optional[jax.Array]
    layout: str = "u8"
    generation: int = 0
    dim_hint: Optional[int] = None

    def __post_init__(self):
        if self.layout not in LAYOUTS:
            raise ValueError(f"layout must be one of {LAYOUTS}, "
                             f"got {self.layout!r}")
        if int(self.codes.shape[0]) != self.n:
            raise ValueError(f"codes rows {self.codes.shape[0]} != "
                             f"graph rows {self.n}")
        if self.vectors is None and self.dim_hint is None:
            raise ValueError("a vector-free BaseSegment needs dim_hint")

    @property
    def n(self) -> int:
        return self.graph.n

    @property
    def dim(self) -> int:
        if self.vectors is None:
            return int(self.dim_hint)
        return int(self.vectors.shape[1])

    @property
    def code_width(self) -> int:
        return int(self.codes.shape[1])

    @classmethod
    def build(cls, key: jax.Array, vectors, model: pqbase.QuantizerModel, *,
              layout: str = "u8", r: int = 24, l: int = 48,
              alpha: float = 1.2, batch: int = 1024,
              generation: int = 0) -> "BaseSegment":
        """Encode + build a Vamana graph over ``vectors`` — the from-scratch
        (or rebuild) path; consolidation produces the incremental ones."""
        from repro.graphs.vamana import build_vamana

        vectors = jnp.asarray(vectors, jnp.float32)
        codes = jnp.asarray(encode_codes(model, vectors, layout))
        graph = build_vamana(key, vectors, r=r, l=l, alpha=alpha, batch=batch)
        return cls(graph=graph, codes=codes, vectors=vectors, layout=layout,
                   generation=generation)

    def memory_bytes(self) -> int:
        vec = 0 if self.vectors is None else self.vectors.size * 4
        return (self.codes.size * self.codes.dtype.itemsize
                + self.graph.neighbors.size * 4 + vec)


def save_segment(directory: str, seg: BaseSegment,
                 keep: Optional[int] = None,
                 model: Optional[pqbase.QuantizerModel] = None) -> str:
    """Atomic snapshot of a base segment at step = generation
    (dist/checkpoint.py: readers see the old complete generation or the new
    one, never a half-written consolidation).

    ``model`` persists the quantizer the codes were encoded with (rotation
    + codebooks + M/K/layout metadata) INSIDE the snapshot, so a restart
    resumes self-contained — required since codebook refresh (DESIGN.md
    §12) means the serving quantizer changes across generations and no
    caller-side model is guaranteed to match. ``model=None`` writes the
    legacy codes-only format (restore then needs an explicit model).
    """
    if seg.vectors is None:
        raise ValueError("cannot snapshot a vector-free BaseSegment — "
                         "consolidation and rerank need the vectors")
    index = {"neighbors": np.asarray(seg.graph.neighbors),
             "medoid": np.asarray(seg.graph.medoid),
             "codes": np.asarray(seg.codes),
             "vectors": np.asarray(seg.vectors),
             "layout": seg.layout,
             "generation": int(seg.generation),
             "dim": int(seg.dim)}
    if model is not None:
        index["quantizer"] = {
            "r": np.asarray(model.r, np.float32),
            "codebooks": np.asarray(model.codebooks, np.float32),
            "m": int(model.m), "k": int(model.k)}
    return ckpt.save(directory, seg.generation, keep=keep, index=index)


def _load_one(directory: str, generation: Optional[int],
              with_model: bool, retry, with_vectors: bool = True):
    drop = () if with_vectors else ("index/vectors",)
    state = ckpt.restore(directory, step=generation, retry=retry, drop=drop)
    t = state["index"]
    graph = Graph(neighbors=jnp.asarray(t["neighbors"], jnp.int32),
                  medoid=jnp.asarray(t["medoid"], jnp.int32))
    if with_vectors:
        vectors, dim_hint = jnp.asarray(t["vectors"], jnp.float32), None
    else:
        # vectors came back as a ckpt.Dropped sentinel — zero bytes read;
        # its manifest shape covers snapshots predating the "dim" key
        vectors = None
        dim_hint = int(t.get("dim") or t["vectors"].shape[1])
    seg = BaseSegment(graph=graph, codes=jnp.asarray(t["codes"]),
                      vectors=vectors, layout=str(t["layout"]),
                      generation=int(t["generation"]), dim_hint=dim_hint)
    if not with_model:
        return seg
    q = t.get("quantizer")
    model = (pqbase.QuantizerModel(
        r=jnp.asarray(q["r"], jnp.float32),
        codebooks=jnp.asarray(q["codebooks"], jnp.float32))
        if q is not None else None)
    return seg, model


def load_segment(directory: str, generation: Optional[int] = None, *,
                 with_model: bool = False, with_vectors: bool = True,
                 retry=None, on_fallback=None):
    """Restore the newest INTACT (or a specific) consolidated generation.

    Every snapshot read is CRC32-verified (dist/checkpoint.py, DESIGN.md
    §13). With ``generation=None`` a snapshot that fails verification — or
    is otherwise unreadable (truncated zip, missing tree, malformed
    manifest) — does NOT poison the restore: the loader falls back
    generation-by-generation to the newest intact one, calling
    ``on_fallback(generation, error)`` per rejected snapshot, and raises a
    clear ``RuntimeError`` naming every failure only when none survives.
    An EXPLICIT ``generation`` never falls back — you asked for that one.

    ``retry`` (a ``dist.retry.RetryPolicy``) retries transient read faults
    per generation before giving up on it. ``with_model=True`` returns
    ``(segment, model_or_None)`` — the model is ``None`` for pre-refresh
    (codebook-less) snapshots, which still load; the caller decides whether
    an explicit model can stand in. ``with_vectors=False`` skips
    materializing the (n, D) float vectors entirely (zero bytes read —
    ``dist.checkpoint.restore(drop=...)``): the segment comes back with
    ``vectors=None`` and a ``dim_hint``, which is all a code-serving tier
    (storage/engine.py) or a segment-format export needs."""
    if generation is not None:
        return _load_one(directory, generation, with_model, retry,
                         with_vectors)
    steps = ckpt.all_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {directory!r}")
    failures = []
    for gen in reversed(steps):
        try:
            return _load_one(directory, gen, with_model, retry,
                             with_vectors)
        except (ckpt.ChecksumError, OSError, KeyError, ValueError,
                zipfile.BadZipFile) as e:
            failures.append((gen, e))
            if on_fallback is not None:
                on_fallback(gen, e)
    detail = "; ".join(f"gen {g}: {type(e).__name__}: {e}"
                       for g, e in failures)
    raise RuntimeError(
        f"no intact snapshot under {directory!r} — every generation failed "
        f"verification or read: {detail}")
