"""Optimized Product Quantization (Ge et al., CVPR'13), non-parametric variant.

Alternating optimization:
  (1) fix R, retrain codebooks with Lloyd on the rotated data;
  (2) fix codebooks, solve the orthogonal Procrustes problem
      min_R ||R X − X'||_F  →  R = U Vᵀ from SVD(X'ᵀ X)
(our convention rotates row-vectors as x @ Rᵀ, so we solve for that R).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.pq import base
from repro.pq.pq import train_pq


def train_opq(key: jax.Array, x: jax.Array, m: int, k: int, *,
              outer_iters: int = 8, kmeans_iters: int = 8) -> base.QuantizerModel:
    n, d = x.shape
    model = train_pq(key, x, m, k, iters=kmeans_iters)  # R = I start
    for it in range(outer_iters):
        key, sub = jax.random.split(key)
        # (2) Procrustes: reconstruction targets in rotated space.
        codes = base.encode(model, x)
        sub_rec = jnp.take_along_axis(
            model.codebooks[None], codes[:, :, None, None].astype(jnp.int32), axis=2
        )[:, :, 0, :].reshape(n, d)                      # x' in rotated space
        # want R minimizing ||x @ R.T − x'||_F ; R = U Vᵀ of  x'ᵀ x
        u, _, vt = jnp.linalg.svd(sub_rec.T @ x, full_matrices=False)
        r = u @ vt
        # (1) Lloyd under the new rotation.
        model = train_pq(sub, x, m, k, iters=kmeans_iters, rotation=r)
    return model
