"""Catalyst-style learned baseline (Sablayrolles et al., "Spreading vectors
for similarity search", ICLR'19) — the paper's strongest learned competitor.

Simplified faithful core: a small MLP f: R^D → R^dout trained with
  * a triplet loss on exact nearest neighbors (rank preservation), and
  * the KoLeo differential-entropy regularizer  −1/n Σ log(min_j ||f_i − f_j||)
    that spreads points over the output sphere,
followed by plain PQ in the output space. Unlike RPQ it is graph-agnostic:
no PG neighborhood sampling, no routing features — exactly the contrast the
paper draws.

Serving: nonlinear encoders can't export a QuantizerModel; this module
provides the same duck-typed protocol the engines accept (`codes`, `lut_fn`).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.common import adam, one_cycle
from repro.kernels import ops as kops
from repro.pq import base
from repro.pq.pq import train_pq


class CatalystParams(NamedTuple):
    w1: jax.Array
    b1: jax.Array
    w2: jax.Array
    b2: jax.Array


class CatalystModel(NamedTuple):
    net: CatalystParams
    pq: base.QuantizerModel   # PQ trained in the output space


def init_net(key: jax.Array, d_in: int, d_hidden: int, d_out: int) -> CatalystParams:
    k1, k2 = jax.random.split(key)
    s1 = 1.0 / jnp.sqrt(d_in)
    s2 = 1.0 / jnp.sqrt(d_hidden)
    return CatalystParams(
        w1=jax.random.uniform(k1, (d_in, d_hidden), jnp.float32, -s1, s1),
        b1=jnp.zeros((d_hidden,), jnp.float32),
        w2=jax.random.uniform(k2, (d_hidden, d_out), jnp.float32, -s2, s2),
        b2=jnp.zeros((d_out,), jnp.float32),
    )


def forward(net: CatalystParams, x: jax.Array) -> jax.Array:
    h = jnp.tanh(x @ net.w1 + net.b1)
    y = h @ net.w2 + net.b2
    return y / (jnp.linalg.norm(y, axis=-1, keepdims=True) + 1e-8)  # sphere


def _koleo(y: jax.Array) -> jax.Array:
    d2 = jnp.sum((y[:, None, :] - y[None, :, :]) ** 2, axis=-1)
    d2 = d2 + jnp.eye(y.shape[0]) * 1e9
    # eps INSIDE the sqrt: duplicate batch rows give d2=0 whose sqrt has an
    # infinite gradient → NaN params (observed: catalyst beam search died
    # with NaN LUTs in the benchmark run)
    return -jnp.mean(0.5 * jnp.log(jnp.min(d2, axis=1) + 1e-10))


def _loss(net, anchors, pos, neg, lam, margin=0.1):
    ya, yp, yn = forward(net, anchors), forward(net, pos), forward(net, neg)
    dp = jnp.sum((ya - yp) ** 2, axis=-1)
    dn = jnp.sum((ya - yn) ** 2, axis=-1)
    trip = jnp.mean(jnp.maximum(0.0, margin + dp - dn))
    return trip + lam * _koleo(ya)


def train_catalyst(key: jax.Array, x: jax.Array, m: int, k: int, *,
                   d_out: int = 40, d_hidden: int = 128, lam: float = 0.005,
                   steps: int = 300, batch: int = 256,
                   n_neighbors: int = 10) -> CatalystModel:
    """Paper-parameter defaults: d_out=40, λ=0.005 (§8.1)."""
    n, d = x.shape
    key, knet, kpq = jax.random.split(key, 3)
    net = init_net(knet, d, d_hidden, d_out)

    # Exact-kNN positives on a training subsample (Catalyst is graph-free).
    sub = x[:min(n, 20000)]
    d2 = (jnp.sum(sub**2, 1)[:, None] - 2 * sub @ sub.T + jnp.sum(sub**2, 1)[None, :])
    d2 = d2 + jnp.eye(sub.shape[0]) * 1e9
    nbr = jax.lax.top_k(-d2, n_neighbors)[1]      # (Ns, n_neighbors)

    opt = adam(one_cycle(1e-3, steps))
    state = opt.init(net)

    @jax.jit
    def step(net, state, kk):
        ka, kp, kn = jax.random.split(kk, 3)
        ai = jax.random.randint(ka, (batch,), 0, sub.shape[0])
        pj = jax.random.randint(kp, (batch,), 0, n_neighbors)
        pi = nbr[ai, pj]
        ni = jax.random.randint(kn, (batch,), 0, sub.shape[0])
        g = jax.grad(_loss)(net, sub[ai], sub[pi], sub[ni], lam)
        from repro.common import clip_by_global_norm
        g, _ = clip_by_global_norm(g, 1.0)
        return opt.update(g, state, net)

    for _ in range(steps):
        key, kk = jax.random.split(key)
        net, state = step(net, state, kk)

    y = forward(net, x)
    pq = train_pq(kpq, y, m, k, iters=10)
    return CatalystModel(net=net, pq=pq)


# ---- serving protocol (duck-typed like pq.base) ---------------------------

def encode(model: CatalystModel, x: jax.Array) -> jax.Array:
    return base.encode(model.pq, forward(model.net, x))


def build_lut(model: CatalystModel, queries: jax.Array) -> jax.Array:
    return base.build_lut(model.pq, forward(model.net, jnp.atleast_2d(queries)))


def adc(model: CatalystModel, codes: jax.Array, queries: jax.Array,
        *, backend: str = "auto") -> jax.Array:
    return kops.adc_scan_batch(codes, build_lut(model, queries), backend=backend)
