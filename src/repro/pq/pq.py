"""Classic Product Quantization (Jégou et al., TPAMI'11) — the DiskANN default.

Vertical split into M chunks, independent K-means per chunk, Lloyd quantizer.
This is both the paper's main baseline and the initializer for OPQ and RPQ.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.pq import base
from repro.pq.kmeans import kmeans_multi


def train_pq(key: jax.Array, x: jax.Array, m: int, k: int, *,
             iters: int = 20, rotation: jax.Array | None = None) -> base.QuantizerModel:
    """Train a PQ codebook on x (N, D). Optional fixed rotation (for OPQ).

    K is free: the classic byte-code regime is K=256, the fast-scan packed
    regime is K=16 (4-bit codes, two per byte — see :func:`train_pq_fs4`).
    """
    n, d = x.shape
    assert d % m == 0, f"D={d} % M={m} != 0"
    r = base.identity_rotation(d) if rotation is None else rotation
    xr = (x @ r.T).reshape(n, m, d // m).transpose(1, 0, 2)  # (M, N, dsub)
    codebooks = kmeans_multi(key, xr, k, iters=iters)
    return base.QuantizerModel(r=r, codebooks=codebooks)


def train_pq_fs4(key: jax.Array, x: jax.Array, m: int, *, iters: int = 20,
                 rotation: jax.Array | None = None) -> base.QuantizerModel:
    """K=16 PQ for the fast-scan layout (DESIGN.md §8).

    At the same bytes-per-vector budget as K=256, double M (e.g. M=8,K=256
    → M=16,K=16): codes from ``encode`` then ``pack.pack_codes`` occupy
    M/2 bytes/vector, and ``build_lut(..., quantize=True)`` emits the
    matching uint8 tables.
    """
    return train_pq(key, x, m, 16, iters=iters, rotation=rotation)
