"""Blocked Lloyd k-means in JAX (the PQ/OPQ/RPQ codebook initializer).

Fully jitted: assignment uses the pq_pairwise kernel path in N-blocks (keeps
the (block, K) distance tile small), the update is a segment_sum, and empty
clusters are re-seeded to the currently-worst-quantized points — essential
for PQ sub-codebooks where K=256 often exceeds the visible cluster count of
a 16-dimensional slice.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


@functools.partial(jax.jit, static_argnames=("k", "iters", "block"))
def kmeans(key: jax.Array, x: jax.Array, k: int, *, iters: int = 20,
           block: int = 8192,
           init: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Lloyd's algorithm. Returns (centroids (K, D), assignments (N,)).

    Any K ≤ N works — K=256 byte codes and K=16 fast-scan nibble codes are
    the two serving regimes (small K leans harder on the empty-cluster
    re-seeding below: 16 seeds land in few visible clusters more often).

    ``init`` (K, D) warm-starts the centroids instead of sampling them —
    the codebook-refresh path (repro/index/refresh.py) refines the SERVING
    codebooks against drifted live data, so codes of unchanged rows move
    as little as the data demands.
    """
    n, d = x.shape
    assert k <= n, f"kmeans needs K <= N, got K={k} > N={n}"
    x = x.astype(jnp.float32)
    if init is None:
        perm = jax.random.permutation(key, n)
        cent0 = x[perm[:k]]
    else:
        assert init.shape == (k, d), (init.shape, (k, d))
        cent0 = jnp.asarray(init, jnp.float32)

    n_pad = (-n) % block
    xp = jnp.pad(x, ((0, n_pad), (0, 0)))
    nb = xp.shape[0] // block
    xb = xp.reshape(nb, block, d)
    validb = (jnp.arange(nb * block) < n).reshape(nb, block)

    def assign(cent):
        def one(args):
            xc, valid = args
            idx, dist = kops.kmeans_assign(xc, cent)
            return idx, jnp.where(valid, dist, -jnp.inf)  # pads never "worst"
        idx, dist = jax.lax.map(one, (xb, validb))
        return idx.reshape(-1)[:n], dist.reshape(-1)[:n]

    def body(_, cent):
        idx, dist = assign(cent)
        sums = jax.ops.segment_sum(x, idx, num_segments=k)
        counts = jax.ops.segment_sum(jnp.ones((n,), jnp.float32), idx,
                                     num_segments=k)
        new = sums / jnp.maximum(counts, 1.0)[:, None]
        # Re-seed empty clusters at the worst-quantized points.
        far = jax.lax.top_k(dist, k)[1]           # (K,) farthest point ids
        empty = counts == 0
        new = jnp.where(empty[:, None], x[far], new)
        return new

    cent = jax.lax.fori_loop(0, iters, body, cent0)
    idx, _ = assign(cent)
    return cent, idx


@functools.partial(jax.jit, static_argnames=("k", "iters", "block"))
def kmeans_multi(key: jax.Array, x: jax.Array, k: int, *, iters: int = 20,
                 block: int = 8192, init: jax.Array | None = None) -> jax.Array:
    """Independent k-means per leading axis: x (M, N, d) → centroids (M, K, d).

    This is exactly "train the M PQ sub-codebooks"; vmapped so all subspaces
    run in one XLA program. ``init`` (M, K, d) warm-starts every subspace
    (see :func:`kmeans`).
    """
    m = x.shape[0]
    keys = jax.random.split(key, m)
    if init is None:
        cent, _ = jax.vmap(
            lambda kk, xx: kmeans(kk, xx, k, iters=iters, block=block))(keys, x)
    else:
        cent, _ = jax.vmap(
            lambda kk, xx, c0: kmeans(kk, xx, k, iters=iters, block=block,
                                      init=c0))(keys, x, init)
    return cent
