"""Baseline quantizers (PQ / OPQ / Catalyst) + the shared serving model."""
from repro.pq.base import QuantizerModel, encode, decode, build_lut, adc, distortion  # noqa: F401
from repro.pq.pq import train_pq  # noqa: F401
from repro.pq.opq import train_opq  # noqa: F401
from repro.pq.kmeans import kmeans, kmeans_multi  # noqa: F401
