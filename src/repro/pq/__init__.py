"""Baseline quantizers (PQ / OPQ / Catalyst) + the shared serving model."""
from repro.pq.base import QuantizerModel, encode, decode, build_lut, adc, distortion  # noqa: F401
from repro.pq.pq import train_pq, train_pq_fs4  # noqa: F401
from repro.pq.opq import train_opq  # noqa: F401
from repro.pq.kmeans import kmeans, kmeans_multi  # noqa: F401
from repro.pq.pack import (QuantizedLUT, pack_codes, packed_width,  # noqa: F401
                           quantize_luts, unpack_codes)
