"""Fast-scan code packing + LUT quantization (the FAISS "fast scan" layout).

The serving hot loops are memory-bound (DESIGN.md §8): at K=16 a PQ code
needs only 4 bits, so two sub-codes pack into one byte — half the bytes per
distance — and the (M, K) f32 LUT quantizes to uint8 with a per-query affine
(scale, bias) — a quarter of the LUT bytes, small enough that a whole query
LUT tile lives in VMEM/L1. Distances accumulate exactly in int32 and
dequantize once per output:

    dist_f32 = scale * sum_j lut_u8[j, code_j] + M * bias

Packing convention (shared with kernels/ref.py and the fs Pallas kernels):
byte b of a row holds sub-code 2b in its LOW nibble and sub-code 2b+1 in its
HIGH nibble; odd M leaves the last byte's high nibble zero.

Everything here is pure jnp with no intra-repo imports, so any layer
(kernels, search, launch) may depend on it without cycles.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

FS_K = 16  # fast-scan codebook size: 4-bit codes, fixed by the nibble layout


class QuantizedLUT(NamedTuple):
    """Per-query uint8 ADC tables with the affine to undo them.

    lut:   (..., M, 16) uint8 — quantized per-subspace distance tables.
    scale: (...,) float32     — per-query step size ((max-min)/255).
    bias:  (...,) float32     — per-query minimum LUT entry.

    ``dist = scale * int_accumulate + M * bias``; the quantization error of
    a single distance is bounded by ``M * scale / 2`` (each of the M summed
    entries is off by at most half a step).
    """
    lut: jax.Array
    scale: jax.Array
    bias: jax.Array

    def dequantize(self) -> jax.Array:
        """(..., M, 16) f32 reconstruction (debug/error-analysis helper)."""
        sb = (None,) * (self.lut.ndim - self.scale.ndim - 2)
        return (self.lut.astype(jnp.float32)
                * self.scale[(...,) + sb + (None, None)]
                + self.bias[(...,) + sb + (None, None)])


def packed_width(m: int) -> int:
    """Bytes per packed code row for M sub-codes: ceil(M / 2)."""
    return (m + 1) // 2


def pack_codes(codes: jax.Array) -> jax.Array:
    """(N, M) sub-codes in [0, 16) → (N, ceil(M/2)) uint8 packed rows.

    Values ≥ 16 are a caller bug (train with K ≤ 16 for the fs4 layout);
    they are masked to 4 bits rather than silently corrupting neighbors.
    """
    n, m = codes.shape
    c = (codes.astype(jnp.uint8) & 0xF)
    if m % 2:
        c = jnp.concatenate([c, jnp.zeros((n, 1), jnp.uint8)], axis=1)
    lo, hi = c[:, 0::2], c[:, 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_codes(packed: jax.Array, m: int) -> jax.Array:
    """(N, ceil(M/2)) packed bytes → (N, M) uint8 sub-codes (inverse)."""
    p = packed.astype(jnp.uint8)
    lo = p & 0xF
    hi = (p >> 4) & 0xF
    full = jnp.stack([lo, hi], axis=-1).reshape(p.shape[0], -1)
    return full[:, :m]


def quantize_luts(luts: jax.Array) -> QuantizedLUT:
    """(Q, M, K≤16) f32 LUTs → per-query uint8 tables + (scale, bias).

    The affine is per QUERY (one scale/bias over the whole (M, K) table),
    matching the int32-accumulate dequantization above. K < 16 tables are
    zero-padded to 16 columns — codes never reference the padding because
    they were trained with the same K.
    """
    q, m, k = luts.shape
    if k > FS_K:
        raise ValueError(f"fast-scan LUTs need K <= {FS_K}, got K={k}")
    luts = luts.astype(jnp.float32)
    lo = jnp.min(luts.reshape(q, -1), axis=1)              # (Q,)
    hi = jnp.max(luts.reshape(q, -1), axis=1)
    scale = jnp.where(hi > lo, (hi - lo) / 255.0, 1.0)
    qv = jnp.clip(jnp.round((luts - lo[:, None, None]) / scale[:, None, None]),
                  0, 255).astype(jnp.uint8)
    if k < FS_K:
        qv = jnp.pad(qv, ((0, 0), (0, 0), (0, FS_K - k)))
    return QuantizedLUT(lut=qv, scale=scale, bias=lo)
