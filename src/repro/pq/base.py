"""Serving-side quantizer representation shared by PQ / OPQ / RPQ.

Every trainable quantizer in this repo (classic PQ, OPQ's alternating
optimization, the paper's learned RPQ) exports a :class:`QuantizerModel` —
an orthonormal rotation + codebooks — which is all the serving engine needs:
``encode`` the base vectors once offline, ``build_lut`` per query online,
``adc`` via the Pallas scan kernel.

Catalyst-style nonlinear encoders don't fit this linear form; they provide
the same *protocol* (codes + ``lut_fn``) via their own module.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


class QuantizerModel(NamedTuple):
    r: jax.Array          # (D, D) orthonormal rotation; identity for PQ
    codebooks: jax.Array  # (M, K, dsub)

    @property
    def dim(self) -> int:
        return self.r.shape[0]

    @property
    def m(self) -> int:
        return self.codebooks.shape[0]

    @property
    def k(self) -> int:
        return self.codebooks.shape[1]

    @property
    def dsub(self) -> int:
        return self.codebooks.shape[2]


def rotate_split(model: QuantizerModel, x: jax.Array) -> jax.Array:
    """(N, D) → (N, M, dsub) rotated sub-vectors."""
    xr = x @ model.r.T
    return xr.reshape(x.shape[0], model.m, model.dsub)


def encode(model: QuantizerModel, x: jax.Array, *, backend: str = "auto") -> jax.Array:
    """(N, D) → (N, M) hard codes (uint8 when K ≤ 256)."""
    d = kops.pq_pairwise(rotate_split(model, x), model.codebooks, backend=backend)
    codes = jnp.argmin(d, axis=-1)
    return codes.astype(jnp.uint8 if model.k <= 256 else jnp.int32)


def decode(model: QuantizerModel, codes: jax.Array) -> jax.Array:
    """(N, M) codes → (N, D) reconstruction in the ORIGINAL space (R^T x')."""
    sub = jnp.take_along_axis(
        model.codebooks[None], codes[:, :, None, None].astype(jnp.int32), axis=2
    )[:, :, 0, :]
    return sub.reshape(codes.shape[0], -1) @ model.r


def build_lut(model: QuantizerModel, queries: jax.Array, *,
              quantize: bool = False):
    """(Q, D) → (Q, M, K) per-query ADC lookup tables.

    ``quantize=True`` returns a :class:`repro.pq.pack.QuantizedLUT`
    instead — (Q, M, 16) uint8 tables + per-query (scale, bias) — for the
    fast-scan serving layout (requires K ≤ 16; pair with
    ``pack.pack_codes(encode(model, x))``).
    """
    qs = rotate_split(model, jnp.atleast_2d(queries))
    luts = kops.pq_pairwise(qs, model.codebooks, backend="ref")
    if not quantize:
        return luts
    from repro.pq.pack import quantize_luts
    return quantize_luts(luts)


def adc(model: QuantizerModel, codes: jax.Array, queries: jax.Array,
        *, backend: str = "auto") -> jax.Array:
    """(Q, D) × (N, M) → (Q, N) estimated squared distances."""
    return kops.adc_scan_batch(codes, build_lut(model, queries), backend=backend)


def distortion(model: QuantizerModel, x: jax.Array) -> jax.Array:
    """Mean squared reconstruction error (the vertex-oriented PQ objective)."""
    codes = encode(model, x)
    return jnp.mean(jnp.sum((x - decode(model, codes)) ** 2, axis=-1))


def identity_rotation(dim: int) -> jax.Array:
    return jnp.eye(dim, dtype=jnp.float32)
