"""Forward-compat shims for older jax (this repo targets the jax >= 0.5
sharding surface; the baked toolchain ships jax 0.4.37).

Patched surface (idempotent, attribute-adds only — NEVER initializes a
backend, so ``XLA_FLAGS`` set after ``import jax`` still takes effect):

* ``jax.shard_map``            — re-exported from ``jax.experimental``.
  ``check_rep`` defaults to False: 0.4.x replication rules are incomplete
  for ``top_k`` / ``axis_index`` used by the scatter-gather engine.
* ``jax.sharding.AxisType``    — Auto/Explicit/Manual enum stand-in.
* ``jax.make_mesh(axis_types=)`` — kwarg accepted and ignored (0.4.x
  meshes are implicitly Auto, which is what every caller here passes).

Loaded from ``repro/__init__.py`` and from ``src/sitecustomize.py`` (the
latter covers subprocesses that touch ``jax.sharding`` BEFORE importing
``repro`` — e.g. the elastic-restore test driver).
"""

from __future__ import annotations

import enum
import functools

import jax
import jax.sharding


class _AxisType(enum.Enum):
    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


def _compat_shard_map(f=None, /, *, mesh=None, in_specs=None, out_specs=None,
                      check_rep=False, **kwargs):
    from jax.experimental.shard_map import shard_map as _shard_map

    if f is None:  # decorator style: jax.shard_map(mesh=..., ...)(f)
        return functools.partial(_compat_shard_map, mesh=mesh,
                                 in_specs=in_specs, out_specs=out_specs,
                                 check_rep=check_rep, **kwargs)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_rep, **kwargs)


def apply() -> None:
    """Install the shims (no-ops on jax versions that already have them)."""
    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = _AxisType

    if not hasattr(jax, "shard_map"):
        jax.shard_map = _compat_shard_map

    try:
        import inspect
        sig = inspect.signature(jax.make_mesh)
        has_axis_types = "axis_types" in sig.parameters
    except (TypeError, ValueError):  # pragma: no cover - builtin signature
        has_axis_types = True
    if not has_axis_types and not getattr(jax.make_mesh, "_repro_compat", False):
        _orig = jax.make_mesh

        @functools.wraps(_orig)
        def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
            return _orig(axis_shapes, axis_names, **kw)

        make_mesh._repro_compat = True
        jax.make_mesh = make_mesh


def shard_map(f, *, mesh, in_specs, out_specs):
    """Version-agnostic shard_map used by the scatter-gather engine."""
    apply()
    return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs)
