"""Auto-loaded (via PYTHONPATH=src) jax forward-compat shims.

Python imports ``sitecustomize`` from sys.path at interpreter startup, so
any process launched with ``PYTHONPATH=src`` — including the test-suite
subprocesses that import ``jax.sharding`` before ``repro`` — gets the
``repro._compat`` patches (jax.shard_map / AxisType / make_mesh axis_types)
without needing to import the package first. Importing jax here does NOT
initialize a backend, so ``XLA_FLAGS`` set later by driver modules (e.g.
``--xla_force_host_platform_device_count``) still applies.
"""

try:
    from repro import _compat
except Exception:  # noqa: BLE001 - never break interpreter startup
    pass
else:
    _compat.apply()
