"""Streaming index under churn: QPS and recall@10 vs churn fraction.

The numbers behind DESIGN.md §10's claim that live mutation is nearly free
until consolidation folds it away: for churn fractions 0%, 5% and 10%
(that fraction of the corpus inserted AND the same count of base rows
deleted), measure the StreamingEngine's QPS and recall@10 against the LIVE
post-churn corpus — before consolidation (tombstoned beam + delta scan)
and after (next-generation compacted graph) — plus the consolidation wall
time.

A second, drifted-corpus section backs DESIGN.md §12 (codebook refresh):
the live distribution narrows hard (most clusters die, fresh rows land in
the survivors — far past 30% churn), then two IDENTICALLY churned engines
consolidate — one with frozen codebooks, one with ``refresh=`` retraining
the quantizer on the live graph — and both serve the same queries at the
same search budget. The ``streaming/drift/*`` rows record recall/QPS per
arm and the live-corpus distortion the refresh bought back;
``streaming/drift_summary`` carries the frozen-vs-refreshed gap the CI
bench job asserts on.

Run as a section of the driver (emits BENCH_streaming.json via --json-dir,
uploaded by the CI bench job):

    PYTHONPATH=src python -m benchmarks.run --only streaming
"""

from __future__ import annotations


def run():
    import time

    import numpy as np
    import jax

    from benchmarks import common as C
    from repro.index import BaseSegment, StreamingEngine
    from repro.pq import train_pq
    from repro.search.metrics import (live_ground_truth, measure_qps,
                                      recall_at_k)

    ds = C.dataset()
    # streaming sandbox: a slice of the bench corpus keeps the three churn
    # points + consolidations CI-sized; the held-out tail is the insert pool
    n0 = min(8000, ds.base.shape[0] * 4 // 5)
    base_x = np.asarray(ds.base[:n0])
    pool = np.asarray(ds.base[n0:])
    queries = ds.queries
    k, h = 10, 32

    model = train_pq(jax.random.PRNGKey(5), ds.train, *C.KM, iters=10)
    seg0 = BaseSegment.build(jax.random.PRNGKey(6), base_x, model,
                             r=24, l=48, batch=2048)
    rows = []

    def evaluate(tag, engine, live, all_x, extra=""):
        gt_g = live_ground_truth(all_x, np.flatnonzero(live), queries, k)
        qps, res = measure_qps(
            lambda q: engine.search(q, k=k, h=h), queries, repeats=2)
        rec = recall_at_k(res.ids, gt_g, k)
        rows.append((f"streaming/{tag}", 1e6 / max(qps, 1e-9),
                     f"recall={rec:.3f};qps={qps:.1f};live={engine.n_live};"
                     f"gen={engine.generation}{extra}"))

    for frac in (0.0, 0.05, 0.10):
        nc = int(n0 * frac)
        engine = StreamingEngine(seg0, model,
                                 delta_capacity=max(nc, 1))
        live = np.zeros(n0 + max(nc, 1), bool)
        live[:n0] = True
        all_x = np.concatenate([base_x, pool[:nc]]) if nc else base_x
        if nc:
            gids = engine.insert(pool[:nc])
            live[gids] = True
            dead = np.random.default_rng(13).choice(n0, nc, replace=False)
            engine.delete(dead)
            live[dead] = False
        tag = f"churn{int(frac * 100)}"
        evaluate(f"{tag}/pre", engine, live, all_x)
        t0 = time.time()
        stats = engine.consolidate()
        wall = time.time() - t0
        old_live = np.flatnonzero(live)
        live2 = np.zeros(stats["n"] + max(nc, 1), bool)
        live2[stats["old2new"][old_live]] = True
        evaluate(f"{tag}/post_consolidate", engine, live2,
                 np.asarray(engine.base.vectors),
                 extra=f";consolidate_s={wall:.2f}")
    rows.extend(drift_rows())
    return rows


def drift_rows():
    """Frozen vs refreshed codebooks under distribution drift (DESIGN.md
    §12): the live corpus narrows to a quarter of its clusters (~75%
    deletes + fresh in-survivor inserts), both arms consolidate from the
    SAME churned state, both serve the same drifted queries at h=32."""
    import time

    import numpy as np
    import jax
    import jax.numpy as jnp

    from benchmarks import common as C
    from repro.graphs import build_vamana
    from repro.graphs.knn import knn_ids
    from repro.index import BaseSegment, RefreshConfig, StreamingEngine
    from repro.index.segment import encode_codes
    from repro.pq import train_pq
    from repro.search.metrics import measure_qps, recall_at_k

    # self-contained drift sandbox: cluster labels drive the drift, and the
    # small per-subspace codebook (M=8, K=16 — the fs4 budget) on 32-d data
    # is the regime where re-allocating codewords to the live regions
    # matters most (at the bench corpus's own dim the codes are too coarse
    # for recall to resolve the gap)
    r = np.random.default_rng(1)
    n, d, nc, n_keep = (4000, 32, 24, 6) if C.QUICK else (20000, 32, 32, 8)
    centers = r.normal(size=(nc, d)).astype(np.float32) * 3
    lab = r.integers(0, nc, n)
    z = centers[lab] + r.normal(size=(n, d)).astype(np.float32)
    basis = (np.linalg.qr(r.normal(size=(d, d)))[0]
             @ np.diag(np.linspace(1.5, 0.3, d))).astype(np.float32)
    x = (z @ basis).astype(np.float32)
    model = train_pq(jax.random.PRNGKey(5), jnp.asarray(x), 8, 16, iters=10)
    graph = build_vamana(jax.random.PRNGKey(6), jnp.asarray(x), r=16, l=32,
                         batch=2048)

    keep_c = np.arange(n_keep)
    dead = np.flatnonzero(~np.isin(lab, keep_c))
    n_ins = n // 4
    zi = centers[r.choice(keep_c, n_ins)] + r.normal(
        size=(n_ins, d)).astype(np.float32)
    xnew = (zi @ basis).astype(np.float32)
    churn_frac = (dead.size + n_ins) / n

    def churned():
        seg = BaseSegment(graph=graph,
                          codes=jnp.asarray(encode_codes(model, x, "u8")),
                          vectors=jnp.asarray(x), layout="u8")
        e = StreamingEngine(seg, model, delta_capacity=n_ins)
        e.insert(xnew)
        e.delete(dead)
        return e

    # post-churn ground truth: compaction order (base survivors then live
    # delta, both in order) makes corpus row == post-consolidation gid
    live_base = np.setdiff1d(np.arange(n), dead)
    corpus = np.concatenate([x[live_base], xnew]).astype(np.float32)
    nq = 100 if C.QUICK else 500
    zq = centers[r.choice(keep_c, nq)] + r.normal(
        size=(nq, d)).astype(np.float32)
    queries = jnp.asarray((zq @ basis).astype(np.float32))
    gt, _ = knn_ids(jnp.asarray(corpus), queries, 10)

    out = []
    recalls = {}
    for tag, refresh in (("frozen", None),
                         ("refreshed", RefreshConfig(steps=30,
                                                     kmeans_iters=10))):
        engine = churned()
        t0 = time.time()
        stats = engine.consolidate(refresh=refresh)
        wall = time.time() - t0
        qps, res = measure_qps(
            lambda q: engine.search(q, k=10, h=32), queries, repeats=2)
        rec = recall_at_k(res.ids, gt, 10)
        recalls[tag] = rec
        extra = ""
        if stats["refreshed"]:
            rep = stats["refresh"]
            extra = (f";distortion_before={rep['distortion_before']:.3f}"
                     f";distortion_after={rep['distortion_after']:.3f}")
        out.append((f"streaming/drift/{tag}", 1e6 / max(qps, 1e-9),
                    f"recall={rec:.3f};qps={qps:.1f};"
                    f"consolidate_s={wall:.2f};live={engine.n_live}"
                    f"{extra}"))
    out.append(("streaming/drift_summary", 0.0,
                f"frozen={recalls['frozen']:.3f};"
                f"refreshed={recalls['refreshed']:.3f};"
                f"delta={recalls['refreshed'] - recalls['frozen']:.3f};"
                f"churn={churn_frac:.2f}"))
    return out


def main():
    print("name,us_per_call,derived")
    for r in run():
        print(f"{r[0]},{r[1]:.2f},{r[2]}", flush=True)


if __name__ == "__main__":
    main()
