"""Streaming index under churn: QPS and recall@10 vs churn fraction.

The numbers behind DESIGN.md §10's claim that live mutation is nearly free
until consolidation folds it away: for churn fractions 0%, 5% and 10%
(that fraction of the corpus inserted AND the same count of base rows
deleted), measure the StreamingEngine's QPS and recall@10 against the LIVE
post-churn corpus — before consolidation (tombstoned beam + delta scan)
and after (next-generation compacted graph) — plus the consolidation wall
time.

Run as a section of the driver (emits BENCH_streaming.json via --json-dir,
uploaded by the CI bench job):

    PYTHONPATH=src python -m benchmarks.run --only streaming
"""

from __future__ import annotations


def run():
    import time

    import numpy as np
    import jax

    from benchmarks import common as C
    from repro.index import BaseSegment, StreamingEngine
    from repro.pq import train_pq
    from repro.search.metrics import (live_ground_truth, measure_qps,
                                      recall_at_k)

    ds = C.dataset()
    # streaming sandbox: a slice of the bench corpus keeps the three churn
    # points + consolidations CI-sized; the held-out tail is the insert pool
    n0 = min(8000, ds.base.shape[0] * 4 // 5)
    base_x = np.asarray(ds.base[:n0])
    pool = np.asarray(ds.base[n0:])
    queries = ds.queries
    k, h = 10, 32

    model = train_pq(jax.random.PRNGKey(5), ds.train, *C.KM, iters=10)
    seg0 = BaseSegment.build(jax.random.PRNGKey(6), base_x, model,
                             r=24, l=48, batch=2048)
    rows = []

    def evaluate(tag, engine, live, all_x, extra=""):
        gt_g = live_ground_truth(all_x, np.flatnonzero(live), queries, k)
        qps, res = measure_qps(
            lambda q: engine.search(q, k=k, h=h), queries, repeats=2)
        rec = recall_at_k(res.ids, gt_g, k)
        rows.append((f"streaming/{tag}", 1e6 / max(qps, 1e-9),
                     f"recall={rec:.3f};qps={qps:.1f};live={engine.n_live};"
                     f"gen={engine.generation}{extra}"))

    for frac in (0.0, 0.05, 0.10):
        nc = int(n0 * frac)
        engine = StreamingEngine(seg0, model,
                                 delta_capacity=max(nc, 1))
        live = np.zeros(n0 + max(nc, 1), bool)
        live[:n0] = True
        all_x = np.concatenate([base_x, pool[:nc]]) if nc else base_x
        if nc:
            gids = engine.insert(pool[:nc])
            live[gids] = True
            dead = np.random.default_rng(13).choice(n0, nc, replace=False)
            engine.delete(dead)
            live[dead] = False
        tag = f"churn{int(frac * 100)}"
        evaluate(f"{tag}/pre", engine, live, all_x)
        t0 = time.time()
        stats = engine.consolidate()
        wall = time.time() - t0
        old_live = np.flatnonzero(live)
        live2 = np.zeros(stats["n"] + max(nc, 1), bool)
        live2[stats["old2new"][old_live]] = True
        evaluate(f"{tag}/post_consolidate", engine, live2,
                 np.asarray(engine.base.vectors),
                 extra=f";consolidate_s={wall:.2f}")
    return rows


def main():
    print("name,us_per_call,derived")
    for r in run():
        print(f"{r[0]},{r[1]:.2f},{r[2]}", flush=True)


if __name__ == "__main__":
    main()
