"""One benchmark per paper table/figure (driven by benchmarks/run.py).

Every function returns a list of CSV rows and prints them; run.py drives.
Scales are sandbox-sized (REPRO_BENCH_SCALE=full for paper-relative sizes);
the claims being validated are the paper's ORDERINGS (RPQ ≥ OPQ ≥ PQ at
matched recall, joint > single-feature ablations, K/M monotonicity), not
absolute QPS of a 1-core CPU.
"""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import common as C


# ---------------------------------------------------------------- Table 2
def table2_features():
    """Paper Table 2: routing quality when the ranking drops geometric
    information. Operationalization: ADC routing (full query geometry; all
    Eq.-5 terms) vs SDC routing (query quantized too — the angular term is
    collapsed onto the codebook grid)."""
    from repro.pq import base
    from repro.search.engine import InMemoryEngine
    from repro.search.metrics import recall_at_k

    ds, gt, g = C.dataset(), C.ground_truth(), C.vamana_graph()
    codes, lut_fn, _ = C.quantizer("pq")
    rows = []
    eng = InMemoryEngine(g, codes, lut_fn)
    t0 = time.time()
    res = eng.search(ds.queries, k=10, h=32)
    adc_rec = recall_at_k(res.ids, gt, 10)
    us = (time.time() - t0) / C.N_QUERY * 1e6

    # SDC: quantize the query first (decode(encode(q))), then ADC on that
    from repro.pq.base import QuantizerModel, encode as enc, decode as dec
    model_codes, model_lut, _ = C.quantizer("pq")
    # rebuild model from quantizer cache: recompute for clarity
    from repro.pq import train_pq
    model = train_pq(jax.random.PRNGKey(1), ds.train, *C.KM, iters=15)
    q_sdc = dec(model, enc(model, ds.queries))
    res2 = eng.search(q_sdc, k=10, h=32)
    sdc_rec = recall_at_k(res2.ids, gt, 10)
    rows.append(("table2/adc_full_geometry", us, f"recall={adc_rec:.3f}"))
    rows.append(("table2/sdc_no_query_geometry", us, f"recall={sdc_rec:.3f}"))
    rows.append(("table2/claim_adc_better", 0.0,
                 f"ok={adc_rec >= sdc_rec}"))
    return rows


# ------------------------------------------------------------- Fig 5 / 6/7
def fig5_hybrid(methods=("pq", "opq", "catalyst", "rpq")):
    """QPS / hops / (modeled) IO vs recall@10, DiskANN-style hybrid."""
    from repro.search.engine import HybridEngine

    ds, gt, g = C.dataset(), C.ground_truth(), C.vamana_graph()
    rows = []
    for meth in methods:
        codes, lut_fn, aux = C.quantizer(meth)
        eng = HybridEngine(g, codes, lut_fn, vectors=ds.base)
        curve = C.sweep_engine(eng, ds.queries, gt)
        for p in curve:
            rows.append((f"fig5/{meth}/h{p['h']}", 1e6 / max(p["qps"], 1e-9),
                         f"recall={p['recall']:.3f};qps={p['qps']:.1f};"
                         f"hops={p['hops']:.1f}"))
        for tgt in C.RECALL_TARGETS:
            q = C.qps_at_recall(curve, tgt)
            rows.append((f"fig5/{meth}/qps@{int(tgt*100)}", 0.0,
                         f"qps={q:.1f}" if q else "unreached"))
    return rows


def fig6_memory(methods=("pq", "opq", "rpq")):
    """In-memory scenario over HNSW and NSG graphs (paper Figs. 6-7)."""
    from repro.graphs import build_hnsw, build_nsg, descend
    from repro.search.engine import InMemoryEngine

    ds, gt = C.dataset(), C.ground_truth()
    rows = []
    h = build_hnsw(jax.random.PRNGKey(2), ds.base, m=12)
    nsg = build_nsg(jax.random.PRNGKey(3), ds.base, r=24, k=32, search_l=32)
    for meth in methods:
        codes, lut_fn, _ = C.quantizer(meth)
        eng_h = InMemoryEngine(h.base, codes, lut_fn,
                               entry_fn=lambda q: descend(h, q, ds.base))
        eng_n = InMemoryEngine(nsg, codes, lut_fn)
        for tag, eng in (("hnsw", eng_h), ("nsg", eng_n)):
            curve = C.sweep_engine(eng, ds.queries, gt)
            best = max(p["recall"] for p in curve)
            q90 = C.qps_at_recall(curve, 0.90)
            rows.append((f"fig67/{tag}-{meth}/best", 0.0,
                         f"best_recall={best:.3f};"
                         f"qps@90={'%.1f' % q90 if q90 else 'unreached'}"))
            for p in curve:
                rows.append((f"fig67/{tag}-{meth}/h{p['h']}",
                             1e6 / max(p["qps"], 1e-9),
                             f"recall={p['recall']:.3f};qps={p['qps']:.1f}"))
    return rows


# ------------------------------------------------------------- Table 4 / 5
def table45_cost():
    rows = []
    for meth in ("pq", "opq", "catalyst", "rpq"):
        _, _, aux = C.quantizer(meth)
        rows.append((f"table4/train_wall/{meth}", aux["wall_s"] * 1e6,
                     f"seconds={aux['wall_s']:.1f}"))
        rows.append((f"table5/model_bytes/{meth}", 0.0,
                     f"bytes={aux['bytes']}"))
    return rows


# ------------------------------------------------------------- Table 6 / 7
def table67_ablation():
    """RPQ vs RPQ w/N vs RPQ w/R (hybrid + in-memory), fixed beam."""
    from repro.search.engine import HybridEngine, InMemoryEngine
    from repro.search.metrics import measure_qps, recall_at_k

    ds, gt, g = C.dataset(), C.ground_truth(), C.vamana_graph()
    rows = []
    for meth, tag in (("rpq", "joint"), ("rpq_n", "w_N"), ("rpq_r", "w_R"),
                      ("pq", "none")):
        codes, lut_fn, _ = C.quantizer(meth)
        hyb = HybridEngine(g, codes, lut_fn, vectors=ds.base)
        mem = InMemoryEngine(g, codes, lut_fn)
        qps_h, res_h = measure_qps(lambda q: hyb.search(q, k=10, h=32),
                                   ds.queries, repeats=2)
        qps_m, res_m = measure_qps(lambda q: mem.search(q, k=10, h=32),
                                   ds.queries, repeats=2)
        rows.append((f"table6/hybrid/{tag}", 1e6 / qps_h,
                     f"recall={recall_at_k(res_h.ids, gt, 10):.3f};"
                     f"qps={qps_h:.1f}"))
        rows.append((f"table7/inmem/{tag}", 1e6 / qps_m,
                     f"recall={recall_at_k(res_m.ids, gt, 10):.3f};"
                     f"qps={qps_m:.1f}"))
    return rows


# ----------------------------------------------------------------- Fig 8
def fig8_kposneg():
    from repro.core import RPQConfig, TrainConfig, train_rpq
    from repro.pq import base
    from repro.search.engine import HybridEngine
    from repro.search.metrics import recall_at_k

    ds, gt, g = C.dataset(), C.ground_truth(), C.vamana_graph()
    rows = []
    steps = max(C.RPQ_STEPS // 2, 100)
    for k_pos, k_neg in ((5, 50), (10, 30), (20, 20)):
        cfg = RPQConfig(dim=C.DIM, m=C.KM[0], k=C.KM[1])
        tcfg = TrainConfig(steps=steps, refresh_every=steps // 2,
                           triplet_batch=512, routing_batch=512,
                           routing_pool_queries=64, k_pos=k_pos, k_neg=k_neg,
                           log_every=steps)
        rpq = train_rpq(jax.random.PRNGKey(4), ds.train, g, cfg=cfg,
                        tcfg=tcfg, verbose=False)
        codes = base.encode(rpq.model, ds.base)
        eng = HybridEngine(g, codes, rpq.lut_fn(), vectors=ds.base)
        res = eng.search(ds.queries, k=10, h=32)
        rows.append((f"fig8/kpos{k_pos}_kneg{k_neg}", 0.0,
                     f"ratio={k_pos/k_neg:.2f};"
                     f"recall={recall_at_k(res.ids, gt, 10):.3f}"))
    return rows


# -------------------------------------------------------------- Fig 9 / 10
def fig9_km():
    from repro.pq import base, train_pq
    from repro.search.engine import HybridEngine
    from repro.search.metrics import recall_at_k

    ds, gt, g = C.dataset(), C.ground_truth(), C.vamana_graph()
    rows = []
    for m in (4, 8):
        for k in (16, 64, 256):
            model = train_pq(jax.random.PRNGKey(5), ds.train, m, k, iters=10)
            codes = base.encode(model, ds.base)
            eng = HybridEngine(g, codes,
                               lambda q, _model=model: base.build_lut(_model, q),
                               vectors=ds.base)
            res = eng.search(ds.queries, k=10, h=32)
            rows.append((f"fig9/M{m}_K{k}", 0.0,
                         f"recall={recall_at_k(res.ids, gt, 10):.3f}"))
    return rows


# ------------------------------------------------------------ Fig 11 / 12
def fig11_scale():
    from repro.data.synth import DatasetSpec, synth
    from repro.graphs import build_vamana
    from repro.graphs.knn import knn_ids
    from repro.pq import base, train_pq
    from repro.search.engine import HybridEngine
    from repro.search.metrics import measure_qps, recall_at_k

    rows = []
    scales = (5_000, 12_000) if C.QUICK else (10_000, 100_000, 500_000)
    for n in scales:
        spec = DatasetSpec(f"scale{n}", C.DIM, n, 100, 64, 0.35, 0.1, seed=9)
        ds = synth(spec)
        gt, _ = knn_ids(ds.base, ds.queries, 10)
        g = build_vamana(jax.random.PRNGKey(0), ds.base, r=24, l=48,
                         batch=2048)
        model = train_pq(jax.random.PRNGKey(1), ds.train, *C.KM, iters=10)
        codes = base.encode(model, ds.base)
        eng = HybridEngine(g, codes, lambda q: base.build_lut(model, q),
                           vectors=ds.base)
        qps, res = measure_qps(lambda q: eng.search(q, k=10, h=32),
                               ds.queries, repeats=2)
        rows.append((f"fig11/n{n}", 1e6 / qps,
                     f"recall={recall_at_k(res.ids, gt, 10):.3f};"
                     f"qps={qps:.1f}"))
    return rows
