"""Benchmark driver — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig5,table67] [--list]

Prints ``name,us_per_call,derived`` CSV rows (brief's contract). Scale via
REPRO_BENCH_SCALE=quick|full (default quick: single-core-CPU sized).
Roofline terms come from the separate dry-run pipeline:
    python -m repro.launch.dryrun && python -m benchmarks.roofline
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def sections():
    from benchmarks import kernel_adc, paper_tables as pt
    from benchmarks import sharded_serving

    return {
        "kernels": kernel_adc.run,
        "table2": pt.table2_features,
        "fig5": pt.fig5_hybrid,
        "fig67": pt.fig6_memory,
        "table45": pt.table45_cost,
        "table67": pt.table67_ablation,
        "fig8": pt.fig8_kposneg,
        "fig9": pt.fig9_km,
        "fig11": pt.fig11_scale,
        # beyond the paper: multi-device serving scenarios (DESIGN.md §6);
        # run `python -m benchmarks.sharded_serving` standalone for a
        # forced 4-shard host split
        "sharded": sharded_serving.run,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated section names")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    secs = sections()
    if args.list:
        print("\n".join(secs))
        return
    chosen = (args.only.split(",") if args.only else list(secs))
    print("name,us_per_call,derived")
    failures = 0
    for name in chosen:
        t0 = time.time()
        try:
            rows = secs[name]()
            for r in rows:
                print(f"{r[0]},{r[1]:.2f},{r[2]}", flush=True)
            print(f"_section/{name},{(time.time()-t0)*1e6:.0f},wall_s="
                  f"{time.time()-t0:.1f}", flush=True)
        except Exception:
            failures += 1
            print(f"_section/{name},0,FAILED", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
