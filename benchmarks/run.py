"""Benchmark driver — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig5,table67] [--list] \
        [--json-dir reports/bench]

Prints ``name,us_per_call,derived`` CSV rows (brief's contract) AND writes a
machine-readable ``BENCH_<section>.json`` per section to ``--json-dir`` —
{git_sha, scale, rows: [{name, us_per_call, derived{...}}], wall_s} — so
perf PRs can diff against a committed/uploaded baseline (CI uploads the
``kernels`` section's artifact on every run). Scale via
REPRO_BENCH_SCALE=quick|full (default quick: single-core-CPU sized).
Roofline terms come from the separate dry-run pipeline:
    python -m repro.launch.dryrun && python -m benchmarks.roofline
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
import traceback


def sections():
    from benchmarks import disk_serving, kernel_adc, paper_tables as pt
    from benchmarks import resilience, sharded_serving, streaming

    return {
        "kernels": kernel_adc.run,
        "table2": pt.table2_features,
        "fig5": pt.fig5_hybrid,
        "fig67": pt.fig6_memory,
        "table45": pt.table45_cost,
        "table67": pt.table67_ablation,
        "fig8": pt.fig8_kposneg,
        "fig9": pt.fig9_km,
        "fig11": pt.fig11_scale,
        # beyond the paper: multi-device serving scenarios (DESIGN.md §6);
        # run `python -m benchmarks.sharded_serving` standalone for a
        # forced 4-shard host split
        "sharded": sharded_serving.run,
        # streaming mutable index under churn (DESIGN.md §10): recall/QPS
        # at 0/5/10% inserts+deletes, before and after consolidation
        "streaming": streaming.run,
        # resilience under injected faults (DESIGN.md §13): deadline
        # budgets, the degradation ladder, snapshot corruption/crash
        # drills, and the seeded 4-shard chaos acceptance row
        "resilience": resilience.run,
        # all-in-storage serving tier (DESIGN.md §14): double-buffered
        # frontier prefetch vs serial read-then-compute at equal recall,
        # cache hit-rates, and the model-vs-measured io_time cross-check
        "disk": disk_serving.run,
    }


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, cwd=os.path.dirname(os.path.dirname(
                os.path.abspath(__file__)))).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def _parse_derived(derived: str) -> dict:
    """'gcodes_per_s=0.98 speedup_vs_f32=2.1' → typed dict (floats where
    they parse, strings otherwise — e.g. recall curves stay strings).
    Accepts space, comma and semicolon separators (the serving sections
    emit 'recall=…;qps=…' rows)."""
    out = {}
    for tok in derived.replace(",", " ").replace(";", " ").split():
        if "=" not in tok:
            continue
        key, val = tok.split("=", 1)
        try:
            out[key] = float(val)
        except ValueError:
            out[key] = val
    return out


def _write_json(json_dir: str, section: str, rows, wall_s: float,
                sha: str) -> str:
    os.makedirs(json_dir, exist_ok=True)
    path = os.path.join(json_dir, f"BENCH_{section}.json")
    doc = {
        "section": section,
        "git_sha": sha,
        "scale": os.environ.get("REPRO_BENCH_SCALE", "quick"),
        "wall_s": round(wall_s, 3),
        "rows": [{"name": r[0], "us_per_call": round(float(r[1]), 2),
                  "derived": _parse_derived(r[2]), "derived_raw": r[2]}
                 for r in rows],
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated section names")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--json-dir", default="reports/bench",
                    help="directory for BENCH_<section>.json artifacts "
                    "(empty string disables)")
    args = ap.parse_args()

    secs = sections()
    if args.list:
        print("\n".join(secs))
        return
    chosen = (args.only.split(",") if args.only else list(secs))
    sha = _git_sha()
    print("name,us_per_call,derived")
    failures = 0
    for name in chosen:
        t0 = time.time()
        try:
            rows = secs[name]()
            for r in rows:
                print(f"{r[0]},{r[1]:.2f},{r[2]}", flush=True)
            wall = time.time() - t0
            print(f"_section/{name},{wall*1e6:.0f},wall_s={wall:.1f}",
                  flush=True)
            if args.json_dir:
                path = _write_json(args.json_dir, name, rows, wall, sha)
                print(f"[bench] wrote {path}", file=sys.stderr, flush=True)
        except Exception:
            failures += 1
            print(f"_section/{name},0,FAILED", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
