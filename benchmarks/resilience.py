"""Resilience under injected faults: recall/QPS/p99 with honest degradation.

The numbers behind DESIGN.md §13's claim that every failure mode degrades
into a cheaper-but-honest answer instead of an error:

* ``resilience/fault_free/*``   — the healthy baseline (recall@10, QPS,
  p99 batch latency) every faulted row is compared against.
* ``resilience/deadline/r*``    — the compute-budget sweep: the beam hard-
  capped at B rounds returns best-so-far with per-query ``truncated``
  flags; recall falls monotonically with B, rounds never exceed it.
* ``resilience/degrade/L*``     — the degradation ladder (search/degrade
  .py): each rung sheds the next recall-for-compute knob; n_dist falls
  with the level.
* ``resilience/io_retry``       — transient-read faults on checkpoint
  restore, retried with exponential backoff + jitter (dist/retry.py):
  the restore succeeds, the row records observed injected faults and the
  closed-form expected retry time.
* ``resilience/snapshot_fallback`` — the newest snapshot's bytes are
  silently flipped (zip-consistent — only the manifest CRC32 can catch
  it); restore() falls back to the newest INTACT generation.
* ``resilience/crash_consolidate`` — an injected crash between the atomic
  snapshot and the in-memory swap; a restart restores the just-written
  generation.
* ``resilience/sharded/*``      — the seeded chaos acceptance drill on a
  forced 4-device host split (subprocess): the ISSUE plan {1 dead shard +
  1 straggler charged dead by the quorum deadline} at the same round
  budget as fault-free. Faulted recall is scored against the REACHABLE
  corpus (rows of the merged shards) — a dead shard's rows are gone by
  construction, and the honest claim is that the surviving shards still
  find their part.
* ``resilience/summary``        — the SLO row CI asserts on:
  ``recall_drop`` (faulted vs fault-free, equal deadline) must stay
  within 5 points.

Run as a section of the driver (emits BENCH_resilience.json):

    PYTHONPATH=src python -m benchmarks.run --only resilience
"""

from __future__ import annotations

import os
import subprocess
import sys

# the chaos acceptance drill needs real shards to kill; forced 4-way host
# split in a subprocess, same pattern as tests/test_sharded_graph.py
_SUBPROC_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.graphs.partition import build_partitioned_vamana, shard_bounds
from repro.pq.pq import train_pq
from repro.pq import base as pqbase
from repro.dist.fault import ChaosPlan, resolve_quorum
from repro.graphs.knn import knn_ids
from repro.search.engine import ShardedGraphEngine
from repro.search.metrics import live_ground_truth, recall_at_k

N, D, Q, K, TOPK, H, BUDGET = 2048, 32, 100, 16, 10, 32, 48
r = np.random.default_rng(7)
centers = r.normal(size=(16, D)) * 2.5
x = (centers[r.integers(0, 16, N)] + r.normal(size=(N, D))).astype(np.float32)
q = (centers[r.integers(0, 16, Q)] + r.normal(size=(Q, D))).astype(np.float32)
x, q = jnp.asarray(x), jnp.asarray(q)
model = train_pq(jax.random.PRNGKey(0), x, 8, K, iters=8)
codes = pqbase.encode(model, x)
lut_fn = lambda qq: pqbase.build_lut(model, qq)
pg = build_partitioned_vamana(jax.random.PRNGKey(1), x, 4, r=16, l=32)
eng = ShardedGraphEngine(pg, codes, lut_fn, vectors=x)
gt, _ = knn_ids(x, q, TOPK)
gt = np.asarray(gt)

free = eng.search(q, k=TOPK, h=H, max_rounds=BUDGET)
rec_free = recall_at_k(free.ids, gt, TOPK)
print(f"ROW sharded/fault_free recall={rec_free:.3f};"
      f"rounds={float(np.asarray(free.rounds).mean()):.2f};"
      f"truncated={float(np.asarray(free.truncated).mean()):.2f};"
      f"degraded={int(free.degraded)}")

plan = ChaosPlan(seed=7, dead_shards=(0,), straggler_shards=(1,),
                 straggler_latency_s=0.050, shard_latency_s=0.002)
deadline = 0.010                      # straggler (50ms) misses it
fault = eng.search(q, k=TOPK, h=H, max_rounds=BUDGET,
                   alive=plan.alive(4), deadline_s=deadline,
                   shard_latency_s=list(plan.latencies(4)))
dec = resolve_quorum(plan.alive(4), list(plan.latencies(4)), deadline, None)
bounds = shard_bounds(N, 4)
reach = np.concatenate([np.arange(lo, hi)
                        for s, (lo, hi) in enumerate(bounds) if dec.alive[s]])
gt_reach = live_ground_truth(np.asarray(x), reach, q, TOPK)
rec_fault = recall_at_k(fault.ids, gt_reach, TOPK)
assert fault.degraded, "dead+straggler must mark the answer degraded"
assert not np.isin(np.asarray(fault.ids),
                   np.setdiff1d(np.arange(N), reach)).any(), \
    "a merged answer leaked rows from a dead/straggler shard"
print(f"ROW sharded/chaos_dead0_straggler1 recall={rec_fault:.3f};"
      f"gt=reachable;merged={sum(dec.alive)}/4;deadline_ms=10;"
      f"rounds={float(np.asarray(fault.rounds).mean()):.2f};"
      f"degraded={int(fault.degraded)}")
print(f"SUMMARY recall_free={rec_free:.4f} recall_fault={rec_fault:.4f}")
"""


def _chaos_subprocess_rows():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(os.path.dirname(
                       os.path.abspath(__file__))), "src")]
                   + os.environ.get("PYTHONPATH", "").split(os.pathsep)))
    proc = subprocess.run([sys.executable, "-c", _SUBPROC_CODE],
                          capture_output=True, text=True, timeout=1200,
                          env=env)
    if proc.returncode != 0:
        raise RuntimeError(f"chaos subprocess failed:\n{proc.stdout}\n"
                           f"{proc.stderr}")
    rows, summary = [], {}
    for line in proc.stdout.splitlines():
        if line.startswith("ROW "):
            _, name, derived = line.split(" ", 2)
            rows.append((f"resilience/{name}", 0.0, derived))
        elif line.startswith("SUMMARY "):
            for tok in line.split()[1:]:
                key, val = tok.split("=")
                summary[key] = float(val)
    return rows, summary


def run():
    import tempfile
    import time

    import numpy as np
    import jax
    import jax.numpy as jnp

    from benchmarks import common as C
    from repro.dist import checkpoint as ckpt
    from repro.dist.fault import (ChaosPlan, InjectedFailure,
                                  corrupt_snapshot)
    from repro.dist.retry import RetryPolicy, expected_retry_time_s
    from repro.index import BaseSegment, StreamingEngine
    from repro.index.segment import encode_codes
    from repro.graphs import build_vamana
    from repro.pq.pq import train_pq
    from repro.search.degrade import MAX_LEVEL, DegradationPolicy
    from repro.search.engine import HybridEngine, InMemoryEngine
    from repro.search.metrics import recall_at_k

    ds, gt, g = C.dataset(), C.ground_truth(), C.vamana_graph()
    codes, lut_fn, _ = C.quantizer("pq")
    k, h = 10, 32
    rows = []

    def timed(engine, repeats=3, chunk=64, **kw):
        """Chunked serving loop → (recall, qps, p99 batch ms, result)."""
        q = np.asarray(ds.queries)
        res = engine.search(jnp.asarray(q[:chunk]), k=k, **kw)  # warmup
        jax.block_until_ready(res.dists)
        lats, ids = [], []
        for _ in range(repeats):
            ids = []
            for s in range(0, len(q), chunk):
                t0 = time.perf_counter()
                res = engine.search(jnp.asarray(q[s:s + chunk]), k=k, **kw)
                jax.block_until_ready(res.dists)
                lats.append(time.perf_counter() - t0)
                ids.append(np.asarray(res.ids))
        rec = recall_at_k(np.concatenate(ids), gt, k)
        qps = chunk / max(float(np.mean(lats)), 1e-12)
        p99 = float(np.percentile(lats, 99)) * 1e3
        return rec, qps, p99, res

    # ---- fault-free baseline --------------------------------------------
    mem = InMemoryEngine(g, codes, lut_fn)
    rec0, qps0, p99_0, res0 = timed(mem, h=h)
    rounds0 = float(np.asarray(res0.rounds).mean())
    rows.append((f"resilience/fault_free/h{h}", 1e6 / max(qps0, 1e-9),
                 f"recall={rec0:.3f};qps={qps0:.1f};p99_ms={p99_0:.2f};"
                 f"rounds={rounds0:.2f}"))

    # ---- deadline sweep: hard round budgets, honest truncation ----------
    for budget in (2, 4, 8, 16):
        rec, qps, p99, res = timed(mem, h=h, max_rounds=budget)
        rmax = int(np.asarray(res.rounds).max())
        if rmax > budget:
            raise SystemExit(f"budget violated: rounds {rmax} > {budget}")
        rows.append((f"resilience/deadline/r{budget}",
                     1e6 / max(qps, 1e-9),
                     f"recall={rec:.3f};qps={qps:.1f};p99_ms={p99:.2f};"
                     f"budget={budget};rounds_max={rmax};"
                     f"truncated="
                     f"{float(np.asarray(res.truncated).mean()):.2f}"))

    # ---- degradation ladder ---------------------------------------------
    hyb = HybridEngine(g, codes, lut_fn, vectors=np.asarray(ds.base))
    policy = DegradationPolicy()
    for lvl in range(MAX_LEVEL + 1):
        kw = policy.apply(hyb, lvl, h=h, expand=4, entries=8,
                          prune_eps=0.1)
        rec, qps, p99, res = timed(hyb, **kw)
        rows.append((f"resilience/degrade/L{lvl}", 1e6 / max(qps, 1e-9),
                     f"recall={rec:.3f};qps={qps:.1f};p99_ms={p99:.2f};"
                     f"n_dist={float(np.asarray(res.n_dist).mean()):.1f}"))

    # ---- snapshot drills: a tiny self-contained streaming sandbox -------
    r = np.random.default_rng(2)
    xs = r.normal(size=(600, 16)).astype(np.float32)
    sm = train_pq(jax.random.PRNGKey(3), jnp.asarray(xs), 4, 16, iters=6)
    sg = build_vamana(jax.random.PRNGKey(4), jnp.asarray(xs), r=8, l=24)
    seg = BaseSegment(graph=sg,
                      codes=jnp.asarray(encode_codes(sm, xs, "u8")),
                      vectors=jnp.asarray(xs), layout="u8")

    with tempfile.TemporaryDirectory() as d:
        # transient-I/O retry: every read flaky at p=0.3, restore retried
        eng = StreamingEngine(seg, sm, delta_capacity=64)
        eng.insert(r.normal(size=(16, 16)).astype(np.float32))
        eng.consolidate(ckpt_dir=d)
        faults = {"n": 0}
        base_hook = ChaosPlan(seed=11, io_fault_p=0.3).io_fault()

        def counting_hook(path):
            try:
                base_hook(path)
            except Exception:
                faults["n"] += 1
                raise
        pol = RetryPolicy(max_attempts=6, base_delay_s=1e-4,
                          max_delay_s=1e-3)
        ckpt.set_io_fault_hook(counting_hook)
        try:
            t0 = time.perf_counter()
            eng2 = StreamingEngine.restore(d, delta_capacity=64, retry=pol)
            wall = time.perf_counter() - t0
        finally:
            ckpt.set_io_fault_hook(None)
        exp = expected_retry_time_s(pol, 0.0, 0.3)
        rows.append(("resilience/io_retry", wall * 1e6,
                     f"io_fault_p=0.3;injected={faults['n']};"
                     f"restored_gen={eng2.generation};"
                     f"expected_retry_s={exp:.4f}"))

        # silent corruption: newest generation flips a byte, restore falls
        # back to the newest intact one
        eng.insert(r.normal(size=(8, 16)).astype(np.float32))
        eng.consolidate(ckpt_dir=d)               # gen 2, intact
        newest = corrupt_snapshot(d, seed=5)
        falls = []
        t0 = time.perf_counter()
        eng3 = StreamingEngine.restore(
            d, delta_capacity=64,
            on_fallback=lambda gen, e: falls.append(gen))
        wall = time.perf_counter() - t0
        if eng3.generation >= newest:
            raise SystemExit("restore served a corrupted generation")
        rows.append(("resilience/snapshot_fallback", wall * 1e6,
                     f"corrupted_gen={newest};landed_gen={eng3.generation};"
                     f"fallbacks={len(falls)}"))

    with tempfile.TemporaryDirectory() as d:
        # crash between snapshot and swap: restart restores the NEW gen
        eng = StreamingEngine(seg, sm, delta_capacity=64)
        eng.insert(r.normal(size=(16, 16)).astype(np.float32))
        plan = ChaosPlan(seed=0, crash_phase="consolidate")
        try:
            eng.consolidate(ckpt_dir=d, chaos=plan.consolidate_hook())
            raise SystemExit("chaos crash did not fire")
        except InjectedFailure:
            pass
        eng4 = StreamingEngine.restore(d, delta_capacity=64)
        rows.append(("resilience/crash_consolidate", 0.0,
                     f"restored_gen={eng4.generation};"
                     f"live={eng4.n_live};crash=post_snapshot"))

    # ---- all-in-storage fallback drill (DESIGN.md §14) ------------------
    # the storage tier's answer to snapshot_fallback: gen 1's segment
    # header is corrupted on disk, DiskEngine.open falls back to the
    # newest INTACT generation and keeps serving — through flaky reads
    # (io_fault_p=0.2, retried per worker chunk) on top
    import dataclasses as _dc

    from repro.storage import (DiskEngine, corrupt_header, segment_path,
                               write_segment)

    with tempfile.TemporaryDirectory() as d:
        write_segment(d, seg, model=sm)                       # gen 0, intact
        write_segment(d, _dc.replace(seg, generation=1), model=sm)
        corrupt_header(segment_path(d, 1), seed=5)
        falls = []
        plan = ChaosPlan(seed=9, io_fault_p=0.2)
        pol = RetryPolicy(max_attempts=6, base_delay_s=1e-4,
                          max_delay_s=1e-3)
        t0 = time.perf_counter()
        with DiskEngine.open(d, cache_records=256, retry=pol,
                             fault_hook=plan.io_fault(),
                             on_fallback=lambda gen, e: falls.append(gen)
                             ) as deng:
            res = deng.search(jnp.asarray(xs[:32]), k=5, h=16)
            wall = time.perf_counter() - t0
            if deng.generation != 0:
                raise SystemExit("disk fallback served the corrupted "
                                 "generation")
            if falls != [1]:
                raise SystemExit(f"disk fallback skipped {falls}, "
                                 f"expected [1]")
            ids = np.asarray(res.ids)
            if ids.max() >= seg.n or not np.isfinite(
                    np.asarray(res.dists)).all():
                raise SystemExit("disk fallback returned invalid answers")
            self_top1 = float((ids[:, 0] == np.arange(32)).mean())
            io = deng.last_io
        rows.append(("resilience/disk_fallback", wall * 1e6,
                     f"corrupted_gen=1;landed_gen=0;fallbacks={len(falls)};"
                     f"self_top1={self_top1:.2f};io_fault_p=0.2;"
                     f"retries={io['n_retries']};"
                     f"cache_hit_rate={io['cache_hit_rate']:.2f}"))

    # ---- the seeded 4-shard chaos acceptance drill ----------------------
    sub_rows, summary = _chaos_subprocess_rows()
    rows.extend(sub_rows)
    drop = summary["recall_free"] - summary["recall_fault"]
    rows.append(("resilience/summary", 0.0,
                 f"recall_free={summary['recall_free']:.4f};"
                 f"recall_fault={summary['recall_fault']:.4f};"
                 f"recall_drop={drop:.4f};slo_drop_max=0.05;"
                 f"p99_free_ms={p99_0:.2f}"))
    return rows


def main():
    print("name,us_per_call,derived")
    for row in run():
        print(f"{row[0]},{row[1]:.2f},{row[2]}", flush=True)


if __name__ == "__main__":
    main()
