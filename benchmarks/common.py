"""Shared benchmark harness: dataset/graph/quantizer caching + QPS@recall.

Scale knob: REPRO_BENCH_SCALE ∈ {"quick", "full"} (default quick — sized
for a single-core CPU sandbox; "full" matches the paper's relative scales).
Every benchmark prints CSV rows `name,us_per_call,derived` per the brief.
"""

from __future__ import annotations

import os
import time
from functools import lru_cache

import numpy as np
import jax
import jax.numpy as jnp

QUICK = os.environ.get("REPRO_BENCH_SCALE", "quick") == "quick"

N_BASE = 15_000 if QUICK else 100_000
N_QUERY = 200 if QUICK else 1000
DIM = 64 if QUICK else 128
RPQ_STEPS = 150 if QUICK else 1000
KM = (8, 256) if QUICK else (16, 256)
BEAMS = (8, 16, 32, 64) if QUICK else (8, 16, 32, 64, 128)
# recall targets for QPS@recall rows: the paper reports QPS@95% on SIFT-like
# data; at this sandbox's bit budget (8 B/vec on 64-d synthetic) the
# reachable ceiling is lower — we report the same statistic at 0.5/0.6.
RECALL_TARGETS = (0.5, 0.6) if QUICK else (0.9, 0.95)


@lru_cache(maxsize=4)
def dataset(name: str = "bench"):
    """Clustered anisotropic synthetic (SIFT-like; see data/synth.py)."""
    from repro.data.synth import DatasetSpec, synth

    spec = DatasetSpec(name, DIM, N_BASE, N_QUERY, n_clusters=32,
                       noise=0.2, spectrum_decay=0.25, seed=7)
    return synth(spec)


@lru_cache(maxsize=4)
def ground_truth():
    from repro.graphs.knn import knn_ids

    ds = dataset()
    gt, _ = knn_ids(ds.base, ds.queries, 10)
    return gt


@lru_cache(maxsize=4)
def vamana_graph():
    from repro.graphs import build_vamana

    ds = dataset()
    return build_vamana(jax.random.PRNGKey(0), ds.base, r=24, l=48,
                        batch=2048)


@lru_cache(maxsize=8)
def quantizer(method: str):
    """method ∈ pq|opq|catalyst|rpq|rpq_n|rpq_r → (codes, lut_fn, aux)."""
    from repro.pq import base, train_pq, train_opq
    from repro.pq import catalyst as cat
    from repro.core import RPQConfig, TrainConfig, train_rpq

    ds = dataset()
    m, k = KM
    t0 = time.time()
    if method == "pq":
        model = train_pq(jax.random.PRNGKey(1), ds.train, m, k, iters=15)
    elif method == "opq":
        model = train_opq(jax.random.PRNGKey(1), ds.train, m, k,
                          outer_iters=4, kmeans_iters=8)
    elif method == "catalyst":
        cm = cat.train_catalyst(jax.random.PRNGKey(1), ds.train, m, k,
                                d_out=min(40, DIM), steps=RPQ_STEPS)
        codes = cat.encode(cm, ds.base)
        wall = time.time() - t0
        size = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cm))
        return codes, (lambda q: cat.build_lut(cm, q)), \
            {"wall_s": wall, "bytes": size}
    elif method.startswith("rpq"):
        cfg = RPQConfig(dim=DIM, m=m, k=k)
        tcfg = TrainConfig(
            steps=RPQ_STEPS, refresh_every=max(RPQ_STEPS // 4, 1),
            triplet_batch=512, routing_batch=512, routing_pool_queries=128,
            log_every=max(RPQ_STEPS // 4, 1),
            use_routing=(method != "rpq_n"),
            use_neighborhood=(method != "rpq_r"))
        rpq = train_rpq(jax.random.PRNGKey(1), ds.train, vamana_graph(),
                        cfg=cfg, tcfg=tcfg, verbose=False)
        model = rpq.model
    else:
        raise KeyError(method)
    wall = time.time() - t0
    codes = base.encode(model, ds.base)
    size = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(model))
    return codes, (lambda q: base.build_lut(model, q)), \
        {"wall_s": wall, "bytes": size}


def sweep_engine(engine, queries, gt, beams=BEAMS, k: int = 10,
                 expand: int = 1, entries: int = 1, prune_eps: float = 0.0):
    """Beam sweep → list of {h, expand, entries, prune_eps, recall, qps,
    hops, rounds, n_dist}.

    ``expand`` is the frontier batch size E (DESIGN.md §9); ``entries``/
    ``prune_eps`` are the adaptive-routing knobs (DESIGN.md §11: PQ-hash
    multi-entry seeding S and probabilistic hop-pruning margin ε) — all
    three forwarded to every ``engine.search`` call so sweeps can chart
    the QPS-vs-recall frontier of any serving configuration. ``rounds``
    (sequential beam rounds) and ``n_dist`` (full-LUT-equivalent distance
    evaluations per query) ride along in every row — they are the
    quantities the adaptive-routing acceptance bars are measured on.
    """
    from repro.search.metrics import measure_qps, recall_at_k

    out = []
    for h in beams:
        qps, res = measure_qps(
            lambda q: engine.search(q, k=k, h=h, expand=expand,
                                    entries=entries, prune_eps=prune_eps),
            queries, repeats=2, warmup=1)
        hops = float(np.mean(np.asarray(res.hops)))
        out.append({"h": h, "expand": expand, "entries": entries,
                    "prune_eps": prune_eps,
                    "recall": recall_at_k(res.ids, gt, k),
                    "qps": qps, "hops": hops,
                    "n_dist": float(np.mean(np.asarray(res.n_dist))),
                    "rounds": (float(np.mean(np.asarray(res.rounds)))
                               if res.rounds is not None else hops)})
    return out


def qps_at_recall(curve, target: float):
    """Interpolated QPS at a target recall (paper reports QPS@95%)."""
    pts = sorted(curve, key=lambda p: p["recall"])
    if not pts or pts[-1]["recall"] < target:
        return None
    below = [p for p in pts if p["recall"] < target]
    above = [p for p in pts if p["recall"] >= target]
    hi = above[0]
    if not below:
        return hi["qps"]
    lo = below[-1]
    t = (target - lo["recall"]) / max(hi["recall"] - lo["recall"], 1e-9)
    return lo["qps"] + t * (hi["qps"] - lo["qps"])


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.2f},{derived}", flush=True)
