"""Kernel microbenchmarks: ADC scan + pairwise table (CPU wall time of the
jitted XLA paths; the Pallas kernels target TPU and are validated in
interpret mode by the tests — their roofline lives in EXPERIMENTS §Roofline).
"""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops


def _time(fn, *args, repeats=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats


def run():
    rng = np.random.default_rng(0)
    rows = []
    n, m, k, q = 200_000, 16, 256, 64
    codes = jnp.asarray(rng.integers(0, k, (n, m)), jnp.uint8)
    lut = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    luts = jnp.asarray(rng.normal(size=(q, m, k)).astype(np.float32))

    f1 = jax.jit(lambda c, l: ops.adc_scan(c, l, backend="ref"))
    t = _time(f1, codes, lut)
    rows.append(("kernel/adc_scan_1q_200k", t * 1e6,
                 f"gcodes_per_s={n / t / 1e9:.2f}"))

    f2 = jax.jit(lambda c, l: ops.adc_scan_batch(c, l, backend="ref"))
    t = _time(f2, codes, luts)
    rows.append(("kernel/adc_scan_batch64_200k", t * 1e6,
                 f"gscores_per_s={n * q / t / 1e9:.2f}"))

    x = jnp.asarray(rng.normal(size=(8192, m, 8)).astype(np.float32))
    cb = jnp.asarray(rng.normal(size=(m, k, 8)).astype(np.float32))
    f3 = jax.jit(lambda a, b: ops.pq_pairwise(a, b, backend="ref"))
    t = _time(f3, x, cb)
    rows.append(("kernel/pq_pairwise_8k", t * 1e6,
                 f"gflops={2 * 8192 * m * k * 8 / t / 1e9:.2f}"))
    return rows
