"""Kernel microbenchmarks: ADC scan + pairwise table (CPU wall time of the
jitted XLA paths; the Pallas kernels target TPU and are validated in
interpret mode by the tests — their roofline lives in EXPERIMENTS §Roofline).

Fast-scan rows (DESIGN.md §8) measure the fs4 layout against the classic
one at the SAME (N, M): packed 4-bit codes + quantized uint8 LUTs vs
1 byte/code + f32 LUTs, for both the bulk scan and the per-hop fused
gather+reduce. ``speedup_vs_f32`` in the derived column is the acceptance
metric (the scan loops are memory-bound, so halving code bytes and
quartering LUT bytes shows up directly as wall time).

Hop-width sweep rows (DESIGN.md §9) measure the frontier-batched hop: the
fused call at R' ∈ {64, 128, 256} for both layouts, with ``per_dist_ns``
(call time / candidates scored) and ``speedup_vs_4x64`` (one E·R = 256-wide
call vs E = 4 separate 64-wide calls — the per-round cost ratio of
``beam_search(expand=4)`` against the classic beam).
"""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.pq import pack


def _time(fn, *args, repeats=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats


def _time_median(fn, *args, repeats=15):
    """Median-of-repeats — the sweep rows feed a CI-asserted derived metric
    and must survive a noisy shared-CPU host (mean-of-5 was seen swinging
    2× under load)."""
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run():
    rng = np.random.default_rng(0)
    rows = []
    n, m, k, q, r = 200_000, 16, 256, 64, 64
    codes = jnp.asarray(rng.integers(0, k, (n, m)), jnp.uint8)
    lut = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    luts = jnp.asarray(rng.normal(size=(q, m, k)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, n, (q, r)), jnp.int32)

    # ---- classic layout: u8 codes, f32 LUTs -----------------------------
    f1 = jax.jit(lambda c, l: ops.adc_scan(c, l, backend="ref"))
    t_f32_1q = _time(f1, codes, lut)
    rows.append(("kernel/adc_scan_1q_200k", t_f32_1q * 1e6,
                 f"gcodes_per_s={n / t_f32_1q / 1e9:.2f}"))

    f2 = jax.jit(lambda c, l: ops.adc_scan_batch(c, l, backend="ref"))
    t_f32_b = _time(f2, codes, luts)
    rows.append(("kernel/adc_scan_batch64_200k", t_f32_b * 1e6,
                 f"gscores_per_s={n * q / t_f32_b / 1e9:.2f}"))

    f3 = jax.jit(lambda c, i, l: ops.hop_adc(c, i, l, backend="ref"))
    t_hop = _time(f3, codes, ids, luts)
    rows.append(("kernel/hop_adc_q64_r64", t_hop * 1e6,
                 f"gscores_per_s={q * r / t_hop / 1e9:.4f}"))

    # ---- fast-scan layout: fs4 packed codes, quantized uint8 LUTs -------
    # same (N, M); K drops to 16 (4-bit sub-codes), half code bytes,
    # quarter LUT bytes, int32 accumulation
    codes16 = rng.integers(0, 16, (n, m)).astype(np.uint8)
    packed = pack.pack_codes(jnp.asarray(codes16))
    luts16 = rng.normal(size=(q, m, 16)).astype(np.float32) ** 2
    ql = jax.tree.map(jnp.asarray, pack.quantize_luts(jnp.asarray(luts16)))
    ql1 = jax.tree.map(lambda a: a[:1], ql)

    ffs1 = jax.jit(lambda p, l, s, b: ops.adc_scan_fs(p, l, s, b,
                                                      backend="ref"))
    t_fs_1q = _time(ffs1, packed, ql1.lut, ql1.scale, ql1.bias)
    rows.append(("kernel/adc_scan_fs4_1q_200k", t_fs_1q * 1e6,
                 f"gcodes_per_s={n / t_fs_1q / 1e9:.2f} "
                 f"speedup_vs_f32={t_f32_1q / t_fs_1q:.2f}"))

    t_fs_b = _time(ffs1, packed, ql.lut, ql.scale, ql.bias)
    rows.append(("kernel/adc_scan_fs4_batch64_200k", t_fs_b * 1e6,
                 f"gscores_per_s={n * q / t_fs_b / 1e9:.2f} "
                 f"speedup_vs_f32={t_f32_b / t_fs_b:.2f}"))

    # isolate the LUT-quantization + packing win from the K change: same
    # 4-bit codes scanned UNPACKED against f32 K=16 LUTs
    codes16_j = jnp.asarray(codes16)
    luts16_j = jnp.asarray(luts16)
    t_k16_f32 = _time(f2, codes16_j, luts16_j)
    rows.append(("kernel/adc_scan_batch64_200k_k16_f32lut", t_k16_f32 * 1e6,
                 f"gscores_per_s={n * q / t_k16_f32 / 1e9:.2f} "
                 f"fs4_speedup_same_k={t_k16_f32 / t_fs_b:.2f}"))

    ffsh = jax.jit(lambda p, i, l, s, b: ops.hop_adc_fs(p, i, l, s, b,
                                                        backend="ref"))
    t_hop_fs = _time(ffsh, packed, ids, ql.lut, ql.scale, ql.bias)
    rows.append(("kernel/hop_adc_fs4_q64_r64", t_hop_fs * 1e6,
                 f"gscores_per_s={q * r / t_hop_fs / 1e9:.4f} "
                 f"speedup_vs_f32={t_hop / t_hop_fs:.2f}"))

    # ---- frontier-width sweep (DESIGN.md §9) ----------------------------
    # multi-expansion beam rounds feed ONE R' = E·R wide hop call instead
    # of E narrow ones; per_dist_ns is the per-candidate cost of the call
    # and speedup_vs_4x64 the acceptance metric (one 256-wide call vs four
    # 64-wide calls, per layout). CI asserts these rows reach the artifact.
    t_wide = {}
    for rp in (64, 128, 256):
        ids_w = jnp.asarray(rng.integers(0, n, (q, rp)), jnp.int32)
        t_u8 = _time_median(f3, codes, ids_w, luts)
        t_wide[("u8", rp)] = t_u8
        rows.append((f"kernel/hop_adc_u8_q64_rp{rp}", t_u8 * 1e6,
                     f"per_dist_ns={t_u8 / (q * rp) * 1e9:.2f}"))
        t_fs = _time_median(ffsh, packed, ids_w, ql.lut, ql.scale, ql.bias)
        t_wide[("fs4", rp)] = t_fs
        rows.append((f"kernel/hop_adc_fs4_q64_rp{rp}", t_fs * 1e6,
                     f"per_dist_ns={t_fs / (q * rp) * 1e9:.2f}"))
    for layout in ("u8", "fs4"):
        t64, t256 = t_wide[(layout, 64)], t_wide[(layout, 256)]
        rows.append((f"kernel/hop_adc_{layout}_wide4_vs_4x64", t256 * 1e6,
                     f"speedup_vs_4x64={4 * t64 / t256:.2f}"))

    # ---- training-side pairwise table ----------------------------------
    x = jnp.asarray(rng.normal(size=(8192, m, 8)).astype(np.float32))
    cb = jnp.asarray(rng.normal(size=(m, k, 8)).astype(np.float32))
    f4 = jax.jit(lambda a, b: ops.pq_pairwise(a, b, backend="ref"))
    t = _time(f4, x, cb)
    rows.append(("kernel/pq_pairwise_8k", t * 1e6,
                 f"gflops={2 * 8192 * m * k * 8 / t / 1e9:.2f}"))
    return rows
