"""All-in-storage serving: double-buffered prefetch vs serial read-then-compute.

The acceptance numbers for the storage tier (DESIGN.md §14, repro/storage/):
a DiskEngine serves the SAME segment file twice — once with ``overlap=
False`` (each round fetches its frontier records, waits, then scores:
wall ≈ rounds × (io + compute)) and once with the double-buffered
prefetcher (round N+1's reads issued before round N scores: wall ≈
rounds × max(io, compute)) — at identical budgets, so the speedup row
isolates exactly the overlap.

Storage latency is MODELED HONESTLY for a page-cached CI host, where raw
preads cost microseconds and any "overlap" would be noise: the reader's
``slow_read_ms`` sleeps inside the worker threads (genuinely overlappable
wall-clock on the real read path — the same knob ``--chaos slow_read=``
drives), and the bench CALIBRATES it to the measured per-round compute
time, the regime where double-buffering pays its theoretical ≈2×. A
real-read row (slow_read_ms=0) is reported alongside, without the bar.

Rows:

* ``disk/serial/h32``, ``disk/prefetch/h32`` — recall@10, service QPS,
  cache hit-rate, bytes read per query batch, post-overlap I/O stall.
* ``disk/overlap_summary`` — ``speedup`` (prefetch QPS / serial QPS)
  against ``bar=1.5`` (CI asserts it) + the recall delta (must stay
  within a point — asserted HERE, it is a correctness invariant).
* ``disk/real_read/h32`` — the same comparison on raw page-cache reads,
  informational.
* ``disk/model_vs_measured`` — HybridEngine's closed-form SSD model
  cross-checked against the DiskEngine's MEASURED per-round I/O stall via
  the ``io_time(measured_io_s=...)`` adapter.

Run as a section of the driver (emits BENCH_disk.json):

    PYTHONPATH=src python -m benchmarks.run --only disk
"""

from __future__ import annotations

CACHE_RECORDS = 2048    # ~14% of the base: top BFS layers stay resident
H = 32
K = 10

# calibrated slow latency sits ABOVE per-round compute by this factor —
# the middle of the speedup plateau (io just dominating compute), so a
# noisy calibration run can't tip the comparison off the max(io, compute)
# regime the 1.5× bar assumes
SLOW_MULT = 1.2
# clamps: below ~0.5 ms sleep scheduling noise dominates; above 20 ms the
# quick-scale bench would crawl
SLOW_MS_MIN, SLOW_MS_MAX = 0.5, 20.0


def _timed(engine, queries, *, overlap, repeats=3):
    """(recall-ready result, qps, last_io of the BEST timed run).

    min-of-repeats, not mean: CI hosts take load spikes, and a single
    slow repeat in either arm would randomize the speedup ratio."""
    import time

    import numpy as np

    res = engine.search(queries, k=K, h=H, overlap=overlap)   # warmup
    np.asarray(res.dists)
    best, res = None, None
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = engine.search(queries, k=K, h=H, overlap=overlap)
        np.asarray(res.dists)
        wall = time.perf_counter() - t0
        if best is None or wall < best[0]:
            best = (wall, dict(engine.last_io))
    nq = int(queries.shape[0])
    qps = nq / max(best[0], 1e-12)
    return res, qps, best[1]


def _fmt_io(io) -> str:
    return (f"cache_hit_rate={io['cache_hit_rate']:.3f};"
            f"bytes_read={io['bytes_read']};n_reads={io['n_reads']};"
            f"io_wait_ms={io['io_wait_s'] * 1e3:.1f};"
            f"rounds_total={io['rounds_total']}")


def run():
    import tempfile

    import numpy as np
    import jax.numpy as jnp

    from benchmarks import common as C
    from repro.index.segment import BaseSegment
    from repro.search.engine import HybridEngine
    from repro.search.metrics import recall_at_k
    from repro.storage import DiskEngine, write_segment

    ds, gt, g = C.dataset(), C.ground_truth(), C.vamana_graph()
    codes, lut_fn, _ = C.quantizer("pq")
    queries = jnp.asarray(ds.queries)
    rows = []

    with tempfile.TemporaryDirectory() as d:
        seg = BaseSegment(graph=g, codes=jnp.asarray(codes), vectors=None,
                          layout="u8", generation=0, dim_hint=C.DIM)
        write_segment(d, seg)

        # ---- calibrate: measure per-round COMPUTE with free reads, then
        # set the modeled storage latency just above it — the io ≳ compute
        # regime where overlap approaches 2× and the 1.5 bar has honest
        # headroom. Both arms are measured and the LARGER per-round
        # compute wins: the pipelined arm pays the stale frontier
        # selection inline, and a latency calibrated under ITS compute
        # would silently land the comparison back in compute-bound
        # territory where overlap can't show
        with DiskEngine.open(d, lut_fn=lut_fn,
                             cache_records=CACHE_RECORDS) as eng:
            res, qps, io = _timed(eng, queries, overlap=False)
            real_serial = (recall_at_k(res.ids, gt, K), qps, io)
            res, qps, io2 = _timed(eng, queries, overlap=True)
            real_prefetch = (recall_at_k(res.ids, gt, K), qps, io2)
            compute_ms = max(
                (x["wall_s"] - x["io_wait_s"]) * 1e3 / max(
                    x["rounds_total"], 1) for x in (io, io2))
        slow_ms = float(np.clip(SLOW_MULT * compute_ms,
                                SLOW_MS_MIN, SLOW_MS_MAX))

        with DiskEngine.open(d, lut_fn=lut_fn, cache_records=CACHE_RECORDS,
                             slow_read_ms=slow_ms) as eng:
            res_s, qps_s, io_s = _timed(eng, queries, overlap=False)
            rec_s = recall_at_k(res_s.ids, gt, K)
            rows.append((f"disk/serial/h{H}", 1e6 / max(qps_s, 1e-9),
                         f"recall={rec_s:.3f};qps={qps_s:.1f};"
                         f"slow_read_ms={slow_ms:.2f};{_fmt_io(io_s)}"))

            res_p, qps_p, io_p = _timed(eng, queries, overlap=True)
            rec_p = recall_at_k(res_p.ids, gt, K)
            rows.append((f"disk/prefetch/h{H}", 1e6 / max(qps_p, 1e-9),
                         f"recall={rec_p:.3f};qps={qps_p:.1f};"
                         f"slow_read_ms={slow_ms:.2f};{_fmt_io(io_p)}"))

            # recall parity is a correctness invariant of the stale-frontier
            # pipeline, not a perf number — enforce it here
            if rec_p < rec_s - 0.01:
                raise SystemExit(
                    f"prefetch recall {rec_p:.4f} fell more than a point "
                    f"below serial {rec_s:.4f} — stale-frontier selection "
                    f"is diverging")
            speedup = qps_p / max(qps_s, 1e-9)
            rows.append(("disk/overlap_summary", 0.0,
                         f"speedup={speedup:.2f};bar=1.5;"
                         f"recall_serial={rec_s:.4f};"
                         f"recall_prefetch={rec_p:.4f};"
                         f"recall_delta={rec_p - rec_s:+.4f};"
                         f"slow_read_ms={slow_ms:.2f};"
                         f"compute_ms_per_round={compute_ms:.2f}"))

            # ---- model vs measured (HybridEngine.io_time adapter) -------
            hyb = HybridEngine(g, codes, lut_fn,
                               vectors=jnp.asarray(ds.base),
                               io_latency_s=slow_ms / 1e3)
            model_per_q = float(hyb.io_time(res_s).mean())
            measured_per_q = float(hyb.io_time(
                res_s, measured_io_s=io_s["io_wait_s"]).mean())
            # apples-to-apples: the model charges one read latency per
            # ROUND-PER-QUERY; the measured batch stall amortizes each
            # round's batched read across all queries — compare per round
            model_per_round = slow_ms / 1e3
            measured_per_round = io_s["io_wait_s"] / max(
                io_s["rounds_total"], 1)
            rows.append(("disk/model_vs_measured", 0.0,
                         f"model_io_s_per_q={model_per_q:.4f};"
                         f"measured_io_s_per_q={measured_per_q:.6f};"
                         f"model_s_per_round={model_per_round:.4f};"
                         f"measured_s_per_round={measured_per_round:.4f};"
                         f"per_round_ratio="
                         f"{measured_per_round / model_per_round:.2f};"
                         f"batch_amortization="
                         f"{model_per_q / max(measured_per_q, 1e-12):.0f}x"))

        # ---- raw page-cache reads: informational, no bar ----------------
        rows.append((f"disk/real_read/h{H}",
                     1e6 / max(real_prefetch[1], 1e-9),
                     f"recall_serial={real_serial[0]:.3f};"
                     f"recall_prefetch={real_prefetch[0]:.3f};"
                     f"qps_serial={real_serial[1]:.1f};"
                     f"qps_prefetch={real_prefetch[1]:.1f};"
                     f"speedup_real="
                     f"{real_prefetch[1] / max(real_serial[1], 1e-9):.2f};"
                     f"bytes_read={real_serial[2]['bytes_read']}"))
    return rows


def main():
    print("name,us_per_call,derived")
    for row in run():
        print(f"{row[0]},{row[1]:.2f},{row[2]}", flush=True)


if __name__ == "__main__":
    main()
