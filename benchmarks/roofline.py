"""Roofline analysis from the dry-run report (EXPERIMENTS.md §Roofline).

Three terms per (arch × shape × mesh), all PER-DEVICE (cost_analysis and
the partitioned-HLO collective shapes are already per-device):

  compute    = flops_analytic / PEAK_FLOPS_BF16
  memory     = hlo_bytes      / HBM_BW
  collective = collective_bytes / ICI_BW

FLOPs source: XLA's cost_analysis counts scan/while bodies ONCE (loop trip
counts are not multiplied in), so for scanned models it under-reports by
~n_layers×microbatches. We therefore use ANALYTIC model FLOPs as the
primary compute term (6·N·D train / 2·N·D decode/serve conventions, per
family below) and report the raw HLO number alongside as `flops_hlo`.
Bytes: cost_analysis "bytes accessed" has the same scan caveat; we take
max(bytes_accessed, 2×param_bytes/device + activation estimate) — and
report both. The MODEL_FLOPS/HLO ratio column in EXPERIMENTS.md uses the
corrected analytic values.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys

sys.path.insert(0, "src")

from repro.launch.mesh import PEAK_FLOPS_BF16, HBM_BW, ICI_BW  # noqa: E402


def _mesh_devices(mesh_name: str) -> int:
    return 512 if "2pod" in mesh_name else 256


def analytic_flops(rec: dict) -> float:
    """Global model FLOPs per step for the cell (then divided per device)."""
    arch, shape, meta = rec["arch"], rec["shape"], rec.get("meta", {})
    mode = meta.get("mode", "")
    if "params" in meta:  # LM family
        n_active = meta.get("active_params", meta["params"])
        toks = meta.get("tokens", 0)
        if mode == "train":
            return 6.0 * n_active * toks
        if mode == "prefill":
            return 2.0 * n_active * toks
        if mode == "decode":
            # 2·N per token + attention over the KV cache
            kv = meta.get("kv_len", 0)
            attn = 0.0
            if kv:
                attn = 4.0 * toks * kv * _lm_attn_dims(arch)
            return 2.0 * n_active * toks + attn
    if arch == "gat-cora":
        e = meta.get("edges", 0)
        n = meta.get("nodes", 0)
        # 2 layers: SDDMM + SpMM per edge on (heads·d) + dense projections
        per_edge = 2 * 2 * 64 * 3
        per_node = 2 * 2 * 1433 * 64
        f = e * per_edge + n * per_node
        return 3.0 * f if mode == "train" else f
    if arch in ("dlrm-mlperf", "deepfm", "din", "bert4rec"):
        batch = meta.get("batch", meta.get("n_candidates", 0))
        per_ex = {"dlrm-mlperf": 2 * (13 * 512 + 512 * 256 + 256 * 128
                                      + 479 * 1024 + 1024 * 1024
                                      + 1024 * 512 + 512 * 256 + 256
                                      + 27 * 27 * 128),
                  "deepfm": 2 * (390 * 400 + 400 * 400 + 400 * 400 + 400
                                 + 39 * 10 * 2),
                  "din": 2 * (100 * (4 * 18 * 80 + 80 * 40 + 40)
                              + 54 * 200 + 200 * 80 + 80),
                  "bert4rec": 2 * (200 * (64 * 64 * 4 + 64 * 256 * 2)
                                   + 200 * 200 * 64 * 2) * 2}[arch]
        if meta.get("mode") == "retrieval":
            d = meta.get("d_emb", 64)
            per_ex = 2 * d
            batch = meta.get("n_candidates", 10 ** 6)
        f = per_ex * batch
        return 3.0 * f if mode == "train" else f
    if arch == "rpq":
        if rec["shape"] == "quant_train":
            b = meta.get("batch", 8192)
            # pairwise tables for 3 triplet legs + h routing candidates
            per_vec = 2 * 16 * 256 * 8 + 2 * 128 * 128  # pq_pairwise + rotate
            return 3.0 * (3 * b + 4096 * 17) * per_vec
        if rec["shape"] in ("adc_bulk", "serve_1m"):
            n = meta.get("n_codes", meta.get("n_base", 10 ** 6))
            q = meta.get("queries", 1024)
            return q * n * 16.0  # M adds per code per query
        if rec["shape"] == "encode_bulk":
            return meta.get("n", 10 ** 6) * (2 * 16 * 256 * 8 + 2 * 128 * 128)
    return 0.0


def _lm_attn_dims(arch: str) -> float:
    dims = {"granite-3-8b": 32 * 128, "llama3-405b": 128 * 128,
            "starcoder2-3b": 24 * 128, "granite-moe-1b-a400m": 16 * 64,
            "olmoe-1b-7b": 16 * 128}
    return float(dims.get(arch, 4096))


def analyze(report_path: str):
    recs = json.load(open(report_path))
    rows = []
    for r in recs:
        if not r.get("ok"):
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "ok": False,
                         "error": r.get("error", "")[:100]})
            continue
        ndev = _mesh_devices(r["mesh"])
        f_analytic = analytic_flops(r) / ndev       # per device
        f_hlo = r["cost"]["flops"]
        bytes_hlo = r["cost"]["bytes_accessed"]
        mem = r["memory"]
        # memory floor: every live byte (args+temp) touched at least once
        bytes_floor = mem["argument_bytes"] + mem["temp_bytes"]
        coll = r["collectives"].get("total", 0)
        t_compute = f_analytic / PEAK_FLOPS_BF16
        t_memory = max(bytes_hlo, bytes_floor) / HBM_BW
        t_coll = coll / ICI_BW
        dominant = max((t_compute, "compute"), (t_memory, "memory"),
                       (t_coll, "collective"))[1]
        step_time = max(t_compute, t_memory, t_coll)
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "ok": True,
            "flops_analytic_perdev": f_analytic, "flops_hlo": f_hlo,
            "bytes_hlo": bytes_hlo, "bytes_floor": bytes_floor,
            "collective_bytes": coll,
            "t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dominant,
            "bound_step_s": step_time,
            "model_flop_frac": (t_compute / step_time) if step_time else 0.0,
            "useful_vs_hlo": (f_analytic / f_hlo) if f_hlo else float("nan"),
            "mem_gb_perdev": (mem["argument_bytes"] + mem["temp_bytes"]) / 1e9,
            "fits_16g": (mem["argument_bytes"] + mem["temp_bytes"]) < 16e9,
            "collectives": {k: v for k, v in r["collectives"].items()
                            if not k.startswith("count_") and k != "total"},
        })
    return rows


def to_markdown(rows, mesh_filter="1pod_16x16") -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "roofline-frac | mem GB/dev | fits 16G |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if not r.get("ok") or r["mesh"] != mesh_filter:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.2e} | "
            f"{r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} | "
            f"{r['dominant']} | {r['model_flop_frac']:.2f} | "
            f"{r['mem_gb_perdev']:.2f} | {'Y' if r['fits_16g'] else 'N'} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--report", default="reports/dryrun.json")
    ap.add_argument("--out", default="reports/roofline.json")
    ap.add_argument("--markdown", default="reports/roofline.md")
    args = ap.parse_args()
    rows = analyze(args.report)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    json.dump(rows, open(args.out, "w"), indent=1)
    md = ["# Roofline (single-pod 16×16, per-device)\n",
          to_markdown(rows, "1pod_16x16"),
          "\n\n# Roofline (multi-pod 2×16×16, per-device)\n",
          to_markdown(rows, "2pod_2x16x16")]
    open(args.markdown, "w").write("\n".join(md))
    ok = [r for r in rows if r.get("ok")]
    print(f"analyzed {len(ok)} cells → {args.out}, {args.markdown}")
    for r in ok:
        if r["mesh"] == "1pod_16x16":
            print(f"{r['arch']:22s} {r['shape']:14s} dom={r['dominant']:10s} "
                  f"frac={r['model_flop_frac']:.2f} mem={r['mem_gb_perdev']:.1f}G")


if __name__ == "__main__":
    main()
