"""Sharded serving: graph-routed vs. exhaustive-scan vs. single-device.

The numbers behind DESIGN.md §6's engine choice: at matched recall targets,
how do the three multi-device-capable scenarios trade QPS, recall@10 and
per-query distance work?

* ``memory``        — single-device InMemoryEngine beam (the baseline the
                      acceptance bar is measured against),
* ``sharded-scan``  — ShardedEngine: every shard exhaustively ADC-scans its
                      rows (O(N/S) distances per query per shard),
* ``sharded-graph`` — ShardedGraphEngine: every shard beam-searches its own
                      Vamana subgraph (O(hops·R) distances), with and
                      without DiskANN-style local exact rerank,

plus a dead-shard row showing graceful recall degradation (never an error).

Run as a section of the driver (uses however many devices exist — 1 in the
default CPU sandbox):

    PYTHONPATH=src python -m benchmarks.run --only sharded

or standalone with a forced 4-way host-device split, the honest multi-shard
configuration:

    PYTHONPATH=src python -m benchmarks.sharded_serving
"""

from __future__ import annotations

import os


def run():
    import numpy as np
    import jax

    from benchmarks import common as C
    from repro.graphs.partition import build_partitioned_vamana
    from repro.search.engine import (InMemoryEngine, ShardedEngine,
                                     ShardedGraphEngine)
    from repro.search.metrics import measure_qps, recall_at_k

    ds, gt, g = C.dataset(), C.ground_truth(), C.vamana_graph()
    codes, lut_fn, _ = C.quantizer("pq")
    n_shards = len(jax.devices())
    pg = build_partitioned_vamana(jax.random.PRNGKey(11), ds.base, n_shards,
                                  r=24, l=48, batch=2048)
    k, h = 10, 32
    rows = []

    def emit(row):
        rows.append(row)

    def bench(tag, engine, **kw):
        qps, res = measure_qps(
            lambda q: engine.search(q, k=k, **kw), ds.queries, repeats=2)
        rec = recall_at_k(res.ids, gt, k)
        hops = float(np.mean(np.asarray(res.hops)))
        ndist = float(np.mean(np.asarray(res.n_dist)))
        emit((f"sharded/{tag}", 1e6 / max(qps, 1e-9),
              f"recall={rec:.3f};qps={qps:.1f};hops={hops:.1f};"
              f"ndist={ndist:.0f};shards={n_shards}"))
        return res

    mem = InMemoryEngine(g, codes, lut_fn)
    bench("memory/h%d" % h, mem, h=h)

    scan = ShardedEngine(codes, lut_fn)
    bench("scan", scan)

    graph_eng = ShardedGraphEngine(pg, codes, lut_fn)
    bench("graph/h%d" % h, graph_eng, h=h)

    graph_rr = ShardedGraphEngine(pg, codes, lut_fn, vectors=ds.base)
    bench("graph_rerank/h%d" % h, graph_rr, h=h)

    # fault drill: kill shard 0, recall degrades, the query still answers.
    # Needs survivors — on a 1-device host (benchmarks/run.py default)
    # every shard would be dead and partial_merge rightly raises.
    if n_shards >= 2:
        alive = [s != 0 for s in range(n_shards)]
        res = graph_eng.search(ds.queries, k=k, h=h, alive=alive)
        emit(("sharded/graph/dead_shard0", 0.0,
              f"recall={recall_at_k(res.ids, gt, k):.3f};"
              f"alive={sum(alive)}/{n_shards}"))
    else:
        emit(("sharded/graph/dead_shard0", 0.0,
              "skipped=single_shard_host"))
    return rows


def main():
    rows = run()
    for r in rows:
        print(f"{r[0]},{r[1]:.2f},{r[2]}", flush=True)
    bad = [r for r in rows if "recall=" in r[2]
           and float(r[2].split("recall=")[1].split(";")[0]) <= 0]
    if bad:
        raise SystemExit(f"degenerate benchmark rows: {bad}")


if __name__ == "__main__":
    # the honest multi-shard configuration on a CPU host: 4 forced devices
    # (must be set before jax initializes its backend)
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=4")
    print("name,us_per_call,derived")
    main()
