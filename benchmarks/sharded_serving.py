"""Sharded serving: graph-routed vs. exhaustive-scan vs. single-device.

The numbers behind DESIGN.md §6's engine choice: at matched recall targets,
how do the three multi-device-capable scenarios trade QPS, recall@10 and
per-query distance work?

* ``memory``        — single-device InMemoryEngine beam (the baseline the
                      acceptance bar is measured against),
* ``sharded-scan``  — ShardedEngine: every shard exhaustively ADC-scans its
                      rows (O(N/S) distances per query per shard),
* ``sharded-graph`` — ShardedGraphEngine: every shard beam-searches its own
                      Vamana subgraph (O(hops·R) distances), with and
                      without DiskANN-style local exact rerank,

plus a dead-shard row showing graceful recall degradation (never an error),
a frontier-batching sweep (E ∈ {1, 2, 4}, DESIGN.md §9) over the beam-routed
engines, an adaptive-routing sweep (S ∈ {1, 4, 8} seeds × ε ∈ {0, 0.1}
prune margin, DESIGN.md §11) whose summary rows record the rounds_cut /
n_dist_cut acceptance bars against the bit-identical S=1/ε=0 baseline, and
the DiskANN-style hybrid scenario whose per-query service time (compute +
per-round batched SSD reads) is where multi-expansion pays end to end on an
IO-modeled host. Every engine row carries ``rounds`` (sequential beam
rounds) and ``n_dist`` (full-LUT-equivalent distances per query) as parsed
derived fields in BENCH_sharded.json.

Run as a section of the driver (uses however many devices exist — 1 in the
default CPU sandbox):

    PYTHONPATH=src python -m benchmarks.run --only sharded

or standalone with a forced 4-way host-device split, the honest multi-shard
configuration:

    PYTHONPATH=src python -m benchmarks.sharded_serving
"""

from __future__ import annotations

import os


def run():
    import numpy as np
    import jax

    from benchmarks import common as C
    from repro.graphs.partition import build_partitioned_vamana
    from repro.search.engine import (HybridEngine, InMemoryEngine,
                                     ShardedEngine, ShardedGraphEngine)
    from repro.search.metrics import measure_qps, recall_at_k

    ds, gt, g = C.dataset(), C.ground_truth(), C.vamana_graph()
    codes, lut_fn, _ = C.quantizer("pq")
    n_shards = len(jax.devices())
    pg = build_partitioned_vamana(jax.random.PRNGKey(11), ds.base, n_shards,
                                  r=24, l=48, batch=2048)
    k, h = 10, 32
    rows = []

    def emit(row):
        rows.append(row)

    def bench(tag, engine, repeats=2, **kw):
        qps, res = measure_qps(
            lambda q: engine.search(q, k=k, **kw), ds.queries,
            repeats=repeats)
        rec = recall_at_k(res.ids, gt, k)
        hops = float(np.mean(np.asarray(res.hops)))
        n_dist = float(np.mean(np.asarray(res.n_dist)))
        rounds = (float(np.mean(np.asarray(res.rounds)))
                  if res.rounds is not None else hops)
        # rounds and n_dist ride in EVERY row — the adaptive-routing
        # acceptance bars (rounds_cut, n_dist_cut) are measured on them
        # and CI asserts BENCH_sharded carries them as parsed fields.
        emit((f"sharded/{tag}", 1e6 / max(qps, 1e-9),
              f"recall={rec:.3f};qps={qps:.1f};hops={hops:.1f};"
              f"rounds={rounds:.2f};n_dist={n_dist:.1f};shards={n_shards}"))
        return {"qps": qps, "recall": rec, "hops": hops, "rounds": rounds,
                "n_dist": n_dist}

    mem = InMemoryEngine(g, codes, lut_fn)
    bench("memory/h%d" % h, mem, h=h)

    scan = ShardedEngine(codes, lut_fn)
    bench("scan", scan)

    graph_eng = ShardedGraphEngine(pg, codes, lut_fn)
    bench("graph/h%d" % h, graph_eng, h=h)

    graph_rr = ShardedGraphEngine(pg, codes, lut_fn, vectors=ds.base)
    bench("graph_rerank/h%d" % h, graph_rr, h=h)

    # frontier-batching sweep (DESIGN.md §9): E ∈ {1, 2, 4} on the two
    # beam-routed engines — the QPS-vs-recall@10 frontier of multi-
    # expansion, plus E=4-vs-E=1 speedup rows. On a CPU host the compute
    # rows sit near parity (XLA fuses the per-hop work into the while body,
    # so there is no per-round dispatch to amortize — §9 explains why the
    # TPU picture differs); the regime where frontier batching pays end to
    # end HERE is the IO-round-bound DiskANN scenario below.
    expand_base = {}
    for tag, engine in (("memory", mem), ("graph", graph_eng)):
        sweep = {}
        for e in (1, 2, 4):
            # repeats=6: the speedup row below is a recorded acceptance
            # metric and 2-repeat means swing 2× on a shared CPU host
            sweep[e] = bench(f"{tag}/h{h}/e{e}", engine, repeats=6, h=h,
                             expand=e)
        expand_base[tag] = sweep
        b1, b4 = sweep[1], sweep[4]
        emit((f"sharded/{tag}/expand_speedup", 1e6 / max(b4["qps"], 1e-9),
              f"qps_e4_over_e1={b4['qps'] / max(b1['qps'], 1e-9):.2f};"
              f"recall_delta={b4['recall'] - b1['recall']:+.3f};"
              f"rounds={b4['rounds']:.2f};n_dist={b4['n_dist']:.1f}"))

    # adaptive routing sweep (DESIGN.md §11): PQ-hash multi-entry seeding
    # (S = entries) × probabilistic hop pruning (ε = prune_eps) on the two
    # beam-routed engines. The S=1/ε=0 cell takes the BIT-IDENTICAL classic
    # path — its recall/rounds/n_dist must equal the e1 row above (CI
    # asserts this against the recorded baseline), so it anchors the
    # rounds_cut / n_dist_cut acceptance rows:
    #   * n_dist_cut — best pruned cell vs S=1/ε=0 at the same E=1 (≥30%
    #     fewer full-LUT-equivalent distance evaluations, recall within
    #     1pt),
    #   * rounds_cut — the combined adaptive config (seeding + pruning +
    #     frontier batching E=4) vs the classic SEQUENTIAL beam (S=1/ε=0/
    #     E=1), the "cut sequential rounds" headline (≥2×, recall within
    #     1pt).
    for tag, engine in (("memory", mem), ("graph", graph_eng)):
        grid = {}
        for s in (1, 4, 8):
            for eps in (0.0, 0.1):
                grid[(s, eps)] = bench(f"{tag}/adaptive/S{s}_eps{eps:g}",
                                       engine, h=h, entries=s, prune_eps=eps)
        # tuned deep-prune cell: short prefix + wide seed set + larger ε
        grid[(16, 0.2)] = bench(f"{tag}/adaptive/S16_eps0.2", engine, h=h,
                                entries=16, prune_eps=0.2)
        base = grid[(1, 0.0)]
        e1 = expand_base[tag][1]
        if abs(base["recall"] - e1["recall"]) > 1e-6 or \
           abs(base["rounds"] - e1["rounds"]) > 1e-6:
            raise SystemExit(
                f"adaptive S=1/eps=0 diverged from the classic beam on "
                f"{tag}: {base} vs {e1}")
        ok = [(key, c) for key, c in grid.items()
              if key[1] > 0 and c["recall"] >= base["recall"] - 0.01]
        (ps, peps), pruned = min(ok, key=lambda kc: kc[1]["n_dist"]) \
            if ok else ((0, 0.0), base)
        combo = bench(f"{tag}/adaptive/S8_eps0.1_e4", engine, h=h,
                      entries=8, prune_eps=0.1, expand=4)
        emit((f"sharded/{tag}/adaptive_summary", 0.0,
              f"n_dist_cut={1.0 - pruned['n_dist'] / base['n_dist']:.3f};"
              f"pruned_cfg=S{ps}_eps{peps:g};"
              f"pruned_recall_delta={pruned['recall'] - base['recall']:+.3f};"
              f"rounds_cut={base['rounds'] / max(combo['rounds'], 1e-9):.2f};"
              f"combo_recall_delta={combo['recall'] - base['recall']:+.3f};"
              f"base_rounds={base['rounds']:.2f};"
              f"combo_rounds={combo['rounds']:.2f}"))

    # DiskANN-style hybrid: per-query service time = compute + modeled SSD
    # reads, where a round's ≤E reads are issued concurrently (engine.
    # HybridEngine.io_time) — the per-round batching that motivated
    # DiskANN's beam width, and the e2e acceptance regime on this host.
    hyb = HybridEngine(g, codes, lut_fn, vectors=np.asarray(ds.base))
    service = {}
    for e in (1, 2, 4):
        qps, res = measure_qps(
            lambda q: hyb.search(q, k=k, h=h, expand=e), ds.queries,
            repeats=6)
        rec = recall_at_k(res.ids, gt, k)
        io_s = float(np.mean(np.asarray(hyb.io_time(res, expand=e))))
        sq = 1.0 / (1.0 / max(qps, 1e-9) + io_s)   # compute + serial IO
        service[e] = (sq, rec)
        emit((f"sharded/hybrid/h{h}/e{e}", 1e6 / max(sq, 1e-9),
              f"recall={rec:.3f};service_qps={sq:.1f};compute_qps={qps:.1f};"
              f"io_ms={io_s * 1e3:.2f};"
              f"rounds={float(np.mean(np.asarray(res.rounds))):.2f};"
              f"n_dist={float(np.mean(np.asarray(res.n_dist))):.1f};"
              f"hops={float(np.mean(np.asarray(res.hops))):.1f}"))
    s1, r1 = service[1]
    s4, r4 = service[4]
    emit(("sharded/hybrid/expand_speedup", 1e6 / max(s4, 1e-9),
          f"service_qps_e4_over_e1={s4 / max(s1, 1e-9):.2f};"
          f"recall_delta={r4 - r1:+.3f}"))

    # fault drill: kill shard 0, recall degrades, the query still answers.
    # Needs survivors — on a 1-device host (benchmarks/run.py default)
    # every shard would be dead and partial_merge rightly raises.
    if n_shards >= 2:
        alive = [s != 0 for s in range(n_shards)]
        res = graph_eng.search(ds.queries, k=k, h=h, alive=alive)
        emit(("sharded/graph/dead_shard0", 0.0,
              f"recall={recall_at_k(res.ids, gt, k):.3f};"
              f"rounds={float(np.mean(np.asarray(res.rounds))):.2f};"
              f"n_dist={float(np.mean(np.asarray(res.n_dist))):.1f};"
              f"alive={sum(alive)}/{n_shards}"))
    else:
        emit(("sharded/graph/dead_shard0", 0.0,
              "skipped=single_shard_host"))
    return rows


def main():
    rows = run()
    for r in rows:
        print(f"{r[0]},{r[1]:.2f},{r[2]}", flush=True)
    bad = [r for r in rows if "recall=" in r[2]
           and float(r[2].split("recall=")[1].split(";")[0]) <= 0]
    if bad:
        raise SystemExit(f"degenerate benchmark rows: {bad}")


if __name__ == "__main__":
    # the honest multi-shard configuration on a CPU host: 4 forced devices
    # (must be set before jax initializes its backend)
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=4")
    print("name,us_per_call,derived")
    main()
