"""search/metrics.py: recall@k semantics and the QPS measurement contract."""

import time

import numpy as np
import jax.numpy as jnp

from repro.search.metrics import measure_qps, recall_at_k


def test_recall_perfect_and_disjoint():
    gt = np.arange(20).reshape(2, 10)
    assert recall_at_k(gt, gt, 10) == 1.0
    assert recall_at_k(gt + 100, gt, 10) == 0.0


def test_recall_is_set_intersection_over_k():
    gt = np.array([[0, 1, 2, 3]])
    pred = np.array([[3, 2, 90, 91]])          # 2 of 4, order-insensitive
    assert recall_at_k(pred, gt, 4) == 0.5
    # averaged over queries
    pred2 = np.array([[0, 1, 2, 3], [10, 11, 12, 13]])
    gt2 = np.array([[0, 1, 2, 3], [0, 1, 2, 3]])
    assert recall_at_k(pred2, gt2, 4) == 0.5


def test_recall_truncates_pred_to_k():
    gt = np.array([[0, 1]])
    pred = np.array([[5, 6, 0, 1]])            # hits only beyond the cutoff
    assert recall_at_k(pred, gt, 2) == 0.0
    assert recall_at_k(np.array([[0, 9, 1]]), gt, 2) == 0.5


def test_recall_sentinel_ids_never_match():
    gt = np.array([[0, 1, 2]])
    pred = np.array([[-1, -1, 0]])             # partial_merge pads with -1
    assert recall_at_k(pred, gt, 3) == 1 / 3
    assert recall_at_k(jnp.asarray(pred), jnp.asarray(gt), 3) == 1 / 3


def test_measure_qps_counts_warmup_and_repeats():
    calls = []

    def search_fn(q):
        calls.append(1)
        return jnp.asarray(np.zeros((q.shape[0], 10)))

    queries = jnp.zeros((50, 8))
    qps, out = measure_qps(search_fn, queries, repeats=3, warmup=2)
    assert len(calls) == 5                     # warmup runs are not timed
    assert qps > 0
    assert out.shape == (50, 10)               # last result is returned


def test_measure_qps_scales_with_latency():
    def slow(q):
        time.sleep(0.02)
        return jnp.zeros((q.shape[0],))

    qps, _ = measure_qps(slow, jnp.zeros((10, 4)), repeats=2, warmup=0)
    assert qps < 10 / 0.02 * 1.5               # bounded by the sleep
