"""PQ / OPQ / RPQ quantizer behaviour + hypothesis property tests."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

pytest.importorskip("hypothesis", reason="optional test dep (pip install "
                    "'.[test]'); property tests need it")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import quantizer as Q
from repro.core import rotation as rot
from repro.pq import base, train_pq, train_opq
from repro.pq.kmeans import kmeans


# ---------- rotation properties -------------------------------------------

@settings(max_examples=10, deadline=None)
@given(dim=st.sampled_from([4, 8, 16]), seed=st.integers(0, 2**31 - 1),
       scale=st.floats(0.0, 2.0))
def test_rotation_is_orthonormal(dim, seed, scale):
    theta = rot.init_rotation_params(dim, scale=scale,
                                     key=jax.random.PRNGKey(seed))
    r = rot.rotation_from_params(theta, dim)
    err = jnp.abs(r @ r.T - jnp.eye(dim)).max()
    assert float(err) < 1e-4


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_rotation_preserves_distances(seed):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    theta = rot.init_rotation_params(16, scale=1.0, key=k1)
    r = rot.rotation_from_params(theta, 16)
    a = jax.random.normal(k2, (5, 16))
    b = jax.random.normal(k3, (5, 16))
    d0 = jnp.sum((a - b) ** 2, -1)
    d1 = jnp.sum((rot.rotate(a, r) - rot.rotate(b, r)) ** 2, -1)
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), rtol=1e-4)


def test_split_merge_roundtrip():
    x = jnp.arange(24.0).reshape(2, 12)
    s = rot.split_subvectors(x, 4)
    assert s.shape == (2, 4, 3)
    np.testing.assert_array_equal(np.asarray(rot.merge_subvectors(s)),
                                  np.asarray(x))


# ---------- kmeans ----------------------------------------------------------

def test_kmeans_improves_and_covers(rng):
    x = jnp.asarray(rng.normal(size=(2000, 8)).astype(np.float32))
    cent, assign = kmeans(jax.random.PRNGKey(0), x, 16, iters=10)
    assert cent.shape == (16, 8)
    # every cluster non-empty after re-seeding logic
    counts = np.bincount(np.asarray(assign), minlength=16)
    assert (counts > 0).all()
    # distortion below the trivial single-centroid bound
    d = float(jnp.mean(jnp.sum((x - cent[assign]) ** 2, -1)))
    d0 = float(jnp.mean(jnp.sum((x - x.mean(0)) ** 2, -1)))
    assert d < 0.9 * d0


# ---------- PQ / OPQ --------------------------------------------------------

def test_hard_rpq_encode_equals_pq_encode(rng):
    """DiffPQ with R=I and the same codebook must reproduce classic PQ."""
    x = jnp.asarray(rng.normal(size=(300, 16)).astype(np.float32))
    model = train_pq(jax.random.PRNGKey(0), x, 4, 8, iters=5)
    cfg = Q.RPQConfig(dim=16, m=4, k=8)
    params = Q.init_params(cfg, model.codebooks)
    np.testing.assert_array_equal(
        np.asarray(Q.encode(cfg, params, x, backend="ref")),
        np.asarray(base.encode(model, x, backend="ref")))


def test_opq_beats_pq_on_correlated_data(rng):
    z = rng.normal(size=(4000, 16)).astype(np.float32)
    mix = rng.normal(size=(16, 16)).astype(np.float32) * 0.7 + np.eye(16, dtype=np.float32)
    x = jnp.asarray(z @ mix)
    pq = train_pq(jax.random.PRNGKey(0), x, 4, 16, iters=10)
    opq = train_opq(jax.random.PRNGKey(0), x, 4, 16, outer_iters=3,
                    kmeans_iters=5)
    assert float(base.distortion(opq, x)) < float(base.distortion(pq, x))


def test_decode_roundtrip_distortion_reasonable(rng):
    x = jnp.asarray(rng.normal(size=(2000, 16)).astype(np.float32))
    model = train_pq(jax.random.PRNGKey(0), x, 8, 64, iters=10)
    d = float(base.distortion(model, x))
    d0 = float(jnp.mean(jnp.sum((x - x.mean(0)) ** 2, -1)))
    assert d < 0.5 * d0  # 8 subspaces × 64 codewords on 16-dim gaussian


# ---------- differentiable quantizer ---------------------------------------

def test_gumbel_st_forward_is_hard_onehot(rng):
    x = jnp.asarray(rng.normal(size=(50, 16)).astype(np.float32))
    model = train_pq(jax.random.PRNGKey(0), x, 4, 8, iters=5)
    cfg = Q.RPQConfig(dim=16, m=4, k=8, straight_through=True)
    params = Q.init_params(cfg, model.codebooks)
    y = Q.gumbel_codes(cfg, params, x, jax.random.PRNGKey(1))
    ssum = np.asarray(jnp.sum(y, -1))
    np.testing.assert_allclose(ssum, 1.0, atol=1e-5)
    assert ((np.asarray(y) == 1.0).sum(-1) == 1).all()


def test_quantizer_gradients_flow(rng):
    x = jnp.asarray(rng.normal(size=(64, 16)).astype(np.float32))
    model = train_pq(jax.random.PRNGKey(0), x, 4, 8, iters=5)
    cfg = Q.RPQConfig(dim=16, m=4, k=8)
    params = Q.init_params(cfg, model.codebooks)

    def loss(p):
        xq = Q.quantize_st(cfg, p, x, jax.random.PRNGKey(2))
        r = Q.rotation_matrix(cfg, p)
        return jnp.mean(jnp.sum((x @ r.T - xq) ** 2, -1))

    g = jax.grad(loss)(params)
    assert float(jnp.abs(g.codebooks).max()) > 0
    assert float(jnp.abs(g.theta).max()) > 0  # rotation receives gradient


def test_soft_assign_is_distribution(rng):
    x = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))
    model = train_pq(jax.random.PRNGKey(0), x, 4, 8, iters=3)
    cfg = Q.RPQConfig(dim=16, m=4, k=8)
    params = Q.init_params(cfg, model.codebooks)
    p = Q.soft_assign(cfg, params, x)
    np.testing.assert_allclose(np.asarray(p.sum(-1)), 1.0, atol=1e-5)
    assert (np.asarray(p) >= 0).all()
    # closest codeword gets the highest probability (sign fix of Eq. 6)
    d = Q.subspace_distances(cfg, params, x, backend="ref")
    np.testing.assert_array_equal(np.asarray(jnp.argmax(p, -1)),
                                  np.asarray(jnp.argmin(d, -1)))
