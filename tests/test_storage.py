"""All-in-storage serving tier (repro/storage/, DESIGN.md §14).

Bottom-up over the tier's promises: the record format round-trips both
code layouts bit-identically and every corruption mode (torn header, bad
magic, truncated records, silent record flips) is either detected or
deliberately invisible; ``open_segment``/``DiskEngine.open`` fall back
generation-by-generation past corrupt headers; the reader's counters,
chunk split, and retry path behave; prefetch ≡ synchronous fetch; the
pinned+LRU cache survives the sequential-scan pathology; and DiskEngine
speaks the engine protocol — recall within a point of StreamingEngine
from the same snapshot, tombstones never returned, budgets truncate
honestly, pipelined ≡ serial recall — with the vector-free restore path
and the ``io_time(measured_io_s=)`` adapter closing the loop.
"""

import dataclasses
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.dist.fault import ChaosPlan
from repro.dist.retry import RetryPolicy, TransientIOError
from repro.index import BaseSegment, StreamingEngine
from repro.index.segment import encode_codes, load_segment, save_segment
from repro.pq import train_pq, train_pq_fs4
from repro.search.metrics import recall_at_k
from repro.storage import (AsyncSegmentReader, DiskEngine,
                           FrontierPrefetcher, HotVertexCache,
                           SegmentFormatError, all_generations,
                           corrupt_header, corrupt_record, open_segment,
                           read_header, record_bytes_for, segment_path,
                           write_segment)


@pytest.fixture(scope="module")
def models(clustered_data):
    x, _, _ = clustered_data
    u8 = train_pq(jax.random.PRNGKey(3), x, 8, 32, iters=8)
    fs4 = train_pq_fs4(jax.random.PRNGKey(3), x, 8, iters=8)
    return {"u8": u8, "fs4": fs4}


@pytest.fixture(scope="module")
def segs(clustered_data, small_graph, models, tmp_path_factory):
    """layout -> (directory with gen-0 on disk, BaseSegment, model)."""
    x, _, _ = clustered_data
    out = {}
    for layout in ("u8", "fs4"):
        model = models[layout]
        seg = BaseSegment(graph=small_graph,
                          codes=jnp.asarray(encode_codes(model, x, layout)),
                          vectors=x, layout=layout)
        d = str(tmp_path_factory.mktemp(f"seg_{layout}"))
        write_segment(d, seg, model=model)
        out[layout] = (d, seg, model)
    return out


def reader_for(d, **kw):
    path, header = open_segment(d)
    return AsyncSegmentReader(path, header, **kw)


# ---------------------------------------------------------------------------
# Format: round trip + corruption detection + generation fallback
# ---------------------------------------------------------------------------

def test_record_bytes_alignment():
    for r, w in [(16, 8), (16, 4), (24, 8), (7, 3), (1, 1)]:
        rb = record_bytes_for(r, w)
        assert rb % 8 == 0 and rb >= 4 * r + w and rb < 4 * r + w + 8


@pytest.mark.parametrize("layout", ["u8", "fs4"])
def test_segment_roundtrip_bit_identical(segs, layout):
    """Every record read back equals exactly what the BaseSegment held —
    adjacency AND code bytes, in both layouts (fs4 stays packed)."""
    d, seg, _ = segs[layout]
    path, header = open_segment(d)
    assert (header.n, header.layout) == (seg.n, layout)
    assert header.medoid == int(seg.graph.medoid)
    assert header.dim == seg.dim
    with AsyncSegmentReader(path, header) as rd:
        adj, codes = rd.read_records(np.arange(header.n))
    np.testing.assert_array_equal(
        adj, np.asarray(seg.graph.neighbors, np.int32))
    np.testing.assert_array_equal(codes, np.asarray(seg.codes, np.uint8))
    header.verify_data(path)        # whole-region CRC audit passes


def test_header_corruption_detected(segs, tmp_path):
    d, seg, _ = segs["u8"]
    import shutil
    p = str(tmp_path / "gen_00000000.seg")
    shutil.copy(segment_path(d, 0), p)
    corrupt_header(p, seed=1)
    with pytest.raises(SegmentFormatError, match="crc32|corrupt"):
        read_header(p)
    # truncated records: header promises more bytes than the file holds
    shutil.copy(segment_path(d, 0), p)
    os.truncate(p, read_header(p).file_bytes - 1)
    with pytest.raises(SegmentFormatError, match="truncated"):
        read_header(p)
    # bad magic
    with open(p, "r+b") as f:
        f.write(b"NOTASEG!")
    with pytest.raises(SegmentFormatError, match="magic"):
        read_header(p)


def test_corrupt_record_is_silent_until_audited(segs, tmp_path):
    """A flipped record byte passes header verification (the hot path
    trusts the device) but fails the offline ``verify_data`` audit."""
    d, _, _ = segs["u8"]
    import shutil
    p = str(tmp_path / "gen_00000000.seg")
    shutil.copy(segment_path(d, 0), p)
    vid = corrupt_record(p, seed=2)
    hdr = read_header(p)            # header still verifies
    assert 0 <= vid < hdr.n
    with pytest.raises(SegmentFormatError, match="data is corrupt"):
        hdr.verify_data(p)


def test_generation_fallback(segs, tmp_path):
    """Newest generation corrupt -> open lands on the newest INTACT one;
    an explicitly requested generation never falls back."""
    d0, seg, model = segs["u8"]
    d = str(tmp_path)
    write_segment(d, seg, model=model)
    write_segment(d, dataclasses.replace(seg, generation=1), model=model)
    write_segment(d, dataclasses.replace(seg, generation=2), model=model)
    corrupt_header(segment_path(d, 2), seed=4)
    assert all_generations(d) == [0, 1, 2]
    falls = []
    path, header = open_segment(d, on_fallback=lambda g, e: falls.append(g))
    assert header.generation == 1 and falls == [2]
    assert path == segment_path(d, 1)
    with pytest.raises(SegmentFormatError):
        open_segment(d, generation=2)
    # the engine-level open takes the same walk (sidecar present per gen)
    falls = []
    with DiskEngine.open(d, cache_records=64, seed_cache=False,
                         on_fallback=lambda g, e: falls.append(g)) as eng:
        assert eng.generation == 1 and falls == [2]
    # every generation corrupt -> loud failure, not a silent empty index
    corrupt_header(segment_path(d, 1), seed=4)
    corrupt_header(segment_path(d, 0), seed=4)
    with pytest.raises(RuntimeError, match="every generation"):
        open_segment(d)


# ---------------------------------------------------------------------------
# Reader: counters, chunk split, retry
# ---------------------------------------------------------------------------

def test_reader_counters_and_chunk_split(segs):
    d, _, _ = segs["u8"]
    with reader_for(d, io_threads=4) as rd:
        ids = np.arange(100)
        adj, codes = rd.read_records(ids)
        assert adj.shape == (100, rd.header.r)
        st = rd.stats()
        assert st["n_reads"] == 100
        assert st["bytes_read"] == 100 * rd.header.record_bytes
        assert st["n_batches"] == 1
        # a batch claims half the workers so two batches can be in flight
        assert rd._n_chunks(100) == 2
        assert rd._n_chunks(1) == 1
        # empty submit resolves immediately with empty arrays
        a, c = rd.submit(np.zeros((0,), np.int64)).result()
        assert a.shape == (0, rd.header.r) and c.shape[0] == 0
        # out-of-range ids raise synchronously, in the caller's thread
        with pytest.raises(ValueError, match="out of range"):
            rd.submit([rd.header.n])
        with pytest.raises(ValueError, match="out of range"):
            rd.read_records([-1])


def test_reader_retries_transient_faults(segs):
    d, _, _ = segs["u8"]
    calls = {"n": 0}

    def hook(path):
        calls["n"] += 1
        if calls["n"] <= 2:
            raise TransientIOError("injected")

    with reader_for(d, io_threads=2,
                    retry=RetryPolicy(max_attempts=5, base_delay_s=1e-4,
                                      max_delay_s=1e-3),
                    fault_hook=hook) as rd:
        adj, _ = rd.read_records(np.arange(8))
        assert adj.shape[0] == 8
        assert rd.stats()["n_retries"] == 2
    # without a policy the same fault fails the read loudly
    calls["n"] = 0
    with reader_for(d, fault_hook=hook) as rd:
        with pytest.raises(TransientIOError):
            rd.read_records(np.arange(8))


# ---------------------------------------------------------------------------
# Cache: LRU + pinned BFS seeds + prefetch equivalence
# ---------------------------------------------------------------------------

def test_cache_lru_eviction():
    cache = HotVertexCache(4)
    a = np.zeros((1, 2), np.int32)
    c = np.zeros((1, 3), np.uint8)
    for vid in range(6):
        cache.put_many([vid], a, c)
    assert len(cache) == 4 and cache.evictions == 2
    assert 0 not in cache and 1 not in cache and 5 in cache
    # a hit refreshes recency: 2 survives the next insert, 3 does not
    cache.get_many([2])
    cache.put_many([6], a, c)
    assert 2 in cache and 3 not in cache
    found, missing = cache.get_many([2, 3])
    assert set(found) == {2} and list(missing) == [3]
    assert cache.stats()["hits"] == 2 and cache.stats()["misses"] == 1


def test_cache_pinned_seeds_survive_scans(segs):
    """The sequential-scan pathology: streaming every record through the
    cache once must NOT evict the BFS-seeded medoid ball."""
    d, _, _ = segs["u8"]
    with reader_for(d) as rd:
        cache = HotVertexCache(64)
        order = cache.seed_bfs(rd, rd.header.medoid)
        assert order.size == 32          # default budget: half the capacity
        assert order[0] == rd.header.medoid
        assert cache.stats()["pinned"] == 32
        # full sequential scan through put_many
        ids = np.arange(rd.header.n)
        adj, codes = rd.read_records(ids)
        cache.put_many(ids, adj, codes)
        assert len(cache) == 64          # 32 pinned + 32 LRU, never more
        found, missing = cache.get_many(order)
        assert missing.size == 0         # every seed still resident
        # seeded records are byte-identical to a direct read
        sadj, scodes = rd.read_records(order)
        for j, vid in enumerate(order):
            np.testing.assert_array_equal(found[int(vid)][0], sadj[j])
            np.testing.assert_array_equal(found[int(vid)][1], scodes[j])


def test_prefetch_equals_fetch(segs):
    """prefetch+collect ≡ read_records, in request order, cache-fronted
    or not — the overlap path may never change WHAT is read."""
    d, _, _ = segs["fs4"]
    with reader_for(d) as rd:
        pf = FrontierPrefetcher(rd, HotVertexCache(16))
        ids = np.asarray([7, 3, 11, 200, 3, 7])
        want = np.unique(ids)
        got_ids, adj, codes = pf.collect(pf.prefetch(ids))
        np.testing.assert_array_equal(got_ids, want)
        radj, rcodes = rd.read_records(want)
        np.testing.assert_array_equal(adj, radj)
        np.testing.assert_array_equal(codes, rcodes)
        # second fetch of the same ids: all hits, zero new reads
        st0 = pf.stats()
        got_ids2, adj2, _ = pf.fetch(ids)
        st1 = pf.stats()
        np.testing.assert_array_equal(adj2, adj)
        assert st1["n_reads"] == st0["n_reads"]
        assert st1["cache_hits"] - st0["cache_hits"] == want.size


# ---------------------------------------------------------------------------
# DiskEngine: protocol parity with the resident engines
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("h", [8, 32])
def test_disk_recall_matches_streaming(clustered_data, segs, h):
    """Same snapshot, two tiers: the storage-backed beam lands within a
    recall point of StreamingEngine at matched budgets."""
    x, q, gt = clustered_data
    d, seg, model = segs["u8"]
    sref = StreamingEngine(seg, model, delta_capacity=64)
    rec_mem = recall_at_k(sref.search(q, k=10, h=h).ids, gt, 10)
    with DiskEngine.open(d, cache_records=512) as eng:
        res = eng.search(q, k=10, h=h)
        rec_disk = recall_at_k(res.ids, gt, 10)
    assert rec_disk >= rec_mem - 0.01, (rec_disk, rec_mem)
    io = eng.last_io
    assert io["cache_hit_rate"] > 0.0       # BFS seeds serve the entry ball
    assert io["bytes_read"] == io["n_reads"] * eng.header.record_bytes


def test_disk_overlap_matches_serial(clustered_data, segs):
    """Pipelined (one-round-stale frontier) vs serial: same recall within
    a point, and both modes report their I/O accounting."""
    x, q, gt = clustered_data
    d, _, _ = segs["fs4"]
    with DiskEngine.open(d, cache_records=256) as eng:
        rec_s = recall_at_k(eng.search(q, k=10, h=32, overlap=False).ids,
                            gt, 10)
        assert eng.last_io["overlap"] is False
        rec_p = recall_at_k(eng.search(q, k=10, h=32, overlap=True).ids,
                            gt, 10)
        assert eng.last_io["overlap"] is True
        assert eng.last_io["rounds_total"] > 0
    assert abs(rec_p - rec_s) <= 0.01, (rec_p, rec_s)


def test_disk_tombstones_never_returned(clustered_data, segs):
    x, q, gt = clustered_data
    d, _, _ = segs["u8"]
    dead = np.unique(np.asarray(gt)[:, 0])
    with DiskEngine.open(d, cache_records=256) as eng:
        assert eng.delete(dead) == dead.size
        ids = np.asarray(eng.search(q, k=10, h=32).ids)
    assert not np.isin(ids, dead).any()
    assert (ids >= 0).any(axis=1).all()     # routing stayed alive


def test_disk_budgets_truncate_honestly(clustered_data, segs):
    x, q, _ = clustered_data
    d, _, _ = segs["u8"]
    with DiskEngine.open(d, cache_records=256) as eng:
        free = eng.search(q[:16], k=10, h=32)
        assert not np.asarray(free.truncated).any()
        capped = eng.search(q[:16], k=10, h=32, max_rounds=2)
        assert np.asarray(capped.rounds).max() <= 2
        assert np.asarray(capped.truncated).all()
        assert (np.asarray(capped.ids)[:, 0] >= 0).all()  # best-so-far
        dcap = eng.search(q[:16], k=10, h=32, max_n_dist=64)
        assert np.asarray(dcap.truncated).any()
        # the pipelined loop selects round N+1 before round N's distances
        # merge, so budget enforcement is one round stale: overshoot is
        # bounded by the two in-flight rounds' candidates (≤ 2·R each)
        assert np.asarray(dcap.n_dist).max() <= 64 + 2 * eng.header.r


def test_vector_free_restore_roundtrip(segs, tmp_path):
    """Snapshot -> ``load_segment(with_vectors=False)`` (zero vector
    bytes, ``Dropped`` sentinel consumed into ``dim_hint``) -> segment
    file -> DiskEngine: the full export path of the storage tier."""
    d0, seg, model = segs["u8"]
    ck = str(tmp_path / "ckpt")
    save_segment(ck, seg, model=model)
    lean = load_segment(ck, with_vectors=False)
    assert lean.vectors is None and lean.dim_hint == seg.dim
    assert lean.dim == seg.dim
    np.testing.assert_array_equal(np.asarray(lean.codes),
                                  np.asarray(seg.codes))
    out = str(tmp_path / "segdir")
    write_segment(out, lean, model=model)
    with DiskEngine.open(out, cache_records=64) as eng:
        assert eng.n == seg.n and eng.header.dim == seg.dim


def test_io_time_measured_adapter(clustered_data, segs):
    """``HybridEngine.io_time(measured_io_s=)``: a real tier's measured
    batch stall replaces the closed-form model, amortized per query."""
    from repro.search.engine import HybridEngine

    x, q, _ = clustered_data
    d, seg, model = segs["u8"]
    with DiskEngine.open(d, cache_records=256, slow_read_ms=0.5) as eng:
        res = eng.search(q[:8], k=10, h=16, overlap=False)
        io_wait = eng.last_io["io_wait_s"]
    assert io_wait > 0.0
    from repro.pq import base as pqbase
    hyb = HybridEngine(seg.graph, np.asarray(seg.codes),
                       lambda qq: pqbase.build_lut(model, qq),
                       vectors=x, io_latency_s=5e-4)
    model_t = np.asarray(hyb.io_time(res))
    meas_t = np.asarray(hyb.io_time(res, measured_io_s=io_wait))
    assert model_t.shape == meas_t.shape == (8,)
    assert (model_t > 0).all()
    np.testing.assert_allclose(meas_t, io_wait / 8, rtol=1e-6)


def test_chaos_plan_storage_tokens():
    plan = ChaosPlan.parse("io=0.5,corrupt_record,slow_read=3,seed=2")
    assert plan.io_fault_p == 0.5
    assert plan.corrupt_record is True
    assert plan.slow_read_ms == 3.0
    assert plan.seed == 2
    off = ChaosPlan.parse("slow_read=0")
    assert off.slow_read_ms == 0.0 and off.corrupt_record is False
