"""Serving engines: InMemory / Hybrid recall + rerank clamping +
memory accounting, and ShardedEngine scatter-gather equivalence (single
device in-process; 4 forced host devices in a subprocess) including
dead-shard degradation via dist.fault.partial_merge."""

import os
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.graphs import build_vamana
from repro.graphs.knn import knn_ids
from repro.pq import base as pqbase
from repro.pq.pq import train_pq
from repro.search.engine import HybridEngine, InMemoryEngine, ShardedEngine

N, D, Q, M, K = 240, 32, 8, 4, 16
TOPK = 10


@pytest.fixture(scope="module")
def setup():
    r = np.random.default_rng(7)
    centers = r.normal(size=(8, D)) * 2.5
    x = (centers[r.integers(0, 8, N)]
         + r.normal(size=(N, D))).astype(np.float32)
    q = (centers[r.integers(0, 8, Q)]
         + r.normal(size=(Q, D))).astype(np.float32)
    x, q = jnp.asarray(x), jnp.asarray(q)
    model = train_pq(jax.random.PRNGKey(0), x, M, K, iters=8)
    codes = pqbase.encode(model, x)
    graph = build_vamana(jax.random.PRNGKey(1), x, r=24, l=48)
    # the exact id-sequence equivalence tests need tie-free ADC distances,
    # so they use UNIQUE random codes (real encodes of clustered data
    # collide: identical codes ⇒ tied distances ⇒ order is undefined)
    codes_uniq = r.integers(0, K, (N, M)).astype(np.uint8)
    while np.unique(codes_uniq, axis=0).shape[0] != N:  # pragma: no cover
        codes_uniq = r.integers(0, K, (N, M)).astype(np.uint8)
    codes_uniq = jnp.asarray(codes_uniq)
    adc = np.asarray(pqbase.adc(model, codes_uniq, q))
    adc_top = np.argsort(adc, axis=1, kind="stable")[:, :TOPK]
    gt, _ = knn_ids(x, q, TOPK)
    return dict(x=x, q=q, model=model, codes=codes, codes_uniq=codes_uniq,
                graph=graph, adc=adc, adc_top=adc_top, gt=np.asarray(gt))


def _lut_fn(model):
    return lambda qq: pqbase.build_lut(model, qq)


def test_inmemory_exhaustive_beam_matches_adc_topk(setup):
    """With h = N on a connected PG, the beam visits every vertex — the
    result must be the exact ADC top-k (this is the single-device oracle
    the sharded engine is later compared against)."""
    eng = InMemoryEngine(setup["graph"], setup["codes_uniq"],
                         _lut_fn(setup["model"]))
    res = eng.search(setup["q"], k=TOPK, h=N, max_steps=2 * N)
    np.testing.assert_array_equal(np.asarray(res.ids), setup["adc_top"])


def test_inmemory_recall_and_memory(setup):
    eng = InMemoryEngine(setup["graph"], setup["codes"],
                         _lut_fn(setup["model"]))
    res = eng.search(setup["q"], k=TOPK, h=48)
    rec = np.mean([len(set(a) & set(b)) / TOPK
                   for a, b in zip(np.asarray(res.ids), setup["gt"])])
    assert rec > 0.5
    assert eng.memory_bytes() == (setup["codes"].size
                                  + setup["graph"].neighbors.size * 4)


def test_hybrid_rerank_clamps_k_and_improves_recall(setup):
    eng = HybridEngine(setup["graph"], setup["codes"],
                       _lut_fn(setup["model"]), vectors=setup["x"])
    # k is clamped to the rerank budget
    res = eng.search(setup["q"], k=TOPK, h=48, rerank=4)
    assert res.ids.shape == (Q, 4)
    # exact rerank of the full beam: recall must beat/equal ADC-only
    res_h = eng.search(setup["q"], k=TOPK, h=48)
    mem = InMemoryEngine(setup["graph"], setup["codes"],
                         _lut_fn(setup["model"]))
    res_m = mem.search(setup["q"], k=TOPK, h=48)
    rec = lambda ids: np.mean([len(set(a) & set(b)) / TOPK for a, b
                               in zip(np.asarray(ids), setup["gt"])])
    assert rec(res_h.ids) >= rec(res_m.ids)
    # resident set = codes only (vectors + graph live "on SSD")
    assert eng.memory_bytes() == setup["codes"].size


def test_sharded_single_device_matches_inmemory(setup):
    """All-shards-alive ShardedEngine ≡ exhaustive-beam InMemoryEngine."""
    eng = ShardedEngine(setup["codes_uniq"], _lut_fn(setup["model"]))
    res = eng.search(setup["q"], k=TOPK)
    np.testing.assert_array_equal(np.asarray(res.ids), setup["adc_top"])
    assert eng.memory_bytes() == setup["codes_uniq"].size
    hyb = ShardedEngine(setup["codes"], _lut_fn(setup["model"]),
                        vectors=setup["x"], shortlist_mult=N)
    res = hyb.search(setup["q"], k=TOPK)
    np.testing.assert_array_equal(np.asarray(res.ids), setup["gt"])
    assert hyb.memory_bytes() == setup["codes"].size + setup["x"].size * 4


_SUBPROC = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, jax.numpy as jnp
from repro.graphs.adjacency import Graph
from repro.pq import base as pqbase
from repro.search.engine import InMemoryEngine, ShardedEngine

assert len(jax.devices()) == 4
z = np.load({path!r})
model = pqbase.QuantizerModel(r=jnp.asarray(z["r"]),
                              codebooks=jnp.asarray(z["codebooks"]))
codes = jnp.asarray(z["codes"])
x, q = jnp.asarray(z["x"]), jnp.asarray(z["q"])
graph = Graph(neighbors=jnp.asarray(z["neighbors"]),
              medoid=jnp.asarray(z["medoid"]))
lut_fn = lambda qq: pqbase.build_lut(model, qq)

se = ShardedEngine(codes, lut_fn)
assert se.n_shards == 4, se.n_shards
res = se.search(q, k={topk})
mem = InMemoryEngine(graph, codes, lut_fn)
rm = mem.search(q, k={topk}, h={n}, max_steps={n2})
np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(rm.ids))
print("EQUIV_OK")

# dead shard 1: its row range must vanish; survivors merge exactly
n_local = {n} // 4
alive = [True, False, True, True]
rd = se.search(q, k={topk}, alive=alive)
ids = np.asarray(rd.ids)
assert not np.any((ids >= n_local) & (ids < 2 * n_local)), ids
adc = np.array(pqbase.adc(model, codes, q))
adc[:, n_local:2 * n_local] = np.inf
expect = np.argsort(adc, axis=1, kind="stable")[:, :{topk}]
np.testing.assert_array_equal(ids, expect)
print("DEGRADE_OK")
"""


def test_sharded_4dev_equivalence_and_dead_shard(setup, tmp_path):
    """ShardedEngine under 4 forced host devices: identical top-k ids to
    InMemoryEngine (all alive), and exact survivors-only merge when a
    shard dies (partial_merge path). Subprocess so this process keeps its
    1-device view (conftest requirement)."""
    path = str(tmp_path / "engine_case.npz")
    np.savez(path, x=np.asarray(setup["x"]), q=np.asarray(setup["q"]),
             codes=np.asarray(setup["codes_uniq"]),
             r=np.asarray(setup["model"].r),
             codebooks=np.asarray(setup["model"].codebooks),
             neighbors=np.asarray(setup["graph"].neighbors),
             medoid=np.asarray(setup["graph"].medoid))
    code = _SUBPROC.format(path=path, topk=TOPK, n=N, n2=2 * N)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600,
                       env={**os.environ, "PYTHONPATH": "src"},
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "EQUIV_OK" in r.stdout and "DEGRADE_OK" in r.stdout, \
        (r.stdout[-1500:], r.stderr[-2000:])


def test_data_parallel_fit_smoke(setup):
    """TrainConfig.data_parallel wires fit() through dist.sharding (+ int8
    error-feedback compression) — must run and produce finite losses on
    however many devices exist (mesh = every local device)."""
    from repro.core import RPQConfig
    from repro.core import trainer as T

    cfg = RPQConfig(dim=D, m=M, k=K)
    tcfg = T.TrainConfig(steps=4, triplet_batch=32, routing_batch=32,
                         routing_pool_queries=8, refresh_every=2,
                         log_every=1, data_parallel=True,
                         compress_grads=True)
    st = T.fit(jax.random.PRNGKey(3), cfg, tcfg, setup["x"], setup["graph"],
               verbose=False)
    assert st.history and all(np.isfinite(h["total"]) for h in st.history)
