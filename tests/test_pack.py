"""Fast-scan layout properties: 4-bit pack/unpack roundtrip, LUT
quantization error bounds, and the fs4-vs-f32 ADC distance bound
(DESIGN.md §8)."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels import ref
from repro.pq import pack


@pytest.mark.parametrize("n,m", [(1, 1), (7, 2), (64, 5), (33, 16),
                                 (100, 15), (256, 8)])
def test_pack_roundtrip(n, m, rng):
    codes = rng.integers(0, 16, (n, m)).astype(np.uint8)
    packed = pack.pack_codes(jnp.asarray(codes))
    assert packed.shape == (n, pack.packed_width(m))
    assert packed.dtype == jnp.uint8
    back = pack.unpack_codes(packed, m)
    assert (np.asarray(back) == codes).all()


def test_pack_odd_m_high_nibble_zero(rng):
    """Odd M leaves the last byte's high nibble zero (the sentinel slot)."""
    codes = rng.integers(0, 16, (20, 5)).astype(np.uint8)
    packed = np.asarray(pack.pack_codes(jnp.asarray(codes)))
    assert (packed[:, -1] >> 4 == 0).all()


def test_pack_sentinel_rows_roundtrip():
    """All-zero sentinel rows (the engines' padding) survive packing."""
    codes = np.zeros((3, 7), np.uint8)
    packed = pack.pack_codes(jnp.asarray(codes))
    assert (np.asarray(packed) == 0).all()
    assert (np.asarray(pack.unpack_codes(packed, 7)) == 0).all()


def test_pack_masks_out_of_range():
    """Values ≥ 16 are masked to 4 bits, never corrupt the neighbor code."""
    codes = np.array([[0x1F, 3]], np.uint8)    # 31 → 15
    back = np.asarray(pack.unpack_codes(pack.pack_codes(jnp.asarray(codes)), 2))
    assert back.tolist() == [[15, 3]]


def test_pack_roundtrip_property(rng):
    """Property sweep over random shapes (hypothesis when available)."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=30, deadline=None)
    @given(st.integers(1, 40), st.integers(1, 20), st.integers(0, 2**31 - 1))
    def prop(n, m, seed):
        r = np.random.default_rng(seed)
        codes = r.integers(0, 16, (n, m)).astype(np.uint8)
        back = pack.unpack_codes(pack.pack_codes(jnp.asarray(codes)), m)
        assert (np.asarray(back) == codes).all()

    prop()


@pytest.mark.parametrize("k", [16, 8, 3])
def test_quantize_luts_bounds(k, rng):
    """Per-entry dequant error ≤ scale/2; K < 16 pads to 16 columns."""
    luts = rng.normal(size=(5, 8, k)).astype(np.float32) ** 2
    ql = pack.quantize_luts(jnp.asarray(luts))
    assert ql.lut.shape == (5, 8, 16)
    assert ql.lut.dtype == jnp.uint8
    deq = np.asarray(ql.dequantize())[:, :, :k]
    err = np.abs(deq - luts)
    bound = np.asarray(ql.scale)[:, None, None] / 2 + 1e-6
    assert (err <= bound).all()


def test_quantize_luts_constant_table():
    """A constant LUT must not divide by zero; dequant stays exact."""
    luts = jnp.full((2, 4, 16), 3.25, jnp.float32)
    ql = pack.quantize_luts(luts)
    assert np.isfinite(np.asarray(ql.scale)).all()
    np.testing.assert_allclose(np.asarray(ql.dequantize()), 3.25)


def test_quantize_luts_rejects_wide_k():
    with pytest.raises(ValueError):
        pack.quantize_luts(jnp.zeros((1, 4, 17), jnp.float32))


def test_fs_adc_error_bound(rng):
    """fs4 ADC distance within M·scale of the f32 ADC distance — the bound
    the LUT quantization math guarantees (M entries × ≤ scale/2 each, plus
    headroom for the affine rounding)."""
    n, m, q = 500, 8, 6
    codes = rng.integers(0, 16, (n, m)).astype(np.uint8)
    packed = pack.pack_codes(jnp.asarray(codes))
    luts = rng.normal(size=(q, m, 16)).astype(np.float32) ** 2
    ql = pack.quantize_luts(jnp.asarray(luts))
    fs = np.asarray(ref.adc_scan_fs_ref(packed, ql.lut, ql.scale, ql.bias))
    f32 = np.asarray(ref.adc_scan_batch_ref(jnp.asarray(codes),
                                            jnp.asarray(luts)))
    err = np.abs(fs - f32)
    bound = m * np.asarray(ql.scale)[:, None] + 1e-4
    assert (err <= bound).all(), (err.max(), bound.max())


def test_paired_lut_equals_nibble_sum(rng):
    """The oracle's paired-byte table == summing the two nibble entries."""
    m = 7
    luts = rng.integers(0, 256, (3, m, 16)).astype(np.uint8)
    pair = np.asarray(ref._pair_lut(jnp.asarray(luts)))
    li = luts.astype(np.int64)
    for byte in (0, 17, 128, 255):
        lo, hi = byte & 0xF, byte >> 4
        want = li[:, 0::2, lo].copy()
        want[:, : m // 2] += li[:, 1::2, hi]
        assert (pair[:, :, byte] == want).all()
