"""Integration guard for deliverable (e): a representative subset of the
dry-run cells must lower + compile on the production meshes.

Runs in a SUBPROCESS so the forced 512-device count never leaks into this
test process (conftest requirement: tests see 1 device). Uses the cheapest
cell of each family (compile ≈ 2 s each); the full 88-cell sweep is
launch/dryrun.py → reports/dryrun.json.
"""

import json
import os
import subprocess
import sys

import pytest

CELLS = [
    ("gat-cora", "molecule"),
    ("deepfm", "serve_p99"),
    ("rpq", "adc_bulk"),
    ("rpq", "sharded_graph_fs4"),   # fast-scan packed serving layout
    ("rpq", "sharded_graph_wide"),  # frontier-batched beam (expand=4, R'=256)
    ("granite-moe-1b-a400m", "long_500k"),
]


@pytest.mark.parametrize("arch,shape", CELLS)
def test_cell_compiles_multi_pod(arch, shape, tmp_path):
    out = tmp_path / "cells.json"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--multi-pod-only", "--out", str(out)],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-1500:])
    rec = json.load(open(out))[0]
    assert rec["ok"], rec.get("error")
    assert rec["memory"]["argument_bytes"] >= 0
    assert rec["collectives"]["total"] >= 0


def test_report_exists_and_green():
    """The shipped report must be complete (regenerate via dryrun.py)."""
    path = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                        "reports", "dryrun.json")
    if not os.path.exists(path):
        pytest.skip("reports/dryrun.json not generated yet")
    recs = json.load(open(path))
    assert len(recs) >= 80
    bad = [f"{r['arch']}×{r['shape']}@{r['mesh']}" for r in recs
           if not r.get("ok")]
    assert not bad, bad
