"""End-to-end fast-scan serving: the fs4 layout (packed codes + quantized
LUTs) must match the u8 layout's recall through every engine — the layout
changes bytes, not answers (LUT quantization costs < 2 recall points)."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.pq import base, pack, train_pq_fs4
from repro.search.engine import (InMemoryEngine, ShardedEngine,
                                 ShardedGraphEngine)
from repro.search.metrics import recall_at_k


@pytest.fixture(scope="module")
def fs4_quantizer(clustered_data):
    x, _, _ = clustered_data
    model = train_pq_fs4(jax.random.PRNGKey(3), x, 8, iters=8)
    codes = base.encode(model, x)
    assert int(codes.max()) < 16          # 4-bit codes by construction
    return model, codes, pack.pack_codes(codes)


def test_inmemory_recall_parity(clustered_data, small_graph, fs4_quantizer):
    """Same K=16 model served u8 vs fs4: recall@10 within 2 points."""
    x, q, gt = clustered_data
    model, codes, packed = fs4_quantizer
    e_u8 = InMemoryEngine(small_graph, codes,
                          lambda qq: base.build_lut(model, qq))
    e_fs = InMemoryEngine(small_graph, packed,
                          lambda qq: base.build_lut(model, qq, quantize=True))
    r_u8 = recall_at_k(e_u8.search(q, k=10, h=32).ids, gt, 10)
    r_fs = recall_at_k(e_fs.search(q, k=10, h=32).ids, gt, 10)
    assert abs(r_u8 - r_fs) <= 0.02, (r_u8, r_fs)


def test_sharded_scan_recall_parity(clustered_data, fs4_quantizer):
    """The exhaustive scan engine in fs4 (ops.adc_scan_fs under shard_map)
    vs u8; with exact local rerank both layouts converge further."""
    x, q, gt = clustered_data
    model, codes, packed = fs4_quantizer
    e_u8 = ShardedEngine(codes, lambda qq: base.build_lut(model, qq))
    e_fs = ShardedEngine(packed,
                         lambda qq: base.build_lut(model, qq, quantize=True))
    r_u8 = recall_at_k(e_u8.search(q, k=10).ids, gt, 10)
    r_fs = recall_at_k(e_fs.search(q, k=10).ids, gt, 10)
    assert abs(r_u8 - r_fs) <= 0.02, (r_u8, r_fs)
    assert e_fs.memory_bytes() < e_u8.memory_bytes()


def test_sharded_graph_fs4(clustered_data, fs4_quantizer):
    """Graph-routed serving accepts the packed layout end to end (packed
    codes through shard_map, QuantizedLUT through the beam's dist fn)."""
    from repro.graphs.partition import build_partitioned_vamana

    x, q, gt = clustered_data
    model, codes, packed = fs4_quantizer
    pg = build_partitioned_vamana(jax.random.PRNGKey(0), x, 1, r=16, l=32)
    e_fs = ShardedGraphEngine(pg, packed,
                              lambda qq: base.build_lut(model, qq,
                                                        quantize=True),
                              vectors=x)
    e_u8 = ShardedGraphEngine(pg, codes,
                              lambda qq: base.build_lut(model, qq),
                              vectors=x)
    res_fs = e_fs.search(q, k=10, h=32)
    r_fs = recall_at_k(res_fs.ids, gt, 10)
    r_u8 = recall_at_k(e_u8.search(q, k=10, h=32).ids, gt, 10)
    assert abs(r_u8 - r_fs) <= 0.02, (r_u8, r_fs)
    assert int(res_fs.hops.min()) > 0


def test_fs4_bulk_adc_close_to_f32(clustered_data, fs4_quantizer):
    """Engine-level distances: fs4 bulk scan within M·scale of f32 ADC."""
    x, q, _ = clustered_data
    model, codes, packed = fs4_quantizer
    ql = base.build_lut(model, q[:8], quantize=True)
    luts = base.build_lut(model, q[:8])
    from repro.kernels import ops

    fs = np.asarray(ops.adc_scan_fs(packed, ql.lut, ql.scale, ql.bias,
                                    backend="ref"))
    f32 = np.asarray(ops.adc_scan_batch(codes, luts, backend="ref"))
    bound = model.m * np.asarray(ql.scale)[:, None] + 1e-4
    assert (np.abs(fs - f32) <= bound).all()
