"""Adaptive routing (DESIGN.md §11): PQ-hash multi-entry seeding +
probabilistic hop pruning.

The contracts under test:

* ``entries=1`` / ``prune_eps=0`` is BIT-identical to the classic beam —
  the adaptive machinery compiles out entirely (regression bar for every
  earlier PR's behavior).
* Multi-entry seeding routes: empty hash buckets fall back to the strided
  pivots, an all-tombstoned candidate set still returns finite entries
  (DEAD_ENTRY_DIST routing, the classic deleted-medoid case), and seeded
  search matches baseline recall with fewer sequential rounds.
* The partial-LUT prefix is a true lower bound on both layouts and the
  kernels' ``m_prefix`` path agrees with the sliced reference oracle.
* ``n_dist`` counts actually-scored lanes only: sentinel padding never
  inflates it (at any expand), streaming charges occupied delta slots
  only, and the hybrid IO model charges the whole seed probe as ONE
  batched read.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.graphs import Graph
from repro.graphs.partition import build_partitioned_vamana
from repro.kernels import ops
from repro.pq import base as pqbase
from repro.pq import pack, train_pq, train_pq_fs4
from repro.search.beam import beam_search, make_adc_dist_fn, \
    make_exact_dist_fn
from repro.search.engine import (HybridEngine, InMemoryEngine,
                                 ShardedGraphEngine)
from repro.search.metrics import recall_at_k
from repro.search.seed import build_seed_index, seed_entries_from


@pytest.fixture(scope="module")
def setup(clustered_data, small_graph):
    x, q, gt = clustered_data
    model = train_pq(jax.random.PRNGKey(0), x, 8, 64, iters=8)
    fs4 = train_pq_fs4(jax.random.PRNGKey(3), x, 8, iters=8)
    return dict(x=x, q=q, gt=np.asarray(gt), graph=small_graph,
                model=model, codes=pqbase.encode(model, x),
                lut_fn=lambda qq: pqbase.build_lut(model, qq),
                fs4_model=fs4, fs4_codes=pqbase.encode(fs4, x),
                fs4_lut_fn=lambda qq: pqbase.build_lut(fs4, qq,
                                                       quantize=True))


# =========================================================================
# S=1 / eps=0 bit-identity (the regression contract)
# =========================================================================

def test_entries1_eps0_bit_identical_engine(setup):
    """The adaptive defaults ARE the classic engine — every SearchResult
    field bitwise equal, including an explicitly-passed m_prefix with the
    eps=0 off switch."""
    eng = InMemoryEngine(setup["graph"], setup["codes"], setup["lut_fn"])
    a = eng.search(setup["q"], k=10, h=32)
    b = eng.search(setup["q"], k=10, h=32, entries=1, prune_eps=0.0)
    c = eng.search(setup["q"], k=10, h=32, entries=1, prune_eps=0.0,
                   m_prefix=4)
    for got in (b, c):
        for fa, fg in zip(a, got):
            np.testing.assert_array_equal(np.asarray(fa), np.asarray(fg))


def test_entry_set_width1_bit_identical_beam(setup):
    """A (Q, 1) entry-set matrix runs the classic single-entry init op for
    op — same result as the scalar medoid."""
    g, q = setup["graph"], setup["q"]
    luts = setup["lut_fn"](q)
    dist_fn = make_adc_dist_fn(ops.pad_sentinel_row(setup["codes"]))
    a = beam_search(g.neighbors, g.medoid, luts, dist_fn, h=32, max_steps=64)
    ent = jnp.full((q.shape[0], 1), int(g.medoid), jnp.int32)
    b = beam_search(g.neighbors, ent, luts, dist_fn, h=32, max_steps=64)
    for fa, fb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))


# =========================================================================
# multi-entry seeding
# =========================================================================

def test_seeded_search_recall_and_rounds(setup):
    """S=8 seeding holds recall while needing no more sequential rounds
    than the single-medoid walk (it skips the escape-the-medoid phase)."""
    eng = InMemoryEngine(setup["graph"], setup["codes"], setup["lut_fn"])
    r1 = eng.search(setup["q"], k=10, h=32)
    r8 = eng.search(setup["q"], k=10, h=32, entries=8)
    assert recall_at_k(r8.ids, setup["gt"], 10) >= \
        recall_at_k(r1.ids, setup["gt"], 10) - 0.02
    assert float(np.mean(np.asarray(r8.rounds))) < \
        float(np.mean(np.asarray(r1.rounds)))


def test_empty_bucket_falls_back_to_pivots(setup):
    """A query hashing to an empty bucket seeds from the strided pivots —
    never -1 lanes, never a crash."""
    ix = build_seed_index(np.asarray(setup["codes"]))
    table = np.asarray(ix.table)
    empty = np.flatnonzero(~(table >= 0).any(axis=1))
    assert empty.size, "fixture corpus fills every bucket — enlarge table"
    key = int(empty[0])
    # craft a LUT whose first-m_hash argmins fold to exactly that key
    m, k = 8, 64
    digits = [(key // (ix.k ** j)) % ix.k for j in range(ix.m_hash)]
    lut = np.ones((1, m, k), np.float32)
    for j, dig in enumerate(digits):
        lut[0, j, dig] = 0.0
    ent = np.asarray(ix.seed_entries(jnp.asarray(lut), 4))
    assert (ent >= 0).all()
    assert set(ent[0].tolist()) <= set(np.asarray(ix.pivots).tolist())


def test_all_tombstoned_candidates_still_route(setup):
    """Every candidate dead → DEAD_ENTRY_DIST seeds: finite, so the beam
    still starts and routes off them (classic deleted-medoid semantics)."""
    n = setup["codes"].shape[0]
    ix = build_seed_index(np.asarray(setup["codes"]))
    luts = setup["lut_fn"](setup["q"][:4])
    dead_all = jnp.full(((n + 31) // 32 + 1,), 0xFFFFFFFF, jnp.uint32)
    ent = np.asarray(ix.seed_entries(luts, 4, tombstones=dead_all))
    assert (ent >= 0).all()          # finite seeds, not -1 padding
    live = jnp.zeros(((n + 31) // 32 + 1,), jnp.uint32)
    ent_live = np.asarray(ix.seed_entries(luts, 4, tombstones=live))
    np.testing.assert_array_equal(
        ent_live, np.asarray(ix.seed_entries(luts, 4)))


def test_seed_entries_shard_functional_core(setup):
    """seed_entries_from — what the sharded engines call inside shard_map —
    agrees with the object API."""
    ix = build_seed_index(np.asarray(setup["codes"]))
    luts = setup["lut_fn"](setup["q"][:8])
    a = np.asarray(ix.seed_entries(luts, 8))
    b = np.asarray(seed_entries_from(ix.table, ix.pivots, ix.codes, luts,
                                     k=ix.k, m_hash=ix.m_hash, s=8))
    np.testing.assert_array_equal(a, b)


# =========================================================================
# layout parity: u8 vs fs4 through the engines, adaptive config on
# =========================================================================

def test_u8_fs4_parity_inmemory_adaptive(setup):
    eng_u8 = InMemoryEngine(setup["graph"], setup["fs4_codes"],
                            lambda qq: pqbase.build_lut(setup["fs4_model"],
                                                        qq))
    eng_fs = InMemoryEngine(setup["graph"],
                            pack.pack_codes(setup["fs4_codes"]),
                            setup["fs4_lut_fn"])
    kw = dict(k=10, h=32, entries=8, prune_eps=0.1)
    r_u8 = recall_at_k(eng_u8.search(setup["q"], **kw).ids, setup["gt"], 10)
    r_fs = recall_at_k(eng_fs.search(setup["q"], **kw).ids, setup["gt"], 10)
    assert abs(r_u8 - r_fs) <= 0.03, (r_u8, r_fs)


def test_u8_fs4_parity_sharded_graph_adaptive(setup):
    """Single-shard ShardedGraphEngine: per-shard seeding + pruning inside
    shard_map, both layouts, and S=1/eps=0 equals the plain engine run."""
    x, q, gt = setup["x"], setup["q"], setup["gt"]
    pg = build_partitioned_vamana(jax.random.PRNGKey(1), x, 1, r=16, l=32)
    eng_u8 = ShardedGraphEngine(pg, setup["fs4_codes"],
                                lambda qq: pqbase.build_lut(
                                    setup["fs4_model"], qq))
    eng_fs = ShardedGraphEngine(pg, pack.pack_codes(setup["fs4_codes"]),
                                setup["fs4_lut_fn"])
    base_res = eng_u8.search(q, k=10, h=32)
    off = eng_u8.search(q, k=10, h=32, entries=1, prune_eps=0.0)
    for fa, fb in zip(base_res, off):
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))
    kw = dict(k=10, h=32, entries=8, prune_eps=0.1)
    r_u8 = recall_at_k(eng_u8.search(q, **kw).ids, gt, 10)
    r_fs = recall_at_k(eng_fs.search(q, **kw).ids, gt, 10)
    assert abs(r_u8 - r_fs) <= 0.03, (r_u8, r_fs)
    assert r_u8 >= recall_at_k(base_res.ids, gt, 10) - 0.02


# =========================================================================
# hop pruning: lower-bound math + kernel m_prefix parity
# =========================================================================

@pytest.mark.parametrize("mp", [1, 3, 4, 7])
def test_prefix_is_lower_bound_u8(setup, mp):
    luts = setup["lut_fn"](setup["q"][:16])
    codes_p = ops.pad_sentinel_row(setup["codes"])
    full = make_adc_dist_fn(codes_p)
    part = make_adc_dist_fn(codes_p, m_prefix=mp)
    ids = jnp.arange(64, dtype=jnp.int32)
    for i in range(4):
        d_full = np.asarray(full(jax.tree.map(lambda l: l[i], luts), ids))
        d_part = np.asarray(part(jax.tree.map(lambda l: l[i], luts), ids))
        assert (d_part <= d_full + 1e-4).all()


@pytest.mark.parametrize("mp", [3, 4])
def test_prefix_is_lower_bound_fs4(setup, mp):
    """Quantized metric too: scale ≥ 0 and bias = min LUT entry ≥ 0 keep
    the prefix sum a lower bound (odd m_prefix exercises the nibble
    boundary)."""
    luts = setup["fs4_lut_fn"](setup["q"][:8])
    packed_p = ops.pad_sentinel_row(pack.pack_codes(setup["fs4_codes"]))
    full = make_adc_dist_fn(packed_p, packed=True)
    part = make_adc_dist_fn(packed_p, packed=True, m_prefix=mp)
    ids = jnp.arange(64, dtype=jnp.int32)
    for i in range(4):
        one = jax.tree.map(lambda l: l[i], luts)
        d_full = np.asarray(full(one, ids))
        d_part = np.asarray(part(one, ids))
        assert (d_part <= d_full + 1e-4).all()


@pytest.mark.parametrize("mp", [0, 3, 4])
def test_kernel_m_prefix_matches_ref(setup, mp):
    """ops.hop_adc / hop_adc_fs with m_prefix: the Pallas kernel (interpret
    mode) must agree with the sliced reference oracle."""
    q = setup["q"][:4]
    ids = jnp.arange(96, dtype=jnp.int32)[None].repeat(4, 0)
    luts = setup["lut_fn"](q)
    codes_p = ops.pad_sentinel_row(setup["codes"])
    a = ops.hop_adc(codes_p, ids, luts, backend="interpret", m_prefix=mp)
    b = ops.hop_adc(codes_p, ids, luts, backend="ref", m_prefix=mp)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)

    qluts = setup["fs4_lut_fn"](q)
    packed_p = ops.pad_sentinel_row(pack.pack_codes(setup["fs4_codes"]))
    a = ops.hop_adc_fs(packed_p, ids, qluts.lut, qluts.scale, qluts.bias,
                       backend="interpret", m_prefix=mp)
    b = ops.hop_adc_fs(packed_p, ids, qluts.lut, qluts.scale, qluts.bias,
                       backend="ref", m_prefix=mp)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)


def test_pruned_search_recall_and_accounting(setup):
    """Pruning with seeding holds recall within 2pt; n_dist stays a
    positive full-LUT-equivalent count no larger than the unpruned run's
    (the gate can only remove full evaluations, and the partial pass is
    charged fractionally)."""
    eng = InMemoryEngine(setup["graph"], setup["codes"], setup["lut_fn"])
    plain = eng.search(setup["q"], k=10, h=32, entries=8)
    pruned = eng.search(setup["q"], k=10, h=32, entries=8, prune_eps=0.2,
                        m_prefix=2)
    assert recall_at_k(pruned.ids, setup["gt"], 10) >= \
        recall_at_k(plain.ids, setup["gt"], 10) - 0.02
    assert (np.asarray(pruned.n_dist) > 0).all()
    assert float(np.mean(np.asarray(pruned.n_dist))) <= \
        float(np.mean(np.asarray(plain.n_dist)))


# =========================================================================
# n_dist counts actually-scored lanes only (satellite: padding never
# inflates it)
# =========================================================================

@pytest.mark.parametrize("expand", [1, 4])
@pytest.mark.parametrize("r_pad", [2, 8])
def test_ndist_exact_on_path_graph(expand, r_pad):
    """A 1-D path graph explored end to end scores every vertex exactly
    once: n_dist == N regardless of expand and of how much sentinel
    padding the adjacency carries."""
    n = 12
    nbrs = np.full((n, r_pad), n, np.int32)
    for i in range(n):
        if i > 0:
            nbrs[i, 0] = i - 1
        if i < n - 1:
            nbrs[i, 1] = i + 1
    vec = np.zeros((n + 1, 2), np.float32)
    vec[:n, 0] = np.arange(n)
    vec[n] = 1e6                       # sentinel row far away
    g = Graph(neighbors=jnp.asarray(nbrs), medoid=jnp.int32(0))
    q = jnp.asarray([[n - 1 + 0.1, 0.0]], jnp.float32)
    res = beam_search(g.neighbors, g.medoid, q,
                      make_exact_dist_fn(jnp.asarray(vec)), h=4,
                      max_steps=64, expand=expand)
    assert int(res.n_dist[0]) == n
    assert int(res.ids[0, 0]) == n - 1


def test_streaming_ndist_counts_occupied_delta_only(clustered_data,
                                                    small_graph):
    """The fixed-shape delta scan touches every slot, but only OCCUPIED
    slots are distance work: inserting 3 rows into a 256-slot delta adds
    exactly 3 to n_dist (capacity never leaks into the count)."""
    from repro.index import BaseSegment, StreamingEngine
    from repro.index.segment import encode_codes

    x, q, _ = clustered_data
    model = train_pq(jax.random.PRNGKey(0), x, 8, 64, iters=8)
    seg = BaseSegment(graph=small_graph,
                      codes=jnp.asarray(encode_codes(model, x, "u8")),
                      vectors=x, layout="u8")
    eng = StreamingEngine(seg, model, delta_capacity=256)
    before = np.asarray(eng.search(q[:8], k=10, h=32).n_dist)
    eng.insert(np.asarray(x)[:3] + 0.01)
    after = np.asarray(eng.search(q[:8], k=10, h=32).n_dist)
    np.testing.assert_array_equal(after, before + 3)


# =========================================================================
# hybrid IO: the seed probe is ONE batched read
# =========================================================================

def test_hybrid_io_charges_seed_probe_once(setup):
    hyb = HybridEngine(setup["graph"], setup["codes"], setup["lut_fn"],
                       vectors=setup["x"])
    res = hyb.search(setup["q"], k=10, h=32, entries=8)
    rounds = np.asarray(res.rounds, np.float32)
    io_seeded = np.asarray(hyb.io_time(res, entries=8))
    np.testing.assert_allclose(io_seeded,
                               (rounds + 1.0) * hyb.io_latency_s, rtol=1e-6)
    # entries=1: unchanged pre-PR model, no extra read
    r1 = hyb.search(setup["q"], k=10, h=32)
    np.testing.assert_allclose(np.asarray(hyb.io_time(r1)),
                               np.asarray(r1.rounds, np.float32)
                               * hyb.io_latency_s, rtol=1e-6)
