"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(the 512-device override belongs ONLY to launch/dryrun.py)."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def clustered_data():
    """Small clustered anisotropic dataset + queries + exact GT (session-wide)."""
    from repro.graphs import knn_ids

    r = np.random.default_rng(1)
    n, d, nc, nq = 3000, 32, 24, 100
    centers = r.normal(size=(nc, d)).astype(np.float32) * 3
    z = centers[r.integers(0, nc, n)] + r.normal(size=(n, d)).astype(np.float32)
    basis = (np.linalg.qr(r.normal(size=(d, d)))[0]
             @ np.diag(np.linspace(1.5, 0.3, d))).astype(np.float32)
    x = jnp.asarray(z @ basis)
    zq = centers[r.integers(0, nc, nq)] + r.normal(size=(nq, d)).astype(np.float32)
    q = jnp.asarray(zq @ basis)
    gt, _ = knn_ids(x, q, 10)
    return x, q, gt


@pytest.fixture(scope="session")
def small_graph(clustered_data):
    from repro.graphs import build_vamana

    x, _, _ = clustered_data
    return build_vamana(jax.random.PRNGKey(0), x, r=16, l=32, batch=1024)
