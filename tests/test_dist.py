"""Distribution substrate: checkpoint/restore (atomic, elastic), gradient
compression (error feedback), failure injection + supervised restart,
straggler-tolerant top-k merge."""

import os
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.dist import checkpoint as ckpt
from repro.dist import compression as comp
from repro.dist.fault import (FailureInjector, InjectedFailure, partial_merge,
                              supervise)


def test_checkpoint_roundtrip_bitexact(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.asarray([1, 2, 3], jnp.int32)},
            "t": (jnp.ones((2, 2), jnp.bfloat16), jnp.zeros((1,)))}
    ckpt.save(str(tmp_path), 7, params=tree, extra={"note": "hi"})
    out = ckpt.restore(str(tmp_path), like={"params": tree})
    assert out["step"] == 7 and out["extra"]["note"] == "hi"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_rotation_and_latest(tmp_path):
    tree = {"w": jnp.ones((2,))}
    for s in (10, 20, 30, 40):
        ckpt.save(str(tmp_path), s, keep=2, params=tree)
    assert ckpt.all_steps(str(tmp_path)) == [30, 40]
    assert ckpt.latest_step(str(tmp_path)) == 40


def test_checkpoint_atomicity_tmp_never_visible(tmp_path):
    tree = {"w": jnp.ones((4,))}
    ckpt.save(str(tmp_path), 1, params=tree)
    assert not any(d.startswith(".tmp") for d in os.listdir(tmp_path))


def test_elastic_restore_across_device_counts(tmp_path):
    """Save under 1 device, restore under 4 forced host devices (subprocess
    so this process keeps 1 device) — arrays must match bit-exactly."""
    tree = {"w": jnp.arange(64.0).reshape(8, 8), "s": jnp.asarray(3)}
    ckpt.save(str(tmp_path), 5, params=tree)
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding, AxisType
from repro.dist import checkpoint as ckpt
assert len(jax.devices()) == 4
tpl = {{"w": jnp.zeros((8, 8)), "s": jnp.asarray(0)}}
out = ckpt.restore({str(tmp_path)!r}, like={{"params": tpl}})
w = out["params"]["w"]
mesh = jax.make_mesh((4,), ("data",), axis_types=(AxisType.Auto,))
ws = jax.device_put(jnp.asarray(w), NamedSharding(mesh, P("data", None)))
assert ws.sharding.num_devices == 4
np.testing.assert_array_equal(np.asarray(ws), np.arange(64.0).reshape(8, 8))
print("ELASTIC_OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**os.environ, "PYTHONPATH": "src"},
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "ELASTIC_OK" in r.stdout, r.stderr[-2000:]


def test_compression_error_feedback_reduces_bias(rng):
    g = {"w": jnp.asarray(rng.normal(size=(256,)).astype(np.float32) * 1e-3)}
    state = comp.init_state(g)
    # one-shot quantization error vs error-feedback accumulation over steps
    acc_plain = np.zeros(256, np.float32)
    acc_ef = np.zeros(256, np.float32)
    for _ in range(50):
        (q, s), state = comp.compress_tree(g, state)
        acc_ef += np.asarray(comp.dequantize_leaf(q["w"], s["w"]))
        q2, s2, _ = comp.quantize_leaf(g["w"], jnp.zeros(256))
        acc_plain += np.asarray(comp.dequantize_leaf(q2, s2))
    true = np.asarray(g["w"]) * 50
    assert np.abs(acc_ef - true).max() <= np.abs(acc_plain - true).max() + 1e-7
    # with EF, accumulated error stays bounded by one quantization step
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127
    assert np.abs(acc_ef - true).max() < 2 * scale * 50 ** 0.5


def test_compressed_values_close(rng):
    g = {"w": jnp.asarray(rng.normal(size=(64, 8)).astype(np.float32))}
    state = comp.init_state(g)
    (q, s), state = comp.compress_tree(g, state)
    deq = comp.decompress_tree((q, s))
    rel = np.abs(np.asarray(deq["w"]) - np.asarray(g["w"])).max() / \
        np.abs(np.asarray(g["w"])).max()
    assert rel < 1.5 / 127


def test_failure_injection_and_supervise(tmp_path):
    calls = {"n": 0, "restarts": 0}

    def run():
        calls["n"] += 1
        inj = FailureInjector(fail_at_step=3)
        start = 0 if calls["n"] == 1 else 4  # "resume from checkpoint"
        for step in range(start, 8):
            if calls["n"] == 1:
                inj.maybe_fail(step)
        return "done"

    out, restarts = supervise(run, max_restarts=2,
                              on_restart=lambda n, e: calls.__setitem__("restarts", n))
    assert out == "done" and restarts == 1 and calls["n"] == 2


def test_partial_merge_straggler_tolerance(rng):
    ids = [np.asarray([[0, 1, 2]]), np.asarray([[10, 11, 12]]),
           np.asarray([[20, 21, 22]])]
    ds = [np.asarray([[0.1, 0.5, 0.9]]), np.asarray([[0.2, 0.6, 1.0]]),
          np.asarray([[0.0, 0.3, 0.7]])]
    merged = partial_merge(ids, ds, [True, True, True], k=3)
    assert merged.ids[0].tolist() == [20, 0, 10]
    assert not merged.degraded
    # shard 2 (the best) dies: merge still succeeds with survivors
    merged = partial_merge(ids, ds, [True, True, False], k=3)
    assert merged.ids[0].tolist() == [0, 10, 1]
    assert merged.degraded
    # ALL shards dead: sentinel answer, never an exception (DESIGN.md §13)
    merged = partial_merge(ids, ds, [False, False, False], k=3)
    assert merged.degraded
    assert (merged.ids == -1).all() and np.isinf(merged.dists).all()


def test_train_driver_crash_resume_bitexact(tmp_path):
    """Full driver: run 60 steps with a crash at 35 + supervised restart;
    per-step RNG keys are fold_in(step)-derived so the resumed run replays
    the same key sequence; final recall must match the uninterrupted run
    (exact bitwise equality is broken only by the routing-pool refresh
    happening at the resume step — see trainer.fit)."""
    from repro.launch import train as train_mod

    class A:  # argparse stand-in
        dataset = "unit-test"; scale = None; steps = 60; m = 4; k = 16
        batch = 64; routing_queries = 16; refresh_every = 30
        graph_r = 8; graph_l = 16; beam = 16
        checkpoint_every = 10; keep = 5; log_every = 30; seed = 0
        resume = False; fail_at_step = None; max_restarts = 3; quiet = True

    a1 = A(); a1.ckpt_dir = str(tmp_path / "clean")
    clean = train_mod.run(a1)

    a2 = A(); a2.ckpt_dir = str(tmp_path / "crashy"); a2.fail_at_step = 35
    def attempt():
        return train_mod.run(a2)
    crashy, restarts = supervise(attempt, max_restarts=2)
    assert restarts == 1
    # recall equal => identical final model behaviour on identical data
    assert abs(clean["recall"] - crashy["recall"]) < 0.15
