"""Codebook refresh across streaming generations (repro/index/refresh.py +
the refresh arm of consolidate(), DESIGN.md §12).

Pins the full loop: the refreshed snapshot persists its quantizer so
``restore()`` is self-contained (with a regression for pre-refresh
codebook-less snapshots), every surviving row re-encodes against the new
codebooks in both layouts, the PQ-hash seed table rebuilds against them,
post-refresh serving is bit-identical to a from-scratch engine on the new
generation (ids, dists AND n_dist accounting), a crash between retraining
and the atomic snapshot leaves the previous generation restorable with its
OLD codebooks, and — the acceptance bar — refreshed codebooks beat frozen
ones on recall under distribution drift at an equal search budget.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.graphs import build_vamana
from repro.graphs.knn import knn_ids
from repro.index import (BaseSegment, RefreshConfig, StreamingEngine,
                         Tombstones, refresh_quantizer)
from repro.index.segment import encode_codes, load_segment, save_segment
from repro.pq import train_pq, train_pq_fs4
from repro.pq.pack import unpack_codes
from repro.search.metrics import recall_at_k

# sized so a refreshed consolidate stays in test-suite time, not train time
TINY = RefreshConfig(steps=4, kmeans_iters=2, triplet_batch=64,
                     routing_batch=64, routing_pool_queries=16,
                     routing_refresh_every=4, beam_h=8)


@pytest.fixture(scope="module")
def models(clustered_data):
    x, _, _ = clustered_data
    return {"u8": train_pq(jax.random.PRNGKey(3), x, 8, 32, iters=8),
            "fs4": train_pq_fs4(jax.random.PRNGKey(3), x, 8, iters=8)}


def make_engine(clustered_data, small_graph, models, layout="u8"):
    x, _, _ = clustered_data
    model = models[layout]
    seg = BaseSegment(graph=small_graph,
                      codes=jnp.asarray(encode_codes(model, x, layout)),
                      vectors=x, layout=layout)
    return StreamingEngine(seg, model, delta_capacity=512)


def churn(eng, x, *, n_del=300, n_ins=100, seed=11):
    rng = np.random.default_rng(seed)
    rows = np.asarray(x)[rng.integers(0, x.shape[0], n_ins)]
    gids = eng.insert(rows + 0.1 * rng.normal(size=rows.shape
                                              ).astype(np.float32))
    # deletes from the engine's OWN base rows — valid after a compaction
    # shrank the id space below len(x)
    eng.delete(rng.choice(eng.base.n, n_del, replace=False))
    return gids


# ---------------------------------------------------------------------------
# refresh_quantizer unit behavior
# ---------------------------------------------------------------------------

def test_refresh_reduces_distortion_and_keeps_rotation(clustered_data,
                                                       small_graph, models):
    x, _, _ = clustered_data
    model = models["u8"]
    seg = BaseSegment(graph=small_graph,
                      codes=jnp.asarray(encode_codes(model, x, "u8")),
                      vectors=x)
    ts = Tombstones(x.shape[0])
    ts.add(np.arange(0, x.shape[0], 3))     # 1/3 churn
    # Lloyd-only: warm-started k-means is monotone in distortion
    new, rep = refresh_quantizer(
        seg, model, tombstones=ts._words,
        cfg=RefreshConfig(steps=0, kmeans_iters=6))
    assert rep["distortion_after"] <= rep["distortion_before"] + 1e-4
    assert rep["n_live"] == x.shape[0] - ts.count
    np.testing.assert_array_equal(np.asarray(new.r), np.asarray(model.r))
    assert new.codebooks.shape == model.codebooks.shape
    # the full two-stage path also returns finite, same-shape codebooks
    new2, rep2 = refresh_quantizer(seg, model, tombstones=ts._words,
                                   cfg=TINY)
    assert np.isfinite(np.asarray(new2.codebooks)).all()
    assert len(rep2["history"]) > 0


def test_refresh_too_few_live_rows_raises(clustered_data, small_graph,
                                          models):
    x, _, _ = clustered_data
    model = models["u8"]
    seg = BaseSegment(graph=small_graph,
                      codes=jnp.asarray(encode_codes(model, x, "u8")),
                      vectors=x)
    ts = Tombstones(x.shape[0])
    ts.add(np.arange(x.shape[0] - 5))       # 5 live < K=32 codewords
    with pytest.raises(ValueError, match="live rows"):
        refresh_quantizer(seg, model, tombstones=ts._words, cfg=TINY)


# ---------------------------------------------------------------------------
# Snapshot persistence: the quantizer travels with the generation
# ---------------------------------------------------------------------------

def test_snapshot_roundtrips_quantizer(clustered_data, small_graph, models,
                                       tmp_path):
    x, _, _ = clustered_data
    model = models["u8"]
    seg = BaseSegment(graph=small_graph,
                      codes=jnp.asarray(encode_codes(model, x, "u8")),
                      vectors=x)
    save_segment(str(tmp_path), seg, model=model)
    seg2, stored = load_segment(str(tmp_path), with_model=True)
    assert stored is not None
    np.testing.assert_array_equal(np.asarray(stored.r), np.asarray(model.r))
    np.testing.assert_array_equal(np.asarray(stored.codebooks),
                                  np.asarray(model.codebooks))
    assert (stored.m, stored.k) == (model.m, model.k)
    # default load path is unchanged (returns just the segment)
    seg3 = load_segment(str(tmp_path))
    assert seg3.n == seg.n


@pytest.mark.parametrize("layout", ["u8", "fs4"])
def test_restore_self_contained_after_refresh(clustered_data, small_graph,
                                              models, layout, tmp_path):
    """The point of persisting codebooks: after a refreshed consolidation
    NO caller-held model matches the generation on disk — restore() must
    reconstruct the quantizer from the snapshot alone and serve
    identically."""
    x, q, _ = clustered_data
    eng = make_engine(clustered_data, small_graph, models, layout)
    churn(eng, x)
    stats = eng.consolidate(ckpt_dir=str(tmp_path), refresh=TINY)
    assert stats["refreshed"] and "refresh" in stats
    res = eng.search(q, k=10, h=32)
    restored = StreamingEngine.restore(str(tmp_path))       # no model arg
    assert restored.generation == 1
    np.testing.assert_array_equal(
        np.asarray(restored.model.codebooks), np.asarray(eng.model.codebooks))
    res2 = restored.search(q, k=10, h=32)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(res2.ids))


def test_legacy_codebookless_snapshot_regression(clustered_data, small_graph,
                                                 models, tmp_path):
    """Pre-refresh snapshots (no stored quantizer) must still load: with an
    explicit model they serve; without one restore() fails loudly instead
    of guessing; and the mismatch guard still rejects a wrong model."""
    x, q, _ = clustered_data
    model = models["u8"]
    seg = BaseSegment(graph=small_graph,
                      codes=jnp.asarray(encode_codes(model, x, "u8")),
                      vectors=x)
    save_segment(str(tmp_path), seg)        # legacy format: model omitted
    _, stored = load_segment(str(tmp_path), with_model=True)
    assert stored is None
    with pytest.raises(ValueError, match="no stored quantizer"):
        StreamingEngine.restore(str(tmp_path))
    eng = StreamingEngine.restore(str(tmp_path), model)
    assert np.isfinite(np.asarray(eng.search(q, k=5, h=16).dists)[:, 0]).all()
    wrong = train_pq(jax.random.PRNGKey(8), x, 4, 32, iters=2)
    with pytest.raises(ValueError, match="does not match"):
        StreamingEngine.restore(str(tmp_path), wrong)


def test_explicit_model_overrides_stored(clustered_data, small_graph, models,
                                         tmp_path):
    x, _, _ = clustered_data
    model = models["u8"]
    seg = BaseSegment(graph=small_graph,
                      codes=jnp.asarray(encode_codes(model, x, "u8")),
                      vectors=x)
    save_segment(str(tmp_path), seg, model=model)
    override = train_pq(jax.random.PRNGKey(9), x, 8, 32, iters=2)
    eng = StreamingEngine.restore(str(tmp_path), override)
    np.testing.assert_array_equal(np.asarray(eng.model.codebooks),
                                  np.asarray(override.codebooks))


# ---------------------------------------------------------------------------
# Re-encode + rebuilt serving state
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", ["u8", "fs4"])
def test_reencoded_codes_roundtrip(clustered_data, small_graph, models,
                                   layout):
    """Post-refresh resident codes ARE the new model's encoding of the
    surviving vectors — in the segment's own layout (u8 ids, fs4 packed
    nibbles)."""
    x, _, _ = clustered_data
    eng = make_engine(clustered_data, small_graph, models, layout)
    churn(eng, x)
    eng.consolidate(refresh=TINY)
    expect = encode_codes(eng.model, np.asarray(eng.base.vectors), layout)
    np.testing.assert_array_equal(np.asarray(eng.base.codes), expect)
    # and the codes actually changed (the refresh moved the codebooks)
    frozen = make_engine(clustered_data, small_graph, models, layout)
    churn(frozen, x)
    frozen.consolidate()
    assert not np.array_equal(np.asarray(eng.base.codes),
                              np.asarray(frozen.base.codes))


def test_seed_index_rebuilt_against_new_codebooks(clustered_data,
                                                  small_graph, models):
    """The PQ-hash seed table keys fold the resident codes; after a refresh
    it must be rebuilt from the NEW model's codes (a stale table would
    hash queries into the wrong buckets)."""
    from repro.search.seed import build_seed_index

    x, q, _ = clustered_data
    eng = make_engine(clustered_data, small_graph, models, "fs4")
    eng.search(q[:4], k=5, h=16, entries=4)     # build the gen-0 table
    assert eng._seedix is not None
    churn(eng, x)
    eng.consolidate(refresh=TINY)
    assert eng._seedix is None                  # _install reset it
    eng.search(q[:4], k=5, h=16, entries=4)     # lazily rebuilt
    expect = build_seed_index(np.asarray(
        unpack_codes(jnp.asarray(eng.base.codes), eng.model.m)))
    np.testing.assert_array_equal(np.asarray(eng._seedix.table),
                                  np.asarray(expect.table))
    np.testing.assert_array_equal(np.asarray(eng._seedix.codes),
                                  np.asarray(expect.codes))


@pytest.mark.parametrize("entries", [1, 4])
def test_post_refresh_serving_matches_fresh_engine(clustered_data,
                                                   small_graph, models,
                                                   entries):
    """Equivalence oracle for the hot swap: the refreshed engine must serve
    EXACTLY like a from-scratch engine on the new generation — same ids,
    same dists, same n_dist. Any stale cache (dist fns, padded codes, seed
    table, delta device arrays) or accounting drift breaks this."""
    x, q, _ = clustered_data
    eng = make_engine(clustered_data, small_graph, models)
    churn(eng, x)
    eng.consolidate(refresh=TINY)
    fresh = StreamingEngine(eng.base, eng.model, delta_capacity=512)
    a = eng.search(q, k=10, h=32, entries=entries)
    b = fresh.search(q, k=10, h=32, entries=entries)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists))
    np.testing.assert_array_equal(np.asarray(a.n_dist), np.asarray(b.n_dist))


# ---------------------------------------------------------------------------
# Crash safety: interrupt between retraining and the atomic snapshot
# ---------------------------------------------------------------------------

def test_crash_mid_refresh_previous_generation_restores(clustered_data,
                                                        small_graph, models,
                                                        tmp_path,
                                                        monkeypatch):
    """Kill consolidate(refresh=True) AFTER the retrain produced new
    codebooks but BEFORE the snapshot/swap: the engine must keep serving
    the old generation with OLD codebooks, and restore() from disk must
    come back with the OLD codebooks too."""
    import importlib

    # NB: repro.index re-exports the consolidate FUNCTION under the same
    # name, so ``import repro.index.consolidate as C`` binds the function
    C = importlib.import_module("repro.index.consolidate")

    x, q, _ = clustered_data
    eng = make_engine(clustered_data, small_graph, models)
    churn(eng, x, seed=7)
    eng.consolidate(ckpt_dir=str(tmp_path))          # gen-1 snapshot on disk
    old_books = np.asarray(eng.model.codebooks).copy()
    churn(eng, x, n_del=200, n_ins=50, seed=13)
    n_live = eng.n_live
    before = eng.search(q, k=10, h=32)

    seen = {}

    def boom(directory, seg, keep=None, model=None):
        # the refresh DID run: consolidate hands save_segment new codebooks
        seen["retrained"] = (model is not None and not np.array_equal(
            np.asarray(model.codebooks), old_books))
        raise RuntimeError("disk died")

    monkeypatch.setattr(C, "save_segment", boom)
    with pytest.raises(RuntimeError, match="disk died"):
        eng.consolidate(ckpt_dir=str(tmp_path), refresh=TINY)
    assert seen["retrained"]

    # engine untouched: generation, model, churn state, serving
    assert eng.generation == 1 and eng.n_live == n_live
    np.testing.assert_array_equal(np.asarray(eng.model.codebooks), old_books)
    after = eng.search(q, k=10, h=32)
    np.testing.assert_array_equal(np.asarray(before.ids),
                                  np.asarray(after.ids))

    # disk untouched: the gen-1 snapshot restores with OLD codebooks
    restored = StreamingEngine.restore(str(tmp_path))
    assert restored.generation == 1
    np.testing.assert_array_equal(np.asarray(restored.model.codebooks),
                                  old_books)


# ---------------------------------------------------------------------------
# Acceptance: refreshed codebooks beat frozen ones under drift
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_drift_recall_refreshed_beats_frozen():
    """Distribution drift (the live corpus narrows to 6 of 24 clusters,
    ~75% deletes + fresh in-cluster inserts — well past the 30% churn bar):
    at an equal search budget the refreshed generation must beat the frozen
    one on recall@10, and the retrain must cut live distortion hard (the
    frozen model wastes most of its codewords on dead regions)."""
    r = np.random.default_rng(1)
    n, d, nc = 3000, 32, 24
    centers = r.normal(size=(nc, d)).astype(np.float32) * 3
    lab = r.integers(0, nc, n)
    z = centers[lab] + r.normal(size=(n, d)).astype(np.float32)
    basis = (np.linalg.qr(r.normal(size=(d, d)))[0]
             @ np.diag(np.linspace(1.5, 0.3, d))).astype(np.float32)
    x = (z @ basis).astype(np.float32)
    model = train_pq(jax.random.PRNGKey(3), jnp.asarray(x), 8, 16, iters=8)
    g = build_vamana(jax.random.PRNGKey(0), jnp.asarray(x), r=16, l=32,
                     batch=1024)

    keep_c = np.arange(6)
    dead = np.flatnonzero(~np.isin(lab, keep_c))
    zi = centers[r.choice(keep_c, 800)] + r.normal(size=(800, d)
                                                   ).astype(np.float32)
    xnew = (zi @ basis).astype(np.float32)
    assert dead.size + len(xnew) >= 0.3 * n          # ≥30% churn, by a lot

    def churned():
        seg = BaseSegment(graph=g,
                          codes=jnp.asarray(encode_codes(model, x, "u8")),
                          vectors=jnp.asarray(x), layout="u8")
        e = StreamingEngine(seg, model, delta_capacity=1024)
        e.insert(xnew)
        e.delete(dead)
        return e

    # post-churn ground truth; compaction order (base survivors then delta)
    # makes corpus row == new global id, so gt indexes both engines directly
    live_base = np.setdiff1d(np.arange(n), dead)
    corpus = np.concatenate([x[live_base], xnew]).astype(np.float32)
    zq = centers[r.choice(keep_c, 100)] + r.normal(size=(100, d)
                                                   ).astype(np.float32)
    q = jnp.asarray((zq @ basis).astype(np.float32))
    gt, _ = knn_ids(jnp.asarray(corpus), q, 10)

    frozen = churned()
    frozen.consolidate()
    refreshed = churned()
    stats = refreshed.consolidate(
        refresh=RefreshConfig(steps=30, kmeans_iters=10))
    rep = stats["refresh"]
    # locally calibrated: drift halves distortion (42.7 → 21.7); assert a
    # comfortable fraction of that
    assert rep["distortion_after"] < 0.75 * rep["distortion_before"], rep

    r_frozen = recall_at_k(frozen.search(q, k=10, h=32).ids, gt, 10)
    r_refresh = recall_at_k(refreshed.search(q, k=10, h=32).ids, gt, 10)
    # calibrated gap ≈ +0.10 at h=32; require less than half of it
    assert r_refresh >= r_frozen + 0.04, (r_frozen, r_refresh)
