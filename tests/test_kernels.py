"""Per-kernel validation: Pallas (interpret mode) vs the pure-jnp oracles,
swept across shapes and dtypes per the deliverable spec."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels import ref


SHAPES = [
    # (N, M, K, dsub)
    (17, 4, 16, 4),
    (256, 8, 256, 16),
    (1000, 8, 64, 8),
    (2049, 16, 256, 8),
]
CODE_DTYPES = [np.uint8, np.int32]
LUT_DTYPES = [np.float32]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("cdt", CODE_DTYPES)
def test_adc_scan_matches_ref(shape, cdt, rng):
    n, m, k, _ = shape
    if k > np.iinfo(cdt).max + 1:
        pytest.skip("code dtype too narrow")
    codes = rng.integers(0, k, (n, m)).astype(cdt)
    lut = rng.normal(size=(m, k)).astype(np.float32)
    want = ref.adc_scan_ref(codes, lut)
    got = ops.adc_scan(codes, lut, backend="interpret", block_n=256)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("shape", SHAPES[:3])
@pytest.mark.parametrize("q", [1, 3, 8])
def test_adc_scan_batch_matches_ref(shape, q, rng):
    n, m, k, _ = shape
    codes = rng.integers(0, k, (n, m)).astype(np.uint8)
    luts = rng.normal(size=(q, m, k)).astype(np.float32)
    want = ref.adc_scan_batch_ref(codes, luts)
    got = ops.adc_scan_batch(codes, luts, backend="interpret",
                             block_n=128, block_q=4)
    # MXU path casts the LUT to bf16 (DESIGN.md): ~0.5% relative tolerance.
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2 * m)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("xdt", [np.float32])
def test_pq_pairwise_matches_ref(shape, xdt, rng):
    n, m, k, dsub = shape
    x = rng.normal(size=(n, m, dsub)).astype(xdt)
    cb = rng.normal(size=(m, k, dsub)).astype(np.float32)
    want = ref.pq_pairwise_ref(x, cb)
    got = ops.pq_pairwise(x, cb, backend="interpret", block_n=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_batch_consistent_with_single(rng):
    n, m, k = 333, 8, 32
    codes = rng.integers(0, k, (n, m)).astype(np.uint8)
    luts = rng.normal(size=(4, m, k)).astype(np.float32)
    batch = ref.adc_scan_batch_ref(codes, luts)
    for i in range(4):
        single = ref.adc_scan_ref(codes, luts[i])
        np.testing.assert_allclose(np.asarray(batch[i]), np.asarray(single),
                                   rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("q,r,m,k", [(5, 8, 4, 16), (16, 32, 8, 256),
                                     (33, 24, 16, 64)])
def test_hop_gather_matches_ref(q, r, m, k, rng):
    codes = rng.integers(0, k, (q, r, m)).astype(np.uint8)
    luts = rng.normal(size=(q, m, k)).astype(np.float32)
    want = ref.hop_gather_ref(codes, luts)
    got = ops.hop_gather(codes, luts, backend="interpret", block_q=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("n,q,r,m,k", [(100, 5, 8, 4, 16),
                                       (257, 16, 32, 8, 256),
                                       (64, 33, 24, 16, 64)])
def test_hop_adc_matches_ref(n, q, r, m, k, rng):
    """Fused gather+reduce kernel (interpret mode) vs the jnp oracle."""
    codes = rng.integers(0, k, (n, m)).astype(np.uint8)
    ids = rng.integers(0, n, (q, r)).astype(np.int32)
    luts = rng.normal(size=(q, m, k)).astype(np.float32)
    want = ref.hop_adc_ref(codes, ids, luts)
    got = ops.hop_adc(codes, ids, luts, backend="interpret", block_q=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-5)


FS_SHAPES = [
    # (N, M, Q, R)
    (100, 4, 3, 8),
    (257, 16, 5, 32),
    (64, 5, 9, 24),    # odd M: last byte's high nibble is padding
    (33, 1, 2, 6),
]


def _fs_inputs(rng, n, m, q):
    from repro.pq import pack

    codes = rng.integers(0, 16, (n, m)).astype(np.uint8)
    packed = pack.pack_codes(jnp.asarray(codes))
    luts = rng.normal(size=(q, m, 16)).astype(np.float32) ** 2
    ql = pack.quantize_luts(jnp.asarray(luts))
    return codes, packed, ql


@pytest.mark.parametrize("shape", FS_SHAPES)
def test_adc_scan_fs_matches_ref_bitexact(shape, rng):
    """Fast-scan bulk kernel (interpret mode) vs the jnp oracle must be
    BIT-exact: integer accumulation + one shared dequant expression."""
    n, m, q, _ = shape
    _, packed, ql = _fs_inputs(rng, n, m, q)
    want = ref.adc_scan_fs_ref(packed, ql.lut, ql.scale, ql.bias)
    got = ops.adc_scan_fs(packed, ql.lut, ql.scale, ql.bias,
                          backend="interpret", block_n=64, block_q=4)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("shape", FS_SHAPES)
def test_hop_adc_fs_matches_ref_bitexact(shape, rng):
    """Packed fused gather+reduce kernel (interpret mode) vs its oracle."""
    n, m, q, r = shape
    _, packed, ql = _fs_inputs(rng, n, m, q)
    ids = rng.integers(0, n, (q, r)).astype(np.int32)
    want = ref.hop_adc_fs_ref(packed, jnp.asarray(ids), ql.lut, ql.scale,
                              ql.bias)
    got = ops.hop_adc_fs(packed, ids, ql.lut, ql.scale, ql.bias,
                         backend="interpret", block_q=4)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_adc_scan_fs_consistent_with_unpacked_scan(rng):
    """fs4 accumulation == scanning the UNPACKED codes against the uint8
    LUT cast to f32, then the same affine — ties the packed path to the
    classic scan's semantics exactly (all-integer, so equality is exact)."""
    n, m, q = 120, 8, 4
    codes, packed, ql = _fs_inputs(rng, n, m, q)
    fs = np.asarray(ops.adc_scan_fs(packed, ql.lut, ql.scale, ql.bias,
                                    backend="ref"))
    acc = np.asarray(ref.adc_scan_batch_ref(
        jnp.asarray(codes), ql.lut.astype(jnp.float32)))
    want = (np.asarray(ql.scale)[:, None] * acc
            + m * np.asarray(ql.bias)[:, None])
    np.testing.assert_allclose(fs, want, rtol=1e-6, atol=1e-5)


def test_hop_adc_fs_duplicate_and_boundary_ids(rng):
    """Duplicate ids in one hop and rows 0 / N-1 must all resolve."""
    n, m, q = 50, 4, 1
    _, packed, ql = _fs_inputs(rng, n, m, q)
    ids = np.array([[0, 0, n - 1, n - 1, 7, 7, 7, 0]], np.int32)
    got = np.asarray(ops.hop_adc_fs(packed, ids, ql.lut, ql.scale, ql.bias,
                                    backend="interpret"))
    want = np.asarray(ref.hop_adc_fs_ref(packed, jnp.asarray(ids), ql.lut,
                                         ql.scale, ql.bias))
    np.testing.assert_array_equal(got, want)
    assert got[0, 0] == got[0, 1] == got[0, 7]


def test_ops_accept_any_int_dtype(rng):
    """The dispatch boundary canonicalizes code dtypes: uint8 and int32
    callers get identical answers from every op (the one-cast rule)."""
    n, m, k, q, r = 80, 4, 16, 3, 8
    codes = rng.integers(0, k, (n, m))
    lut = rng.normal(size=(m, k)).astype(np.float32)
    luts = rng.normal(size=(q, m, k)).astype(np.float32)
    ids = rng.integers(0, n, (q, r))
    for a, b in [(np.uint8, np.int32), (np.int32, np.uint8)]:
        s1 = ops.adc_scan(codes.astype(a), lut, backend="ref")
        s2 = ops.adc_scan(codes.astype(b), lut, backend="ref")
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
        h1 = ops.hop_adc(codes.astype(a), ids.astype(a), luts, backend="ref")
        h2 = ops.hop_adc(codes.astype(b), ids.astype(b), luts, backend="ref")
        np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))


def test_hop_adc_consistent_with_hop_gather(rng):
    """Fused kernel == pre-gather + hop_gather (the op it replaces)."""
    n, q, r, m, k = 120, 7, 16, 8, 32
    codes = rng.integers(0, k, (n, m)).astype(np.uint8)
    ids = rng.integers(0, n, (q, r)).astype(np.int32)
    luts = rng.normal(size=(q, m, k)).astype(np.float32)
    fused = ops.hop_adc(codes, ids, luts, backend="interpret", block_q=2)
    unfused = ops.hop_gather(codes[ids], luts, backend="ref")
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               rtol=1e-6, atol=1e-5)


def test_hop_adc_duplicate_and_boundary_ids(rng):
    """Duplicate ids in one hop and rows 0 / N-1 must all resolve."""
    n, m, k = 50, 4, 16
    codes = rng.integers(0, k, (n, m)).astype(np.uint8)
    ids = np.array([[0, 0, n - 1, n - 1, 7, 7, 7, 0]], np.int32)
    luts = rng.normal(size=(1, m, k)).astype(np.float32)
    got = np.asarray(ops.hop_adc(codes, ids, luts, backend="interpret"))
    want = np.asarray(ref.hop_adc_ref(codes, ids, luts))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-5)
    assert got[0, 0] == got[0, 1] == got[0, 7]


def test_default_interpret_off_tpu():
    """The ONE autodetect switch: interpreter everywhere except real TPU
    (this container is CPU, so it must say True)."""
    import jax
    assert ops.default_interpret() == (jax.default_backend() != "tpu")
    assert ops.default_interpret() is True  # CPU container


def test_hop_gather_consistent_with_adc_scan(rng):
    """hop_gather on one query's R codes == adc_scan of those codes."""
    r, m, k = 16, 8, 32
    codes = rng.integers(0, k, (r, m)).astype(np.uint8)
    lut = rng.normal(size=(m, k)).astype(np.float32)
    a = ref.adc_scan_ref(codes, lut)
    b = ref.hop_gather_ref(codes[None], lut[None])[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_kmeans_assign_matches_ref(rng):
    x = rng.normal(size=(500, 24)).astype(np.float32)
    c = rng.normal(size=(32, 24)).astype(np.float32)
    ia, da = ops.kmeans_assign(x, c, backend="ref")
    ib, db = ref.kmeans_assign_ref(x, c)
    assert (np.asarray(ia) == np.asarray(ib)).all()
    np.testing.assert_allclose(np.asarray(da), np.asarray(db), rtol=1e-5, atol=1e-4)


def test_adc_equals_decode_distance(rng):
    """ADC(q, codes) == ||q − decode(codes)||² — the LUT identity."""
    from repro.pq import base, train_pq
    import jax

    x = jnp.asarray(rng.normal(size=(800, 32)).astype(np.float32))
    model = train_pq(jax.random.PRNGKey(0), x, 4, 16, iters=5)
    codes = base.encode(model, x)
    q = x[:6]
    adc = base.adc(model, codes, q, backend="ref")
    dec = base.decode(model, codes)
    exact = jnp.sum((q[:, None, :] - dec[None, :, :]) ** 2, -1)
    np.testing.assert_allclose(np.asarray(adc), np.asarray(exact),
                               rtol=1e-3, atol=1e-2)
