"""Graph builders + batched beam search + serving engines."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.graphs import (build_hnsw, build_nsg, build_vamana, degree_stats,
                          descend, knn_ids)
from repro.graphs.adjacency import Graph
from repro.graphs.prune import robust_prune
from repro.pq import base, train_pq
from repro.search import beam_search, beam_search_trace, make_adc_dist_fn, \
    make_exact_dist_fn
from repro.search.engine import HybridEngine, InMemoryEngine
from repro.search.metrics import recall_at_k


def _pad(x):
    return jnp.concatenate([x, jnp.zeros((1, x.shape[1]), x.dtype)])


def test_knn_ids_exact(rng):
    x = jnp.asarray(rng.normal(size=(500, 8)).astype(np.float32))
    q = x[:10]
    ids, dist = knn_ids(x, q, 5)
    # brute-force oracle
    d2 = np.sum((np.asarray(q)[:, None] - np.asarray(x)[None]) ** 2, -1)
    want = np.argsort(d2, axis=1)[:, :5]
    assert (np.asarray(ids) == want).mean() > 0.99  # ties may swap
    assert (np.diff(np.asarray(dist), axis=1) >= -1e-5).all()  # ascending


def test_robust_prune_degree_and_no_dups():
    ids = jnp.asarray([[1, 2, 3, 2, 9, 9]], jnp.int32)   # dup 2, pad 9
    dv = jnp.asarray([[1.0, 2.0, 3.0, 2.0, 0.0, 0.0]])
    pair = jnp.full((1, 6, 6), 10.0)
    out = robust_prune(ids, dv, pair, 1.0, 3, sentinel=9)
    got = np.asarray(out[0])
    valid = got[got != 9]
    assert len(set(valid.tolist())) == len(valid)
    assert set(valid.tolist()) <= {1, 2, 3}


def test_beam_search_exact_on_knn_graph_high_recall(rng):
    # uniform data: a kNN graph is connected there (clustered data would
    # split into per-cluster components — that's WHY Vamana/NSG prune with
    # long-range edges; covered by test_builders_reach_reasonable_recall)
    x = jnp.asarray(rng.normal(size=(2000, 16)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(100, 16)).astype(np.float32))
    gt, _ = knn_ids(x, q, 10)
    ids, _ = knn_ids(x, x, 24, exclude_self=True)
    g = Graph(neighbors=ids, medoid=jnp.asarray(0, jnp.int32))
    res = beam_search(g.neighbors, g.medoid, q, make_exact_dist_fn(_pad(x)),
                      h=64, max_steps=512)
    assert recall_at_k(res.ids, gt, 10) > 0.9


def test_beam_monotone_in_width(clustered_data, small_graph):
    x, q, gt = clustered_data
    f = make_exact_dist_fn(_pad(x))
    r16 = recall_at_k(beam_search(small_graph.neighbors, small_graph.medoid,
                                  q, f, h=16).ids, gt, 10)
    r64 = recall_at_k(beam_search(small_graph.neighbors, small_graph.medoid,
                                  q, f, h=64).ids, gt, 10)
    assert r64 >= r16 - 0.02  # monotone up to tie noise


def test_beam_results_sorted_unique(clustered_data, small_graph):
    x, q, _ = clustered_data
    res = beam_search(small_graph.neighbors, small_graph.medoid, q,
                      make_exact_dist_fn(_pad(x)), h=32)
    ids = np.asarray(res.ids)
    dists = np.asarray(res.dists)
    n = x.shape[0]
    for row_i, row_d in zip(ids, dists):
        valid = row_i[row_i < n]
        assert len(set(valid.tolist())) == len(valid)
        vd = row_d[: len(valid)]
        assert (np.diff(vd) >= -1e-5).all()


def test_trace_records_hops(clustered_data, small_graph):
    x, q, _ = clustered_data
    model_x = _pad(x)
    tr = beam_search_trace(small_graph.neighbors, small_graph.medoid, q[:8],
                           make_exact_dist_fn(model_x), h=8, trace_len=16)
    assert tr.beam_ids.shape == (8, 16, 8)
    hops = np.asarray(tr.result.hops)
    valid = np.asarray(tr.hop_valid).sum(1)
    assert (valid == np.minimum(hops, 16)).all()


def test_trace_hop_valid_semantics(clustered_data, small_graph):
    """hop_valid[q, t] is True IFF hop t actually happened: the flags are a
    prefix (no holes), count exactly min(hops, trace_len), and slots past
    the last valid hop still hold the sentinel-initialized beam."""
    x, q, _ = clustered_data
    tr = beam_search_trace(small_graph.neighbors, small_graph.medoid, q[:8],
                           make_exact_dist_fn(_pad(x)), h=8, trace_len=512)
    hv = np.asarray(tr.hop_valid)
    hops = np.asarray(tr.result.hops)
    n = x.shape[0]
    for qi in range(hv.shape[0]):
        nv = hv[qi].sum()
        assert nv == min(hops[qi], hv.shape[1])
        assert hv[qi, :nv].all() and not hv[qi, nv:].any()  # prefix, no holes
        # never-written slots keep the sentinel beam, written ones are real
        assert (np.asarray(tr.beam_ids)[qi, nv:] == n).all()
        assert (np.asarray(tr.beam_ids)[qi, :nv] < n).any(axis=1).all()


def test_trace_overflow_keeps_last_slot(clustered_data, small_graph):
    """Steps beyond trace_len must NOT clobber slot trace_len-1: the short
    trace's last slot equals the long trace's slot at the same hop index,
    not the beam at the (later) final hop."""
    x, q, _ = clustered_data
    f = make_exact_dist_fn(_pad(x))
    short_len = 4
    args = (small_graph.neighbors, small_graph.medoid, q[:8], f)
    t_short = beam_search_trace(*args, h=8, trace_len=short_len)
    t_long = beam_search_trace(*args, h=8, trace_len=512)
    hops = np.asarray(t_long.result.hops)
    assert (hops > short_len).all(), "fixture too easy to exercise overflow"
    np.testing.assert_array_equal(np.asarray(t_short.beam_ids)[:, -1],
                                  np.asarray(t_long.beam_ids)[:, short_len - 1])
    np.testing.assert_array_equal(np.asarray(t_short.beam_dists)[:, -1],
                                  np.asarray(t_long.beam_dists)[:, short_len - 1])
    assert np.asarray(t_short.hop_valid).all()  # every slot was reached
    # the search result itself is unaffected by the trace buffer size
    np.testing.assert_array_equal(np.asarray(t_short.result.ids),
                                  np.asarray(t_long.result.ids))


def test_trace_matches_untraced_result(clustered_data, small_graph):
    """beam_search_trace's embedded result ≡ plain beam_search."""
    x, q, _ = clustered_data
    f = make_exact_dist_fn(_pad(x))
    plain = beam_search(small_graph.neighbors, small_graph.medoid, q[:8], f,
                        h=16)
    traced = beam_search_trace(small_graph.neighbors, small_graph.medoid,
                               q[:8], f, h=16, trace_len=8)
    np.testing.assert_array_equal(np.asarray(plain.ids),
                                  np.asarray(traced.result.ids))
    np.testing.assert_array_equal(np.asarray(plain.hops),
                                  np.asarray(traced.result.hops))


@pytest.mark.parametrize("builder", ["vamana", "nsg"])
def test_builders_reach_reasonable_recall(clustered_data, builder):
    x, q, gt = clustered_data
    key = jax.random.PRNGKey(0)
    if builder == "vamana":
        g = build_vamana(key, x, r=16, l=32, batch=1024)
    else:
        g = build_nsg(key, x, r=16, k=24, search_l=24, batch=1024)
    st = degree_stats(g)
    assert st["max"] <= 16
    res = beam_search(g.neighbors, g.medoid, q, make_exact_dist_fn(_pad(x)),
                      h=48, max_steps=512)
    assert recall_at_k(res.ids, gt, 10) > 0.55


def test_hnsw_descend_and_search(clustered_data):
    x, q, gt = clustered_data
    h = build_hnsw(jax.random.PRNGKey(0), x, m=8, scale=8)
    entries = descend(h, q, x)
    assert entries.shape == (q.shape[0],)
    res = beam_search(h.base.neighbors, entries, q,
                      make_exact_dist_fn(_pad(x)), h=48, max_steps=512)
    assert recall_at_k(res.ids, gt, 10) > 0.55


def test_engines_end_to_end(clustered_data, small_graph):
    x, q, gt = clustered_data
    model = train_pq(jax.random.PRNGKey(0), x, 8, 64, iters=8)
    codes = base.encode(model, x)
    lut_fn = lambda qq: base.build_lut(model, qq)
    mem = InMemoryEngine(small_graph, codes, lut_fn)
    r1 = mem.search(q, k=10, h=48)
    hyb = HybridEngine(small_graph, codes, lut_fn, vectors=x)
    r2 = hyb.search(q, k=10, h=48)
    rec1 = recall_at_k(r1.ids, gt, 10)
    rec2 = recall_at_k(r2.ids, gt, 10)
    assert rec2 >= rec1  # exact rerank can only help
    assert rec2 > 0.3
    io = np.asarray(hyb.io_time(r2))
    assert (io > 0).all()
    assert mem.memory_bytes() < x.size * 4  # codes much smaller than vectors
