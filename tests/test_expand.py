"""Frontier-batched beam search (DESIGN.md §9).

* ``expand=1`` must be BIT-identical to the pre-PR one-hop-per-step beam —
  the legacy implementation is embedded below verbatim (old ``_scatter_or``
  all-pairs dedup, old over-allocated bitset) and compared field by field,
  trace included.
* ``expand>1`` must hold recall@10 at an equal n_dist budget through every
  engine, report ``rounds ∈ [ceil(hops/E), hops]``, and keep the trace's
  hop_valid prefix semantics (one slot per ROUND).
* visited-bitset boundary ids {0, 31, 32, n−1, n} exercise the word-count
  fix ((n+31)//32 + 1 sentinel-inclusive words).
* ``HybridEngine.io_time`` models per-round batched SSD reads.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.graphs import build_vamana
from repro.graphs.partition import build_partitioned_vamana
from repro.pq import base as pqbase
from repro.pq.pq import train_pq
from repro.search import beam_search, beam_search_trace
from repro.search.beam import (INF, _bit_get, _first_occurrence, _scatter_or,
                               make_adc_dist_fn, make_exact_dist_fn)
from repro.search.engine import (HybridEngine, InMemoryEngine, SearchResult,
                                 ShardedEngine, ShardedGraphEngine)
from repro.search.metrics import recall_at_k


# =========================================================================
# The PRE-PR beam, verbatim (git f4285bc src/repro/search/beam.py) — the
# regression oracle for expand=1 bit-identity.
# =========================================================================

def _legacy_scatter_or(bits, word, mask):
    r = word.shape[0]
    same = (word[:, None] == word[None, :]) & (mask[:, None] == mask[None, :])
    first = ~jnp.any(same & (jnp.arange(r)[:, None] > jnp.arange(r)[None, :]),
                     axis=1)
    contrib = jnp.zeros_like(bits).at[word].add(
        jnp.where(first, mask, jnp.uint32(0)))
    return bits | contrib


def _legacy_single_query(neighbors, entry, qdata, dist_fn, h, max_steps,
                         trace_len=0):
    n = neighbors.shape[0]
    r = neighbors.shape[1]
    nwords = (n + 32) // 32 + 1

    ids0 = jnp.full((h,), n, jnp.int32).at[0].set(entry)
    d_entry = dist_fn(qdata, entry[None])[0]
    dists0 = jnp.full((h,), INF).at[0].set(d_entry)
    exp0 = jnp.ones((h,), bool).at[0].set(False)
    visited0 = _legacy_scatter_or(
        jnp.zeros((nwords,), jnp.uint32), (entry >> 5)[None],
        (jnp.uint32(1) << (entry & 31).astype(jnp.uint32))[None])

    do_trace = trace_len > 0
    tb_ids0 = jnp.full((max(trace_len, 1), h), n, jnp.int32)
    tb_d0 = jnp.full((max(trace_len, 1), h), INF)
    tb_v0 = jnp.zeros((max(trace_len, 1),), bool)

    def cond(state):
        step, ids, dists, exp, visited, hops, ndist, tbi, tbd, tbv = state
        return jnp.logical_and(step < max_steps, jnp.any(~exp & (dists < INF)))

    def body(state):
        step, ids, dists, exp, visited, hops, ndist, tbi, tbd, tbv = state
        cand = jnp.where(~exp & (dists < INF), dists, INF)
        sel = jnp.argmin(cand)
        exp = exp.at[sel].set(True)
        hops = hops + 1
        nbr = neighbors[ids[sel]]
        valid = nbr < n
        seen = _bit_get(visited, jnp.where(valid, nbr, 0)).astype(bool)
        fresh = valid & ~seen
        visited = _legacy_scatter_or(
            visited, jnp.where(fresh, nbr, n) >> 5,
            jnp.where(fresh, jnp.uint32(1) << (nbr & 31).astype(jnp.uint32),
                      jnp.uint32(0)))
        nd = dist_fn(qdata, jnp.where(fresh, nbr, 0))
        nd = jnp.where(fresh, nd, INF)
        ndist = ndist + jnp.sum(fresh.astype(jnp.int32))
        all_ids = jnp.concatenate([ids, jnp.where(fresh, nbr, n)])
        all_d = jnp.concatenate([dists, nd])
        all_e = jnp.concatenate([exp, jnp.zeros((r,), bool)])
        neg, order = jax.lax.top_k(-all_d, h)
        ids = all_ids[order]
        dists = -neg
        exp = all_e[order] | (dists == INF)
        if do_trace:
            ti = jnp.minimum(step, trace_len - 1)
            in_range = step < trace_len
            tbi = tbi.at[ti].set(jnp.where(in_range, ids, tbi[ti]))
            tbd = tbd.at[ti].set(jnp.where(in_range, dists, tbd[ti]))
            tbv = tbv.at[ti].set(tbv[ti] | in_range)
        return (step + 1, ids, dists, exp, visited, hops, ndist, tbi, tbd, tbv)

    state = (jnp.int32(0), ids0, dists0, exp0, visited0,
             jnp.int32(0), jnp.int32(1), tb_ids0, tb_d0, tb_v0)
    step, ids, dists, exp, visited, hops, ndist, tbi, tbd, tbv = \
        jax.lax.while_loop(cond, body, state)
    res = (ids, dists, hops, ndist)
    return res + ((tbi, tbd, tbv) if do_trace else ())


def _legacy_beam_search(neighbors, entry, qdatas, dist_fn, *, h, max_steps,
                        trace_len=0):
    entry = jnp.asarray(entry, jnp.int32)
    nq = jax.tree.leaves(qdatas)[0].shape[0]
    entries = jnp.broadcast_to(entry, (nq,)) if entry.ndim == 0 else entry
    fn = jax.jit(jax.vmap(
        lambda e, qd: _legacy_single_query(neighbors, e, qd, dist_fn, h,
                                           max_steps, trace_len=trace_len)))
    return fn(entries, qdatas)


# =========================================================================
# fixtures
# =========================================================================

@pytest.fixture(scope="module")
def pq_setup(clustered_data, small_graph):
    x, q, gt = clustered_data
    model = train_pq(jax.random.PRNGKey(0), x, 8, 64, iters=8)
    codes = pqbase.encode(model, x)
    lut_fn = lambda qq: pqbase.build_lut(model, qq)
    return dict(x=x, q=q, gt=np.asarray(gt), model=model, codes=codes,
                lut_fn=lut_fn, graph=small_graph)


def _pad(x):
    return jnp.concatenate([x, jnp.zeros((1, x.shape[1]), x.dtype)])


# =========================================================================
# expand=1 bit-identity vs the pre-PR beam
# =========================================================================

def test_expand1_bit_identical_to_legacy_adc(pq_setup):
    """ids, dists, hops, n_dist all bitwise-equal on the ADC routing path
    (and rounds == hops at expand=1)."""
    g, q = pq_setup["graph"], pq_setup["q"]
    luts = pq_setup["lut_fn"](q)
    dist_fn = make_adc_dist_fn(_pad(pq_setup["codes"]))
    new = beam_search(g.neighbors, g.medoid, luts, dist_fn, h=32,
                      max_steps=512, expand=1)
    ids, dists, hops, ndist = _legacy_beam_search(
        g.neighbors, g.medoid, luts, dist_fn, h=32, max_steps=512)
    np.testing.assert_array_equal(np.asarray(new.ids), np.asarray(ids))
    np.testing.assert_array_equal(np.asarray(new.dists), np.asarray(dists))
    np.testing.assert_array_equal(np.asarray(new.hops), np.asarray(hops))
    np.testing.assert_array_equal(np.asarray(new.n_dist), np.asarray(ndist))
    np.testing.assert_array_equal(np.asarray(new.rounds), np.asarray(hops))


def test_expand1_bit_identical_to_legacy_exact(clustered_data, small_graph):
    """Same bit-identity on the exact-distance routing path."""
    x, q, _ = clustered_data
    g = small_graph
    dist_fn = make_exact_dist_fn(_pad(x))
    new = beam_search(g.neighbors, g.medoid, q, dist_fn, h=16, max_steps=512)
    ids, dists, hops, ndist = _legacy_beam_search(
        g.neighbors, g.medoid, q, dist_fn, h=16, max_steps=512)
    np.testing.assert_array_equal(np.asarray(new.ids), np.asarray(ids))
    np.testing.assert_array_equal(np.asarray(new.dists), np.asarray(dists))
    np.testing.assert_array_equal(np.asarray(new.hops), np.asarray(hops))
    np.testing.assert_array_equal(np.asarray(new.n_dist), np.asarray(ndist))


def test_expand1_trace_bit_identical_to_legacy(clustered_data, small_graph):
    """The recorded trace (beam_ids/beam_dists/hop_valid) is unchanged."""
    x, q, _ = clustered_data
    g = small_graph
    dist_fn = make_exact_dist_fn(_pad(x))
    tr = beam_search_trace(g.neighbors, g.medoid, q[:16], dist_fn, h=8,
                           trace_len=16, max_steps=512, expand=1)
    ids, dists, hops, ndist, tbi, tbd, tbv = _legacy_beam_search(
        g.neighbors, g.medoid, q[:16], dist_fn, h=8, max_steps=512,
        trace_len=16)
    np.testing.assert_array_equal(np.asarray(tr.beam_ids), np.asarray(tbi))
    np.testing.assert_array_equal(np.asarray(tr.beam_dists), np.asarray(tbd))
    np.testing.assert_array_equal(np.asarray(tr.hop_valid), np.asarray(tbv))
    np.testing.assert_array_equal(np.asarray(tr.result.ids), np.asarray(ids))


# =========================================================================
# expand>1 semantics
# =========================================================================

@pytest.mark.parametrize("e", [2, 4])
def test_expand_rounds_bounds_and_recall(pq_setup, e):
    """rounds ∈ [ceil(hops/E), hops], and recall@10 within 2 points of the
    classic beam at an EQUAL n_dist budget (the E>1 run's round cap is set
    so its expansion budget matches the E=1 run's measured hops)."""
    g, q, gt = pq_setup["graph"], pq_setup["q"], pq_setup["gt"]
    luts = pq_setup["lut_fn"](q)
    dist_fn = make_adc_dist_fn(_pad(pq_setup["codes"]))
    r1 = beam_search(g.neighbors, g.medoid, luts, dist_fn, h=32,
                     max_steps=512, expand=1)
    budget = int(np.ceil(float(np.asarray(r1.hops).max()) / e))
    re = beam_search(g.neighbors, g.medoid, luts, dist_fn, h=32,
                     max_steps=budget, expand=e)
    hops = np.asarray(re.hops)
    rounds = np.asarray(re.rounds)
    assert (rounds <= hops).all()
    assert (rounds >= np.ceil(hops / e) - 1e-9).all()
    rec1 = recall_at_k(r1.ids, gt, 10)
    rece = recall_at_k(re.ids, gt, 10)
    assert rece >= rec1 - 0.02, (rece, rec1)


def test_expand_trace_hop_valid_counts_rounds(pq_setup):
    """Under multi-expansion hop_valid flags ROUNDS: a prefix with no
    holes, exactly min(rounds, trace_len) slots, result unchanged vs the
    untraced search."""
    g, q = pq_setup["graph"], pq_setup["q"]
    luts = jax.tree.map(lambda a: a[:16], pq_setup["lut_fn"](q))
    dist_fn = make_adc_dist_fn(_pad(pq_setup["codes"]))
    kw = dict(h=16, max_steps=512, expand=4)
    tr = beam_search_trace(g.neighbors, g.medoid, luts, dist_fn,
                           trace_len=8, **kw)
    plain = beam_search(g.neighbors, g.medoid, luts, dist_fn, **kw)
    hv = np.asarray(tr.hop_valid)
    rounds = np.asarray(tr.result.rounds)
    hops = np.asarray(tr.result.hops)
    for qi in range(hv.shape[0]):
        nv = hv[qi].sum()
        assert nv == min(rounds[qi], hv.shape[1])
        assert hv[qi, :nv].all() and not hv[qi, nv:].any()
        assert rounds[qi] < hops[qi]  # E=4 really batched some rounds
    np.testing.assert_array_equal(np.asarray(tr.result.ids),
                                  np.asarray(plain.ids))
    np.testing.assert_array_equal(np.asarray(tr.result.rounds),
                                  np.asarray(plain.rounds))


def test_expand_caps_at_beam_width(pq_setup):
    """expand > h must clamp (can never select more than h entries)."""
    g, q = pq_setup["graph"], pq_setup["q"]
    luts = jax.tree.map(lambda a: a[:8], pq_setup["lut_fn"](q))
    dist_fn = make_adc_dist_fn(_pad(pq_setup["codes"]))
    big = beam_search(g.neighbors, g.medoid, luts, dist_fn, h=8,
                      max_steps=256, expand=64)
    capped = beam_search(g.neighbors, g.medoid, luts, dist_fn, h=8,
                         max_steps=256, expand=8)
    np.testing.assert_array_equal(np.asarray(big.ids), np.asarray(capped.ids))


# =========================================================================
# engines: expand threads end to end
# =========================================================================

@pytest.mark.parametrize("e", [2, 4])
def test_inmemory_and_hybrid_recall_no_worse(pq_setup, e):
    x, q, gt = pq_setup["x"], pq_setup["q"], pq_setup["gt"]
    mem = InMemoryEngine(pq_setup["graph"], pq_setup["codes"],
                         pq_setup["lut_fn"])
    r1 = mem.search(q, k=10, h=32, expand=1)
    re = mem.search(q, k=10, h=32, expand=e)
    assert recall_at_k(re.ids, gt, 10) >= recall_at_k(r1.ids, gt, 10) - 0.02
    assert float(np.asarray(re.rounds).mean()) < \
        float(np.asarray(r1.rounds).mean())
    hyb = HybridEngine(pq_setup["graph"], pq_setup["codes"],
                       pq_setup["lut_fn"], vectors=x)
    h1 = hyb.search(q, k=10, h=32, expand=1)
    he = hyb.search(q, k=10, h=32, expand=e)
    assert recall_at_k(he.ids, gt, 10) >= recall_at_k(h1.ids, gt, 10) - 0.02


def test_sharded_graph_engine_expand(pq_setup):
    """Single-shard ShardedGraphEngine threads expand through shard_map and
    reports summed hops / max rounds."""
    x, q, gt = pq_setup["x"], pq_setup["q"], pq_setup["gt"]
    pg = build_partitioned_vamana(jax.random.PRNGKey(1), x, 1, r=16, l=32)
    eng = ShardedGraphEngine(pg, pq_setup["codes"], pq_setup["lut_fn"])
    r1 = eng.search(q, k=10, h=32, expand=1)
    r4 = eng.search(q, k=10, h=32, expand=4)
    assert recall_at_k(r4.ids, gt, 10) >= recall_at_k(r1.ids, gt, 10) - 0.02
    assert (np.asarray(r4.rounds) <= np.asarray(r4.hops)).all()
    assert (np.asarray(r4.rounds) >=
            np.ceil(np.asarray(r4.hops) / 4) - 1e-9).all()
    np.testing.assert_array_equal(np.asarray(r1.rounds),
                                  np.asarray(r1.hops))


def test_sharded_scan_engine_ignores_expand(pq_setup):
    """ShardedEngine has no beam: expand is accepted and a no-op."""
    q = pq_setup["q"]
    eng = ShardedEngine(pq_setup["codes"], pq_setup["lut_fn"])
    a = eng.search(q, k=10)
    b = eng.search(q, k=10, expand=4)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    assert (np.asarray(b.rounds) == 0).all()


# =========================================================================
# HybridEngine.io_time: per-round batched SSD reads
# =========================================================================

def test_hybrid_io_time_rounds_model(pq_setup):
    x, q = pq_setup["x"], pq_setup["q"]
    hyb = HybridEngine(pq_setup["graph"], pq_setup["codes"],
                       pq_setup["lut_fn"], vectors=x)
    res = hyb.search(q, k=10, h=32, expand=4)
    hops = np.asarray(res.hops, np.float32)
    rounds = np.asarray(res.rounds, np.float32)
    io = np.asarray(hyb.io_time(res))
    # measured rounds drive the model: E concurrent reads per round
    np.testing.assert_allclose(io, rounds * hyb.io_latency_s, rtol=1e-6)
    assert (io <= hops * hyb.io_latency_s + 1e-12).all()
    assert (rounds >= np.ceil(hops / 4) - 1e-9).all()
    # both counters reported so QPS projections stay honest
    assert res.hops.shape == res.rounds.shape
    # a result without a round count falls back to the ceil(hops/E) model
    bare = SearchResult(res.ids, res.dists, res.hops, res.n_dist)
    io_bare = np.asarray(hyb.io_time(bare, expand=4))
    np.testing.assert_allclose(io_bare,
                               np.ceil(hops / 4) * hyb.io_latency_s,
                               rtol=1e-6)
    # expand=1: one read per expansion, the pre-PR model
    r1 = hyb.search(q, k=10, h=32, expand=1)
    np.testing.assert_allclose(np.asarray(hyb.io_time(r1)),
                               np.asarray(r1.hops) * hyb.io_latency_s,
                               rtol=1e-6)


# =========================================================================
# visited bitset: word count + boundary ids
# =========================================================================

@pytest.mark.parametrize("n", [31, 32, 33, 64, 95, 100])
def test_scatter_or_boundary_ids(n):
    """ids {0, 31, 32, n−1, n} must all land in allocated words — including
    the sentinel n, the id the (n+31)//32 + 1 sizing must still cover."""
    nwords = (n + 31) // 32 + 1
    cases = sorted({0, min(31, n), min(32, n), n - 1, n})
    idx = jnp.asarray(cases, jnp.int32)
    bits = _scatter_or(jnp.zeros((nwords,), jnp.uint32), idx,
                       jnp.ones((len(cases),), bool))
    got = np.asarray(_bit_get(bits, idx))
    assert (got == 1).all()
    # exactly those bits set — nothing carried into a neighbor bit/word
    popcount = np.unpackbits(np.asarray(bits).view(np.uint8)).sum()
    assert popcount == len(cases)


def test_scatter_or_duplicates_sort_dedup():
    """Duplicate ids in one call must OR, not carry into neighbor bits —
    the sort-based first-occurrence dedup replacing the O(W²) compare."""
    n = 100
    nwords = (n + 31) // 32 + 1
    idx = jnp.asarray([5, 5, 5, 37, 37, 5, 99, 0, 0, 99], jnp.int32)
    on = jnp.ones((10,), bool)
    bits = np.asarray(_scatter_or(jnp.zeros((nwords,), jnp.uint32), idx, on))
    want = np.zeros((nwords,), np.uint32)
    for i in {5, 37, 99, 0}:
        want[i // 32] |= np.uint32(1) << (i % 32)
    np.testing.assert_array_equal(bits, want)
    # masked lanes contribute nothing
    bits2 = np.asarray(_scatter_or(jnp.zeros((nwords,), jnp.uint32), idx,
                                   jnp.zeros((10,), bool)))
    assert (bits2 == 0).all()


def test_first_occurrence_matches_numpy():
    rng = np.random.default_rng(0)
    for w in (1, 7, 64, 256):
        idx = rng.integers(0, 40, (w,)).astype(np.int32)
        on = rng.random(w) < 0.7
        got = np.asarray(_first_occurrence(jnp.asarray(idx),
                                           jnp.asarray(on)))
        seen = set()
        want = np.zeros((w,), bool)
        for i in range(w):
            if on[i] and idx[i] not in seen:
                want[i] = True
                seen.add(idx[i])
        np.testing.assert_array_equal(got, want)


def test_beam_on_word_boundary_corpus():
    """A corpus whose size straddles a 32-bit word boundary routes
    correctly (the old sizing masked off-by-one errors with slack)."""
    rng = np.random.default_rng(5)
    for n in (32, 33, 64):
        x = jnp.asarray(rng.normal(size=(n, 8)).astype(np.float32))
        g = build_vamana(jax.random.PRNGKey(0), x, r=8, l=16)
        res = beam_search(g.neighbors, g.medoid, x[:4],
                          make_exact_dist_fn(_pad(x)), h=n, max_steps=4 * n)
        ids = np.asarray(res.ids)
        # every query must find itself at distance 0
        assert (ids[:, 0] == np.arange(4)).all()
        for e in (2, 4):
            re = beam_search(g.neighbors, g.medoid, x[:4],
                             make_exact_dist_fn(_pad(x)), h=n,
                             max_steps=4 * n, expand=e)
            assert (np.asarray(re.ids)[:, 0] == np.arange(4)).all()
