"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + finiteness. The FULL configs are exercised only by the
dry-run (ShapeDtypeStruct, no allocation)."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_arch, list_archs

LM_ARCHS = ["granite-3-8b", "llama3-405b", "starcoder2-3b",
            "granite-moe-1b-a400m", "olmoe-1b-7b"]
RECSYS_ARCHS = ["bert4rec", "deepfm", "din", "dlrm-mlperf"]


def test_registry_has_all_assigned():
    have = set(list_archs())
    want = set(LM_ARCHS + RECSYS_ARCHS + ["gat-cora", "rpq"])
    assert want <= have


def _finite(x):
    return bool(jnp.isfinite(x).all())


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_reduced_train_and_decode(arch):
    from repro.models import transformer as tf

    cfg = get_arch(arch).make_reduced()
    key = jax.random.PRNGKey(0)
    init, train_step, opt_init = tf.make_train_step(cfg, lr=1e-3)
    params = init(key)
    opt_state = opt_init(params)
    b, s = 4, 16
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    labels = jax.random.randint(key, (b, s), 0, cfg.vocab)
    params2, opt_state, loss = jax.jit(train_step)(params, opt_state, toks, labels)
    assert _finite(loss) and float(loss) > 0
    # a step must actually move the params
    moved = jax.tree.map(lambda a, b_: float(jnp.abs(a.astype(jnp.float32)
                                                     - b_.astype(jnp.float32)).max()),
                         params, params2)
    assert max(jax.tree.leaves(moved)) > 0
    # prefill + decode path
    logits, cache = tf.prefill(cfg, params2, toks, max_len=s + 8)
    assert logits.shape == (b, cfg.vocab) and _finite(logits)
    nxt = jnp.argmax(logits, -1)
    logits2, cache = tf.decode_step(cfg, params2, cache, nxt)
    assert logits2.shape == (b, cfg.vocab) and _finite(logits2)
    assert int(cache.length) == s + 1


def test_lm_decode_matches_forward():
    """Greedy decode logits == teacher-forced forward logits (same tokens)."""
    from repro.models import transformer as tf

    cfg = get_arch("granite-3-8b").make_reduced()
    key = jax.random.PRNGKey(1)
    params = tf.init_lm(key, cfg)
    b, s = 2, 8
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    full_logits, _ = tf.forward(cfg, params, toks)
    _, cache = tf.prefill(cfg, params, toks[:, :s - 1], max_len=s + 1)
    dec_logits, _ = tf.decode_step(cfg, params, cache, toks[:, s - 1])
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits[:, -1]),
                               rtol=2e-2, atol=2e-2)


def test_gat_full_graph_train():
    from repro.models import gnn

    cfg = get_arch("gat-cora").make_reduced()
    key = jax.random.PRNGKey(0)
    n, e = 64, 256
    x = jax.random.normal(key, (n, cfg.d_in))
    src = jax.random.randint(key, (e,), 0, n)
    dst = jax.random.randint(jax.random.PRNGKey(1), (e,), 0, n)
    labels = jax.random.randint(key, (n,), 0, cfg.n_classes)
    mask = jnp.ones((n,), bool)
    init, train_step, opt_init = gnn.make_train_step(cfg)
    params = init(key)
    opt_state = opt_init(params)
    losses = []
    step = jax.jit(train_step)
    for _ in range(5):
        params, opt_state, loss = step(params, opt_state, x, src, dst,
                                       labels, mask)
        losses.append(float(loss))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]  # overfits a tiny random graph


def test_gat_molecule_batched_pooling():
    from repro.models import gnn

    cfg = get_arch("gat-cora").make_reduced()
    key = jax.random.PRNGKey(0)
    b, n_per, e_per = 8, 10, 20
    n = b * n_per
    x = jax.random.normal(key, (n, cfg.d_in))
    graph_id = jnp.repeat(jnp.arange(b), n_per)
    src = jax.random.randint(key, (b * e_per,), 0, n_per) \
        + jnp.repeat(jnp.arange(b) * n_per, e_per)
    dst = jax.random.randint(jax.random.PRNGKey(1), (b * e_per,), 0, n_per) \
        + jnp.repeat(jnp.arange(b) * n_per, e_per)
    y = jax.random.randint(key, (b,), 0, cfg.n_classes)
    params = gnn.init_gat(key, cfg)
    loss = gnn.graph_pool_loss(cfg, params, x, src, dst, graph_id, b, y)
    assert np.isfinite(float(loss))


def test_gnn_neighbor_sampler_block():
    from repro.models import gnn

    rng = np.random.default_rng(0)
    n = 200
    # random CSR graph
    deg = rng.integers(1, 8, n)
    indptr = np.concatenate([[0], np.cumsum(deg)]).astype(np.int64)
    indices = rng.integers(0, n, indptr[-1]).astype(np.int64)
    feats = rng.normal(size=(n, 16)).astype(np.float32)
    labels = rng.integers(0, 4, n)
    seeds = rng.choice(n, 8, replace=False)
    blk = gnn.sample_block(rng, indptr, indices, feats, labels, seeds, (3, 2))
    assert blk.src.shape == blk.dst.shape == blk.edge_mask.shape
    assert blk.src.shape[0] == 8 * 3 + 8 * 3 * 2
    assert blk.feats.shape[1] == 16
    # run a GAT layer over the block
    cfg = get_arch("gat-cora").make_reduced()
    cfg2 = gnn.GATConfig(name="t", d_in=16, d_hidden=4, n_heads=2, n_layers=2,
                         n_classes=4)
    params = gnn.init_gat(jax.random.PRNGKey(0), cfg2)
    out = gnn.forward(cfg2, params, blk.feats, blk.src, blk.dst,
                      edge_mask=blk.edge_mask)
    assert out.shape == (blk.feats.shape[0], 4)
    assert bool(jnp.isfinite(out).all())


def test_dlrm_reduced_train():
    from repro.models import recsys as rs

    cfg = get_arch("dlrm-mlperf").make_reduced()
    key = jax.random.PRNGKey(0)
    params = rs.init_dlrm(key, cfg)
    b = 32
    batch = {
        "dense": jax.random.normal(key, (b, cfg.n_dense)),
        "sparse": jax.random.randint(key, (b, cfg.n_sparse), 0, 100),
        "label": jax.random.bernoulli(key, 0.3, (b,)).astype(jnp.float32),
    }
    fwd = lambda p, bt: rs.dlrm_forward(cfg, p, bt["dense"], bt["sparse"])
    init, step, opt_init = rs.make_bce_train_step(fwd, lambda k: params)
    opt_state = opt_init(params)
    step = jax.jit(step)
    l0 = None
    for _ in range(5):
        params, opt_state, loss = step(params, opt_state, batch)
        l0 = l0 or float(loss)
    assert np.isfinite(float(loss)) and float(loss) < l0 + 0.1


def test_deepfm_reduced_forward_backward():
    from repro.models import recsys as rs

    cfg = get_arch("deepfm").make_reduced()
    key = jax.random.PRNGKey(0)
    params = rs.init_deepfm(key, cfg)
    b = 16
    sparse = jax.random.randint(key, (b, cfg.n_fields), 0, 50)
    label = jax.random.bernoulli(key, 0.5, (b,)).astype(jnp.float32)
    loss, g = jax.value_and_grad(
        lambda p: rs.bce_loss(rs.deepfm_forward(cfg, p, sparse), label))(params)
    assert np.isfinite(float(loss))
    assert float(jnp.abs(g["table"]).max()) > 0


def test_din_reduced_forward():
    from repro.models import recsys as rs

    cfg = get_arch("din").make_reduced()
    key = jax.random.PRNGKey(0)
    params = rs.init_din(key, cfg)
    b = 16
    hist = jax.random.randint(key, (b, cfg.seq_len), 0, cfg.n_items)
    mask = jnp.arange(cfg.seq_len)[None, :] < 8
    target = jax.random.randint(key, (b,), 0, cfg.n_items)
    out = rs.din_forward(cfg, params, hist, jnp.broadcast_to(mask, hist.shape),
                         target)
    assert out.shape == (b,) and bool(jnp.isfinite(out).all())


def test_bert4rec_reduced_mlm():
    from repro.models import recsys as rs

    cfg = get_arch("bert4rec").make_reduced()
    key = jax.random.PRNGKey(0)
    params = rs.init_bert4rec(key, cfg)
    b, s, p = 8, cfg.seq_len, 4
    items = jax.random.randint(key, (b, s), 0, cfg.n_items)
    pad = jnp.ones((b, s), bool)
    pos = jax.random.randint(key, (b, p), 0, s)
    labels = jax.random.randint(key, (b, p), 0, cfg.n_items)
    items = items.at[jnp.arange(b)[:, None], pos].set(cfg.mask_token)
    loss = rs.bert4rec_mlm_loss(cfg, params, items, pad, pos, labels)
    assert np.isfinite(float(loss)) and float(loss) > 0


def test_embedding_bag_matches_loop_oracle(rng):
    from repro.models.recsys import embedding_bag

    table = jnp.asarray(rng.normal(size=(50, 8)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 50, 40), jnp.int32)
    bags = jnp.asarray(np.sort(rng.integers(0, 10, 40)), jnp.int32)
    got = embedding_bag(table, ids, bags, 10, mode="sum")
    want = np.zeros((10, 8), np.float32)
    for i, b in zip(np.asarray(ids), np.asarray(bags)):
        want[b] += np.asarray(table)[i]
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


def test_retrieval_scoring_exact_vs_adc(rng):
    """ADC top-k should strongly overlap the exact dot top-k (paper §5 use)."""
    import jax
    from repro.models import recsys as rs
    from repro.pq import base, train_pq

    n, d = 4000, 32
    emb = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    qv = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    sv, si = rs.score_candidates_exact(qv, emb, k=50)
    model = train_pq(jax.random.PRNGKey(0), emb, 8, 64, iters=10)
    codes = base.encode(model, emb)
    # score by distance to the query point: top-k closest ≅ top dot for
    # normalized queries; use the distance formulation directly
    lut = base.build_lut(model, qv[None])[0]
    dv, di = rs.score_candidates_adc(lut, codes, k=50, backend="ref")
    exact_d = jnp.sum((emb - qv[None]) ** 2, -1)
    _, gt = jax.lax.top_k(-exact_d, 50)
    overlap = len(set(np.asarray(di).tolist()) & set(np.asarray(gt).tolist()))
    assert overlap >= 18  # ≥36% recall at 48-bit codes on iid gaussian
    # and far above chance (50/4000 → expected overlap < 1)


def test_moe_dispatch_matches_dense_oracle(rng):
    """Capacity-unconstrained MoE == per-token dense expert mixing."""
    from repro.models.moe import MoEConfig, moe_ffn

    t, d, e, k, f = 32, 8, 4, 2, 16
    cfg = MoEConfig(n_experts=e, top_k=k, d_ff_expert=f,
                    capacity_factor=8.0, group_size=32)  # no drops
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    w = {
        "router": jax.random.normal(ks[0], (d, e)),
        "w1": jax.random.normal(ks[1], (e, d, f)) * 0.1,
        "w3": jax.random.normal(ks[2], (e, d, f)) * 0.1,
        "w2": jax.random.normal(ks[3], (f, d))[None].repeat(e, 0) * 0.1,
    }
    x = jax.random.normal(jax.random.PRNGKey(5), (t, d))
    out = moe_ffn(x, w, cfg)
    # oracle: per-token loop
    probs = jax.nn.softmax(x @ w["router"], -1)
    gv, gi = jax.lax.top_k(probs, k)
    gv = gv / gv.sum(-1, keepdims=True)
    want = np.zeros((t, d), np.float32)
    for ti in range(t):
        for kk in range(k):
            ei = int(gi[ti, kk])
            h = jax.nn.silu(x[ti] @ w["w1"][ei]) * (x[ti] @ w["w3"][ei])
            want[ti] += float(gv[ti, kk]) * np.asarray(h @ w["w2"][ei])
    np.testing.assert_allclose(np.asarray(out.y), want, rtol=2e-2, atol=2e-2)
