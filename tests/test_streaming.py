"""Streaming mutable index (repro/index/, DESIGN.md §10).

Covers the tombstone semantics the subsystem promises — a deleted id is
NEVER returned, at any beam width, in either code layout; deleting the
medoid keeps routing alive; word-boundary ids behave ((n+31)//32 + 1 bitset
sizing); delete-then-reinsert resolves to the new row — plus the delta
capacity bound, consolidation invariants (compaction, generation bump,
atomic restore), and the recall-under-churn acceptance bar against a
from-scratch rebuild.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.graphs.knn import knn_ids
from repro.index import (BaseSegment, DeltaFullError, StreamingEngine,
                         Tombstones)
from repro.index.segment import bitset_words, encode_codes
from repro.pq import train_pq, train_pq_fs4
from repro.search.metrics import recall_at_k


@pytest.fixture(scope="module")
def models(clustered_data):
    x, _, _ = clustered_data
    u8 = train_pq(jax.random.PRNGKey(3), x, 8, 32, iters=8)
    fs4 = train_pq_fs4(jax.random.PRNGKey(3), x, 8, iters=8)
    return {"u8": u8, "fs4": fs4}


def make_engine(clustered_data, small_graph, models, layout="u8", *,
                capacity=512, **kw):
    x, _, _ = clustered_data
    model = models[layout]
    seg = BaseSegment(graph=small_graph,
                      codes=jnp.asarray(encode_codes(model, x, layout)),
                      vectors=x, layout=layout)
    return StreamingEngine(seg, model, delta_capacity=capacity, **kw)


def new_rows(x, count, seed=9):
    """Fresh vectors from the fixture's distribution: jittered samples."""
    r = np.random.default_rng(seed)
    rows = np.asarray(x)[r.integers(0, x.shape[0], count)]
    return rows + 0.1 * r.normal(size=rows.shape).astype(np.float32)


# ---------------------------------------------------------------------------
# Tombstone semantics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", ["u8", "fs4"])
@pytest.mark.parametrize("h", [8, 32, 64])
def test_tombstoned_id_never_returned(clustered_data, small_graph, models,
                                      layout, h):
    """The hard guarantee: delete each query's true top-1 (base) plus some
    delta rows — no beam width, no layout ever returns them."""
    x, q, gt = clustered_data
    eng = make_engine(clustered_data, small_graph, models, layout)
    dgids = eng.insert(new_rows(x, 64))
    dead_base = np.unique(np.asarray(gt)[:, 0])
    dead_delta = dgids[::3]
    eng.delete(dead_base)
    eng.delete(dead_delta)
    ids = np.asarray(eng.search(q, k=10, h=h).ids)
    dead = np.concatenate([dead_base, dead_delta])
    assert not np.isin(ids, dead).any()
    # and live results still flow (beam + delta arms both answer)
    assert (ids >= 0).any(axis=1).all()


def test_delete_medoid_keeps_routing(clustered_data, small_graph, models):
    x, q, gt = clustered_data
    eng = make_engine(clustered_data, small_graph, models)
    r_before = recall_at_k(eng.search(q, k=10, h=32).ids, gt, 10)
    medoid = int(small_graph.medoid)
    eng.delete(medoid)
    res = eng.search(q, k=10, h=32)
    ids = np.asarray(res.ids)
    assert not (ids == medoid).any()
    assert int(res.hops.min()) > 0          # the beam actually routed
    # one lost vertex cannot crater recall
    r_after = recall_at_k(ids, gt, 10)
    assert r_after >= r_before - 0.02, (r_before, r_after)
    # entry point was re-anchored onto a live vertex
    assert not eng.tombstones.contains([eng._entry])[0]


def test_delete_every_medoid_neighbor_then_medoid(clustered_data,
                                                  small_graph, models):
    """Entry re-anchoring survives its preferred candidates being dead."""
    x, q, _ = clustered_data
    eng = make_engine(clustered_data, small_graph, models)
    medoid = int(small_graph.medoid)
    nbrs = np.asarray(small_graph.neighbors[medoid])
    nbrs = nbrs[nbrs < x.shape[0]]
    eng.delete(nbrs)
    eng.delete(medoid)
    ids = np.asarray(eng.search(q, k=10, h=32).ids)
    assert not np.isin(ids, np.concatenate([nbrs, [medoid]])).any()
    assert (ids >= 0).any()


def test_word_boundary_ids(clustered_data, small_graph, models):
    """Bitset edges: ids on uint32 word boundaries and the last id under
    the (n+31)//32 + 1 sizing (PR 4's visited-set convention)."""
    x, q, _ = clustered_data
    n = x.shape[0]
    eng = make_engine(clustered_data, small_graph, models)
    boundary = np.array([0, 31, 32, 63, 64, n - 33, n - 32, n - 1])
    eng.delete(boundary)
    assert eng.tombstones.contains(boundary).all()
    inside = np.array([1, 30, 33, 65, n - 31, n - 2])
    assert not eng.tombstones.contains(inside).any()
    ids = np.asarray(eng.search(q, k=10, h=32).ids)
    assert not np.isin(ids, boundary).any()


def test_make_adc_dist_fn_baked_tombstones(clustered_data, small_graph,
                                           models):
    """The frozen-snapshot variant (bitset baked into the dist fn): dead
    ids score +inf and never appear with a finite distance. Entry must be
    live — unlike beam_search(tombstones=), this path has no dead-entry
    rescue (documented in make_adc_dist_fn)."""
    from repro.kernels.ops import pad_sentinel_row
    from repro.pq.base import build_lut
    from repro.search.beam import beam_search, make_adc_dist_fn

    x, q, gt = clustered_data
    model = models["u8"]
    codes_p = pad_sentinel_row(jnp.asarray(encode_codes(model, x, "u8")))
    ts = Tombstones(x.shape[0])
    dead = np.unique(np.asarray(gt)[:, 0])
    dead = dead[dead != int(small_graph.medoid)]   # keep the entry live
    ts.add(dead)
    dist_fn = make_adc_dist_fn(codes_p, tombstones=ts.words)
    res = beam_search(small_graph.neighbors, small_graph.medoid,
                      build_lut(model, q), dist_fn, h=32)
    ids, dists = np.asarray(res.ids), np.asarray(res.dists)
    assert not np.isin(ids[np.isfinite(dists)], dead).any()
    assert np.isfinite(dists[:, 0]).all()          # live results still flow


def test_tombstones_bitset_unit():
    ts = Tombstones(64)                     # capacity exactly 2 words + 1
    assert ts._words.shape[0] == bitset_words(64) == 3
    assert ts.add([0, 31, 32, 63]) == 4
    assert ts.add([31, 63]) == 0            # idempotent
    assert ts.count == 4
    assert ts.contains([31, 32]).all() and not ts.contains([1, 33]).any()
    with pytest.raises(ValueError):
        ts.add([64])
    ts.clear()
    assert ts.count == 0 and not ts.contains([0]).any()


def test_delete_then_reinsert(clustered_data, small_graph, models):
    """A reinserted vector gets a NEW id; the old id stays dead."""
    x, _, _ = clustered_data
    eng = make_engine(clustered_data, small_graph, models)
    victim = 123
    eng.delete(victim)
    (new_gid,) = eng.insert(np.asarray(x)[victim][None])
    assert new_gid == x.shape[0]            # first delta slot
    res = eng.search(np.asarray(x)[victim][None], k=5, h=32)
    ids = np.asarray(res.ids)[0]
    assert ids[0] == new_gid                # exact row wins under ADC too
    assert victim not in ids


def test_delete_validation_and_idempotence(clustered_data, small_graph,
                                           models):
    x, _, _ = clustered_data
    eng = make_engine(clustered_data, small_graph, models)
    assert eng.delete([5, 5, 7]) == 2       # dup in one call counts once
    assert eng.delete([5]) == 0             # already dead: no-op
    with pytest.raises(ValueError, match="out of the occupied range"):
        eng.delete([x.shape[0]])            # delta slot 0 is unoccupied
    gid = eng.insert(new_rows(x, 1))[0]
    assert eng.delete([gid]) == 1           # now occupied → deletable
    with pytest.raises(ValueError):
        eng.delete([-1])


# ---------------------------------------------------------------------------
# Delta segment
# ---------------------------------------------------------------------------

def test_delta_capacity_overflow(clustered_data, small_graph, models):
    x, q, _ = clustered_data
    eng = make_engine(clustered_data, small_graph, models, capacity=8)
    eng.insert(new_rows(x, 5))
    with pytest.raises(DeltaFullError, match="consolidate"):
        eng.insert(new_rows(x, 4))
    assert eng.delta.count == 5             # failed batch left no residue
    eng.insert(new_rows(x, 3))              # exactly full is fine
    assert np.isfinite(
        np.asarray(eng.search(q[:4], k=5, h=16).dists)[:, 0]).all()


def test_k512_int32_codes_roundtrip(clustered_data, small_graph):
    """K > 256 quantizers encode to int32 codes — the delta must store
    them unclipped (dtype follows the base segment, no uint8 wrap)."""
    from repro.pq.base import QuantizerModel, identity_rotation

    x, _, _ = clustered_data
    r = np.random.default_rng(3)
    cb = jnp.asarray(r.normal(size=(4, 512, 8)).astype(np.float32))
    model = QuantizerModel(r=identity_rotation(32), codebooks=cb)
    codes = encode_codes(model, x, "u8")
    assert codes.dtype == np.int32 and int(codes.max()) > 255
    seg = BaseSegment(graph=small_graph, codes=jnp.asarray(codes),
                      vectors=x)
    eng = StreamingEngine(seg, model, delta_capacity=8)
    assert eng.delta.codes.dtype == np.int32
    rows = new_rows(x, 4)
    gids = eng.insert(rows)
    assert (np.asarray(eng.search(rows, k=3, h=32).ids)[:, 0] == gids).all()


def test_inserted_rows_are_found(clustered_data, small_graph, models):
    """Query AT an inserted vector: the new gid must win top-1."""
    x, _, _ = clustered_data
    eng = make_engine(clustered_data, small_graph, models)
    rows = new_rows(x, 16)
    gids = eng.insert(rows)
    ids = np.asarray(eng.search(rows, k=3, h=32).ids)
    assert (ids[:, 0] == gids).all()


# ---------------------------------------------------------------------------
# Consolidation
# ---------------------------------------------------------------------------

def test_consolidate_snapshot_and_restore(clustered_data, small_graph,
                                          models, tmp_path):
    x, q, _ = clustered_data
    eng = make_engine(clustered_data, small_graph, models)
    gids = eng.insert(new_rows(x, 50))
    eng.delete(np.arange(0, 200, 4))
    eng.delete(gids[:10])
    n_live = eng.n_live
    stats = eng.consolidate(ckpt_dir=str(tmp_path))
    assert stats["generation"] == eng.generation == 1
    assert stats["n"] == n_live == eng.base.n
    assert eng.tombstones.count == 0 and eng.delta.count == 0
    o2n = stats["old2new"]
    assert (o2n[np.arange(0, 200, 4)] == -1).all()
    assert (o2n[gids[:10]] == -1).all()
    assert (np.sort(o2n[o2n >= 0]) == np.arange(stats["n"])).all()
    res = eng.search(q, k=10, h=32)
    restored = StreamingEngine.restore(str(tmp_path), models["u8"])
    assert restored.generation == 1 and restored.base.n == stats["n"]
    res2 = restored.search(q, k=10, h=32)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(res2.ids))
    # restoring with a mismatched quantizer is rejected, not served
    wrong = train_pq(jax.random.PRNGKey(8), x, 4, 32, iters=2)
    with pytest.raises(ValueError, match="does not match"):
        StreamingEngine.restore(str(tmp_path), wrong)


def test_consolidate_all_dead_raises(clustered_data, small_graph, models):
    x, _, _ = clustered_data
    eng = make_engine(clustered_data, small_graph, models)
    eng.delete(np.arange(x.shape[0]))
    with pytest.raises(ValueError, match="every row is tombstoned"):
        eng.consolidate()


# ---------------------------------------------------------------------------
# Acceptance: recall under churn vs a from-scratch rebuild
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", ["u8", "fs4"])
def test_churn_recall_vs_rebuild(clustered_data, small_graph, models,
                                 layout):
    """10% inserts + 10% deletes: live serving within 3 recall points of a
    full rebuild on the post-churn corpus; within 1 point after
    consolidate() (ISSUE acceptance bar, both layouts)."""
    x, q, _ = clustered_data
    n = x.shape[0]
    model = models[layout]
    frac = n // 10
    rng = np.random.default_rng(17)
    dead = rng.choice(n, frac, replace=False)
    xnew = new_rows(x, frac, seed=21)

    eng = make_engine(clustered_data, small_graph, models, layout)
    gids = eng.insert(xnew)
    eng.delete(dead)

    # post-churn corpus + ground truth (vector space, then to global ids)
    live_base = np.setdiff1d(np.arange(n), dead)
    corpus = np.concatenate([np.asarray(x)[live_base], xnew])
    gid_of = np.concatenate([live_base, gids])
    gt, _ = knn_ids(jnp.asarray(corpus), q, 10)
    gt_gid = gid_of[np.asarray(gt)]

    r_live = recall_at_k(eng.search(q, k=10, h=32).ids, gt_gid, 10)

    rebuild = BaseSegment.build(jax.random.PRNGKey(7), corpus, model,
                                layout=layout, r=16, l=32)
    r_rebuild = recall_at_k(
        StreamingEngine(rebuild, model).search(q, k=10, h=32).ids,
        np.asarray(gt), 10)
    assert r_live >= r_rebuild - 0.03, (r_live, r_rebuild)

    stats = eng.consolidate()
    gt_new = stats["old2new"][gt_gid]
    assert (gt_new >= 0).all()              # every live neighbor survived
    r_cons = recall_at_k(eng.search(q, k=10, h=32).ids, gt_new, 10)
    assert r_cons >= r_rebuild - 0.01, (r_cons, r_rebuild)
