"""Input pipeline: determinism, resumability, elastic sharding + hypothesis."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional test dep (pip install "
                    "'.[test]'); property tests need it")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.data.pipeline import IndexStream
from repro.data.tokens import lm_batch, zipf_tokens


def test_stream_deterministic_and_resumable():
    a = IndexStream(n=1000, batch=64, seed=3)
    seq1 = [next(a).copy() for _ in range(40)]
    # resume from a checkpointed cursor mid-epoch
    b = IndexStream.from_state(
        IndexStream(n=1000, batch=64, seed=3, step=25).state())
    seq2 = [next(b).copy() for _ in range(15)]
    for x, y in zip(seq1[25:], seq2):
        np.testing.assert_array_equal(x, y)


def test_epoch_reshuffle_covers_all():
    s = IndexStream(n=128, batch=32, seed=0)
    seen = np.concatenate([next(s) for _ in range(s.batches_per_epoch)])
    assert sorted(seen.tolist()) == list(range(128))
    nxt = np.concatenate([next(s) for _ in range(s.batches_per_epoch)])
    assert sorted(nxt.tolist()) == list(range(128))
    assert not np.array_equal(seen, nxt)  # epochs reshuffled


@settings(max_examples=20, deadline=None)
@given(step=st.integers(0, 200), n_hosts=st.sampled_from([1, 2, 4]))
def test_elastic_sharding_partitions_global_batch(step, n_hosts):
    full = IndexStream(n=512, batch=64, seed=1).peek(step)
    shards = [IndexStream(n=512, batch=64, seed=1, host_id=h,
                          n_hosts=n_hosts).shard(full) for h in range(n_hosts)]
    got = np.concatenate(shards)
    np.testing.assert_array_equal(got, full[: len(got)])
    sizes = {len(s) for s in shards}
    assert len(sizes) == 1  # equal per-host shares


def test_zipf_tokens_shape_and_skew():
    t = zipf_tokens(0, 8, 128, 100)
    assert t.shape == (8, 128) and t.min() >= 0 and t.max() < 100
    # Zipf: token 0 much more frequent than token 50
    counts = np.bincount(t.reshape(-1), minlength=100)
    assert counts[0] > 3 * max(counts[50], 1)


def test_lm_batch_next_token_alignment():
    toks, labels = lm_batch(0, 4, 32, 64)
    np.testing.assert_array_equal(toks[:, 1:], labels[:, :-1])
