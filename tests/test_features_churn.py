"""Feature sampling under churn (core/features.py ``tombstones=``).

The codebook-refresh loop (DESIGN.md §12) retrains the quantizer on
features of the LIVE graph while the tombstone bitset marks deleted rows.
These tests pin the contract that makes that sound: no dead vertex ever
appears in any emitted feature (triplet legs or routing candidates), a
dead anchor invalidates its triplet, output shapes are fixed (churn never
retraces the samplers), and sampling is seeded-deterministic.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import features as F
from repro.index.segment import Tombstones, encode_codes
from repro.pq import base as pqbase
from repro.pq import train_pq


@pytest.fixture(scope="module")
def churn_setup(clustered_data, small_graph):
    x, _, _ = clustered_data
    n = x.shape[0]
    ts = Tombstones(n)
    rng = np.random.default_rng(5)
    dead = np.sort(rng.choice(n, n // 5, replace=False))   # 20% churn
    ts.add(dead)
    model = train_pq(jax.random.PRNGKey(3), x, 8, 16, iters=6)
    return x, small_graph, ts, dead, model


def live_anchors(n, dead, count, seed=2):
    live = np.setdiff1d(np.arange(n), dead)
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.choice(live, count, replace=False), jnp.int32)


# ---------------------------------------------------------------------------
# No dead ids in any emitted feature
# ---------------------------------------------------------------------------

def test_triplets_exclude_dead_ids(churn_setup):
    x, g, ts, dead, _ = churn_setup
    anchors = live_anchors(g.n, dead, 128)
    t = F.sample_triplets(jax.random.PRNGKey(1), g, x, anchors,
                          tombstones=ts.words)
    ok = np.asarray(t.valid)
    assert ok.mean() > 0.8          # masking 20% must not starve sampling
    vp, vn = np.asarray(t.vpos)[ok], np.asarray(t.vneg)[ok]
    assert not np.isin(vp, dead).any()
    assert not np.isin(vn, dead).any()
    # and masking changed the draw only via exclusion: legs are live rows
    assert (vp < g.n).all() and (vn < g.n).all()


def test_dead_anchor_invalidates_triplet(churn_setup):
    x, g, ts, dead, _ = churn_setup
    anchors = jnp.asarray(dead[:64], jnp.int32)
    t = F.sample_triplets(jax.random.PRNGKey(1), g, x, anchors,
                          tombstones=ts.words)
    assert not np.asarray(t.valid).any()


def test_routing_excludes_dead_ids(churn_setup):
    x, g, ts, dead, model = churn_setup
    codes = jnp.asarray(encode_codes(model, x, "u8"))
    # entry must be live for this check to exercise real routing
    live = np.setdiff1d(np.arange(g.n), dead)
    entry = jnp.int32(live[0])
    rb = F.sample_routing(g, x, x[:16], codes,
                          lut_fn=lambda q: pqbase.build_lut(model, q),
                          h=8, trace_len=16, tombstones=ts.words,
                          entry=entry)
    cand = np.asarray(rb.cand)
    real = cand[cand < g.n]          # sentinel g.n = masked/padding
    assert not np.isin(real, dead).any()
    # labels always point at live candidates on valid hops
    ok = np.asarray(rb.valid)
    assert ok.sum() > 0
    labeled = cand[ok, np.asarray(rb.label)[ok]]
    assert (labeled < g.n).all()
    assert not np.isin(labeled, dead).any()


def test_routing_label_is_exact_argmin_over_live(churn_setup):
    x, g, ts, dead, model = churn_setup
    codes = jnp.asarray(encode_codes(model, x, "u8"))
    live = np.setdiff1d(np.arange(g.n), dead)
    rb = F.sample_routing(g, x, x[:8], codes,
                          lut_fn=lambda q: pqbase.build_lut(model, q),
                          h=8, trace_len=8, tombstones=ts.words,
                          entry=jnp.int32(live[0]))
    ok = np.asarray(rb.valid)
    cand = np.asarray(rb.cand)[ok]
    qv = np.asarray(rb.q)[ok]
    xp = np.concatenate([np.asarray(x),
                         np.zeros((1, x.shape[1]), np.float32)])
    d = np.sum((xp[cand] - qv[:, None]) ** 2, -1)
    d[cand == g.n] = np.inf
    assert (d.argmin(1) == np.asarray(rb.label)[ok]).all()


# ---------------------------------------------------------------------------
# Fixed shapes / no retrace across churn, seeded determinism
# ---------------------------------------------------------------------------

def test_no_retrace_across_tombstone_patterns(churn_setup):
    """Tombstone words are TRACED: flipping bits between generations must
    reuse the same compiled sampler (shapes depend only on batch sizes)."""
    x, g, ts, dead, _ = churn_setup
    anchors = live_anchors(g.n, dead, 32)

    f = jax.jit(lambda key, a, w: F.sample_triplets(
        key, g, x, a, tombstones=w))
    t1 = f(jax.random.PRNGKey(0), anchors, ts.words)
    ts2 = Tombstones(g.n)
    ts2.add(np.arange(0, g.n, 7))            # a different churn pattern
    t2 = f(jax.random.PRNGKey(0), anchors, ts2.words)
    assert f._cache_size() == 1
    assert t1.v.shape == t2.v.shape and t1.valid.shape == t2.valid.shape


def test_routing_shapes_fixed_under_churn(churn_setup):
    x, g, ts, dead, model = churn_setup
    codes = jnp.asarray(encode_codes(model, x, "u8"))
    lut_fn = lambda q: pqbase.build_lut(model, q)  # noqa: E731
    rb0 = F.sample_routing(g, x, x[:8], codes, lut_fn=lut_fn,
                           h=8, trace_len=8)
    rb1 = F.sample_routing(g, x, x[:8], codes, lut_fn=lut_fn,
                           h=8, trace_len=8, tombstones=ts.words)
    assert rb0.cand.shape == rb1.cand.shape == (64, 8)
    assert rb0.q.shape == rb1.q.shape
    assert rb0.label.shape == rb1.label.shape


def test_sampling_is_seeded_deterministic(churn_setup):
    x, g, ts, dead, model = churn_setup
    anchors = live_anchors(g.n, dead, 64)
    t1 = F.sample_triplets(jax.random.PRNGKey(9), g, x, anchors,
                           tombstones=ts.words)
    t2 = F.sample_triplets(jax.random.PRNGKey(9), g, x, anchors,
                           tombstones=ts.words)
    for a, b in zip(t1, t2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    codes = jnp.asarray(encode_codes(model, x, "u8"))
    lut_fn = lambda q: pqbase.build_lut(model, q)  # noqa: E731
    r1 = F.sample_routing(g, x, x[:8], codes, lut_fn=lut_fn, h=8,
                          trace_len=8, tombstones=ts.words)
    r2 = F.sample_routing(g, x, x[:8], codes, lut_fn=lut_fn, h=8,
                          trace_len=8, tombstones=ts.words)
    for a, b in zip(r1, r2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_no_tombstones_path_unchanged(churn_setup):
    """tombstones=None must be byte-identical to the pre-churn sampler
    (the all-live bitset is a no-op, not a behavior change)."""
    x, g, ts, dead, _ = churn_setup
    anchors = jnp.arange(64, dtype=jnp.int32)
    t0 = F.sample_triplets(jax.random.PRNGKey(4), g, x, anchors)
    empty = Tombstones(g.n)
    t1 = F.sample_triplets(jax.random.PRNGKey(4), g, x, anchors,
                           tombstones=empty.words)
    for a, b in zip(t0, t1):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
