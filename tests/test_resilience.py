"""Resilient serving (DESIGN.md §13): deadline budgets, the degradation
ladder, retry/backoff, quorum merge, snapshot verification, and the seeded
chaos acceptance drill.

The contracts under test:

* budgets compile out: ``max_rounds=None`` / ``max_n_dist=None`` is the
  pre-§13 beam, and a huge budget is BITWISE identical to no budget;
* budgets bind per lane: no query's ``rounds`` ever exceeds ``max_rounds``
  (the vmapped while_loop freezes each lane's carry independently), and
  capped queries report honest ``truncated`` flags;
* a truncated query NEVER returns a tombstoned id — including word-boundary
  ids (31/32/63/64) and the skip_delta degraded path;
* retry/backoff is deterministic (seeded jitter), deadline-aware, and is
  the schedule ``supervise`` restarts follow;
* ``partial_merge`` answers sentinels — never raises — at S ∈ {1, 4}
  all-dead, and ``resolve_quorum`` charges stragglers dead only while the
  quorum holds;
* snapshot manifests carry per-array CRC32s: silent corruption raises
  ``ChecksumError`` on an explicit generation and falls back to the newest
  intact generation otherwise, with a clear error when nothing survives;
* the ISSUE's seeded chaos plan (dead shard + straggler + corrupted newest
  snapshot + crash mid-consolidate) serves every query within budget,
  never throws, stays within 5 recall points of fault-free on the
  reachable corpus, and restores the newest checksum-intact generation.
"""

import dataclasses
import os
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.dist import checkpoint as ckpt
from repro.dist.fault import (ChaosPlan, InjectedFailure, corrupt_snapshot,
                              partial_merge, resolve_quorum, supervise)
from repro.dist.retry import (DeadlineExceeded, RetryPolicy, TransientIOError,
                              backoff_schedule, call_with_retry,
                              expected_retry_time_s)
from repro.index import BaseSegment, StreamingEngine
from repro.index.segment import encode_codes, load_segment, save_segment
from repro.pq import base as pqbase
from repro.pq import train_pq
from repro.search.degrade import (MAX_LEVEL, DegradationPolicy,
                                  recommend_level)
from repro.search.engine import HybridEngine, InMemoryEngine


@pytest.fixture(scope="module")
def setup(clustered_data, small_graph):
    x, q, gt = clustered_data
    model = train_pq(jax.random.PRNGKey(0), x, 8, 32, iters=8)
    return dict(x=x, q=q, gt=np.asarray(gt), graph=small_graph, model=model,
                codes=pqbase.encode(model, x),
                lut_fn=lambda qq: pqbase.build_lut(model, qq))


def streaming_engine(setup, capacity=256):
    seg = BaseSegment(graph=setup["graph"],
                      codes=jnp.asarray(encode_codes(
                          setup["model"], np.asarray(setup["x"]), "u8")),
                      vectors=setup["x"], layout="u8")
    return StreamingEngine(seg, setup["model"], delta_capacity=capacity)


# =========================================================================
# Deadline budgets on the beam
# =========================================================================

def test_budget_none_is_bitwise_identical_to_huge_budget(setup):
    """The budget=None trace is the pre-§13 beam; a budget too large to
    bind must produce the SAME bits (the cond-only gating never perturbs
    the carry)."""
    eng = InMemoryEngine(setup["graph"], setup["codes"], setup["lut_fn"])
    a = eng.search(setup["q"], k=10, h=32)
    b = eng.search(setup["q"], k=10, h=32, max_rounds=10**6,
                   max_n_dist=10**9)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists))
    np.testing.assert_array_equal(np.asarray(a.rounds),
                                  np.asarray(b.rounds))
    assert not np.asarray(b.truncated).any()


def test_max_rounds_binds_per_lane_with_honest_truncation(setup):
    """No lane exceeds the cap; lanes that stopped early on their own are
    NOT flagged; capped-mid-walk lanes are."""
    eng = InMemoryEngine(setup["graph"], setup["codes"], setup["lut_fn"])
    free = eng.search(setup["q"], k=10, h=32)
    capped = eng.search(setup["q"], k=10, h=32, max_rounds=2)
    rounds = np.asarray(capped.rounds)
    assert rounds.max() <= 2
    trunc = np.asarray(capped.truncated)
    # lanes that naturally converged in <= 2 rounds must not be flagged
    natural = np.asarray(free.rounds) <= 2
    assert not trunc[natural].any()
    # the cap must actually bind somewhere on this corpus
    assert trunc[~natural].all()
    # best-so-far answers are still real ids with finite distances
    assert (np.asarray(capped.ids) >= 0).all()
    assert np.isfinite(np.asarray(capped.dists)).all()


def test_max_rounds_sweep_is_monotone_to_convergence(setup):
    """Recall (vs the unbudgeted beam's own answer) grows with the budget
    and reaches exact agreement once the budget covers every lane."""
    eng = InMemoryEngine(setup["graph"], setup["codes"], setup["lut_fn"])
    free = eng.search(setup["q"], k=10, h=32)
    full_budget = int(np.asarray(free.rounds).max())
    agree_prev = -1.0
    for budget in (1, 4, full_budget):
        res = eng.search(setup["q"], k=10, h=32, max_rounds=budget)
        agree = float(np.mean(np.asarray(res.ids) == np.asarray(free.ids)))
        assert agree >= agree_prev - 1e-9
        agree_prev = agree
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(free.ids))
    assert not np.asarray(res.truncated).any()


def test_max_n_dist_caps_distance_work(setup):
    """The n_dist budget stops the walk within one round's overshoot and
    flags the stop; a huge cap is a no-op."""
    eng = InMemoryEngine(setup["graph"], setup["codes"], setup["lut_fn"])
    free = eng.search(setup["q"], k=10, h=32)
    cap = int(np.asarray(free.n_dist).max()) // 4
    res = eng.search(setup["q"], k=10, h=32, max_n_dist=cap)
    ndist = np.asarray(res.n_dist)
    # the check runs before each round, so overshoot <= one frontier (R+1
    # lanes per expanded node; expand=1 here → degree+1 per round)
    per_round = setup["graph"].neighbors.shape[1] + 1
    assert (ndist <= cap + per_round).all()
    binds = np.asarray(free.n_dist) > cap
    assert binds.any() and np.asarray(res.truncated)[binds].all()


def test_hybrid_budget_and_skip_rerank(setup):
    """HybridEngine threads budgets through its beam, and rerank=-1 (the
    L4 degradation rung) answers straight from the ADC beam."""
    hyb = HybridEngine(setup["graph"], setup["codes"], setup["lut_fn"],
                       vectors=setup["x"])
    capped = hyb.search(setup["q"], k=10, h=32, max_rounds=2)
    assert np.asarray(capped.rounds).max() <= 2
    assert np.asarray(capped.truncated).any()
    adc_only = hyb.search(setup["q"], k=10, h=32, rerank=-1)
    mem = InMemoryEngine(setup["graph"], setup["codes"], setup["lut_fn"])
    np.testing.assert_array_equal(
        np.asarray(adc_only.ids),
        np.asarray(mem.search(setup["q"], k=10, h=32).ids))


# =========================================================================
# Tombstones × truncation (the degraded path keeps the hard guarantee)
# =========================================================================

@pytest.mark.parametrize("skip_delta", [False, True])
def test_truncated_search_never_returns_tombstoned_word_boundary_ids(
        setup, skip_delta):
    """Word-boundary ids (31/32/63/64) tombstoned, beam truncated at 1
    round: the scrub happens AFTER the early exit, so no budget and no
    degradation rung may leak a deleted id."""
    eng = streaming_engine(setup)
    boundary = [31, 32, 63, 64]
    gids = eng.insert(np.asarray(setup["x"])[boundary] * 1.0)
    eng.delete(boundary)          # base rows at the bitset word boundaries
    eng.delete(gids[:2])          # plus delta rows
    for budget in (1, 3, None):
        res = eng.search(setup["q"], k=10, h=32, max_rounds=budget,
                         skip_delta=skip_delta)
        ids = np.asarray(res.ids)
        assert not np.isin(ids, boundary).any()
        assert not np.isin(ids, gids[:2]).any()
        if skip_delta:            # the delta arm is dark entirely
            assert not np.isin(ids, gids).any()


def test_skip_delta_preserves_base_answers(setup):
    """skip_delta answers base-only: same base ids as the merged path
    returns once delta candidates are discounted."""
    eng = streaming_engine(setup)
    eng.insert(np.asarray(setup["q"])[:4])     # delta rows AT the queries
    merged = eng.search(setup["q"][:4], k=5, h=32)
    base_only = eng.search(setup["q"][:4], k=5, h=32, skip_delta=True)
    assert (np.asarray(merged.ids)[:, 0] >= eng.base.n).all()
    ids = np.asarray(base_only.ids)
    assert (ids < eng.base.n).all() and (ids >= 0).all()


# =========================================================================
# Degradation ladder
# =========================================================================

def test_degradation_ladder_is_cumulative_and_clamped():
    pol = DegradationPolicy()
    assert pol.overrides(0) == {}
    assert pol.overrides(1) == {"expand": 1}
    l3 = pol.overrides(3)
    assert l3["expand"] == 1 and l3["entries"] == 1
    assert l3["prune_eps"] == pol.prune_eps
    assert pol.overrides(5)["skip_delta"] is True
    assert pol.overrides(99) == pol.overrides(MAX_LEVEL)  # clamped
    capped = DegradationPolicy(max_level=2)
    assert "prune_eps" not in capped.overrides(5)
    with pytest.raises(ValueError):
        DegradationPolicy(max_level=MAX_LEVEL + 1)


def test_degradation_apply_filters_per_engine(setup):
    """One ladder, many engines: rungs an engine cannot express are
    dropped, caller kwargs survive underneath."""
    pol = DegradationPolicy()
    mem = InMemoryEngine(setup["graph"], setup["codes"], setup["lut_fn"])
    kw = pol.apply(mem, 5, h=32, entries=8)
    assert "rerank" not in kw and "skip_delta" not in kw
    assert kw["entries"] == 1 and kw["expand"] == 1 and kw["h"] == 32
    hyb = HybridEngine(setup["graph"], setup["codes"], setup["lut_fn"],
                       vectors=setup["x"])
    assert pol.apply(hyb, 4)["rerank"] == -1
    stream = streaming_engine(setup)
    assert pol.apply(stream, 5)["skip_delta"] is True
    # the ladder must actually shed distance work on a real engine
    full = pol.search(mem, setup["q"], level=0, h=32, entries=8,
                      prune_eps=0.1, expand=4)
    shed = pol.search(mem, setup["q"], level=3, h=32, entries=8,
                      prune_eps=0.1, expand=4)
    assert (np.asarray(shed.n_dist).mean()
            < np.asarray(full.n_dist).mean())


def test_recommend_level_hysteresis():
    pol = DegradationPolicy()
    assert recommend_level(pol, observed_s=0.2, deadline_s=0.1,
                           current=0) == 1
    assert recommend_level(pol, observed_s=0.05, deadline_s=0.1,
                           current=1) == 0
    # inside the hysteresis band: hold
    assert recommend_level(pol, observed_s=0.09, deadline_s=0.1,
                           current=2) == 2
    assert recommend_level(pol, observed_s=9.9, deadline_s=0.1,
                           current=MAX_LEVEL) == MAX_LEVEL


# =========================================================================
# Retry / backoff / supervise
# =========================================================================

def test_backoff_schedule_nominal_and_jittered():
    pol = RetryPolicy(max_attempts=5, base_delay_s=0.01, multiplier=2.0,
                      max_delay_s=0.05, jitter=0.25)
    assert backoff_schedule(pol, seed=None) == [0.01, 0.02, 0.04, 0.05]
    j1 = backoff_schedule(pol, seed=7)
    assert j1 == backoff_schedule(pol, seed=7)      # deterministic
    assert j1 != backoff_schedule(pol, seed=8)
    for nom, jit in zip([0.01, 0.02, 0.04, 0.05], j1):
        assert 0.75 * nom <= jit <= 1.25 * nom


def test_call_with_retry_schedule_with_fake_sleep():
    slept = []
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] <= 2:
            raise TransientIOError("flap")
        return "ok"

    pol = RetryPolicy(max_attempts=4, base_delay_s=0.01, jitter=0.0)
    out, retries = call_with_retry(flaky, policy=pol, sleep=slept.append)
    assert out == "ok" and retries == 2
    assert slept == backoff_schedule(pol)[:2]

    with pytest.raises(ValueError):    # non-retryable propagates at once
        call_with_retry(lambda: (_ for _ in ()).throw(ValueError("bug")),
                        policy=pol, sleep=slept.append)

    with pytest.raises(TransientIOError):   # attempts exhausted re-raises
        call_with_retry(lambda: (_ for _ in ()).throw(
            TransientIOError("down")), policy=pol, sleep=lambda s: None)


def test_call_with_retry_deadline():
    """A sleep that would cross the deadline raises DeadlineExceeded
    instead of parking the caller — and it chains the causal error."""
    clock = {"t": 0.0}

    def fake_sleep(s):
        clock["t"] += s

    pol = RetryPolicy(max_attempts=10, base_delay_s=0.04, multiplier=2.0,
                      jitter=0.0, deadline_s=0.1)
    with pytest.raises(DeadlineExceeded) as ei:
        call_with_retry(
            lambda: (_ for _ in ()).throw(TransientIOError("down")),
            policy=pol, sleep=fake_sleep, clock=lambda: clock["t"])
    assert isinstance(ei.value.__cause__, TransientIOError)
    assert clock["t"] <= pol.deadline_s
    # DeadlineExceeded(TimeoutError) is an OSError: outer handlers that
    # catch I/O errors see it without special-casing
    assert isinstance(ei.value, OSError)


def test_expected_retry_time_closed_form():
    pol = RetryPolicy(max_attempts=3, base_delay_s=0.01, multiplier=2.0,
                      jitter=0.0)
    p, lat = 0.5, 0.002
    want = (lat + p * (0.01 + lat) + p * p * (0.02 + lat))
    assert expected_retry_time_s(pol, lat, p) == pytest.approx(want)
    assert expected_retry_time_s(pol, lat, 0.0) == pytest.approx(lat)


def test_supervise_restarts_follow_backoff_schedule():
    slept, calls = [], {"n": 0}

    def run():
        calls["n"] += 1
        if calls["n"] <= 3:
            raise InjectedFailure(f"crash {calls['n']}")
        return "done"

    pol = RetryPolicy(max_attempts=2, base_delay_s=0.01, multiplier=2.0,
                      max_delay_s=1.0, jitter=0.1)
    out, restarts = supervise(run, max_restarts=3, backoff=pol, seed=5,
                              sleep=slept.append)
    assert out == "done" and restarts == 3
    want = backoff_schedule(dataclasses.replace(pol, max_attempts=4),
                            seed=5)
    assert slept == want
    # exhausting restarts propagates the crash (no swallow)
    calls["n"] = 0
    with pytest.raises(InjectedFailure):
        supervise(run, max_restarts=1, backoff=pol, sleep=lambda s: None)


# =========================================================================
# partial_merge / quorum
# =========================================================================

@pytest.mark.parametrize("n_shards", [1, 4])
def test_partial_merge_all_dead_returns_sentinels(n_shards):
    """All-shards-dead answers (-1, +inf, degraded=True) — never raises
    (the pre-§13 behavior was a RuntimeError)."""
    rng = np.random.default_rng(0)
    ids = [rng.integers(0, 100, (3, 5)) for _ in range(n_shards)]
    ds = [rng.random((3, 5)).astype(np.float32) for _ in range(n_shards)]
    merged = partial_merge(ids, ds, [False] * n_shards, k=5)
    assert merged.degraded
    assert merged.ids.shape == (3, 5) and (merged.ids == -1).all()
    assert np.isinf(merged.dists).all()
    # one alive shard un-degrades nothing silently
    if n_shards == 4:
        alive = [True] + [False] * 3
        m2 = partial_merge(ids, ds, alive, k=5)
        assert m2.degraded and (m2.ids != -1).any()


def test_resolve_quorum_straggler_and_quorum_floor():
    alive = [True, True, True, True]
    lat = [0.002, 0.050, 0.002, 0.002]
    # straggler misses the 10ms deadline, majority quorum (2 of 4) holds
    dec = resolve_quorum(alive, lat, 0.010, None)
    assert dec.alive == [True, False, True, True] and dec.degraded
    assert dec.waited_s == pytest.approx(0.002)
    # quorum outranks the deadline: with Q=4 the straggler must be waited on
    dec = resolve_quorum(alive, lat, 0.010, 4)
    assert dec.alive == alive and not dec.degraded
    assert dec.waited_s == pytest.approx(0.050)
    # dead shards never count, even under quorum pressure
    dec = resolve_quorum([False, True, False, True], lat, 0.001, 3)
    assert dec.alive == [False, True, False, True] and dec.degraded
    # no deadline → liveness passes through
    dec = resolve_quorum(alive, None, None, None)
    assert dec.alive == alive and not dec.degraded
    assert resolve_quorum([False] * 4, lat, 0.01, None).degraded


def test_chaos_plan_parse_grammar():
    plan = ChaosPlan.parse("dead=0+2, straggler=1; straggler_ms=40,"
                           "latency_ms=3,io=0.25,corrupt,"
                           "crash=consolidate,seed=9")
    assert plan.dead_shards == (0, 2) and plan.straggler_shards == (1,)
    assert plan.straggler_latency_s == pytest.approx(0.040)
    assert plan.shard_latency_s == pytest.approx(0.003)
    assert plan.io_fault_p == 0.25 and plan.corrupt_latest_snapshot
    assert plan.crash_phase == "consolidate" and plan.seed == 9
    assert plan.alive(4) == [False, True, False, True]
    assert list(plan.latencies(4)) == pytest.approx(
        [0.003, 0.040, 0.003, 0.003])
    with pytest.raises(ValueError):
        ChaosPlan.parse("crash=sideways")
    with pytest.raises(ValueError):
        ChaosPlan.parse("banana=1")


# =========================================================================
# Snapshot integrity
# =========================================================================

def test_restore_empty_or_missing_dir_raises_clear_error(tmp_path):
    empty = str(tmp_path / "nothing")
    os.makedirs(empty)
    with pytest.raises(FileNotFoundError, match="no checkpoints under"):
        ckpt.restore(empty)
    with pytest.raises(FileNotFoundError, match="no checkpoints under"):
        ckpt.restore(str(tmp_path / "never_created"))
    with pytest.raises(FileNotFoundError, match="no checkpoints under"):
        load_segment(empty)


def test_restore_missing_step_lists_available(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, step=3, state={"w": np.arange(4.0)})
    with pytest.raises(FileNotFoundError, match="available"):
        ckpt.restore(d, step=7)


def test_checksum_verifies_and_detects_silent_corruption(tmp_path):
    d = str(tmp_path)
    state = {"w": np.arange(64, dtype=np.float32).reshape(8, 8),
             "meta": {"note": "x"}}
    ckpt.save(d, step=1, state=state)
    back = ckpt.restore(d)                 # intact: verifies silently
    np.testing.assert_array_equal(np.asarray(back["state"]["w"]),
                                  state["w"])
    corrupt_snapshot(d, seed=0)
    with pytest.raises(ckpt.ChecksumError, match="crc32"):
        ckpt.restore(d, step=1)


def test_load_segment_falls_back_to_newest_intact_generation(setup,
                                                             tmp_path):
    d = str(tmp_path)
    eng = streaming_engine(setup, capacity=64)
    eng.insert(np.asarray(setup["x"])[:8] * 1.01)
    eng.consolidate(ckpt_dir=d)            # generation 1
    eng.insert(np.asarray(setup["x"])[8:16] * 1.01)
    eng.consolidate(ckpt_dir=d)            # generation 2
    newest = corrupt_snapshot(d, seed=1)
    assert newest == 2
    seen = []
    seg, _ = load_segment(d, with_model=True,
                          on_fallback=lambda g, e: seen.append((g, e)))
    assert seg.generation == 1
    assert [g for g, _ in seen] == [2]
    assert isinstance(seen[0][1], ckpt.ChecksumError)
    # explicit generation NEVER falls back — the caller asked for those bits
    with pytest.raises(ckpt.ChecksumError):
        load_segment(d, 2)
    # restore() rides the same path
    eng2 = StreamingEngine.restore(d, delta_capacity=64)
    assert eng2.generation == 1
    # every generation corrupt → one clear error naming the failures
    corrupt_snapshot(d, step=1, seed=2)
    with pytest.raises(RuntimeError, match="no intact snapshot"):
        load_segment(d)


def test_restore_retries_transient_io_faults(setup, tmp_path):
    d = str(tmp_path)
    eng = streaming_engine(setup, capacity=64)
    eng.consolidate(ckpt_dir=d)
    always, hits = ChaosPlan(seed=0, io_fault_p=1.0).io_fault(), {"n": 0}

    def hook(path):
        hits["n"] += 1
        if hits["n"] <= 2:                 # two flaps, then healthy
            raise TransientIOError(f"injected: {path}")
    ckpt.set_io_fault_hook(hook)
    try:
        eng2 = StreamingEngine.restore(
            d, delta_capacity=64,
            retry=RetryPolicy(max_attempts=4, base_delay_s=1e-4))
        assert eng2.generation == 1
        # without a retry policy the same fault surfaces
        hits["n"] = 0
        with pytest.raises((RuntimeError, TransientIOError)):
            StreamingEngine.restore(d, delta_capacity=64)
    finally:
        ckpt.set_io_fault_hook(None)
    assert always is not None


# =========================================================================
# The seeded chaos acceptance drill (ISSUE plan, forced 4-device split)
# =========================================================================

_CHAOS_SUBPROC = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, jax.numpy as jnp
from repro.dist.fault import ChaosPlan, InjectedFailure, corrupt_snapshot, \\
    resolve_quorum
from repro.graphs.partition import build_partitioned_vamana, shard_bounds
from repro.graphs.vamana import build_vamana
from repro.index import BaseSegment, StreamingEngine
from repro.index.segment import encode_codes
from repro.pq import base as pqbase
from repro.pq.pq import train_pq
from repro.search.engine import ShardedGraphEngine
from repro.search.metrics import live_ground_truth, recall_at_k

assert len(jax.devices()) == 4
N, D, Q, TOPK, H, BUDGET = 512, 32, 50, 10, 32, 64
r = np.random.default_rng(7)
centers = r.normal(size=(8, D)) * 2.5
x = (centers[r.integers(0, 8, N)] + r.normal(size=(N, D))).astype(np.float32)
q = (centers[r.integers(0, 8, Q)] + r.normal(size=(Q, D))).astype(np.float32)
x, q = jnp.asarray(x), jnp.asarray(q)
model = train_pq(jax.random.PRNGKey(0), x, 8, 16, iters=8)
codes = pqbase.encode(model, x)
lut_fn = lambda qq: pqbase.build_lut(model, qq)

plan = ChaosPlan(seed=7, dead_shards=(0,), straggler_shards=(1,),
                 straggler_latency_s=0.050, shard_latency_s=0.002,
                 corrupt_latest_snapshot=True, crash_phase="consolidate")
deadline_s = 0.010

# --- sharded serving under the plan: never throws, budget holds ---------
pg = build_partitioned_vamana(jax.random.PRNGKey(1), x, 4, r=12, l=24)
eng = ShardedGraphEngine(pg, codes, lut_fn, vectors=x)
from repro.graphs.knn import knn_ids
gt, _ = knn_ids(x, q, TOPK)
free = eng.search(q, k=TOPK, h=H, max_rounds=BUDGET)
rec_free = recall_at_k(free.ids, np.asarray(gt), TOPK)

fault = eng.search(q, k=TOPK, h=H, max_rounds=BUDGET,
                   alive=plan.alive(4), deadline_s=deadline_s,
                   shard_latency_s=list(plan.latencies(4)))
assert fault.degraded, "dead+straggler answer must be marked degraded"
assert np.asarray(fault.rounds).max() <= BUDGET
assert np.asarray(fault.truncated).shape == (Q,)      # honest flags exist
dec = resolve_quorum(plan.alive(4), list(plan.latencies(4)), deadline_s,
                     None)
assert dec.alive == [False, False, True, True]
reach = np.concatenate([np.arange(lo, hi) for s, (lo, hi)
                        in enumerate(shard_bounds(N, 4)) if dec.alive[s]])
banned = np.setdiff1d(np.arange(N), reach)
assert not np.isin(np.asarray(fault.ids), banned).any(), \\
    "answer leaked rows from a dead or straggler-charged shard"
gt_reach = live_ground_truth(np.asarray(x), reach, q, TOPK)
rec_fault = recall_at_k(fault.ids, gt_reach, TOPK)
assert rec_fault >= rec_free - 0.05, (rec_fault, rec_free)
print(f"SHARDED_OK free={rec_free:.3f} fault={rec_fault:.3f}")

# --- streaming under the plan: crash + corruption, restore stays intact --
g = build_vamana(jax.random.PRNGKey(2), x, r=12, l=24)
seg = BaseSegment(graph=g, codes=jnp.asarray(encode_codes(model, np.asarray(x), "u8")),
                  vectors=x, layout="u8")
se = StreamingEngine(seg, model, delta_capacity=64)
d = {snap_dir!r}
se.insert(np.asarray(x)[:16] * 1.01)
se.consolidate(ckpt_dir=d)                       # generation 1, intact
se.insert(np.asarray(x)[16:32] * 1.01)
try:
    se.consolidate(ckpt_dir=d, chaos=plan.consolidate_hook())
    raise SystemExit("chaos crash did not fire")
except InjectedFailure:
    pass                                          # gen-2 snapshot durable
corrupted = corrupt_snapshot(d, seed=plan.seed)   # newest (gen 2) corrupted
falls = []
se2 = StreamingEngine.restore(d, delta_capacity=64,
                              on_fallback=lambda gen, e: falls.append(gen))
assert corrupted == 2 and se2.generation == 1 and falls == [2], \\
    (corrupted, se2.generation, falls)
res = se2.search(q, k=TOPK, h=H, max_rounds=4)
assert np.isfinite(np.asarray(res.dists)[:, 0]).all()
print("RESTORE_OK gen=%d" % se2.generation)
"""


def test_seeded_chaos_plan_acceptance(tmp_path):
    """The ISSUE acceptance drill: under {1 dead shard + 1 straggler + 1
    corrupted latest snapshot + crash mid-consolidate}, serving never
    throws, every query answers within budget with honest flags, recall
    stays within 5 points of fault-free on the reachable corpus, and
    restore() lands on the newest checksum-intact generation. Subprocess
    so this process keeps its 1-device view (conftest requirement)."""
    code = _CHAOS_SUBPROC.replace(
        "{snap_dir!r}", repr(str(tmp_path / "snaps")))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900,
                       env={**os.environ, "PYTHONPATH": "src",
                            "JAX_PLATFORMS": "cpu"},
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-2500:])
    assert "SHARDED_OK" in r.stdout and "RESTORE_OK gen=1" in r.stdout, \
        r.stdout[-1500:]
