"""Graph-routed sharded serving: graphs/partition.py invariants, the
single-device ShardedGraphEngine ≡ InMemoryEngine equivalence, and the
4-forced-host-device acceptance bar (recall within 5 points of the
single-device beam; a dead shard degrades recall, never errors)."""

import os
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.graphs.partition import (PartitionedGraph,
                                    build_partitioned_vamana, shard_bounds,
                                    shard_subgraph)
from repro.pq import base as pqbase
from repro.pq.pq import train_pq
from repro.search.engine import InMemoryEngine, ShardedGraphEngine

N, D, Q, M, K = 256, 32, 12, 8, 32
TOPK = 10


@pytest.fixture(scope="module")
def setup():
    r = np.random.default_rng(3)
    centers = r.normal(size=(8, D)) * 2.5
    x = (centers[r.integers(0, 8, N)]
         + r.normal(size=(N, D))).astype(np.float32)
    q = (centers[r.integers(0, 8, Q)]
         + r.normal(size=(Q, D))).astype(np.float32)
    x, q = jnp.asarray(x), jnp.asarray(q)
    model = train_pq(jax.random.PRNGKey(0), x, M, K, iters=8)
    codes = pqbase.encode(model, x)
    from repro.graphs.knn import knn_ids
    gt, _ = knn_ids(x, q, TOPK)
    return dict(x=x, q=q, model=model, codes=codes, gt=np.asarray(gt))


def _lut_fn(model):
    return lambda qq: pqbase.build_lut(model, qq)


# ------------------------------------------------------------ partitioning

def test_shard_bounds_cover_disjoint():
    for n, s in ((240, 4), (241, 4), (9, 4), (7, 7), (100, 1)):
        b = shard_bounds(n, s)
        assert len(b) == s
        assert b[0][0] == 0 and max(hi for _, hi in b) == n
        covered = [i for lo, hi in b for i in range(lo, hi)]
        assert covered == list(range(n))        # every row exactly once
        widths = {hi - lo for lo, hi in b if hi > lo}
        assert max(widths) == b[0][1]           # first shard is the widest


def test_partitioned_build_invariants(setup):
    pg = build_partitioned_vamana(jax.random.PRNGKey(1), setup["x"], 4,
                                  r=12, l=24)
    assert pg.n_shards == 4 and pg.n == N and pg.degree == 12
    nb = np.asarray(pg.neighbors)
    med = np.asarray(pg.medoids)
    for s in range(4):
        lo, hi = pg.shard_rows(s)
        ns = hi - lo
        # local ids stay local: valid edges < n_local, sentinel == n_local
        assert ((nb[s] <= pg.n_local).all()
                and (nb[s, :ns] < pg.n_local).any())
        assert nb[s, ns:].min() == pg.n_local if ns < pg.n_local else True
        assert 0 <= med[s] < ns                 # entry is a real local row
        # no self loops among valid edges
        rows = np.arange(pg.n_local)[:, None]
        assert not ((nb[s] == rows) & (nb[s] < pg.n_local)).any()
    g0 = shard_subgraph(pg, 0)
    assert g0.neighbors.shape == (pg.n_local, 12)


def test_partitioned_build_degenerate_last_shard():
    """n chosen so the last shard is empty — must build, not crash, and
    the empty shard must be all-sentinel."""
    r = np.random.default_rng(0)
    x = jnp.asarray(r.normal(size=(9, 8)).astype(np.float32))
    pg = build_partitioned_vamana(jax.random.PRNGKey(0), x, 4, r=4, l=8)
    assert pg.shard_rows(3) == (9, 9)
    assert (np.asarray(pg.neighbors)[3] == pg.n_local).all()


def test_engine_validates_shard_and_row_counts(setup):
    pg = build_partitioned_vamana(jax.random.PRNGKey(1), setup["x"], 2,
                                  r=12, l=24)
    with pytest.raises(ValueError, match="shards"):
        ShardedGraphEngine(pg, setup["codes"], _lut_fn(setup["model"]))
    pg1 = build_partitioned_vamana(jax.random.PRNGKey(1), setup["x"], 1,
                                   r=12, l=24)
    with pytest.raises(ValueError, match="rows"):
        ShardedGraphEngine(pg1, setup["codes"][:-3],
                           _lut_fn(setup["model"]))


# ------------------------------------------- single-device engine semantics

def test_single_shard_engine_matches_inmemory(setup):
    """With one shard the partitioned engine IS an in-memory beam over the
    same subgraph — identical ids, identical hop counts."""
    pg = build_partitioned_vamana(jax.random.PRNGKey(1), setup["x"], 1,
                                  r=16, l=32)
    eng = ShardedGraphEngine(pg, setup["codes"], _lut_fn(setup["model"]))
    res = eng.search(setup["q"], k=TOPK, h=32)
    mem = InMemoryEngine(shard_subgraph(pg, 0), setup["codes"],
                         _lut_fn(setup["model"]))
    rm = mem.search(setup["q"], k=TOPK, h=32)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(rm.ids))
    np.testing.assert_allclose(np.asarray(res.dists), np.asarray(rm.dists),
                               rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(res.hops), np.asarray(rm.hops))
    assert eng.memory_bytes() == (setup["codes"].size
                                  + pg.neighbors.size * 4)


def test_single_shard_local_rerank_hits_exact_topk(setup):
    """h=N beam + full local rerank == exact ground truth (the DiskANN
    guarantee, locally)."""
    pg = build_partitioned_vamana(jax.random.PRNGKey(1), setup["x"], 1,
                                  r=24, l=48)
    eng = ShardedGraphEngine(pg, setup["codes"], _lut_fn(setup["model"]),
                             vectors=setup["x"])
    res = eng.search(setup["q"], k=TOPK, h=N, max_steps=2 * N)
    np.testing.assert_array_equal(np.asarray(res.ids), setup["gt"])


# ----------------------------------------------- 4-device acceptance bar

_SUBPROC = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, jax.numpy as jnp
from repro.graphs.adjacency import Graph
from repro.graphs.partition import build_partitioned_vamana
from repro.graphs.vamana import build_vamana
from repro.pq import base as pqbase
from repro.search.engine import InMemoryEngine, ShardedGraphEngine
from repro.search.metrics import recall_at_k

assert len(jax.devices()) == 4
z = np.load({path!r})
model = pqbase.QuantizerModel(r=jnp.asarray(z["r"]),
                              codebooks=jnp.asarray(z["codebooks"]))
codes = jnp.asarray(z["codes"])
x, q, gt = jnp.asarray(z["x"]), jnp.asarray(z["q"]), z["gt"]
lut_fn = lambda qq: pqbase.build_lut(model, qq)

pg = build_partitioned_vamana(jax.random.PRNGKey(1), x, 4, r=16, l=32)
eng = ShardedGraphEngine(pg, codes, lut_fn)
assert eng.n_shards == 4, eng.n_shards
res = eng.search(q, k={topk}, h=32)
g1 = build_vamana(jax.random.PRNGKey(1), x, r=16, l=32)
mem = InMemoryEngine(g1, codes, lut_fn)
rm = mem.search(q, k={topk}, h=32)
r_sharded = recall_at_k(res.ids, gt, {topk})
r_mem = recall_at_k(rm.ids, gt, {topk})
assert r_sharded >= r_mem - 0.05, (r_sharded, r_mem)
print(f"RECALL_OK sharded={{r_sharded:.3f}} memory={{r_mem:.3f}}")

# local exact rerank can only help
rr = ShardedGraphEngine(pg, codes, lut_fn, vectors=x).search(
    q, k={topk}, h=32)
assert recall_at_k(rr.ids, gt, {topk}) >= r_sharded - 1e-9
print("RERANK_OK")

# dead shard 1: its row range vanishes, recall degrades, no exception
alive = [True, False, True, True]
rd = eng.search(q, k={topk}, alive=alive)
ids = np.asarray(rd.ids)
nl = pg.n_local
assert not np.any((ids >= nl) & (ids < 2 * nl)), ids
assert recall_at_k(rd.ids, gt, {topk}) <= r_sharded + 1e-9
print("DEGRADE_OK")
"""


def test_sharded_graph_4dev_recall_and_dead_shard(setup, tmp_path):
    """The ISSUE acceptance bar, on 4 forced host devices in a subprocess
    (this process must keep its 1-device view — conftest requirement)."""
    path = str(tmp_path / "sharded_graph_case.npz")
    np.savez(path, x=np.asarray(setup["x"]), q=np.asarray(setup["q"]),
             codes=np.asarray(setup["codes"]), gt=setup["gt"],
             r=np.asarray(setup["model"].r),
             codebooks=np.asarray(setup["model"].codebooks))
    code = _SUBPROC.format(path=path, topk=TOPK)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600,
                       env={**os.environ, "PYTHONPATH": "src"},
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert ("RECALL_OK" in r.stdout and "RERANK_OK" in r.stdout
            and "DEGRADE_OK" in r.stdout), \
        (r.stdout[-1500:], r.stderr[-2000:])
