"""End-to-end RPQ core: feature extraction, losses, training loop."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import RPQConfig, TrainConfig, train_rpq
from repro.core import features as F
from repro.core import losses as L
from repro.core import quantizer as Q
from repro.core.trainer import init_rpq, to_model
from repro.pq import base


@pytest.fixture(scope="module")
def rpq_setup(clustered_data, small_graph):
    x, q, gt = clustered_data
    cfg = RPQConfig(dim=x.shape[1], m=4, k=32)
    params = init_rpq(jax.random.PRNGKey(0), cfg, x, kmeans_iters=5)
    return x, small_graph, cfg, params


def test_sample_triplets_shapes_and_validity(rpq_setup):
    x, g, cfg, params = rpq_setup
    anchors = jnp.arange(64, dtype=jnp.int32)
    t = F.sample_triplets(jax.random.PRNGKey(1), g, x, anchors,
                          n_hops=2, k_pos=5, k_neg=15)
    assert t.v.shape == t.vpos.shape == t.vneg.shape == (64,)
    v, vp, vn = np.asarray(t.v), np.asarray(t.vpos), np.asarray(t.vneg)
    ok = np.asarray(t.valid)
    assert ok.mean() > 0.9
    # positive is closer to anchor than negative (by construction via ranking)
    xa, xp_, xn = np.asarray(x)[v], np.asarray(x)[vp], np.asarray(x)[vn]
    dp = np.sum((xa - xp_) ** 2, -1)
    dn = np.sum((xa - xn) ** 2, -1)
    assert (dp[ok] <= dn[ok] + 1e-5).all()
    assert (vp[ok] != v[ok]).all() and (vn[ok] != v[ok]).all()
    assert (vp[ok] != vn[ok]).all()


def test_sample_routing_labels_are_exact_argmin(rpq_setup):
    x, g, cfg, params = rpq_setup
    model = to_model(cfg, params)
    codes = base.encode(model, x)
    rb = F.sample_routing(g, x, x[:16], codes,
                          lut_fn=lambda q: base.build_lut(model, q),
                          h=8, trace_len=16)
    ok = np.asarray(rb.valid)
    assert ok.sum() > 0
    cand = np.asarray(rb.cand)[ok]
    label = np.asarray(rb.label)[ok]
    qv = np.asarray(rb.q)[ok]
    xp = np.concatenate([np.asarray(x), np.zeros((1, x.shape[1]), np.float32)])
    d = np.sum((xp[cand] - qv[:, None]) ** 2, -1)
    d[cand == x.shape[0]] = np.inf
    assert (d.argmin(1) == label).all()


def test_losses_finite_and_positive(rpq_setup):
    x, g, cfg, params = rpq_setup
    anchors = jnp.arange(32, dtype=jnp.int32)
    trip = F.sample_triplets(jax.random.PRNGKey(2), g, x, anchors)
    model = to_model(cfg, params)
    codes = base.encode(model, x)
    rb = F.sample_routing(g, x, x[:8], codes,
                          lut_fn=lambda q: base.build_lut(model, q),
                          h=8, trace_len=8)
    key = jax.random.PRNGKey(3)
    ln = L.neighborhood_loss(cfg, params, x, trip, key)
    lr = L.routing_loss(cfg, params, x, rb, key)
    total, rep = L.joint_loss(cfg, params, x, trip, rb, key)
    for v in (ln, lr, total):
        assert np.isfinite(float(v))
    assert float(lr) >= 0
    assert float(ln) >= 0


def test_joint_loss_gradients_reach_all_params(rpq_setup):
    x, g, cfg, params = rpq_setup
    anchors = jnp.arange(32, dtype=jnp.int32)
    trip = F.sample_triplets(jax.random.PRNGKey(2), g, x, anchors)
    model = to_model(cfg, params)
    codes = base.encode(model, x)
    rb = F.sample_routing(g, x, x[:8], codes,
                          lut_fn=lambda q: base.build_lut(model, q),
                          h=8, trace_len=8)

    def f(p):
        return L.joint_loss(cfg, p, x, trip, rb, jax.random.PRNGKey(4))[0]

    grads = jax.grad(f)(params)
    assert float(jnp.abs(grads.codebooks).max()) > 0
    assert float(jnp.abs(grads.theta).max()) > 0
    assert float(jnp.abs(grads.log_alpha)) > 0


def test_short_training_improves_joint_loss(clustered_data, small_graph):
    x, _, _ = clustered_data
    cfg = RPQConfig(dim=x.shape[1], m=4, k=32)
    tcfg = TrainConfig(steps=60, refresh_every=30, triplet_batch=128,
                       routing_batch=128, routing_pool_queries=32,
                       log_every=10)
    rpq = train_rpq(jax.random.PRNGKey(0), x, small_graph, cfg=cfg, tcfg=tcfg,
                    verbose=False)
    hist = rpq.history
    assert len(hist) >= 3
    first = np.mean([h["total"] for h in hist[:2]])
    last = np.mean([h["total"] for h in hist[-2:]])
    # stability bound: 60 tiny steps with a fresh Kendall α won't always
    # decrease the *joint* objective — recall improvement is asserted in the
    # integration benchmark; here we require it not to diverge
    assert np.isfinite(last) and last < first * 1.5
    # exported model is orthonormal
    r = np.asarray(rpq.model.r)
    np.testing.assert_allclose(r @ r.T, np.eye(r.shape[0]), atol=1e-4)


def test_ablation_flags(clustered_data, small_graph):
    x, _, _ = clustered_data
    cfg = RPQConfig(dim=x.shape[1], m=4, k=32)
    for kwargs in ({"use_routing": False}, {"use_neighborhood": False}):
        tcfg = TrainConfig(steps=5, refresh_every=5, triplet_batch=64,
                           routing_batch=64, routing_pool_queries=16,
                           log_every=5, **kwargs)
        rpq = train_rpq(jax.random.PRNGKey(0), x, small_graph, cfg=cfg,
                        tcfg=tcfg, verbose=False)
        assert np.isfinite(rpq.history[-1]["total"])
