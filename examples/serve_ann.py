"""Batched ANN serving demo: both paper scenarios + QPS measurement.

    PYTHONPATH=src python examples/serve_ann.py [--n 20000] [--h 16 32 64]

Builds an index (Vamana + trained RPQ codes) and serves query batches via
 (a) the in-memory engine (PQ distances only — paper §7 scenario 2) and
 (b) the DiskANN hybrid engine (ADC routing + exact rerank, modeled SSD IO).
Reports a QPS / recall@10 operating curve — the paper's Fig. 5/6 axes.
"""

import argparse
import sys
sys.path.insert(0, "src")

import numpy as np
import jax

from repro.core import RPQConfig, TrainConfig, train_rpq
from repro.data.synth import DatasetSpec, synth
from repro.graphs import build_vamana
from repro.graphs.knn import knn_ids
from repro.pq import base
from repro.search.engine import HybridEngine, InMemoryEngine
from repro.search.metrics import measure_qps, recall_at_k


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--h", type=int, nargs="+", default=[8, 16, 32, 64])
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    ds = synth(DatasetSpec("serve", args.dim, args.n, args.queries, 96,
                           0.35, 0.1, seed=3))
    graph = build_vamana(jax.random.PRNGKey(0), ds.base, r=24, l=48)
    gt, _ = knn_ids(ds.base, ds.queries, 10)

    cfg = RPQConfig(dim=args.dim, m=8, k=64)
    tcfg = TrainConfig(steps=args.steps, refresh_every=args.steps // 3,
                       triplet_batch=512, routing_batch=512,
                       routing_pool_queries=96, log_every=args.steps // 3)
    rpq = train_rpq(jax.random.PRNGKey(1), ds.train, graph, cfg=cfg,
                    tcfg=tcfg)
    codes = rpq.encode(ds.base)
    lut_fn = rpq.lut_fn()

    mem = InMemoryEngine(graph, codes, lut_fn)
    hyb = HybridEngine(graph, codes, lut_fn, vectors=ds.base)
    print(f"index: n={args.n} codes={codes.shape[1]}B/vec "
          f"resident={mem.memory_bytes()/1e6:.1f}MB "
          f"(full vectors would be {ds.base.size*4/1e6:.1f}MB)")
    print(f"{'engine':8s} {'h':>4s} {'recall@10':>10s} {'QPS':>9s} "
          f"{'hops':>6s} {'SSD ms/q':>9s}")
    for h in args.h:
        qps, res = measure_qps(lambda q: mem.search(q, k=10, h=h), ds.queries)
        print(f"{'inmem':8s} {h:4d} {recall_at_k(res.ids, gt, 10):10.3f} "
              f"{qps:9.1f} {float(res.hops.mean()):6.1f} {'—':>9s}")
        qps, res = measure_qps(lambda q: hyb.search(q, k=10, h=h), ds.queries)
        io_ms = float(np.mean(np.asarray(hyb.io_time(res)))) * 1e3
        print(f"{'hybrid':8s} {h:4d} {recall_at_k(res.ids, gt, 10):10.3f} "
              f"{qps:9.1f} {float(res.hops.mean()):6.1f} {io_ms:9.2f}")


if __name__ == "__main__":
    main()
