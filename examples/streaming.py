"""Streaming index walkthrough: insert → query → delete → consolidate.

    PYTHONPATH=src python examples/streaming.py [--dry-run]

1. build a frozen base segment (Vamana graph + PQ codes) over a small
   clustered dataset,
2. insert a batch of new vectors — they are encoded with the same
   quantizer and served from the bounded delta segment immediately,
3. delete some rows (including the graph's own entry point) — tombstones
   mask them out of every answer without touching the graph,
4. consolidate — the delta folds into the next base generation, tombstoned
   rows are compacted away, and the snapshot can be restored,
5. consolidate with a codebook REFRESH (DESIGN.md §12) — the quantizer
   retrains on the live graph, every surviving row re-encodes, and the
   snapshot carries the new codebooks so ``restore()`` needs no
   caller-side model at all.

``--dry-run`` shrinks the dataset so CI can prove the walkthrough runs in
seconds; the pipeline and printed format are identical.
"""

import argparse
import dataclasses
import sys
import tempfile
sys.path.insert(0, "src")

import numpy as np
import jax

from repro.data import load_dataset
from repro.index import BaseSegment, RefreshConfig, StreamingEngine
from repro.pq import train_pq
from repro.search.metrics import live_ground_truth, recall_at_k


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny data so the walkthrough runs in seconds")
    args = ap.parse_args()

    ds = load_dataset("unit-test")          # 2k × 32, clustered anisotropic
    if args.dry_run:
        ds = dataclasses.replace(ds, base=ds.base[:500],
                                 queries=ds.queries[:20],
                                 train=ds.train[:250])
    n = int(ds.base.shape[0])
    n0 = n - n // 10                        # hold out 10% as the stream
    base_x, stream = np.asarray(ds.base[:n0]), np.asarray(ds.base[n0:])
    print(f"corpus: {n0} base rows + {len(stream)} streamed, dim {ds.dim}")

    model = train_pq(jax.random.PRNGKey(1), ds.train, 4, 32)
    seg = BaseSegment.build(jax.random.PRNGKey(0), base_x, model,
                            r=16, l=32)
    engine = StreamingEngine(seg, model, delta_capacity=len(stream))

    def report(tag):
        occupied = np.arange(n0 + engine.delta.count)
        live = occupied[~engine.tombstones.contains(occupied)]
        all_x = np.concatenate([base_x, stream])
        gt_g = live_ground_truth(all_x, live, ds.queries, 10)
        rec = recall_at_k(engine.search(ds.queries, k=10, h=32).ids,
                          gt_g, 10)
        print(f"{tag}: recall@10 = {rec:.3f}  live rows = {engine.n_live}  "
              f"generation = {engine.generation}")

    report("frozen base        ")

    # INSERT: the stream lands in the delta and is served immediately
    gids = engine.insert(stream)
    report("after insert       ")

    # QUERY at an inserted vector: read-your-writes, the new id wins
    hit = engine.search(stream[:1], k=1, h=32)
    assert int(hit.ids[0, 0]) == int(gids[0])

    # DELETE: tombstone some base rows AND the entry point itself
    dead = np.arange(0, n0, 97)
    engine.delete(dead)
    engine.delete(int(seg.graph.medoid))
    assert not np.isin(
        np.asarray(engine.search(ds.queries, k=10, h=32).ids),
        np.append(dead, int(seg.graph.medoid))).any()
    print(f"deleted {len(dead) + 1} rows (incl. the medoid) — "
          f"never returned again")

    # CONSOLIDATE: fold delta + tombstones into generation 1
    stats = engine.consolidate()
    print(f"consolidated: dropped {stats['dropped']}, folded "
          f"{stats['folded']} delta rows → {stats['n']} rows")
    rec = recall_at_k(engine.search(ds.queries, k=10, h=32).ids,
                      live_ground_truth(engine.base.vectors,
                                        np.arange(stats["n"]),
                                        ds.queries, 10), 10)
    print(f"generation {engine.generation}: recall@10 = {rec:.3f}  "
          f"live rows = {engine.n_live}")

    # REFRESH: another churn round, then a consolidation that also
    # retrains the codebooks on the live graph (sized tiny here — a real
    # deployment would run more steps; see launch/serve.py --refresh-every)
    engine.delete(np.arange(0, engine.base.n, 5))
    stats = engine.consolidate(
        refresh=RefreshConfig(steps=4, kmeans_iters=3, triplet_batch=64,
                              routing_batch=64, routing_pool_queries=16,
                              beam_h=8))
    rep = stats["refresh"]
    print(f"refreshed consolidation → generation {engine.generation}: "
          f"live distortion {rep['distortion_before']:.3f} → "
          f"{rep['distortion_after']:.3f} over {stats['n']} re-encoded rows")

    # the snapshot carries the refreshed quantizer: restore() rebuilds the
    # engine from disk alone — no model argument
    with tempfile.TemporaryDirectory() as td:
        from repro.index.segment import save_segment
        save_segment(td, engine.base, model=engine.model)
        restored = StreamingEngine.restore(td)
        a = np.asarray(engine.search(ds.queries, k=5, h=16).ids)
        b = np.asarray(restored.search(ds.queries, k=5, h=16).ids)
        assert np.array_equal(a, b)
    print("self-contained restore: snapshot → engine, no caller-side model")


if __name__ == "__main__":
    main()
