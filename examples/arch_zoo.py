"""Architecture-zoo tour: one reduced-config step of every assigned arch.

    PYTHONPATH=src python examples/arch_zoo.py [--arch granite-3-8b]

Instantiates each --arch's REDUCED config, runs one train step (and a
decode step for the LMs) on CPU, printing loss/shape/params — the same
models the 512-chip dry-run lowers at full scale (launch/dryrun.py).
"""

import argparse
import sys
sys.path.insert(0, "src")

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_arch, list_archs


def run_lm(arch):
    from repro.models import transformer as tf

    cfg = get_arch(arch).make_reduced()
    key = jax.random.PRNGKey(0)
    init, step, opt_init = tf.make_train_step(cfg, lr=1e-3)
    params = init(key)
    opt = opt_init(params)
    toks = jax.random.randint(key, (4, 16), 0, cfg.vocab)
    params, opt, loss = jax.jit(step)(params, opt, toks, toks)
    logits, cache = tf.prefill(cfg, params, toks, max_len=24)
    logits, cache = tf.decode_step(cfg, params, cache, jnp.argmax(logits, -1))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"{arch:24s} loss={float(loss):7.3f} decode_logits={logits.shape} "
          f"params={n/1e6:.2f}M")


def run_gnn(arch):
    from repro.models import gnn

    cfg = get_arch(arch).make_reduced()
    key = jax.random.PRNGKey(0)
    n, e = 128, 512
    x = jax.random.normal(key, (n, cfg.d_in))
    src = jax.random.randint(key, (e,), 0, n)
    dst = jax.random.randint(jax.random.PRNGKey(1), (e,), 0, n)
    y = jax.random.randint(key, (n,), 0, cfg.n_classes)
    init, step, opt_init = gnn.make_train_step(cfg)
    params = init(key)
    opt = opt_init(params)
    params, opt, loss = jax.jit(step)(params, opt, x, src, dst, y,
                                      jnp.ones((n,), bool))
    print(f"{arch:24s} loss={float(loss):7.3f} nodes={n} edges={e}")


def run_recsys(arch):
    from repro.models import recsys as rs

    cfg = get_arch(arch).make_reduced()
    key = jax.random.PRNGKey(0)
    b = 32
    if arch == "dlrm-mlperf":
        params = rs.init_dlrm(key, cfg)
        out = rs.dlrm_forward(cfg, params, jax.random.normal(key, (b, cfg.n_dense)),
                              jax.random.randint(key, (b, cfg.n_sparse), 0, 50))
    elif arch == "deepfm":
        params = rs.init_deepfm(key, cfg)
        out = rs.deepfm_forward(cfg, params,
                                jax.random.randint(key, (b, cfg.n_fields), 0, 40))
    elif arch == "din":
        params = rs.init_din(key, cfg)
        hist = jax.random.randint(key, (b, cfg.seq_len), 0, cfg.n_items)
        out = rs.din_forward(cfg, params, hist, jnp.ones_like(hist, bool),
                             jax.random.randint(key, (b,), 0, cfg.n_items))
    else:  # bert4rec
        params = rs.init_bert4rec(key, cfg)
        items = jax.random.randint(key, (b, cfg.seq_len), 0, cfg.n_items)
        out = rs.bert4rec_encode(cfg, params, items,
                                 jnp.ones_like(items, bool))[:, -1, 0]
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"{arch:24s} out_mean={float(jnp.mean(out)):7.3f} "
          f"params={n/1e6:.2f}M")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list_archs() + [None])
    args = ap.parse_args()
    for arch in ([args.arch] if args.arch else list_archs()):
        spec = get_arch(arch)
        if spec.family == "lm":
            run_lm(arch)
        elif spec.family == "gnn":
            run_gnn(arch)
        elif spec.family == "recsys":
            run_recsys(arch)
        else:
            print(f"{arch:24s} (RPQ itself — see quickstart.py)")


if __name__ == "__main__":
    main()
