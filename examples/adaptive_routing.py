"""Adaptive routing walkthrough: multi-entry seeding + hop pruning.

    PYTHONPATH=src python examples/adaptive_routing.py [--dry-run]

The classic beam starts every query at the one graph medoid and spends its
first hops escaping the medoid's neighborhood; then every hop full-scores
the whole frontier. Adaptive routing (DESIGN.md §11) attacks both costs
with machinery the index already has:

* ``--entries S``: a PQ-hash coarse index over the resident codes turns the
  query's own LUT into S near-query entry points (the LUT argmin per
  subspace IS the sub-code the quantizer would assign the query), so the
  beam starts next to the answer instead of at the medoid;
* ``--prune-eps ε``: each hop scores the frontier on the first m′ < M
  subspaces (a certified lower bound d_m′ ≤ d_M), extrapolates to
  d̂ = d_m′·cal — cal is calibrated per query from the LUT's own subspace
  masses, not the naive M/m′ — and full-scores only lanes with
  d̂·(1+ε) ≤ τ.

Both default OFF and S=1/ε=0 is bit-identical to the classic beam. The
table this prints shows the two knobs separately and combined, against the
sequential baseline — rounds (sequential trips) and n_dist (full-LUT-
equivalent distance evaluations) are the costs being cut.

``--dry-run`` shrinks every knob so CI can prove the walkthrough runs.
"""

import argparse
import sys
sys.path.insert(0, "src")

import numpy as np
import jax
import jax.numpy as jnp

from repro.data.synth import DatasetSpec, synth
from repro.graphs import build_vamana
from repro.graphs.knn import knn_ids
from repro.pq import base, train_pq
from repro.search.engine import InMemoryEngine
from repro.search.metrics import recall_at_k
from repro.search.seed import build_seed_index


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--h", type=int, default=32)
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny corpus, CI-sized")
    args = ap.parse_args()
    if args.dry_run:
        args.n, args.queries = 3000, 64

    ds = synth(DatasetSpec("adaptive", args.dim, args.n, args.queries, 32,
                           0.3, 0.2, seed=5))
    graph = build_vamana(jax.random.PRNGKey(0), ds.base, r=16, l=32)
    gt, _ = knn_ids(ds.base, ds.queries, 10)
    model = train_pq(jax.random.PRNGKey(1), ds.train, 8, 64,
                     iters=8 if args.dry_run else 15)
    codes = base.encode(model, ds.base)
    eng = InMemoryEngine(graph, codes, lambda q: base.build_lut(model, q))

    # a peek at the seeding machinery: the coarse index hashes the corpus
    # on the first m_hash sub-codes; the query gets its bucket key for free
    # from the LUT it already built
    ix = build_seed_index(np.asarray(codes))
    occupied = int((np.asarray(ix.table) >= 0).any(axis=1).sum())
    print(f"seed index: {ix.table.shape[0]} buckets on the first "
          f"{ix.m_hash} sub-code(s) (base K={ix.k}), {occupied} occupied, "
          f"{ix.n_candidates} candidates probed per query "
          f"(bucket cap {ix.table.shape[1]} + {ix.pivots.shape[0]} pivots)")

    def run(tag, **kw):
        res = eng.search(ds.queries, k=10, h=args.h, **kw)
        return dict(tag=tag,
                    recall=recall_at_k(res.ids, np.asarray(gt), 10),
                    rounds=float(jnp.mean(res.rounds.astype(jnp.float32))),
                    n_dist=float(jnp.mean(res.n_dist.astype(jnp.float32))))

    rows = [
        run("classic (S=1, eps=0, E=1)"),
        run("seeded (S=8)", entries=8),
        run("pruned (eps=0.2, m'=2)", prune_eps=0.2, m_prefix=2),
        run("seeded+pruned", entries=8, prune_eps=0.2, m_prefix=2),
        run("full adaptive (+E=4)", entries=8, prune_eps=0.2, m_prefix=2,
            expand=4),
    ]
    base_row = rows[0]
    print(f"\n{'config':28s} {'recall@10':>10s} {'rounds':>8s} "
          f"{'n_dist':>8s} {'rounds cut':>11s} {'n_dist cut':>11s}")
    for r in rows:
        print(f"{r['tag']:28s} {r['recall']:10.3f} {r['rounds']:8.2f} "
              f"{r['n_dist']:8.1f} "
              f"{base_row['rounds'] / max(r['rounds'], 1e-9):10.2f}x "
              f"{1 - r['n_dist'] / base_row['n_dist']:+10.1%}")
    print("\n(rounds = sequential while-loop trips; n_dist = full-LUT-"
          "equivalent\n distance evaluations incl. the seed probe; S=1/"
          "eps=0 is bit-identical\n to the classic beam — "
          "tests/test_adaptive.py holds that bar)")


if __name__ == "__main__":
    main()
