"""End-to-end fault-tolerant training driver demo (deliverable (b)).

    PYTHONPATH=src python examples/train_rpq_e2e.py

Thin wrapper over launch/train.py: trains RPQ for a few hundred steps with
checkpointing, INJECTS A CRASH mid-run, and lets the supervisor restart
from the latest checkpoint — then evaluates serving recall. This is the
"train for a few hundred steps" end-to-end driver of the brief, in the
paper's own domain (index training + serving).
"""

import sys
sys.path.insert(0, "src")

from repro.dist.fault import supervise
from repro.launch import train as T


class Args:
    dataset = "sift-small"
    scale = None
    steps = 300
    m = 8
    k = 64
    batch = 256
    routing_queries = 64
    refresh_every = 75
    graph_r = 24
    graph_l = 48
    beam = 48
    ckpt_dir = "runs/e2e_demo"
    checkpoint_every = 50
    keep = 3
    log_every = 50
    seed = 0
    resume = False
    fail_at_step = 160          # <- injected node failure
    max_restarts = 3
    quiet = False


def main():
    args = Args()
    print(f"[e2e] training RPQ on {args.dataset} for {args.steps} steps; "
          f"a crash will be injected at step {args.fail_at_step}")
    result, restarts = supervise(
        lambda: T.run(args), max_restarts=args.max_restarts,
        on_restart=lambda n, e: print(f"[e2e] supervisor restart #{n}: {e}"))
    print(f"[e2e] finished with {restarts} restart(s); "
          f"final recall@10 = {result['recall']:.3f}")


if __name__ == "__main__":
    main()
