"""All-in-storage serving walkthrough: segment file → DiskEngine → chaos.

    PYTHONPATH=src python examples/disk_serving.py [--dry-run]

1. build a frozen base segment (Vamana graph + PQ codes) and export it to
   the storage tier's on-disk format — one mmap-able file of per-vertex
   records (adjacency + codes in the same 8-byte-aligned slab) plus the
   quantizer sidecar, written atomically,
2. restore the segment VECTOR-FREE (``load_segment(with_vectors=False)``
   reads zero vector bytes) — all the export path needs,
3. open a :class:`~repro.storage.engine.DiskEngine` on the directory: DRAM
   holds only the query LUTs and a bounded hot-vertex cache (BFS-seeded
   from the medoid and pinned); every beam round reads its frontier
   records through the async reader,
4. search twice — serial read-then-compute vs double-buffered prefetch —
   and compare answers, wall time, and the engine's I/O accounting,
5. tombstone rows and cap budgets: deletes mask answers immediately,
   ``max_rounds`` truncates honestly,
6. corrupt the newest generation's header on disk: ``DiskEngine.open``
   falls back to the newest intact generation and keeps serving.

``--dry-run`` shrinks the dataset so CI can prove the walkthrough runs in
seconds; the pipeline and printed format are identical.
"""

import argparse
import dataclasses
import sys
import tempfile
import time
sys.path.insert(0, "src")

import numpy as np
import jax

from repro.data import load_dataset
from repro.index import BaseSegment
from repro.pq import train_pq
from repro.index.segment import load_segment, save_segment
from repro.search.metrics import live_ground_truth, recall_at_k
from repro.storage import (DiskEngine, corrupt_header, segment_path,
                           write_segment)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny data so the walkthrough runs in seconds")
    args = ap.parse_args()

    ds = load_dataset("unit-test")          # 2k × 32, clustered anisotropic
    if args.dry_run:
        ds = dataclasses.replace(ds, base=ds.base[:600],
                                 queries=ds.queries[:32],
                                 train=ds.train[:300])
    model = train_pq(jax.random.PRNGKey(1), ds.train, 4, 32)
    seg = BaseSegment.build(jax.random.PRNGKey(0), ds.base, model,
                            r=16, l=32)
    gt = live_ground_truth(np.asarray(ds.base),
                           np.arange(int(ds.base.shape[0])),
                           ds.queries, 10)

    with tempfile.TemporaryDirectory() as d:
        # 1. export: checkpoint snapshot -> vector-free restore -> segment
        save_segment(f"{d}/ckpt", seg, model=model)
        lean = load_segment(f"{d}/ckpt", with_vectors=False)
        assert lean.vectors is None and lean.dim == seg.dim
        path = write_segment(d, lean, model=model)
        import os
        print(f"segment: {os.path.getsize(path)} bytes on disk for "
              f"{seg.n} records ({seg.n} x "
              f"{os.path.getsize(path) // max(seg.n, 1)}B)")

        # 2-4. serve from storage, serial vs double-buffered prefetch
        with DiskEngine.open(d, cache_mb=0.02) as eng:
            print(f"DRAM-resident serving state: {eng.memory_bytes()} "
                  f"bytes (cache), generation {eng.generation}")
            t0 = time.perf_counter()
            res_s = eng.search(ds.queries, k=10, h=32, overlap=False)
            wall_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            res_p = eng.search(ds.queries, k=10, h=32, overlap=True)
            wall_p = time.perf_counter() - t0
            io = eng.last_io
            rec_s = recall_at_k(res_s.ids, gt, 10)
            rec_p = recall_at_k(res_p.ids, gt, 10)
            print(f"serial   : recall@10 = {rec_s:.3f}  "
                  f"wall = {wall_s * 1e3:.0f} ms")
            print(f"prefetch : recall@10 = {rec_p:.3f}  "
                  f"wall = {wall_p * 1e3:.0f} ms  "
                  f"cache_hit_rate = {io['cache_hit_rate']:.2f}  "
                  f"bytes_read = {io['bytes_read']}")
            assert abs(rec_p - rec_s) <= 0.02, "stale frontier diverged"

            # 5. deletes + budgets
            dead = np.arange(0, seg.n, 37)
            eng.delete(dead)
            assert not np.isin(
                np.asarray(eng.search(ds.queries, k=10, h=32).ids),
                dead).any()
            capped = eng.search(ds.queries, k=10, h=32, max_rounds=4)
            print(f"tombstoned {dead.size} rows — never returned; "
                  f"max_rounds=4 truncated "
                  f"{float(np.asarray(capped.truncated).mean()):.0%} "
                  f"of queries honestly")

        # 6. corruption fallback: gen 1 arrives broken, serving survives
        write_segment(d, dataclasses.replace(lean, generation=1),
                      model=model)
        corrupt_header(segment_path(d, 1), seed=3)
        falls = []
        with DiskEngine.open(
                d, cache_mb=0.02,
                on_fallback=lambda g, e: falls.append(g)) as eng:
            rec = recall_at_k(eng.search(ds.queries, k=10, h=32).ids,
                              gt, 10)
            print(f"gen 1 corrupted on disk -> fell back past {falls} to "
                  f"generation {eng.generation}, recall@10 = {rec:.3f}")
            assert eng.generation == 0 and falls == [1]


if __name__ == "__main__":
    main()
