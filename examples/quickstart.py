"""Quickstart: the whole RPQ pipeline in ~1 minute on CPU.

    PYTHONPATH=src python examples/quickstart.py [--dry-run]

1. synthesize a small clustered dataset,
2. build a Vamana proximity graph,
3. train the paper's routing-guided quantizer (RPQ) end to end,
4. serve queries through the DiskANN-style hybrid engine,
5. compare against classic PQ at the same bit budget.

``--dry-run`` shrinks every knob (a few hundred vectors, a handful of
training steps) so CI can prove the example still runs in seconds; the
pipeline and printed format are identical.
"""

import argparse
import dataclasses
import sys
sys.path.insert(0, "src")

import jax

from repro.core import RPQConfig, TrainConfig, train_rpq
from repro.data import load_dataset
from repro.graphs import build_vamana
from repro.graphs.knn import knn_ids
from repro.pq import base, train_pq
from repro.search.engine import HybridEngine
from repro.search.metrics import recall_at_k


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="minutes → seconds: tiny data + few train steps")
    args = ap.parse_args()

    ds = load_dataset("unit-test")          # 2k × 32, clustered anisotropic
    if args.dry_run:
        ds = dataclasses.replace(ds, base=ds.base[:400],
                                 queries=ds.queries[:20],
                                 train=ds.train[:200])
    steps = 10 if args.dry_run else 150
    print(f"dataset: {ds.base.shape[0]} base vectors, dim {ds.dim}")

    graph = build_vamana(jax.random.PRNGKey(0), ds.base, r=16, l=32)
    gt, _ = knn_ids(ds.base, ds.queries, 10)

    m, k = 4, 32                            # 4 sub-bytes per vector
    pq_model = train_pq(jax.random.PRNGKey(1), ds.train, m, k)
    cfg = RPQConfig(dim=ds.dim, m=m, k=k)
    tcfg = TrainConfig(steps=steps, refresh_every=max(steps // 3, 1),
                       triplet_batch=256, routing_batch=256,
                       routing_pool_queries=48,
                       log_every=max(steps // 3, 1))
    rpq = train_rpq(jax.random.PRNGKey(2), ds.train, graph, cfg=cfg,
                    tcfg=tcfg)

    for name, model in (("PQ ", pq_model), ("RPQ", rpq.model)):
        codes = base.encode(model, ds.base)
        engine = HybridEngine(graph, codes,
                              lambda q, _m=model: base.build_lut(_m, q),
                              vectors=ds.base)
        res = engine.search(ds.queries, k=10, h=32)
        print(f"{name}: recall@10 = {recall_at_k(res.ids, gt, 10):.3f}  "
              f"mean hops = {float(res.hops.mean()):.1f}  "
              f"codes = {codes.shape[0]}×{codes.shape[1]}B")


if __name__ == "__main__":
    main()
