#!/usr/bin/env python
"""Check that intra-repo Markdown links resolve to real files.

    python tools/check_docs.py [root]

Scans every tracked ``*.md`` under the repo root (skipping .git / runs /
reports build products) for inline links and reference-style definitions,
ignores external schemes (http/https/mailto) and pure in-page anchors, and
verifies that each remaining target exists relative to the file that links
it (``#fragment`` suffixes are stripped; fragment validity is not checked).

Also checks the inverse for the walkthroughs: every ``examples/*.py``
must be referenced from the top-level README (by path), so a new example
can't land undocumented — the CI docs job runs each one with
``--dry-run``, and an unreferenced example is one nobody will find.

Exit code 1 lists every broken link — the CI docs job runs this so README
and DESIGN can't silently rot as files move.
"""

from __future__ import annotations

import os
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
REF_RE = re.compile(r"^\s*\[[^\]]+\]:\s+(\S+)", re.M)
SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", "runs", "reports",
             "node_modules", ".eggs"}
EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def md_files(root: str):
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        for f in filenames:
            if f.endswith(".md"):
                yield os.path.join(dirpath, f)


def check(root: str) -> list[str]:
    errors = []
    for path in sorted(md_files(root)):
        text = open(path, encoding="utf-8").read()
        targets = LINK_RE.findall(text) + REF_RE.findall(text)
        for t in targets:
            if t.startswith(EXTERNAL) or t.startswith("#"):
                continue
            t = t.split("#", 1)[0]
            if not t:
                continue
            resolved = os.path.normpath(os.path.join(os.path.dirname(path), t))
            if not os.path.exists(resolved):
                rel = os.path.relpath(path, root)
                errors.append(f"{rel}: broken link -> {t}")
    return errors


def check_examples_referenced(root: str) -> list[str]:
    readme = os.path.join(root, "README.md")
    ex_dir = os.path.join(root, "examples")
    if not (os.path.exists(readme) and os.path.isdir(ex_dir)):
        return []
    text = open(readme, encoding="utf-8").read()
    return [f"README.md: examples/{f} exists but is never referenced"
            for f in sorted(os.listdir(ex_dir))
            if f.endswith(".py") and f"examples/{f}" not in text]


def main() -> int:
    root = sys.argv[1] if len(sys.argv) > 1 else "."
    errors = check(root) + check_examples_referenced(root)
    for e in errors:
        print(e, file=sys.stderr)
    n = len(list(md_files(root)))
    print(f"check_docs: scanned {n} markdown files, "
          f"{len(errors)} broken link(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
